"""Scenario subsystem — declarative specs, on-device market synthesis, and
chunked scenario streams (DESIGN.md §8).

A *scenario* is one realized spot-price path; the engine evaluates the whole
(policy x job) grid against S scenarios in a single pass (the scenario axis
is a batch dimension for the jax backend and a grid dimension for the pallas
kernel). Five families:

* ``fresh``       — i.i.d. redraws of the paper's price law under new seeds
  (sampling noise of the market itself);
* ``regime``      — the price-law mean swept across a range (regime shifts:
  cheap/expensive spot epochs), exercising policies under markets their
  beta grid was not tuned for;
* ``replay``      — recorded per-slot traces (the replay-trace adapter);
* ``adversarial`` — square-wave lure/spike paths built to drive worst-case
  regret for TOLA: long cheap epochs bait the learner toward low-bid,
  spot-heavy policies, then the price spikes to the on-demand ceiling for
  a stretch comparable to a task window, so work sampled into the lure
  lands its window on the spike and pays the full on-demand backstop. The
  spike period is swept across scenarios (no single policy-window length
  is safe), which is what makes the family a regret stress test rather
  than one unlucky trace.
* ``adaptive``    — the adversarial family with the period chosen by
  WATCHING the learner: each chunk's realized regret is fed back through
  ``ScenarioStream.observe`` and the next chunk's spikes concentrate on
  the period that hurt the learner most so far. The round trip is defined
  at the chunk boundary, so the compiled interior stays pure.

Two representations coexist:

* ``list[SpotMarket]`` — the legacy materialized path (``make_scenarios``,
  ``replay_scenarios``): one host Python object per scenario, exact f64.
* ``ScenarioSpec`` — a declarative, hashable description of a family. Its
  randomness is a stateless counter hash of (seed, scenario index, slot),
  NOT numpy's Generator, so any chunk of scenarios can be synthesized
  independently, in any order, on host (f64 — the bit-exact oracle,
  identical to wrapping ``spec.prices()`` rows in ``SpotMarket.from_prices``)
  or on device (one jitted program from PRNG levels to the stacked per-bid
  A/C cumulative tensors; f32 value noise, but per-slot AVAILABILITY is
  decided by an exact integer threshold comparison so no knife-edge slot
  ever flips between the host and device paths).

Both are consumed through ``ScenarioSource.chunks`` — ``(s0, s1, batch)``
triples whose ``ScenarioBatch`` caches the stacked (S_chunk, n_slots+1)
A/C tensors per bid (keyed on ``round(bid, 12)`` like the GridPlan dedup),
so no backend ever restacks a bid's views.

All scenarios of a batch share the slot grid and horizon so their cumulative
arrays stack into one (S, n_slots+1) tensor.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Sequence

import numpy as np

from repro.obs import METRICS, record_jit, span

from repro.core.market import (
    P_ONDEMAND,
    PRICE_HI,
    PRICE_LO,
    PRICE_MEAN,
    SLOTS_PER_UNIT,
    SpotMarket,
    stacked_view_arrays,
)

__all__ = ["ScenarioSpec", "ScenarioStream", "ScenarioBatch",
           "MarketListBatch", "SynthBatch", "as_source",
           "make_scenarios", "adversarial_scenarios", "replay_scenarios",
           "check_scenarios", "stack_views", "SCENARIO_KINDS"]

SCENARIO_KINDS = ("fresh", "regime", "replay", "adversarial", "adaptive")

_M32 = 0xFFFFFFFF
_GOLD = np.uint32(0x9E3779B9)   # odd golden-ratio constants decorrelate the
_COL = np.uint32(0x85EBCA6B)    # row/column/stream counters before mixing
_MIX1 = np.uint32(0x7FEB352D)
_MIX2 = np.uint32(0x846CA68B)


# --------------------------------------------------------------------------
# Counter-based randomness: 24-bit levels from a stateless uint32 hash.
# --------------------------------------------------------------------------

def _mix(x):
    """lowbias32 finalizer, elementwise on numpy OR jax uint32 arrays.

    Pure uint32 arithmetic (wraparound multiplies), so the host f64 oracle
    and the jitted device generator draw bit-identical levels — the entire
    randomness of the spec-based scenario families flows through here.
    """
    x = x ^ (x >> 16)
    x = x * _MIX1
    x = x ^ (x >> 15)
    x = x * _MIX2
    x = x ^ (x >> 16)
    return x


def _mix_int(x: int) -> int:
    """Python-int twin of ``_mix`` (numpy SCALAR uint32 overflow warns)."""
    x &= _M32
    x ^= x >> 16
    x = (x * 0x7FEB352D) & _M32
    x ^= x >> 15
    x = (x * 0x846CA68B) & _M32
    x ^= x >> 16
    return x


def _levels(seed: int, stream: int, idx, n_cols: int, xp=np):
    """(len(idx), n_cols) uint32 levels in [0, 2^24).

    ``idx`` holds GLOBAL scenario indices, so any chunk reproduces exactly
    the rows a monolithic synthesis would produce — chunked-vs-monolithic
    bit-identity is by construction, not by bookkeeping. 24 bits because
    ``level * 2^-24`` is exactly representable in BOTH f32 and f64: the two
    paths start from identical uniforms.
    """
    base = np.uint32(_mix_int((seed & _M32) ^ ((stream * 0x9E3779B9) & _M32)))
    row = _mix(xp.asarray(idx).astype(xp.uint32) * _GOLD ^ base)
    col = xp.arange(n_cols, dtype=xp.uint32) * _COL
    return _mix(row[:, None] ^ col[None, :]) >> np.uint32(8)


def _exp_prices(u, mean, lo, hi, xp=np):
    """Inverse-CDF shifted-exponential price law, clipped at the ceiling."""
    return xp.minimum(lo + mean * (-xp.log1p(-u)), hi)


@functools.lru_cache(maxsize=4096)  # bounded: distinct bid levels
def _avail_threshold(mean: float, lo: float, hi: float, bid: float) -> int:
    """Largest 24-bit level whose f64 price clears ``bid``.

    Replicates ``price <= bid + 1e-12`` (the SpotMarket availability rule)
    EXACTLY: the analytic inverse-CDF estimate is corrected by walking the
    actual f64 price formula across the boundary, so the device path's
    integer comparison ``level <= threshold`` selects precisely the slots
    the host f64 comparison would — no f32 knife edge can flip a slot.
    """
    b = float(bid) + 1e-12

    def price(h: int) -> float:
        return min(lo + mean * (-np.log1p(-(h * 2.0 ** -24))), hi)

    top = (1 << 24) - 1
    if price(0) > b:
        return -1
    if price(top) <= b:
        return top
    t = int((1.0 - np.exp(-(b - lo) / mean)) * 2.0 ** 24)
    t = max(0, min(t, top - 1))
    while t + 1 <= top and price(t + 1) <= b:
        t += 1
    while t >= 0 and price(t) > b:
        t -= 1
    return t


# --------------------------------------------------------------------------
# ScenarioSpec — the declarative family description.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Declarative, hashable description of a scenario family.

    A spec fully determines every price path of the family (see the module
    docstring for the counter-hash randomness), so it can serve as a cache
    key, travel between processes, and synthesize any chunk of its
    scenarios on demand — host f64 (``prices`` / ``materialize``, the
    bit-exact oracle) or on device (``SynthBatch``). ``traces`` is only
    used by ``kind="replay"`` (one tuple per scenario, right-padded to the
    longest — see :func:`replay_scenarios` for the padding contract).
    """

    kind: str
    horizon_units: float
    n_scenarios: int
    seed: int = 0
    slots_per_unit: int = SLOTS_PER_UNIT
    p_ondemand: float = P_ONDEMAND
    price_mean: float = PRICE_MEAN
    price_lo: float = PRICE_LO
    price_hi: float = PRICE_HI
    mean_range: tuple = (0.125, 0.22)
    spike_range: tuple = (0.5, 4.0)
    spike_frac: float = 0.5
    n_periods: int = 8              # adaptive: size of the spike-period menu
    n_phases: int = 6               # adaptive: candidate phase offsets
    traces: tuple = ()

    def __post_init__(self):
        if self.kind not in SCENARIO_KINDS:
            raise ValueError(f"unknown scenario kind {self.kind!r}; pick "
                             f"from {SCENARIO_KINDS}")
        if self.n_scenarios < 1:
            raise ValueError("need at least one scenario "
                             f"(n_scenarios={self.n_scenarios})")
        if self.kind == "replay":
            if not self.traces:
                raise ValueError("kind='replay' needs at least one trace")
            object.__setattr__(self, "traces", tuple(
                tuple(float(x) for x in t) for t in self.traces))
            if len(self.traces) != self.n_scenarios:
                raise ValueError(
                    f"replay spec carries {len(self.traces)} traces for "
                    f"{self.n_scenarios} scenarios")
        elif self.traces:
            raise ValueError(f"traces are only valid with kind='replay' "
                             f"(got kind={self.kind!r})")
        object.__setattr__(self, "mean_range", tuple(self.mean_range))
        object.__setattr__(self, "spike_range", tuple(self.spike_range))

    @classmethod
    def from_traces(cls, traces, slots_per_unit: int = SLOTS_PER_UNIT,
                    p_ondemand: float = P_ONDEMAND) -> "ScenarioSpec":
        traces = tuple(tuple(float(x) for x in t) for t in traces)
        if not traces:
            raise ValueError("need at least one trace")
        n = max(len(t) for t in traces)
        return cls(kind="replay", horizon_units=n / slots_per_unit,
                   n_scenarios=len(traces), slots_per_unit=slots_per_unit,
                   p_ondemand=p_ondemand, traces=traces)

    # -- slot-grid geometry (shared with SpotMarket) -----------------------
    @property
    def slot(self) -> float:
        return 1.0 / self.slots_per_unit

    @property
    def n_slots(self) -> int:
        if self.kind == "replay":
            return max(len(t) for t in self.traces)
        return int(np.ceil(self.horizon_units * self.slots_per_unit)) + 1

    @property
    def generative(self) -> bool:
        """Whether price paths come from the counter hash (device-synthesizable)."""
        return self.kind != "replay"

    # -- family parameters over GLOBAL scenario indices --------------------
    def regime_means(self) -> np.ndarray:
        """(S,) price-law mean per scenario of the regime sweep."""
        return np.linspace(*self.mean_range, self.n_scenarios)

    def period_menu(self) -> np.ndarray:
        """Adaptive spike-period menu (time units, geometric over the range)."""
        return np.geomspace(*self.spike_range, self.n_periods)

    def default_periods(self, idx: np.ndarray) -> np.ndarray:
        """Feedback-free spike periods (time units) for global indices.

        ``adversarial`` sweeps the range geometrically across the WHOLE
        batch (mirroring :func:`adversarial_scenarios`); ``adaptive`` with
        no feedback yet cycles its period menu round-robin.
        """
        if self.kind == "adaptive":
            return self.period_menu()[np.asarray(idx) % self.n_periods]
        if self.n_scenarios == 1:
            sweep = np.array([np.sqrt(self.spike_range[0]
                                      * self.spike_range[1])])
        else:
            sweep = np.geomspace(*self.spike_range, self.n_scenarios)
        return sweep[np.asarray(idx)]

    def wave_slots(self, periods: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(period_slots, spike_slots) int arrays from periods in time units."""
        pslots = np.maximum(np.round(np.asarray(periods, np.float64)
                                     * self.slots_per_unit), 2).astype(np.int64)
        sslots = np.maximum(np.round(self.spike_frac * pslots), 1) \
            .astype(np.int64)
        return pslots, sslots

    # -- host synthesis (f64 oracle) ---------------------------------------
    def prices(self, start: int = 0, stop: int | None = None,
               periods: np.ndarray | None = None,
               offsets: np.ndarray | None = None) -> np.ndarray:
        """(stop-start, n_slots) f64 per-slot prices for global scenarios
        ``start..stop-1`` — the bit-exact oracle every other path is tested
        against. ``periods`` overrides the spike periods (time units) of the
        adversarial/adaptive wave for these rows, and ``offsets`` the phase
        offsets in slots (entries < 0 keep the hash-random phase) — the
        ScenarioStream's feedback hooks; other kinds ignore both.
        """
        stop = self.n_scenarios if stop is None else stop
        if not 0 <= start < stop <= self.n_scenarios:
            raise ValueError(f"bad scenario slice [{start}, {stop}) of "
                             f"{self.n_scenarios}")
        idx = np.arange(start, stop)
        n = self.n_slots
        if self.kind == "replay":
            # Padded once per spec (cached): chunked streaming must not
            # re-pad the whole trace set per chunk (O(S^2)) or re-fire the
            # padding warning.
            return _padded_spec_traces(self)[start:stop]
        h = _levels(self.seed, 0, idx, n)
        u = h * 2.0 ** -24
        if self.kind == "fresh":
            return _exp_prices(u, self.price_mean, self.price_lo,
                               self.price_hi)
        if self.kind == "regime":
            means = self.regime_means()[idx][:, None]
            return _exp_prices(u, means, self.price_lo, self.price_hi)
        # adversarial / adaptive: lure from a halved-mean law + spike wave.
        lure = _exp_prices(u, 0.5 * self.price_mean, self.price_lo,
                           self.price_hi)
        if periods is None:
            periods = self.default_periods(idx)
        pslots, sslots = self.wave_slots(periods)
        rand = (_levels(self.seed, 1, idx, 1)[:, 0].astype(np.int64)
                % pslots)
        if offsets is None:
            offs = rand
        else:
            offsets = np.asarray(offsets, np.int64)
            offs = np.where(offsets >= 0, offsets % pslots, rand)
        phase = (np.arange(n)[None, :] + offs[:, None]) % pslots[:, None]
        return np.where(phase < sslots[:, None], self.price_hi, lure)

    def materialize(self, start: int = 0,
                    stop: int | None = None) -> list[SpotMarket]:
        """The spec's scenarios as concrete ``SpotMarket`` objects (today's
        ``from_prices`` path) — the host oracle the streamed/device paths
        are parity-tested against, and the adapter for host-only consumers
        (the greedy baseline, the realized shared-pool replay)."""
        return [SpotMarket.from_prices(row, slots_per_unit=self.slots_per_unit,
                                       p_ondemand=self.p_ondemand)
                for row in self.prices(start, stop)]

    def lure_mean(self) -> float:
        return 0.5 * self.price_mean

    def thresholds(self, bid: float, idx: np.ndarray) -> np.ndarray:
        """(len(idx),) int32 availability thresholds for one bid.

        The exact-integer edition of ``price <= bid + 1e-12`` per scenario
        (regime sweeps get a per-row mean; the spike phases of the
        adversarial families are excluded separately by the wave mask).
        """
        if self.kind == "regime":
            means = self.regime_means()[np.asarray(idx)]
            return np.array([_avail_threshold(float(m), self.price_lo,
                                              self.price_hi, float(bid))
                             for m in means], np.int32)
        mean = self.lure_mean() if self.kind in ("adversarial", "adaptive") \
            else self.price_mean
        t = _avail_threshold(float(mean), self.price_lo, self.price_hi,
                             float(bid))
        return np.full(len(idx), t, np.int32)


# --------------------------------------------------------------------------
# Device synthesis: spec -> (levels, prices, spike mask) -> per-bid views,
# all jitted and cached per spec (ScenarioSpec is hashable by design).
# --------------------------------------------------------------------------

# Bounded: a long-lived process sweeping many specs must not accumulate
# one compiled XLA program per spec forever (LRU eviction caps retention;
# bench_pipeline's synthesis sweep additionally cache_clear()s per size).
@functools.lru_cache(maxsize=32)
def _device_synth_fn(spec: ScenarioSpec, mesh=None):
    """Jitted generator: global indices (+ wave params) -> chunk tensors.

    Returns ``(levels int32 (K, n), prices f32 (K, n), spike bool (K, n))``
    on device. Levels are bit-identical to the host hash; prices are the
    f32 evaluation of the same transform (value noise ~1e-7, harmless —
    availability never reads them, see ``_device_views_fn``).

    With ``mesh`` (a ``GridMesh``) the generator is ``shard_map``ed over
    the scenario axis — ``"data"`` only; on a 2-D mesh the ``"model"``
    axis sees replicated synthesis, since groups don't exist yet at this
    stage. Each shard hashes only its own GLOBAL indices, so per-shard
    synthesis is bit-identical to monolithic by construction and the
    program contains zero cross-device collectives (asserted in
    tests/test_shard.py). Row counts must be padded to ``data_shards`` —
    ``SynthBatch`` owns that contract.
    """
    import jax
    import jax.numpy as jnp

    n = spec.n_slots
    kind = spec.kind
    lo, hi = spec.price_lo, spec.price_hi
    S = spec.n_scenarios

    def gen(idx, pslots, sslots, offsets):
        h = _levels(spec.seed, 0, idx, n, xp=jnp)           # (K, n) uint32
        u = h.astype(jnp.float32) * jnp.float32(2.0 ** -24)
        if kind == "fresh":
            price = _exp_prices(u, spec.price_mean, lo, hi, xp=jnp)
            spike = jnp.zeros(price.shape, bool)
        elif kind == "regime":
            a, b = spec.mean_range
            frac = idx.astype(jnp.float32) / jnp.float32(max(S - 1, 1))
            means = (jnp.float32(a) + jnp.float32(b - a) * frac)[:, None]
            price = _exp_prices(u, means, lo, hi, xp=jnp)
            spike = jnp.zeros(price.shape, bool)
        else:                                               # adversarial*
            lure = _exp_prices(u, spec.lure_mean(), lo, hi, xp=jnp)
            ph = _levels(spec.seed, 1, idx, 1, xp=jnp)[:, 0]
            rand = (ph % pslots.astype(jnp.uint32)).astype(jnp.int32)
            offs = jnp.where(offsets >= 0, offsets % pslots, rand)
            phase = (jnp.arange(n, dtype=jnp.int32)[None, :]
                     + offs[:, None]) % pslots[:, None]
            spike = phase < sslots[:, None]
            price = jnp.where(spike, jnp.float32(hi), lure)
        return h.astype(jnp.int32), price, spike

    if mesh is None:
        return jax.jit(gen)
    from jax.experimental.shard_map import shard_map

    dp = mesh.spec("scenario")
    return jax.jit(shard_map(gen, mesh=mesh.mesh,
                             in_specs=(dp, dp, dp, dp), out_specs=dp))


@functools.lru_cache(maxsize=32)   # bounded: one entry per (slot, mesh)
def _device_views_fn(slot: float, mesh=None):
    """Jitted (levels, prices, spike, thresholds) -> stacked (A, C) views.

    Availability is the EXACT integer comparison ``level <= threshold`` —
    the same slot set the f64 oracle selects (``_avail_threshold``). A_cum
    is exact-count * slot (one f32 rounding, no cumsum drift on the array
    the cost kernels' searchsorted queries are knife-edge-sensitive to);
    C_cum is an f32 cumsum of the payment steps (value-only, tolerance
    covered by the engine's 1e-5 parity contract).

    With ``mesh`` the view build is ``shard_map``ed per scenario shard
    (cumsums run along the SLOT axis, within a row — no cross-scenario,
    hence no cross-device, dependence).
    """
    import jax
    import jax.numpy as jnp

    def views(h, price, spike, thresh, spike_clears):
        avail = (h <= thresh[:, None]) & (~spike | spike_clears)
        counts = jnp.cumsum(avail.astype(jnp.int32), axis=-1)
        pad = jnp.zeros(h.shape[:-1] + (1,), jnp.float32)
        A = jnp.concatenate(
            [pad, counts.astype(jnp.float32) * jnp.float32(slot)], axis=-1)
        # C from the shared traceable twin (one definition of the payment
        # arithmetic); its f32-cumsum A is dead code XLA drops — the exact
        # integer-count A above is what the searchsorted queries consume.
        _, C = stacked_view_arrays(price, avail, slot, xp=jnp)
        return A, C

    if mesh is None:
        return jax.jit(views)
    from jax.experimental.shard_map import shard_map

    dp = mesh.spec("scenario")
    rp = mesh.spec()   # empty P(): replicated, valid for rank-0 scalars
    return jax.jit(shard_map(views, mesh=mesh.mesh,
                             in_specs=(dp, dp, dp, dp, rp),
                             out_specs=dp))


# --------------------------------------------------------------------------
# Batches — what the backends consume (stacked views, cached per bid).
# --------------------------------------------------------------------------

def _bid_key(bid: float) -> float:
    # Same rounding rule as the GridPlan dedup (plan.py::_bid_key): views
    # cached, listed and looked up on one rounded value.
    return round(float(bid), 12)


def _stack_bid_views(markets: Sequence[SpotMarket], bid: float):
    """The one definition of host per-bid view stacking (one ``view`` call
    per market; both the list and the spec-host batches delegate here)."""
    views = [m.view(bid) for m in markets]
    return (np.stack([v.A_cum for v in views]),
            np.stack([v.C_cum for v in views]))


class ScenarioBatch:
    """One chunk of scenarios presented as stacked per-bid view tensors.

    ``stacked(bid)`` returns the (S_chunk, n_slots+1) A/C cumulative
    arrays, computed once per bid and cached (the no-recompute contract —
    repeated calls hand back the same arrays). ``markets`` lazily adapts
    the chunk to host-only consumers (the numpy oracle backend).

    With a ``GridMesh`` the stacked tensors are padded to ``n_rows``
    (a multiple of ``data_shards``; the last scenario repeated) and placed
    sharded over the mesh's ``"data"`` axis (replicated over ``"model"``)
    — consumers slice results back to ``n_scenarios`` valid rows (the
    DESIGN.md §9 padding contract).
    """

    slot: float
    slots_per_unit: int
    p_ondemand: float
    n_slots: int
    n_scenarios: int
    device: bool = False

    def __init__(self, mesh=None):
        self._stacked: dict[float, tuple] = {}
        self.mesh = mesh

    @property
    def n_rows(self) -> int:
        """Row count of the stacked tensors (padded under a mesh)."""
        if self.mesh is None:
            return self.n_scenarios
        return self.mesh.pad(self.n_scenarios)

    def dispatch(self) -> "ScenarioBatch":
        """Launch (but do not await) the chunk's synthesis — the
        double-buffering hook: a no-op wherever synthesis is host work."""
        return self

    def prepare(self) -> "ScenarioBatch":
        """Synthesize/realize the chunk's price paths (timed by the API)."""
        return self

    def stacked(self, bid: float):
        key = _bid_key(bid)
        if key not in self._stacked:
            from repro.engine import cache as _cache

            # Cross-call reuse (DESIGN.md §11): batches whose views are a
            # pure function of (spec, chunk range, bid) publish a cache
            # key and survive the batch; feedback-driven chunks and meshed
            # batches return None and keep the per-batch memo only.
            ck = self._view_key(bid) if _cache.enabled() else None
            views = _cache.VIEW_CACHE.get(ck) if ck is not None else None
            if views is None:
                A, C = self._build_views(bid)
                if self.mesh is not None and isinstance(A, np.ndarray):
                    # Host-built views under a mesh: pad + place sharded
                    # once, here, so every backend consumes one layout.
                    A, C = self.mesh.put_rows(A), self.mesh.put_rows(C)
                views = (A, C)
                if ck is not None:
                    _cache.VIEW_CACHE.put(ck, views)
            self._stacked[key] = views
        return self._stacked[key]

    def _view_key(self, bid: float):
        """Cross-call identity of this chunk's per-bid views, or None when
        they have none (materialized market lists would need a content
        hash per call; feedback-driven synthesis depends on state outside
        any key; meshed tensors are placed for one device topology)."""
        return None

    def _build_views(self, bid: float):
        raise NotImplementedError

    @property
    def markets(self) -> list[SpotMarket]:
        raise NotImplementedError


class MarketListBatch(ScenarioBatch):
    """Materialized scenarios: a list of ``SpotMarket`` objects."""

    def __init__(self, markets: Sequence[SpotMarket], *, checked=False,
                 mesh=None):
        super().__init__(mesh=mesh)
        self._markets = list(markets)
        if not checked:
            check_scenarios(self._markets)
        m0 = self._markets[0]
        self.slot = m0.slot
        self.slots_per_unit = m0.slots_per_unit
        self.p_ondemand = m0.p_ondemand
        self.n_slots = m0.n_slots
        self.n_scenarios = len(self._markets)

    @property
    def markets(self) -> list[SpotMarket]:
        return self._markets

    def _build_views(self, bid: float):
        return _stack_bid_views(self._markets, bid)


class SynthBatch(ScenarioBatch):
    """A chunk of a ``ScenarioSpec``, synthesized on demand.

    ``device=False`` keeps everything host f64 (prices from the oracle
    hash; ``markets`` wraps them in ``SpotMarket.from_prices`` — bit-exact
    with the materialized path by construction). ``device=True`` runs the
    jitted generator once per chunk and builds per-bid views on device —
    no per-scenario Python objects, no host staging.
    """

    def __init__(self, spec: ScenarioSpec, start: int, stop: int,
                 periods: np.ndarray | None = None,
                 offsets: np.ndarray | None = None, device: bool = False,
                 mesh=None):
        super().__init__(mesh=mesh)
        if device and not spec.generative:
            raise ValueError("replay traces are host data; device synthesis "
                             "supports the generative families only")
        self.spec = spec
        self.start, self.stop = start, stop
        self.device = device
        self.slot = spec.slot
        self.slots_per_unit = spec.slots_per_unit
        self.p_ondemand = spec.p_ondemand
        self.n_slots = spec.n_slots
        self.n_scenarios = stop - start
        self._idx = np.arange(start, stop)
        self._periods = periods
        self._offsets = offsets
        self._parts = None
        self._markets: list[SpotMarket] | None = None

    def _pad(self, a: np.ndarray) -> np.ndarray:
        """Pad a per-scenario parameter row to ``n_rows`` (repeat the last
        entry — the padded rows synthesize a real, duplicated scenario)."""
        if self.mesh is None or len(a) == self.n_rows:
            return a
        return np.concatenate(
            [a, np.repeat(a[-1:], self.n_rows - len(a), axis=0)])

    def dispatch(self) -> "SynthBatch":
        """Launch the device synthesis WITHOUT blocking on the result.

        jax dispatch is async: the synthesis of this chunk runs while the
        caller is still consuming the previous one (the double-buffering
        win ``EngineResult.timings['overlap']`` tracks). ``prepare`` then
        only pays the residual wait. Host synthesis stays synchronous (no
        async runtime to hand it to) and keeps its work in ``prepare``.
        """
        if not self.device or self._parts is not None:
            return self
        import jax.numpy as jnp

        if self.spec.kind in ("adversarial", "adaptive"):
            periods = self._periods if self._periods is not None \
                else self.spec.default_periods(self._idx)
            pslots, sslots = self.spec.wave_slots(periods)
        else:
            pslots = np.full(self.n_scenarios, 2, np.int64)
            sslots = np.ones(self.n_scenarios, np.int64)
        offsets = np.full(self.n_scenarios, -1, np.int64) \
            if self._offsets is None else self._offsets
        fn = _device_synth_fn(self.spec, self.mesh)
        args = (jnp.asarray(self._pad(self._idx), jnp.int32),
                jnp.asarray(self._pad(pslots), jnp.int32),
                jnp.asarray(self._pad(sslots), jnp.int32),
                jnp.asarray(self._pad(offsets), jnp.int32))
        record_jit("scenarios.synth:" + self.spec.kind
                   + (":sharded" if self.mesh is not None else ""),
                   fn, *args)
        with span("synth.dispatch", s0=self.start, s1=self.stop,
                  kind=self.spec.kind):
            self._parts = fn(*args)
        return self

    def prepare(self) -> "SynthBatch":
        if not self.device:
            self.markets  # noqa: B018 — realize the oracle rows (timed)
            return self
        if self._parts is None:
            self.dispatch()
        import jax

        # Under overlap the dispatch already ran during the previous
        # chunk's eval, so this span measures only the RESIDUAL wait — the
        # quantity EngineResult.timings["synth"] reports per chunk.
        with span("synth.wait", s0=self.start, s1=self.stop):
            self._parts = jax.block_until_ready(self._parts)
        return self

    @property
    def markets(self) -> list[SpotMarket]:
        # Oracle rows wrapped in from_prices — bit-exact with the spec's
        # materialized path by construction (same f64 price arrays).
        if self._markets is None:
            self._markets = [
                SpotMarket.from_prices(row,
                                       slots_per_unit=self.slots_per_unit,
                                       p_ondemand=self.p_ondemand)
                for row in self.spec.prices(self.start, self.stop,
                                            periods=self._periods,
                                            offsets=self._offsets)]
        return self._markets

    def _view_key(self, bid: float):
        if self.mesh is not None or self._periods is not None \
                or self._offsets is not None:
            # Explicit periods/offsets mean an adaptive adversary planned
            # this chunk from feedback — no cross-call identity.
            return None
        return (self.spec, self.start, self.stop, self.device,
                _bid_key(bid))

    def _build_views(self, bid: float):
        if not self.device:
            return _stack_bid_views(self.markets, bid)
        import jax
        import jax.numpy as jnp

        self.prepare()
        h, price, spike = self._parts
        thresh = jnp.asarray(
            self.spec.thresholds(bid, self._pad(self._idx)))
        spike_clears = self.spec.price_hi <= bid + 1e-12
        fn = _device_views_fn(self.slot, self.mesh)
        record_jit("scenarios.views"
                   + (":sharded" if self.mesh is not None else ""),
                   fn, h, price, spike, thresh, spike_clears)
        with span("views", bid=bid, s0=self.start, s1=self.stop):
            return jax.block_until_ready(
                fn(h, price, spike, thresh, spike_clears))


# --------------------------------------------------------------------------
# Sources — the chunk streams the engine iterates.
# --------------------------------------------------------------------------

class ScenarioSource:
    """Common protocol: slot-grid metadata + ``chunks(K, device)``."""

    n_scenarios: int
    slots_per_unit: int
    p_ondemand: float
    n_slots: int

    @property
    def slot(self) -> float:
        return 1.0 / self.slots_per_unit

    @property
    def reactive(self) -> bool:
        """True when chunk k+1's CONTENT depends on feedback about chunk k
        (the adaptive adversary) — such a stream cannot be prefetched, so
        the engine's double-buffering is disabled for it."""
        return False

    def chunks(self, chunk: int, device: bool = False, mesh=None):
        raise NotImplementedError

    def observe(self, values: np.ndarray) -> None:
        """Adaptive feedback hook — a no-op for every other source."""

    @property
    def markets(self) -> list[SpotMarket]:
        raise NotImplementedError


class _ListSource(ScenarioSource):
    """Materialized markets, chunked by slicing. The whole-list batch is
    cached so repeated full-batch evaluations (policy sweeps, TOLA
    refinement rounds) reuse the stacked per-bid views across calls."""

    def __init__(self, markets: Sequence[SpotMarket]):
        self._whole = MarketListBatch(markets)
        self.n_scenarios = self._whole.n_scenarios
        self.slots_per_unit = self._whole.slots_per_unit
        self.p_ondemand = self._whole.p_ondemand
        self.n_slots = self._whole.n_slots

    @property
    def markets(self) -> list[SpotMarket]:
        return self._whole.markets

    def chunks(self, chunk: int, device: bool = False, mesh=None):
        S = self.n_scenarios
        if chunk >= S and mesh is None:
            yield 0, S, self._whole
            return
        if chunk >= S:
            # Fresh batch under a mesh: the cached whole-list batch's
            # per-bid views are unsharded host arrays — mixing layouts in
            # one cache would hand a later unsharded call padded tensors.
            yield 0, S, MarketListBatch(self._whole.markets, checked=True,
                                        mesh=mesh)
            return
        for s0 in range(0, S, chunk):
            s1 = min(s0 + chunk, S)
            yield s0, s1, MarketListBatch(self._whole.markets[s0:s1],
                                          checked=True, mesh=mesh)


class ScenarioStream(ScenarioSource):
    """Chunk stream over a ``ScenarioSpec`` — stateful only for ``adaptive``.

    The adaptive adversary watches the learner through
    ``observe(regret_per_scenario)`` at every chunk boundary and escalates
    in three stages:

    1. **period sweep** — the spec's geometric period menu round-robin
       (random phases), until every period has been observed at least once;
    2. **phase sweep** — all spikes at the period with the highest mean
       observed regret, cycling ``n_phases`` evenly spaced phase offsets —
       the lever no FIXED square-wave family has (their phases are
       randomized), which is what lets the adaptive family's realized
       regret exceed the best fixed member on the same scenario budget;
    3. **locked** — every remaining scenario plays the (period, phase)
       cell with the highest mean observed regret, still accumulating
       statistics.

    The round trip happens strictly at chunk boundaries, so the synthesized
    interior of every chunk stays a pure function of
    (spec, indices, periods, offsets) — compiled code never sees the
    adversary's state.
    """

    def __init__(self, spec: ScenarioSpec):
        self.spec = spec
        self.n_scenarios = spec.n_scenarios
        self.slots_per_unit = spec.slots_per_unit
        self.p_ondemand = spec.p_ondemand
        self.n_slots = spec.n_slots
        self._menu = spec.period_menu() if spec.kind == "adaptive" else None
        self._p_harm = np.zeros(spec.n_periods)
        self._p_count = np.zeros(spec.n_periods, np.int64)
        self._f_harm = np.zeros(spec.n_phases)
        self._f_count = np.zeros(spec.n_phases, np.int64)
        self._locked_period: int | None = None
        self._pending: tuple[str, np.ndarray] | None = None
        self._last_stage: str | None = None
        self.chunk_periods: list[np.ndarray] = []  # audit trail (time units)
        self.chunk_offsets: list[np.ndarray] = []  # audit trail (slots)
        self._materialized: list[SpotMarket] | None = None

    @property
    def markets(self) -> list[SpotMarket]:
        """Full materialization with DEFAULT (feedback-free) periods —
        host-only consumers; the streamed chunks are the real path."""
        if self._materialized is None:
            self._materialized = self.spec.materialize()
        return self._materialized

    @property
    def stage(self) -> str:
        if self.spec.kind != "adaptive":
            return "stateless"
        if np.any(self._p_count == 0):
            return "periods"
        if np.any(self._f_count == 0):
            return "phases"
        return "locked"

    def _phase_candidates(self, period_idx: int) -> np.ndarray:
        pslots = int(self.spec.wave_slots(self._menu[[period_idx]])[0][0])
        return (np.arange(self.spec.n_phases) * pslots
                // self.spec.n_phases).astype(np.int64)

    def _best_period(self) -> int:
        mean = np.where(self._p_count > 0,
                        self._p_harm / np.maximum(self._p_count, 1), -np.inf)
        return int(np.argmax(mean))

    def _plan_chunk(self, idx: np.ndarray):
        if self.spec.kind != "adaptive":
            return None, None
        stage = self.stage
        if METRICS.enabled:
            METRICS.counter("scenarios.adaptive_chunks").inc(stage=stage)
            if self._last_stage is not None and stage != self._last_stage:
                METRICS.counter("scenarios.adaptive_escalations").inc(
                    to=stage)
        self._last_stage = stage
        if stage == "periods":
            menu_idx = idx % self.spec.n_periods
            periods = self._menu[menu_idx]
            offsets = np.full(len(idx), -1, np.int64)   # hash-random phases
            self._pending = ("periods", menu_idx)
        else:
            p = self._best_period()
            if self._locked_period != p:
                # (Re)target the phase stats at the current worst period —
                # offsets are period-relative, stale stats would lie.
                self._locked_period = p
                self._f_harm[:] = 0.0
                self._f_count[:] = 0
            cand = self._phase_candidates(p)
            if np.any(self._f_count == 0):              # phase sweep
                phase_idx = idx % self.spec.n_phases
            else:                                       # locked
                mean = np.where(self._f_count > 0, self._f_harm
                                / np.maximum(self._f_count, 1), -np.inf)
                phase_idx = np.full(len(idx), int(np.argmax(mean)))
            periods = self._menu[np.full(len(idx), p)]
            offsets = cand[phase_idx]
            self._pending = ("phases", phase_idx)
        self.chunk_periods.append(periods)
        self.chunk_offsets.append(offsets)
        return periods, offsets

    def observe(self, values: np.ndarray) -> None:
        """Feed back per-scenario learner regret for the LAST issued chunk."""
        if self.spec.kind != "adaptive" or self._pending is None:
            return
        kind, cells = self._pending
        values = np.asarray(values, np.float64)
        if len(values) != len(cells):
            raise ValueError(
                f"observe got {len(values)} values for a chunk of "
                f"{len(cells)} scenarios")
        if kind == "periods":
            np.add.at(self._p_harm, cells, values)
            np.add.at(self._p_count, cells, 1)
        else:
            np.add.at(self._f_harm, cells, values)
            np.add.at(self._f_count, cells, 1)
            # Phase-stage scenarios also refine the period estimate.
            self._p_harm[self._locked_period] += values.sum()
            self._p_count[self._locked_period] += len(values)
        self._pending = None

    @property
    def reactive(self) -> bool:
        return self.spec.kind == "adaptive"

    def chunks(self, chunk: int, device: bool = False, mesh=None):
        S = self.n_scenarios
        device = device and self.spec.generative
        for s0 in range(0, S, chunk):
            s1 = min(s0 + chunk, S)
            periods, offsets = self._plan_chunk(np.arange(s0, s1))
            yield s0, s1, SynthBatch(self.spec, s0, s1, periods=periods,
                                     offsets=offsets, device=device,
                                     mesh=mesh)


def as_source(scenarios) -> ScenarioSource:
    """Normalize any accepted scenario argument into a ``ScenarioSource``.

    Accepts a ``ScenarioSource`` (passed through — this is how a stateful
    adaptive stream survives across engine calls), a ``ScenarioSpec``, a
    single ``SpotMarket``, or a sequence of them.
    """
    if isinstance(scenarios, ScenarioSource):
        return scenarios
    if isinstance(scenarios, ScenarioSpec):
        return ScenarioStream(scenarios)
    if isinstance(scenarios, SpotMarket):
        return _ListSource([scenarios])
    return _ListSource(list(scenarios))


# --------------------------------------------------------------------------
# Materialized-list constructors (the legacy families).
# --------------------------------------------------------------------------

def make_scenarios(
    horizon_units: float,
    n_scenarios: int,
    seed: int = 0,
    kind: str = "fresh",
    price_model: str = "shifted",
    mean_range: tuple[float, float] = (0.125, 0.22),
    spike_range: tuple[float, float] = (0.5, 4.0),
    spike_frac: float = 0.5,
) -> list[SpotMarket]:
    """Build S materialized markets over a common horizon (legacy path).

    ``kind="fresh"``: same price law, seeds seed..seed+S-1.
    ``kind="regime"``: price mean swept linearly over ``mean_range`` (one
    regime per scenario, fresh seed each) — with ``price_model="truncate"``
    this is the truncated-exp regime sweep; the default "shifted" model keeps
    the paper's reading of the price law (DESIGN.md §4).
    ``kind="adversarial"``: lure/spike square waves — the spike period is
    swept geometrically over ``spike_range`` (time units, bracketing the
    Dealloc window lengths of the paper's policy grid) with ``spike_frac``
    of each period pinned at the on-demand ceiling; the cheap epochs draw
    from a halved-mean price law so every bid of the grid clears during the
    lure and none clears inside the spike.

    This family keeps numpy's ``Generator`` streams (bit-compatible with
    every earlier PR); declarative, chunkable, device-synthesizable
    families live in :class:`ScenarioSpec` (``kind="adaptive"`` only exists
    there — it needs the chunk-boundary feedback of a stream).
    """
    if n_scenarios < 1:
        raise ValueError("need at least one scenario")
    if kind == "fresh":
        return [SpotMarket(horizon_units, seed=seed + s,
                           price_model=price_model)
                for s in range(n_scenarios)]
    if kind == "regime":
        means = np.linspace(*mean_range, n_scenarios)
        return [SpotMarket(horizon_units, seed=seed + s,
                           price_mean=float(means[s]),
                           price_model=price_model)
                for s in range(n_scenarios)]
    if kind == "adversarial":
        return adversarial_scenarios(horizon_units, n_scenarios, seed=seed,
                                     spike_range=spike_range,
                                     spike_frac=spike_frac)
    if kind == "adaptive":
        raise ValueError(
            "kind='adaptive' needs chunk-boundary feedback — build a "
            "ScenarioSpec(kind='adaptive', ...) and stream it (e.g. "
            "repro.learn.replay_stream) instead of materializing a list")
    raise ValueError(f"unknown scenario kind {kind!r}")


def adversarial_scenarios(
    horizon_units: float,
    n_scenarios: int,
    seed: int = 0,
    slots_per_unit: int | None = None,
    spike_range: tuple[float, float] = (0.5, 4.0),
    spike_frac: float = 0.5,
) -> list[SpotMarket]:
    """Worst-case-regret price paths (ROADMAP scenario family).

    Scenario s is a square wave with period ``P_s`` (geometric sweep over
    ``spike_range`` time units): a cheap *lure* phase whose prices are drawn
    from the paper's law with half the usual mean (so every bid in B
    clears and spot looks like free money to the learner), then a *spike*
    phase of ``spike_frac * P_s`` pinned at ``PRICE_HI`` — above every bid,
    so any task whose Dealloc window straddles the spike exhausts its
    flexibility against zero availability and pays the on-demand backstop
    for the remainder. Phase offsets are randomized per scenario so job
    arrivals cannot be systematically in phase with the lure.
    """
    from repro.core.market import SLOTS_PER_UNIT

    if n_scenarios < 1:
        raise ValueError("need at least one scenario")
    spu = slots_per_unit or SLOTS_PER_UNIT
    n_slots = int(np.ceil(horizon_units * spu)) + 1
    if n_scenarios == 1:
        periods = [float(np.sqrt(spike_range[0] * spike_range[1]))]
    else:
        periods = np.geomspace(*spike_range, n_scenarios)
    markets = []
    for s in range(n_scenarios):
        rng = np.random.default_rng(seed + s)
        lure = np.minimum(PRICE_LO + rng.exponential(0.5 * PRICE_MEAN,
                                                     n_slots), PRICE_HI)
        period_slots = max(int(round(periods[s] * spu)), 2)
        spike_slots = max(int(round(spike_frac * period_slots)), 1)
        phase = (np.arange(n_slots) + rng.integers(period_slots)) \
            % period_slots
        price = np.where(phase < spike_slots, PRICE_HI, lure)
        markets.append(SpotMarket.from_prices(price, slots_per_unit=spu))
    return markets


@functools.lru_cache(maxsize=8)   # bounded — replay specs can carry big traces
def _padded_spec_traces(spec: "ScenarioSpec") -> np.ndarray:
    """(S, n_slots) padded trace rows of a replay spec, built once."""
    return _pad_traces(list(spec.traces), spec.n_slots,
                       max(spec.price_hi, spec.p_ondemand))


def _pad_traces(traces: list, n: int, pad_price: float) -> np.ndarray:
    """(len(traces), n) f64 rows, right-padded; warns naming the padding."""
    out = np.empty((len(traces), n))
    short = 0
    padded_slots = 0
    for i, t in enumerate(traces):
        t = np.asarray(t, dtype=np.float64)
        if len(t) < n:
            short += 1
            padded_slots += n - len(t)
            t = np.concatenate([t, np.full(n - len(t), pad_price)])
        out[i] = t
    if short:
        warnings.warn(
            f"replay traces right-padded to the longest ({n} slots): "
            f"{short} trace(s) padded with {padded_slots} total slots at "
            f"price {pad_price} (spot never clears there — padded tail "
            f"work pays the on-demand backstop)", stacklevel=3)
    return out


def replay_scenarios(
    traces: Sequence[np.ndarray],
    slots_per_unit: int = 12,
    p_ondemand: float = 1.0,
) -> list[SpotMarket]:
    """Replay-trace adapter: one scenario per recorded per-slot price trace.

    Padding contract: all scenarios of a batch must share one slot grid, so
    traces shorter than the longest are right-padded with
    ``max(PRICE_HI, p_ondemand)`` — a price above every bid, i.e. spot is
    never available in the padded tail and any work scheduled there pays
    the on-demand backstop. A ``UserWarning`` names how many traces/slots
    were padded; pre-trim or pre-extend traces to silence it.
    """
    if not traces:
        raise ValueError("need at least one trace")
    n = max(len(t) for t in traces)
    padded = _pad_traces(list(traces), n, max(PRICE_HI, p_ondemand))
    return [SpotMarket.from_prices(row, slots_per_unit=slots_per_unit,
                                   p_ondemand=p_ondemand)
            for row in padded]


def check_scenarios(markets: Sequence[SpotMarket]) -> None:
    """Scenarios of one batch must share the slot grid and horizon."""
    if len(markets) == 0:
        raise ValueError(
            "scenario batch is empty: 'markets' needs at least one "
            "SpotMarket (or pass a ScenarioSpec)")
    m0 = markets[0]
    for m in markets[1:]:
        if m.n_slots != m0.n_slots or m.slots_per_unit != m0.slots_per_unit:
            raise ValueError(
                "scenario markets must share slot grid and horizon "
                f"(got n_slots {m.n_slots} vs {m0.n_slots})")
        if abs(m.p_ondemand - m0.p_ondemand) > 1e-12:
            raise ValueError("scenario markets must share p_ondemand")


def stack_views(markets: Sequence[SpotMarket], bid: float):
    """(S, n_slots+1) stacked A/C cumulative arrays for one bid.

    One-shot utility; the engine's backends go through ``ScenarioBatch``
    instead, whose per-bid cache avoids restacking across calls."""
    return MarketListBatch(markets).stacked(bid)
