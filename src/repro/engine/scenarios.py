"""Scenario layer — families of spot-market traces for batched evaluation.

A *scenario* is one realized spot-price path; the engine evaluates the whole
(policy x job) grid against S scenarios in a single pass (the scenario axis
is a batch dimension for the jax backend and a grid dimension for the pallas
kernel). Three families:

* ``fresh``  — i.i.d. redraws of the paper's price law under new seeds
  (sampling noise of the market itself);
* ``regime`` — the price-law mean swept across a range (regime shifts:
  cheap/expensive spot epochs), exercising policies under markets their
  beta grid was not tuned for;
* ``replay`` — recorded per-slot traces wrapped via
  ``SpotMarket.from_prices`` (the replay-trace adapter);
* ``adversarial`` — square-wave lure/spike paths built to drive worst-case
  regret for TOLA: long cheap epochs bait the learner toward low-bid,
  spot-heavy policies, then the price spikes to the on-demand ceiling for
  a stretch comparable to a task window, so work sampled into the lure
  lands its window on the spike and pays the full on-demand backstop. The
  spike period is swept across scenarios (no single policy-window length
  is safe), which is what makes the family a regret stress test rather
  than one unlucky trace.

All scenarios of a batch share the slot grid and horizon so their cumulative
arrays stack into one (S, n_slots+1) tensor.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.market import PRICE_HI, PRICE_LO, PRICE_MEAN, SpotMarket

__all__ = ["make_scenarios", "adversarial_scenarios", "replay_scenarios",
           "check_scenarios", "stack_views"]


def make_scenarios(
    horizon_units: float,
    n_scenarios: int,
    seed: int = 0,
    kind: str = "fresh",
    price_model: str = "shifted",
    mean_range: tuple[float, float] = (0.125, 0.22),
    spike_range: tuple[float, float] = (0.5, 4.0),
    spike_frac: float = 0.5,
) -> list[SpotMarket]:
    """Build S markets over a common horizon.

    ``kind="fresh"``: same price law, seeds seed..seed+S-1.
    ``kind="regime"``: price mean swept linearly over ``mean_range`` (one
    regime per scenario, fresh seed each) — with ``price_model="truncate"``
    this is the truncated-exp regime sweep; the default "shifted" model keeps
    the paper's reading of the price law (DESIGN.md §4).
    ``kind="adversarial"``: lure/spike square waves — the spike period is
    swept geometrically over ``spike_range`` (time units, bracketing the
    Dealloc window lengths of the paper's policy grid) with ``spike_frac``
    of each period pinned at the on-demand ceiling; the cheap epochs draw
    from a halved-mean price law so every bid of the grid clears during the
    lure and none clears inside the spike.
    """
    if n_scenarios < 1:
        raise ValueError("need at least one scenario")
    if kind == "fresh":
        return [SpotMarket(horizon_units, seed=seed + s,
                           price_model=price_model)
                for s in range(n_scenarios)]
    if kind == "regime":
        means = np.linspace(*mean_range, n_scenarios)
        return [SpotMarket(horizon_units, seed=seed + s,
                           price_mean=float(means[s]),
                           price_model=price_model)
                for s in range(n_scenarios)]
    if kind == "adversarial":
        return adversarial_scenarios(horizon_units, n_scenarios, seed=seed,
                                     spike_range=spike_range,
                                     spike_frac=spike_frac)
    raise ValueError(f"unknown scenario kind {kind!r}")


def adversarial_scenarios(
    horizon_units: float,
    n_scenarios: int,
    seed: int = 0,
    slots_per_unit: int | None = None,
    spike_range: tuple[float, float] = (0.5, 4.0),
    spike_frac: float = 0.5,
) -> list[SpotMarket]:
    """Worst-case-regret price paths (ROADMAP scenario family).

    Scenario s is a square wave with period ``P_s`` (geometric sweep over
    ``spike_range`` time units): a cheap *lure* phase whose prices are drawn
    from the paper's law with half the usual mean (so every bid in B
    clears and spot looks like free money to the learner), then a *spike*
    phase of ``spike_frac * P_s`` pinned at ``PRICE_HI`` — above every bid,
    so any task whose Dealloc window straddles the spike exhausts its
    flexibility against zero availability and pays the on-demand backstop
    for the remainder. Phase offsets are randomized per scenario so job
    arrivals cannot be systematically in phase with the lure.
    """
    from repro.core.market import SLOTS_PER_UNIT

    if n_scenarios < 1:
        raise ValueError("need at least one scenario")
    spu = slots_per_unit or SLOTS_PER_UNIT
    n_slots = int(np.ceil(horizon_units * spu)) + 1
    if n_scenarios == 1:
        periods = [float(np.sqrt(spike_range[0] * spike_range[1]))]
    else:
        periods = np.geomspace(*spike_range, n_scenarios)
    markets = []
    for s in range(n_scenarios):
        rng = np.random.default_rng(seed + s)
        lure = np.minimum(PRICE_LO + rng.exponential(0.5 * PRICE_MEAN,
                                                     n_slots), PRICE_HI)
        period_slots = max(int(round(periods[s] * spu)), 2)
        spike_slots = max(int(round(spike_frac * period_slots)), 1)
        phase = (np.arange(n_slots) + rng.integers(period_slots)) \
            % period_slots
        price = np.where(phase < spike_slots, PRICE_HI, lure)
        markets.append(SpotMarket.from_prices(price, slots_per_unit=spu))
    return markets


def replay_scenarios(
    traces: Sequence[np.ndarray],
    slots_per_unit: int = 12,
    p_ondemand: float = 1.0,
) -> list[SpotMarket]:
    """Replay-trace adapter: one scenario per recorded per-slot price trace.

    Traces are right-padded with the on-demand price (spot never clears) to
    the longest trace so all scenarios share one slot grid.
    """
    if not traces:
        raise ValueError("need at least one trace")
    n = max(len(t) for t in traces)
    markets = []
    for t in traces:
        t = np.asarray(t, dtype=np.float64)
        if len(t) < n:
            t = np.concatenate([t, np.full(n - len(t), max(PRICE_HI,
                                                           p_ondemand))])
        markets.append(SpotMarket.from_prices(t, slots_per_unit=slots_per_unit,
                                              p_ondemand=p_ondemand))
    return markets


def check_scenarios(markets: Sequence[SpotMarket]) -> None:
    """Scenarios of one batch must share the slot grid and horizon."""
    m0 = markets[0]
    for m in markets[1:]:
        if m.n_slots != m0.n_slots or m.slots_per_unit != m0.slots_per_unit:
            raise ValueError(
                "scenario markets must share slot grid and horizon "
                f"(got n_slots {m.n_slots} vs {m0.n_slots})")
        if abs(m.p_ondemand - m0.p_ondemand) > 1e-12:
            raise ValueError("scenario markets must share p_ondemand")


def stack_views(markets: Sequence[SpotMarket], bid: float):
    """(S, n_slots+1) stacked A/C cumulative arrays for one bid."""
    check_scenarios(markets)
    A = np.stack([m.view(bid).A_cum for m in markets])
    C = np.stack([m.view(bid).C_cum for m in markets])
    return A, C
