"""Pallas-kernel backend — the TPU fast path.

Early-start grids go through ``kernels/policy_cost.py::policy_cost_chain``:
ONE kernel launch per bid covers the whole (scenario x policy x job) grid —
scenarios are a grid dimension selecting the VMEM-resident cumulative
arrays, (policy, job) cells are flattened rows, and the chain recurrence
runs inside the kernel. Planned-start grids (early_start=False) use the
original per-task ``policy_cost`` kernel on the flattened task batch.

Off-TPU the kernels run in interpret mode (slow, parity-testing only);
``interpret`` can be forced either way.
"""

from __future__ import annotations

import numpy as np

from repro.engine.scenarios import stack_views

__all__ = ["run"]


def run(gplan, markets, early_start: bool, out, interpret: bool | None = None,
        block_rows: int = 128) -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.policy_cost import policy_cost, policy_cost_chain

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    slot = markets[0].slot
    p_od = markets[0].p_ondemand
    J = gplan.n_jobs
    S = len(markets)

    for bid in gplan.bids:
        groups = gplan.groups_for_bid(bid)
        A, C = stack_views(markets, bid)        # (S, n_slots+1)
        ends = np.concatenate([g.plan.ends for g in groups])
        z_t = np.concatenate([g.z_t for g in groups])
        d_eff = np.concatenate([g.d_eff for g in groups])
        if early_start:
            pins = np.concatenate([g.pins for g in groups])
            arrival = np.tile(gplan.arrival, len(groups))
            res = policy_cost_chain(
                A, C, arrival, ends, z_t, d_eff, pins, slot=slot, p_od=p_od,
                block_rows=block_rows, interpret=interpret)
            vals = {k: np.asarray(v, np.float64).reshape(
                        S, len(groups), J) for k, v in res.items()}
        else:
            starts = np.concatenate([g.plan.starts for g in groups])
            R, L = ends.shape
            flat = lambda a: jnp.asarray(a.reshape(R * L), jnp.float32)
            per_s = []
            for s in range(S):
                r = policy_cost(
                    jnp.asarray(A[s], jnp.float32),
                    jnp.asarray(C[s], jnp.float32),
                    flat(starts), flat(ends), flat(z_t), flat(d_eff),
                    slot=slot, p_od=p_od, interpret=interpret)
                r["ondemand_work"] = (
                    r["ondemand_cost"] / p_od if p_od > 0
                    else jnp.maximum(flat(z_t) - r["spot_work"], 0.0)
                    * (flat(z_t) > 1e-15))
                per_s.append({k: np.asarray(v, np.float64)
                              .reshape(len(groups), J, L).sum(axis=2)
                              for k, v in r.items() if k != "finish"})
            vals = {k: np.stack([p[k] for p in per_s])
                    for k in per_s[0]}
        for key in ("spot_cost", "ondemand_cost", "spot_work",
                    "ondemand_work"):
            v = vals[key]
            for gi, g in enumerate(groups):
                out[key][:, :, g.policy_idx] = v[:, gi, :, None]
