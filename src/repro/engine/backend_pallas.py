"""Pallas-kernel backend — the TPU fast path.

Early-start grids go through ``kernels/policy_cost.py::policy_cost_chain``:
ONE kernel launch covers the whole (bid x scenario x policy x job) sweep —
bids and scenarios are grid dimensions selecting the VMEM-resident
cumulative arrays, (policy, job) cells are flattened rows (zero-padded to
the widest bid), and the chain recurrence runs inside the kernel. Planned-
start grids (early_start=False) use the original per-task ``policy_cost``
kernel on the flattened task batch.

Off-TPU the kernels run in interpret mode (slow, parity-testing only);
``interpret`` can be forced either way.
"""

from __future__ import annotations

import numpy as np

from repro.engine.plan import concat_rows, scenario_cat

__all__ = ["run"]


def run(gplan, batch, early_start: bool, out, interpret: bool | None = None,
        block_rows: int = 128) -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.policy_cost import policy_cost, policy_cost_chain

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    slot = batch.slot
    p_od = batch.p_ondemand
    J = gplan.n_jobs
    S = batch.n_scenarios
    L = gplan.L
    bids = gplan.bids
    groups_per_bid = [gplan.groups_for_bid(b) for b in bids]

    if early_start:
        # Stack every bid's row batch into one (B, R_max, L) tensor (rows
        # zero-padded past the bid's own groups) -> ONE kernel launch for
        # the whole sweep.
        B = len(bids)
        per_scenario = gplan.per_scenario
        R_max = max(len(gs) for gs in groups_per_bid) * J
        arrival = np.zeros((B, R_max))
        for bi, groups in enumerate(groups_per_bid):
            arrival[bi, :len(groups) * J] = np.tile(gplan.arrival,
                                                    len(groups))
        if batch.device:
            # Device-synthesized chunk: the per-bid views are already f32
            # jax arrays — stack them with jnp so the kernel consumes them
            # without a host round trip.
            AC = [batch.stacked(bid) for bid in bids]
            A = jnp.stack([a for a, _ in AC])
            C = jnp.stack([c for _, c in AC])
        else:
            A = np.zeros((B, S, batch.n_slots + 1), np.float32)
            C = np.zeros_like(A)
            for bi, bid in enumerate(bids):
                A[bi], C[bi] = batch.stacked(bid)
        if gplan.device:
            # Device grid plan: build the zero-padded (B, ..., R_max, L)
            # stacks with jnp so the plan tensors feed the kernel without a
            # host round trip.
            def pad(a, raxis):
                if a.shape[raxis] == R_max:
                    return a
                w = [(0, 0)] * a.ndim
                w[raxis] = (0, R_max - a.shape[raxis])
                return jnp.pad(a, w)

            raxis = 1 if per_scenario else 0  # row axis of the s-o stacks

            def cat(groups, attr):
                if per_scenario:
                    return scenario_cat(groups, attr, S)
                return concat_rows([getattr(g, attr) for g in groups])

            ends = jnp.stack(
                [pad(concat_rows([g.plan.ends for g in gs]), 0)
                 for gs in groups_per_bid])
            z_t = jnp.stack([pad(cat(gs, "z_t"), raxis)
                             for gs in groups_per_bid])
            d_eff = jnp.stack([pad(cat(gs, "d_eff"), raxis)
                               for gs in groups_per_bid])
            pins = jnp.stack([pad(cat(gs, "pins"), raxis)
                              for gs in groups_per_bid])
        else:
            ends = np.zeros((B, R_max, L))
            pshape = (B, S, R_max, L) if per_scenario else (B, R_max, L)
            z_t = np.zeros(pshape)
            d_eff = np.zeros(pshape)
            pins = np.zeros(pshape, dtype=bool)
            for bi, groups in enumerate(groups_per_bid):
                R = len(groups) * J
                ends[bi, :R] = np.concatenate([g.plan.ends for g in groups])
                if per_scenario:
                    sl = (bi, slice(None), slice(0, R))
                    cat = lambda attr: scenario_cat(groups, attr, S)
                else:
                    sl = (bi, slice(0, R))
                    cat = lambda attr: np.concatenate(
                        [getattr(g, attr) for g in groups])
                z_t[sl] = cat("z_t")
                d_eff[sl] = cat("d_eff")
                pins[sl] = cat("pins")
        res = policy_cost_chain(
            A, C, arrival, ends, z_t, d_eff, pins, slot=slot, p_od=p_od,
            block_rows=block_rows, interpret=interpret)
        for key in ("spot_cost", "ondemand_cost", "spot_work",
                    "ondemand_work"):
            vals = np.asarray(res[key], np.float64)     # (B, S, R_max)
            for bi, groups in enumerate(groups_per_bid):
                per_g = vals[bi, :, :len(groups) * J].reshape(
                    S, len(groups), J)
                for gi, g in enumerate(groups):
                    out[key][:, :, g.policy_idx] = per_g[:, gi, :, None]
        return

    for bid, groups in zip(bids, groups_per_bid):
        A, C = batch.stacked(bid)               # (S, n_slots+1)
        starts = concat_rows([g.plan.starts for g in groups])
        ends = concat_rows([g.plan.ends for g in groups])
        R, L = ends.shape
        if gplan.per_scenario:
            z_all = scenario_cat(groups, "z_t", S)       # (S, R, L)
            d_all = scenario_cat(groups, "d_eff", S)
        else:
            z_one = concat_rows([g.z_t for g in groups])
            d_one = concat_rows([g.d_eff for g in groups])
        per_s = []
        for s in range(S):
            z_t = z_all[s] if gplan.per_scenario else z_one
            d_eff = d_all[s] if gplan.per_scenario else d_one
            flat = lambda a: jnp.asarray(a.reshape(R * L), jnp.float32)
            r = policy_cost(
                jnp.asarray(A[s], jnp.float32),
                jnp.asarray(C[s], jnp.float32),
                flat(starts), flat(ends), flat(z_t), flat(d_eff),
                slot=slot, p_od=p_od, interpret=interpret)
            r["ondemand_work"] = (
                r["ondemand_cost"] / p_od if p_od > 0
                else jnp.maximum(flat(z_t) - r["spot_work"], 0.0)
                * (flat(z_t) > 1e-15))
            per_s.append({k: np.asarray(v, np.float64)
                          .reshape(len(groups), J, L).sum(axis=2)
                          for k, v in r.items() if k != "finish"})
        vals = {k: np.stack([p[k] for p in per_s])
                for k in per_s[0]}
        for key in ("spot_cost", "ondemand_cost", "spot_work",
                    "ondemand_work"):
            v = vals[key]
            for gi, g in enumerate(groups):
                out[key][:, :, g.policy_idx] = v[:, gi, :, None]
