"""Plan layer of the evaluation engine.

Turns (jobs x policies) into a deduplicated batch of *evaluation groups*.
The key observation: the padded ``PlanBatch`` (the canonical interchange
type) depends on a policy only through its Dealloc parameter, the
self-owned allocation only through (plan, beta_0), and the market
realization additionally through the bid. Policies sharing the triple
(window key, beta_0, bid) are therefore EXACT duplicates of one another
and collapse into one group — the paper's C1 x C2 x B grid of 175 policies
reduces to 35 distinct evaluations because every beta >= beta_0 drives
Dealloc with beta_0 (Alg. 2 lines 1-5).

The plan layer is itself part of the array program, and it is
**backend-parametric** (``plan_backend``):

* ``"host"`` — float64 numpy, the bit-exact oracle: window plans for ALL
  distinct Dealloc parameters come out of ONE vectorized
  ``build_plans_batch`` pass (``core.dealloc.window_sizes_batch``,
  bit-identical to the legacy per-job loop), and the market-independent
  arithmetic (policy-(12) counts, cloud residuals, pins) follows in f64.
* ``"device"`` — the same pipeline as ONE fused jit program (device dtype,
  usually f32): the Alg.-1 waterfill (``core.dealloc`` jnp twin), the
  policy-(12) counts (``core.scheduler._selfowned_counts_impl``), the
  cloud residuals, and the group gather all trace into a single XLA
  computation whose outputs stay on device — the jax/pallas cost kernels
  consume them without a host staging copy. Parity with the host path is
  float-level (<=1e-5 relative on unit costs; tests/test_plan_batch.py),
  NOT bitwise, and integral-count ceils use a widened epsilon
  (``scheduler._DEVICE_CEIL_EPS``) to absorb f32 noise.

Every backend (numpy / jax / pallas) consumes the same ``GridPlan``
structure; the numpy oracle requires a host plan. When ``availability`` is
a *list* of per-scenario queries (TOLA's batched pool refinement), the
self-owned arrays gain a leading scenario axis — groups carry (S, J, L)
tensors and backends pair scenario s with slice s. Availability queries
are host callables, so the device path stages the planned windows to host
once to evaluate them (the default query-free path never leaves device).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.obs import record_jit, span

from repro.engine import cache as _cache
from repro.core.scheduler import (
    PlanBatch,
    Policy,
    _allocate_pool,
    _selfowned_counts_vec,
    build_plans_batch,
    job_arrays,
)
from repro.core.types import ChainJob

__all__ = ["EvalGroup", "GridPlan", "build_grid_plan", "scenario_cat",
           "concat_rows", "distinct_window_params"]

_PLAN_BACKENDS = ("host", "device")

# Dust threshold of the DEVICE residual-workload kill. The host oracle
# zeroes residuals below 1e-9 * (z + 1) — the f64 cancellation floor of
# z - r * sizes. Device arithmetic is f32 whose cancellation noise is
# ~1e-7 relative, so the same subtraction leaves phantom residuals the
# 1e-9 threshold would keep alive; 1e-6 kills them. Genuine residuals are
# either 0 or substantial, so the widened window changes nothing real.
_DEVICE_DUST = 1e-6


def _xp_of(a):
    """numpy for host arrays, jax.numpy for device-resident arrays."""
    if isinstance(a, np.ndarray):
        return np
    import jax.numpy as jnp

    return jnp


def concat_rows(arrays):
    """Concatenate group row batches along axis 0 without forcing device
    tensors through host (np.concatenate on jax arrays would)."""
    return _xp_of(arrays[0]).concatenate(arrays)


def scenario_cat(groups, attr: str, S: int):
    """Concatenate a group attribute into an (S, R, L) scenario-major stack,
    broadcasting groups whose arrays are scenario-independent — the one
    place the per-scenario/shared mixing rule lives (both the jax and the
    pallas backend consume it). Device tensors stay on device."""
    xp = _xp_of(getattr(groups[0], attr))
    return xp.concatenate(
        [xp.broadcast_to(getattr(g, attr),
                         (S,) + tuple(g.plan.ends.shape)) for g in groups],
        axis=1)


def _bid_key(bid: float) -> float:
    """The one bid-comparison rule of the plan layer: groups are deduped,
    listed and looked up on the SAME rounded value (raw-float comparison
    would let two bids differing below 1e-12 collapse into one group and
    then miss it on lookup)."""
    return round(bid, 12)


@dataclasses.dataclass
class EvalGroup:
    """One distinct (window plan, beta_0, bid) evaluation cell.

    ``policy_idx`` lists every policy of the original grid that this group
    realizes. The self-owned arrays are (J, L) when market-independent and
    (S, J, L) when the caller supplied per-scenario availability queries
    (``per_scenario`` distinguishes the two). On the device plan path they
    are jax device arrays (f32) instead of host numpy (f64).
    """

    plan: PlanBatch
    policy_idx: np.ndarray   # (k,) columns of the cost matrix this fills
    bid: float
    r_alloc: np.ndarray      # (J, L) | (S, J, L) self-owned instances
    z_t: np.ndarray          # (J, L) | (S, J, L) cloud workload after s-o
    d_eff: np.ndarray        # (J, L) | (S, J, L) cloud parallelism after s-o
    pins: np.ndarray         # bool — tasks holding reservations
    selfowned_work: np.ndarray      # (J,) | (S, J)
    selfowned_reserved: np.ndarray  # (J,) | (S, J)

    @property
    def per_scenario(self) -> bool:
        return self.z_t.ndim == 3


@dataclasses.dataclass
class GridPlan:
    """The full batched evaluation plan for (jobs x policies)."""

    jobs: list[ChainJob]
    policies: list[Policy]
    groups: list[EvalGroup]
    workload: np.ndarray     # (J,) Z_j
    arrival: np.ndarray      # (J,)
    n_jobs: int
    n_policies: int
    L: int
    plan_seconds: float = 0.0   # window-plan tensor construction
    pool_seconds: float = 0.0   # self-owned allocation + residuals
    plan_backend: str = "host"  # "host" (numpy f64) | "device" (jit)
    plan_cached: int = 0        # groups served from the cross-call cache
    jobs_fp: str = ""           # content fingerprint of the job batch
    group_keys: list | None = None  # per-group dedup signatures (cache keys)

    @property
    def device(self) -> bool:
        return self.plan_backend == "device"

    @property
    def bids(self) -> list[float]:
        seen: dict[float, float] = {}
        for g in self.groups:
            seen.setdefault(_bid_key(g.bid), g.bid)
        return sorted(seen.values())

    @property
    def per_scenario(self) -> bool:
        return any(g.per_scenario for g in self.groups)

    def groups_for_bid(self, bid: float) -> list[EvalGroup]:
        key = _bid_key(bid)
        return [g for g in self.groups if _bid_key(g.bid) == key]


def _window_key(policy: Policy, r_total: int, windows: str):
    if windows == "even":
        return ("even",)
    return ("dealloc", round(policy.dealloc_param(r_total), 12))


def distinct_window_params(policies, r_total: int,
                           windows: str = "dealloc") -> dict[tuple, float]:
    """Window-key dedup of a policy grid: {window key -> exact Dealloc param
    of the FIRST policy carrying it} in first-appearance order (the rounded
    key only dedups; the plan is always built from the exact parameter).
    The single source of the dedup rule — the engine, the pipeline
    benchmark, and the bit-compat tests all measure the same grid."""
    key_param: dict[tuple, float] = {}
    for pol in policies:
        wkey = _window_key(pol, r_total, windows)
        if wkey not in key_param:
            key_param[wkey] = (pol.dealloc_param(r_total)
                               if windows != "even" else 0.0)
    return key_param


@dataclasses.dataclass
class _GridStructure:
    """First-appearance-ordered dedup of the (window, beta_0, bid) grid —
    the host-side index arithmetic both plan backends share, so grouping
    is identical by construction."""

    key_param: dict[tuple, float]   # window key -> exact Dealloc param
    a_plan: list[int]               # akey -> window-plan index
    a_beta0: list[float | None]     # akey -> beta_0 of its first policy
    g_akey: list[int]               # group -> akey index
    g_bid: list[float]              # group -> exact bid of its first policy
    g_pols: list[list[int]]         # group -> policy columns it fills
    g_key: list[tuple]              # group -> full (window, b0, bid) key


def _grid_structure(policies, r_total: int, windows: str) -> _GridStructure:
    key_param = distinct_window_params(policies, r_total, windows)
    w_index = {k: i for i, k in enumerate(key_param)}
    akey_index: dict[tuple, int] = {}
    g_index: dict[tuple, int] = {}
    s = _GridStructure(key_param, [], [], [], [], [], [])
    for pi, pol in enumerate(policies):
        wkey = _window_key(pol, r_total, windows)
        b0 = None if pol.beta0 is None else round(pol.beta0, 12)
        akey = wkey + (b0,)
        ai = akey_index.get(akey)
        if ai is None:
            ai = akey_index[akey] = len(s.a_plan)
            s.a_plan.append(w_index[wkey])
            s.a_beta0.append(pol.beta0)
        gkey = akey + (_bid_key(pol.bid),)
        gi = g_index.get(gkey)
        if gi is None:
            gi = g_index[gkey] = len(s.g_bid)
            s.g_akey.append(ai)
            s.g_bid.append(pol.bid)
            s.g_pols.append([pi])
            s.g_key.append(gkey)
        else:
            s.g_pols[gi].append(pi)
    return s


def _cloud_residuals(plan: PlanBatch, r_alloc: np.ndarray):
    """The market-independent tail of ``_simulate_plan``: residual cloud
    workload (dust-killed), effective parallelism, pins, self-owned stats.
    ``r_alloc`` may carry a leading scenario axis; everything broadcasts."""
    sizes = plan.sizes
    z_t = np.maximum(plan.z - r_alloc * sizes, 0.0)
    z_t[z_t <= 1e-9 * (plan.z + 1.0)] = 0.0
    d_eff = np.maximum(plan.delta - r_alloc, 0.0)
    selfowned = np.minimum(r_alloc * sizes, plan.z)
    return z_t, d_eff, r_alloc > 0, selfowned.sum(axis=-1), \
        (r_alloc * sizes).sum(axis=-1)


def build_grid_plan(
    jobs: list[ChainJob],
    policies: list[Policy],
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    pool: str = "dedicated",
    availability=None,
    slots_per_unit: int = 12,
    n_scenarios: int | None = None,
    plan_backend: str = "host",
    mesh=None,
) -> GridPlan:
    """Deduplicate (jobs x policies) into evaluation groups.

    ``pool="dedicated"`` scores each policy against an uncontended pool (the
    counterfactual evaluator TOLA uses; ``availability`` optionally replaces
    the constant ``r_total`` with a realized residual-occupancy query, or a
    LIST of per-scenario queries — one per market scenario of the batch —
    for scenario-batched pool refinement; pass ``n_scenarios`` so the list
    length is validated HERE, before an (S', J, L) stack of the wrong S
    ships to a backend).
    ``pool="shared"`` replays the chronological shared-pool allocation per
    policy (the realized ``run_jobs`` semantics used by fixed-policy sweeps).
    ``plan_backend="device"`` builds the plan tensors as one fused jit
    program (see module docstring); requires jax and ``pool="dedicated"``.
    ``mesh`` (a ``GridMesh``) does not change the built tensors, but its
    (data, model) partition joins the cross-call plan-cache key: a cached
    group's device buffers are only reused by calls that will shard them
    identically, so warm hits stay bitwise per partition.
    """
    if pool not in ("dedicated", "shared"):
        raise ValueError(f"unknown pool mode {pool!r}")
    if plan_backend not in _PLAN_BACKENDS:
        raise ValueError(f"unknown plan backend {plan_backend!r}; pick from "
                         f"{_PLAN_BACKENDS}")
    if isinstance(availability, (list, tuple)) and n_scenarios is not None \
            and len(availability) != n_scenarios:
        raise ValueError(
            f"per-scenario availability needs one query per scenario "
            f"({len(availability)} queries, {n_scenarios} scenarios)")
    if plan_backend == "device" and pool == "shared":
        raise ValueError(
            "plan_backend='device' supports pool='dedicated' only (the "
            "chronological shared-pool replay is host code)")

    structure = _grid_structure(policies, r_total, windows)
    arrays = job_arrays(jobs)
    jobs_fp = _cache.fingerprint_job_arrays(arrays)
    # Availability queries are opaque host callables — their results have
    # no fingerprint, so refined plans never enter the cross-call cache.
    use_cache = availability is None and _cache.enabled()
    mesh_part = None if mesh is None else (mesh.data_shards,
                                           mesh.model_shards)
    if plan_backend == "device":
        return _build_grid_plan_device(jobs, policies, structure, arrays,
                                       r_total, windows, selfowned,
                                       availability, jobs_fp=jobs_fp,
                                       use_cache=use_cache,
                                       mesh_part=mesh_part)
    return _build_grid_plan_host(jobs, policies, structure, arrays, r_total,
                                 windows, selfowned, pool, availability,
                                 slots_per_unit, jobs_fp=jobs_fp,
                                 use_cache=use_cache, mesh_part=mesh_part)


def _cache_lookup(s: _GridStructure, base: tuple, use_cache: bool):
    """Consult the cross-call group cache: {group index -> cached record}
    plus the miss list, with hit/miss counters emitted. The miss set
    drives SUBSET builds below — only the window plans and allocations
    the missing groups actually need are recomputed, and building a
    subset of the Dealloc parameters is bit-identical to building all of
    them (``build_plans_batch`` vectorizes per parameter)."""
    cached: dict[int, EvalGroup] = {}
    if use_cache:
        for gi in range(len(s.g_bid)):
            rec = _cache.PLAN_CACHE.get((base, s.g_key[gi]))
            if rec is not None:
                cached[gi] = rec
        _cache.plan_cache_events(hits=len(cached),
                                 misses=len(s.g_bid) - len(cached))
    miss = [gi for gi in range(len(s.g_bid)) if gi not in cached]
    return cached, miss


def _build_grid_plan_host(jobs, policies, s: _GridStructure, arrays, r_total,
                          windows, selfowned, pool, availability,
                          slots_per_unit, jobs_fp: str = "",
                          use_cache: bool = False,
                          mesh_part=None) -> GridPlan:
    # ``mesh_part`` partitions the cache by (data, model) shard counts so a
    # warm hit never hands one partition another partition's buffers.
    base = (jobs_fp, float(r_total), windows, selfowned, pool,
            int(slots_per_unit), "host", mesh_part)
    cached, miss = _cache_lookup(s, base, use_cache)
    need_ai = sorted({s.g_akey[gi] for gi in miss})
    need_w = sorted({s.a_plan[ai] for ai in need_ai})
    w_pos = {w: i for i, w in enumerate(need_w)}
    params = list(s.key_param.values())

    # Spans are emitted even on an all-hit call: timings["plan"/"pool"]
    # must stay the same floats as the span tracer's totals (test_obs).
    with span("plan", plan_backend="host", windows=windows,
              n_plans=len(need_w), n_cached=len(cached)) as sp:
        if not need_w:
            built: list[PlanBatch] = []
        elif windows == "even":
            built = build_plans_batch(jobs, windows="even", arrays=arrays)
        else:
            built = build_plans_batch(jobs, [params[w] for w in need_w],
                                      windows="dealloc", arrays=arrays)
    plan_seconds = sp.seconds

    with span("pool", plan_backend="host", pool=pool,
              n_groups=len(miss)) as sp:
        alloc: dict[int, np.ndarray] = {
            ai: _group_alloc(built[w_pos[s.a_plan[ai]]], s.a_beta0[ai],
                             r_total, selfowned, pool, availability,
                             slots_per_unit)
            for ai in need_ai}
        groups: list[EvalGroup] = []
        for gi in range(len(s.g_bid)):
            rec = cached.get(gi)
            if rec is not None:
                # The cached record keeps ITS exact bid: two bids rounding
                # to the same 12-decimal key are one group, in-grid and
                # cross-call alike, so the hit is bitwise.
                groups.append(dataclasses.replace(
                    rec, policy_idx=np.asarray(s.g_pols[gi])))
                continue
            ai = s.g_akey[gi]
            plan = built[w_pos[s.a_plan[ai]]]
            r_alloc = alloc[ai]
            z_t, d_eff, pins, so_work, so_res = _cloud_residuals(plan,
                                                                 r_alloc)
            g = EvalGroup(
                plan=plan, policy_idx=np.asarray(s.g_pols[gi]),
                bid=s.g_bid[gi], r_alloc=r_alloc, z_t=z_t, d_eff=d_eff,
                pins=pins, selfowned_work=so_work, selfowned_reserved=so_res)
            groups.append(g)
            if use_cache:
                _cache.PLAN_CACHE.put((base, s.g_key[gi]), g)
    pool_seconds = sp.seconds
    return GridPlan(jobs=jobs, policies=policies, groups=groups,
                    workload=arrays.z.sum(axis=1), arrival=arrays.arrival,
                    n_jobs=len(jobs), n_policies=len(policies),
                    L=arrays.z.shape[1], plan_seconds=plan_seconds,
                    pool_seconds=pool_seconds, plan_backend="host",
                    plan_cached=len(cached), jobs_fp=jobs_fp,
                    group_keys=list(s.g_key))


def _group_alloc(plan: PlanBatch, pol_beta0: float | None, r_total: int,
                 selfowned: str, pool: str, availability,
                 slots_per_unit: int) -> np.ndarray:
    if r_total <= 0:
        return np.zeros_like(plan.z)
    beta0 = np.full(plan.z.shape[0],
                    np.nan if pol_beta0 is None else pol_beta0)
    if pool == "shared":
        # Chronological shared-pool replay on the planned windows; each
        # policy of a sweep owns a fresh pool (sweep semantics of run_jobs).
        # bid is deliberately NaN: the allocation is bid-independent (and is
        # cached per (windows, beta0) across bids) — if _allocate_pool ever
        # starts consulting the bid, this surfaces loudly and the alloc
        # cache key must gain the bid.
        pplan = dataclasses.replace(plan, beta0=beta0,
                                    bid=np.full(plan.z.shape[0], np.nan))
        r_alloc, _ = _allocate_pool(pplan, r_total, selfowned, slots_per_unit)
        return r_alloc
    if availability is None:
        avail = float(r_total)
    elif isinstance(availability, (list, tuple)):
        # Per-scenario residual-occupancy queries -> (S, J, L) availability.
        avail = np.stack([q(plan.starts, plan.ends) for q in availability])
    else:
        avail = availability(plan.starts, plan.ends)
    r_alloc = _selfowned_counts_vec(
        plan.z, plan.delta, plan.sizes, beta0[:, None], avail, selfowned)
    return np.where(plan.mask, r_alloc, 0.0)


# --------------------------------------------------------------------------
# Device plan path: jobs -> plan tensors as ONE fused jit program.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)   # bounded: one entry per mode pair
def _device_plan_fns(selfowned_mode: str, windows: str):
    """Jitted device builders, cached per (self-owned mode, window mode).

    ``full`` is the fused query-free program (windows -> plans -> policy-(12)
    counts -> residuals -> group gather, one XLA computation); ``plans`` /
    ``groups`` are the same pieces split so availability queries (host
    callables) can run between them.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.dealloc import _jax_impls
    from repro.core.scheduler import _selfowned_counts_impl

    waterfill = _jax_impls()["window_sizes_batch"]
    counts_fn = _selfowned_counts_impl(selfowned_mode)

    def plans(e, delta, mask, omega, arrival, xs):
        if windows == "even":
            # xs carries the per-job Even slack share (slack_even / l).
            sizes = jnp.where(mask, e + xs[:, None], 0.0)[None]
        else:
            sizes = waterfill(e, delta, mask, omega, xs)
        cum = jnp.cumsum(sizes, axis=2)
        ends = arrival[None, :, None] + cum
        first = jnp.broadcast_to(arrival[None, :, None],
                                 sizes.shape[:2] + (1,))
        starts = jnp.concatenate([first, ends[:, :, :-1]], axis=2)
        # The raw waterfill sizes ride along: recomputing them as
        # ends - starts would round-trip through the cumsum and inflate the
        # f32 noise ~L-fold, blowing the policy-(12) knife-edge guards
        # (every fully-capped task sits EXACTLY at f(beta_0) = 0).
        return sizes, starts, ends

    def groups(z, delta, mask, sizes, plan_of_akey, b0_of_akey,
               avail, akey_of_group):
        sizes_a = sizes[plan_of_akey]                   # (Ga, J, L)
        b0 = b0_of_akey[:, None, None]
        if avail.ndim == 4:                             # (Ga, S, J, L)
            sizes_a = sizes_a[:, None]
            b0 = b0[:, None]
        # Broadcast up front: a counts rule need not touch every operand
        # (naive = min(avail, delta) ignores the sizes), but the group
        # gather below indexes axis 0 as the akey axis, so r must carry
        # the full combined shape.
        shape = jnp.broadcast_shapes(sizes_a.shape, jnp.shape(avail),
                                     z.shape)
        r = jnp.broadcast_to(
            jnp.where(mask, counts_fn(z, delta, sizes_a, b0, avail), 0.0),
            shape)
        z_t = jnp.maximum(z - r * sizes_a, 0.0)
        z_t = jnp.where(z_t <= _DEVICE_DUST * (z + 1.0), 0.0, z_t)
        d_eff = jnp.maximum(delta - r, 0.0)
        so_work = jnp.minimum(r * sizes_a, z).sum(axis=-1)
        so_res = (r * sizes_a).sum(axis=-1)
        gi = akey_of_group
        return (r[gi], z_t[gi], d_eff[gi], r[gi] > 0,
                so_work[gi], so_res[gi])

    def full(e, delta, mask, omega, arrival, z, xs, plan_of_akey,
             b0_of_akey, avail, akey_of_group):
        sizes, starts, ends = plans(e, delta, mask, omega, arrival, xs)
        return (starts, ends) + groups(z, delta, mask, sizes,
                                       plan_of_akey, b0_of_akey, avail,
                                       akey_of_group)

    return {"plans": jax.jit(plans), "groups": jax.jit(groups),
            "full": jax.jit(full)}


def _build_grid_plan_device(jobs, policies, s: _GridStructure, arrays,
                            r_total, windows, selfowned, availability,
                            jobs_fp: str = "",
                            use_cache: bool = False,
                            mesh_part=None) -> GridPlan:
    import jax
    import jax.numpy as jnp

    # Same validation the host waterfill performs (device code would
    # silently clamp instead of raising).
    if np.any(arrays.omega < -1e-9):
        raise ValueError("infeasible job: window < critical path")
    if windows == "even":
        xs = np.maximum(arrays.slack_even(), 0.0) / arrays.l
    else:
        xs = np.fromiter(s.key_param.values(), dtype=np.float64)
        if np.any((xs <= 0.0) | (xs > 1.0)):
            bad = xs[(xs <= 0.0) | (xs > 1.0)][0]
            raise ValueError(f"Dealloc parameter must be in (0, 1], got {bad}")
    fns = _device_plan_fns(selfowned, windows)

    if availability is None or r_total <= 0:
        return _device_query_free(jobs, policies, s, arrays, r_total,
                                  windows, selfowned, xs, fns, jobs_fp,
                                  use_cache, mesh_part=mesh_part)
    plan_of_akey = np.asarray(s.a_plan, np.int32)
    b0 = np.asarray([np.nan if b is None else b for b in s.a_beta0])
    akey_of_group = np.asarray(s.g_akey, np.int32)
    plans_args = (arrays.e, arrays.delta, arrays.mask, arrays.omega,
                  arrays.arrival, xs)
    record_jit("plan.device.plans", fns["plans"], *plans_args)
    with span("plan", plan_backend="device", windows=windows) as sp:
        sizes, starts, ends = jax.block_until_ready(
            fns["plans"](*plans_args))
    plan_seconds = sp.seconds
    # Availability queries are host callables: stage the planned windows
    # out once, query per distinct (plan, beta_0) cell, ship back.
    with span("pool", plan_backend="device") as sp:
        h_starts, h_ends = np.asarray(starts), np.asarray(ends)
        if isinstance(availability, (list, tuple)):
            avail = np.stack([[q(h_starts[p], h_ends[p])
                               for q in availability]
                              for p in plan_of_akey])
        else:
            avail = np.stack([availability(h_starts[p], h_ends[p])
                              for p in plan_of_akey])
        group_args = (arrays.z, arrays.delta, arrays.mask, sizes,
                      plan_of_akey, b0, jnp.asarray(avail),
                      akey_of_group)
        record_jit("plan.device.groups", fns["groups"], *group_args)
        parts = jax.block_until_ready(fns["groups"](*group_args))
    pool_seconds = sp.seconds

    nan = np.full(len(jobs), np.nan)
    dev_plans = [PlanBatch(arrival=arrays.arrival, starts=starts[w],
                           ends=ends[w], z=arrays.z, delta=arrays.delta,
                           mask=arrays.mask, bid=nan, beta0=nan)
                 for w in range(len(s.key_param))]
    r_g, z_t_g, d_eff_g, pins_g, so_w_g, so_r_g = parts
    # The self-owned stats are consumed host-side only (the EngineResult
    # scatter); ship the two small stacks across once here instead of one
    # device sync per group later. Everything the cost kernels read
    # (ends/starts, z_t, d_eff, pins) stays on device.
    so_w_g, so_r_g = np.asarray(so_w_g), np.asarray(so_r_g)
    groups = [EvalGroup(plan=dev_plans[s.a_plan[s.g_akey[gi]]],
                        policy_idx=np.asarray(s.g_pols[gi]),
                        bid=s.g_bid[gi], r_alloc=r_g[gi], z_t=z_t_g[gi],
                        d_eff=d_eff_g[gi], pins=pins_g[gi],
                        selfowned_work=so_w_g[gi],
                        selfowned_reserved=so_r_g[gi])
              for gi in range(len(s.g_bid))]
    return GridPlan(jobs=jobs, policies=policies, groups=groups,
                    workload=arrays.z.sum(axis=1), arrival=arrays.arrival,
                    n_jobs=len(jobs), n_policies=len(policies),
                    L=arrays.z.shape[1], plan_seconds=plan_seconds,
                    pool_seconds=pool_seconds, plan_backend="device",
                    jobs_fp=jobs_fp, group_keys=list(s.g_key))


def _device_query_free(jobs, policies, s: _GridStructure, arrays, r_total,
                       windows, selfowned, xs, fns, jobs_fp: str,
                       use_cache: bool, mesh_part=None) -> GridPlan:
    """The default (query-free) device plan path, cache-aware.

    Misses run through the SAME fused jit program as before, over the
    SUBSET of window params / akeys / groups they need — on a cold cache
    the subset is the full grid, so the traced shapes (and therefore the
    compiled programs) are identical to the uncached path. On an all-hit
    call no device program runs at all.
    """
    import jax

    base = (jobs_fp, float(r_total), windows, selfowned, "device",
            mesh_part)
    cached, miss = _cache_lookup(s, base, use_cache)
    need_ai = sorted({s.g_akey[gi] for gi in miss})
    ai_pos = {ai: i for i, ai in enumerate(need_ai)}
    need_w = sorted({s.a_plan[ai] for ai in need_ai})
    w_pos = {w: i for i, w in enumerate(need_w)}
    if windows == "even":
        xs_sub = xs                         # per-job slack, single plan
    else:
        xs_sub = xs[np.asarray(need_w, np.intp)]
    plan_of_akey = np.asarray([w_pos[s.a_plan[ai]] for ai in need_ai],
                              np.int32)
    b0 = np.asarray([np.nan if s.a_beta0[ai] is None else s.a_beta0[ai]
                     for ai in need_ai])
    akey_of_group = np.asarray([ai_pos[s.g_akey[gi]] for gi in miss],
                               np.int32)

    if miss:
        full_args = (arrays.e, arrays.delta, arrays.mask, arrays.omega,
                     arrays.arrival, arrays.z, xs_sub, plan_of_akey, b0,
                     float(max(r_total, 0)), akey_of_group)
        record_jit("plan.device.full", fns["full"], *full_args)
    with span("plan", plan_backend="device", windows=windows,
              n_cached=len(cached)) as sp:
        if miss:
            # The fused program: no host staging between windows and
            # residuals.
            out = jax.block_until_ready(fns["full"](*full_args))
    plan_seconds = sp.seconds

    new_groups: dict[int, EvalGroup] = {}
    if miss:
        (starts, ends), parts = out[:2], out[2:]
        nan = np.full(len(jobs), np.nan)
        dev_plans = [PlanBatch(arrival=arrays.arrival, starts=starts[i],
                               ends=ends[i], z=arrays.z, delta=arrays.delta,
                               mask=arrays.mask, bid=nan, beta0=nan)
                     for i in range(starts.shape[0])]
        r_g, z_t_g, d_eff_g, pins_g, so_w_g, so_r_g = parts
        # The self-owned stats are consumed host-side only (the
        # EngineResult scatter); ship the two small stacks across once
        # here instead of one device sync per group later. Everything the
        # cost kernels read (ends/starts, z_t, d_eff, pins) stays on
        # device.
        so_w_g, so_r_g = np.asarray(so_w_g), np.asarray(so_r_g)
        for k, gi in enumerate(miss):
            g = EvalGroup(plan=dev_plans[w_pos[s.a_plan[s.g_akey[gi]]]],
                          policy_idx=np.asarray(s.g_pols[gi]),
                          bid=s.g_bid[gi], r_alloc=r_g[k], z_t=z_t_g[k],
                          d_eff=d_eff_g[k], pins=pins_g[k],
                          selfowned_work=so_w_g[k],
                          selfowned_reserved=so_r_g[k])
            new_groups[gi] = g
            if use_cache:
                _cache.PLAN_CACHE.put((base, s.g_key[gi]), g)
    groups = [
        dataclasses.replace(cached[gi], policy_idx=np.asarray(s.g_pols[gi]))
        if gi in cached else new_groups[gi]
        for gi in range(len(s.g_bid))]
    return GridPlan(jobs=jobs, policies=policies, groups=groups,
                    workload=arrays.z.sum(axis=1), arrival=arrays.arrival,
                    n_jobs=len(jobs), n_policies=len(policies),
                    L=arrays.z.shape[1], plan_seconds=plan_seconds,
                    pool_seconds=0.0, plan_backend="device",
                    plan_cached=len(cached), jobs_fp=jobs_fp,
                    group_keys=list(s.g_key))
