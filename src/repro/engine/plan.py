"""Plan layer of the evaluation engine.

Turns (jobs x policies) into a deduplicated batch of *evaluation groups*.
The key observation: the padded ``PlanBatch`` (the canonical interchange
type) depends on a policy only through its Dealloc parameter, the
self-owned allocation only through (plan, beta_0), and the market
realization additionally through the bid. Policies sharing the triple
(window key, beta_0, bid) are therefore EXACT duplicates of one another
and collapse into one group — the paper's C1 x C2 x B grid of 175 policies
reduces to 35 distinct evaluations because every beta >= beta_0 drives
Dealloc with beta_0 (Alg. 2 lines 1-5).

The plan layer is itself part of the array program: the window plans for
ALL distinct Dealloc parameters come out of ONE vectorized
``build_plans_batch`` pass over the padded (G, J, L) tensor
(``core.dealloc.window_sizes_batch``, bit-identical to the legacy per-job
loop), so plan construction scales with the deduplicated grid, not with
n_policies x n_jobs Python iterations.

Every backend (numpy / jax / pallas) consumes the same ``GridPlan``; all
market-independent arithmetic (self-owned counts, cloud residual workloads,
pins) happens here exactly once, in float64 numpy, so backends only differ
in how they realize the spot market. When ``availability`` is a *list* of
per-scenario queries (TOLA's batched pool refinement), the self-owned
arrays gain a leading scenario axis — groups carry (S, J, L) tensors and
backends pair scenario s with slice s.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.scheduler import (
    PlanBatch,
    Policy,
    _allocate_pool,
    _selfowned_counts_vec,
    build_plans_batch,
    job_arrays,
)
from repro.core.types import ChainJob

__all__ = ["EvalGroup", "GridPlan", "build_grid_plan", "scenario_cat",
           "distinct_window_params"]


def scenario_cat(groups, attr: str, S: int) -> np.ndarray:
    """Concatenate a group attribute into an (S, R, L) scenario-major stack,
    broadcasting groups whose arrays are scenario-independent — the one
    place the per-scenario/shared mixing rule lives (both the jax and the
    pallas backend consume it)."""
    return np.concatenate(
        [np.broadcast_to(getattr(g, attr),
                         (S,) + g.plan.ends.shape) for g in groups], axis=1)


@dataclasses.dataclass
class EvalGroup:
    """One distinct (window plan, beta_0, bid) evaluation cell.

    ``policy_idx`` lists every policy of the original grid that this group
    realizes. The self-owned arrays are (J, L) when market-independent and
    (S, J, L) when the caller supplied per-scenario availability queries
    (``per_scenario`` distinguishes the two).
    """

    plan: PlanBatch
    policy_idx: np.ndarray   # (k,) columns of the cost matrix this fills
    bid: float
    r_alloc: np.ndarray      # (J, L) | (S, J, L) self-owned instances
    z_t: np.ndarray          # (J, L) | (S, J, L) cloud workload after s-o
    d_eff: np.ndarray        # (J, L) | (S, J, L) cloud parallelism after s-o
    pins: np.ndarray         # bool — tasks holding reservations
    selfowned_work: np.ndarray      # (J,) | (S, J)
    selfowned_reserved: np.ndarray  # (J,) | (S, J)

    @property
    def per_scenario(self) -> bool:
        return self.z_t.ndim == 3


@dataclasses.dataclass
class GridPlan:
    """The full batched evaluation plan for (jobs x policies)."""

    jobs: list[ChainJob]
    policies: list[Policy]
    groups: list[EvalGroup]
    workload: np.ndarray     # (J,) Z_j
    arrival: np.ndarray      # (J,)
    n_jobs: int
    n_policies: int
    L: int
    plan_seconds: float = 0.0   # window-plan tensor construction
    pool_seconds: float = 0.0   # self-owned allocation + residuals

    @property
    def bids(self) -> list[float]:
        return sorted({g.bid for g in self.groups})

    @property
    def per_scenario(self) -> bool:
        return any(g.per_scenario for g in self.groups)

    def groups_for_bid(self, bid: float) -> list[EvalGroup]:
        return [g for g in self.groups if g.bid == bid]


def _window_key(policy: Policy, r_total: int, windows: str):
    if windows == "even":
        return ("even",)
    return ("dealloc", round(policy.dealloc_param(r_total), 12))


def distinct_window_params(policies, r_total: int,
                           windows: str = "dealloc") -> dict[tuple, float]:
    """Window-key dedup of a policy grid: {window key -> exact Dealloc param
    of the FIRST policy carrying it} in first-appearance order (the rounded
    key only dedups; the plan is always built from the exact parameter).
    The single source of the dedup rule — the engine, the pipeline
    benchmark, and the bit-compat tests all measure the same grid."""
    key_param: dict[tuple, float] = {}
    for pol in policies:
        wkey = _window_key(pol, r_total, windows)
        if wkey not in key_param:
            key_param[wkey] = (pol.dealloc_param(r_total)
                               if windows != "even" else 0.0)
    return key_param


def _cloud_residuals(plan: PlanBatch, r_alloc: np.ndarray):
    """The market-independent tail of ``_simulate_plan``: residual cloud
    workload (dust-killed), effective parallelism, pins, self-owned stats.
    ``r_alloc`` may carry a leading scenario axis; everything broadcasts."""
    sizes = plan.sizes
    z_t = np.maximum(plan.z - r_alloc * sizes, 0.0)
    z_t[z_t <= 1e-9 * (plan.z + 1.0)] = 0.0
    d_eff = np.maximum(plan.delta - r_alloc, 0.0)
    selfowned = np.minimum(r_alloc * sizes, plan.z)
    return z_t, d_eff, r_alloc > 0, selfowned.sum(axis=-1), \
        (r_alloc * sizes).sum(axis=-1)


def build_grid_plan(
    jobs: list[ChainJob],
    policies: list[Policy],
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    pool: str = "dedicated",
    availability=None,
    slots_per_unit: int = 12,
) -> GridPlan:
    """Deduplicate (jobs x policies) into evaluation groups.

    ``pool="dedicated"`` scores each policy against an uncontended pool (the
    counterfactual evaluator TOLA uses; ``availability`` optionally replaces
    the constant ``r_total`` with a realized residual-occupancy query, or a
    LIST of per-scenario queries — one per market scenario of the batch —
    for scenario-batched pool refinement).
    ``pool="shared"`` replays the chronological shared-pool allocation per
    policy (the realized ``run_jobs`` semantics used by fixed-policy sweeps).
    """
    if pool not in ("dedicated", "shared"):
        raise ValueError(f"unknown pool mode {pool!r}")
    J = len(jobs)

    t0 = time.perf_counter()
    key_param = distinct_window_params(policies, r_total, windows)
    arrays = job_arrays(jobs)
    if windows == "even":
        built = build_plans_batch(jobs, windows="even", arrays=arrays)
    else:
        built = build_plans_batch(jobs, list(key_param.values()),
                                  windows="dealloc", arrays=arrays)
    plans: dict[tuple, PlanBatch] = dict(zip(key_param, built))
    plan_seconds = time.perf_counter() - t0

    t0 = time.perf_counter()
    alloc: dict[tuple, np.ndarray] = {}
    group_of: dict[tuple, EvalGroup] = {}
    groups: list[EvalGroup] = []
    for pi, pol in enumerate(policies):
        wkey = _window_key(pol, r_total, windows)
        plan = plans[wkey]
        b0 = None if pol.beta0 is None else round(pol.beta0, 12)
        akey = wkey + (b0,)
        if akey not in alloc:
            alloc[akey] = _group_alloc(plan, pol, r_total, selfowned, pool,
                                       availability, slots_per_unit)
        gkey = akey + (round(pol.bid, 12),)
        if gkey in group_of:
            group_of[gkey].policy_idx = np.append(
                group_of[gkey].policy_idx, pi)
            continue
        r_alloc = alloc[akey]
        z_t, d_eff, pins, so_work, so_res = _cloud_residuals(plan, r_alloc)
        g = EvalGroup(plan=plan, policy_idx=np.array([pi]), bid=pol.bid,
                      r_alloc=r_alloc, z_t=z_t, d_eff=d_eff, pins=pins,
                      selfowned_work=so_work, selfowned_reserved=so_res)
        group_of[gkey] = g
        groups.append(g)
    pool_seconds = time.perf_counter() - t0
    some_plan = built[0]
    return GridPlan(jobs=jobs, policies=policies, groups=groups,
                    workload=some_plan.workload,
                    arrival=some_plan.arrival, n_jobs=J,
                    n_policies=len(policies), L=some_plan.z.shape[1],
                    plan_seconds=plan_seconds, pool_seconds=pool_seconds)


def _group_alloc(plan: PlanBatch, pol: Policy, r_total: int, selfowned: str,
                 pool: str, availability, slots_per_unit: int) -> np.ndarray:
    if r_total <= 0:
        return np.zeros_like(plan.z)
    beta0 = np.full(plan.z.shape[0],
                    np.nan if pol.beta0 is None else pol.beta0)
    if pool == "shared":
        # Chronological shared-pool replay on the planned windows; each
        # policy of a sweep owns a fresh pool (sweep semantics of run_jobs).
        # bid is deliberately NaN: the allocation is bid-independent (and is
        # cached per (windows, beta0) across bids) — if _allocate_pool ever
        # starts consulting the bid, this surfaces loudly and the alloc
        # cache key must gain the bid.
        pplan = dataclasses.replace(plan, beta0=beta0,
                                    bid=np.full(plan.z.shape[0], np.nan))
        r_alloc, _ = _allocate_pool(pplan, r_total, selfowned, slots_per_unit)
        return r_alloc
    if availability is None:
        avail = float(r_total)
    elif isinstance(availability, (list, tuple)):
        # Per-scenario residual-occupancy queries -> (S, J, L) availability.
        avail = np.stack([q(plan.starts, plan.ends) for q in availability])
    else:
        avail = availability(plan.starts, plan.ends)
    r_alloc = _selfowned_counts_vec(
        plan.z, plan.delta, plan.sizes, beta0[:, None], avail, selfowned)
    return np.where(plan.mask, r_alloc, 0.0)
