"""Backend-dispatching batched evaluation engine (DESIGN.md §6).

The system's hot loop — scoring every job under every policy of the TOLA
grid, across market scenarios — as one batched computation:

    from repro.engine import ScenarioSpec, evaluate_grid
    res = evaluate_grid(jobs, policies, markets, backend="auto")
    C = res.unit_cost[s]          # (n_jobs, n_policies) cost matrix

    # declarative scenario family, synthesized on device, streamed in
    # chunks of 256 — peak memory independent of S under reduce="mean"
    spec = ScenarioSpec("adversarial", horizon, n_scenarios=4096)
    res = evaluate_grid(jobs, policies, spec, scenario_chunk=256,
                        reduce="mean", backend="jax")

Layers: plan (``plan.py`` — deduplicated PlanBatch groups), backends
(``backend_{numpy,jax,pallas}.py``), scenarios (``scenarios.py`` —
declarative ``ScenarioSpec`` families + chunked ``ScenarioStream``s,
DESIGN.md §8).
"""

from repro.engine.api import (
    GridChunk,
    available_backends,
    evaluate_grid,
    evaluate_grid_chunks,
    resolve_backend,
    resolve_plan_backend,
)
from repro.engine.cache import (
    clear_caches,
    evaluate_grid_delta,
    jobs_fingerprint,
    scenario_fingerprint,
    setup_persistent_cache,
)
from repro.engine.cache import configure as configure_caches
from repro.engine.mesh import GridMesh, ScenarioMesh, as_scenario_mesh
from repro.engine.plan import EvalGroup, GridPlan, build_grid_plan
from repro.engine.result import EngineResult
from repro.engine.scenarios import (
    ScenarioBatch,
    ScenarioSpec,
    ScenarioStream,
    adversarial_scenarios,
    as_source,
    check_scenarios,
    make_scenarios,
    replay_scenarios,
    stack_views,
)

__all__ = [
    "evaluate_grid", "evaluate_grid_chunks", "GridChunk",
    "available_backends", "resolve_backend", "resolve_plan_backend",
    "evaluate_grid_delta", "clear_caches", "configure_caches",
    "jobs_fingerprint", "scenario_fingerprint", "setup_persistent_cache",
    "EngineResult", "EvalGroup", "GridPlan", "build_grid_plan",
    "GridMesh", "ScenarioMesh", "as_scenario_mesh",
    "ScenarioSpec", "ScenarioStream", "ScenarioBatch", "as_source",
    "make_scenarios", "adversarial_scenarios", "replay_scenarios",
    "check_scenarios", "stack_views",
]
