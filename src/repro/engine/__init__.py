"""Backend-dispatching batched evaluation engine (DESIGN.md §6).

The system's hot loop — scoring every job under every policy of the TOLA
grid, across market scenarios — as one batched computation:

    from repro.engine import evaluate_grid
    res = evaluate_grid(jobs, policies, markets, backend="auto")
    C = res.unit_cost[s]          # (n_jobs, n_policies) cost matrix

Layers: plan (``plan.py`` — deduplicated PlanBatch groups), backends
(``backend_{numpy,jax,pallas}.py``), scenarios (``scenarios.py`` — fresh /
regime-shifted / replay market families).
"""

from repro.engine.api import (
    available_backends,
    evaluate_grid,
    resolve_backend,
    resolve_plan_backend,
)
from repro.engine.plan import EvalGroup, GridPlan, build_grid_plan
from repro.engine.result import EngineResult
from repro.engine.scenarios import (
    adversarial_scenarios,
    check_scenarios,
    make_scenarios,
    replay_scenarios,
    stack_views,
)

__all__ = [
    "evaluate_grid", "available_backends", "resolve_backend",
    "resolve_plan_backend",
    "EngineResult", "EvalGroup", "GridPlan", "build_grid_plan",
    "make_scenarios", "adversarial_scenarios", "replay_scenarios",
    "check_scenarios", "stack_views",
]
