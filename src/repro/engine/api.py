"""``evaluate_grid`` — the single entry point of the evaluation engine.

Batches the TOLA counterfactual cost matrix (and every fixed-policy sweep)
across policies x bids x market scenarios and dispatches to a backend:

* ``numpy``  — float64 closed-form simulators from ``core/`` (exact oracle);
* ``jax``    — vectorized jnp (``kernels/ref.py``), scenario axis vmapped;
* ``pallas`` — the ``policy_cost_chain`` TPU kernel, ONE launch covering
  the whole (bid x scenario x policy x job) sweep;
* ``auto``   — pallas on TPU/GPU, numpy otherwise.

All backends consume the same deduplicated ``GridPlan`` (see ``plan.py``)
and fill the same (S, J, P) result tensors, so parity is testable cell by
cell (tests/test_engine.py).
"""

from __future__ import annotations

import os
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.market import SpotMarket
from repro.core.scheduler import Policy
from repro.core.types import ChainJob
from repro.engine.plan import build_grid_plan
from repro.engine.result import EngineResult
from repro.engine.scenarios import check_scenarios

__all__ = ["evaluate_grid", "available_backends", "resolve_backend"]

_BACKENDS = ("numpy", "jax", "pallas")


def available_backends() -> list[str]:
    """Backends usable in this process (jax/pallas need importable jax)."""
    out = ["numpy"]
    try:
        import jax  # noqa: F401
        out += ["jax", "pallas"]
    except Exception:
        pass
    return out


def resolve_backend(backend: str) -> str:
    """Resolve "auto" (env override REPRO_ENGINE_BACKEND honored first)."""
    if backend == "auto":
        backend = os.environ.get("REPRO_ENGINE_BACKEND", "auto")
    if backend == "auto":
        try:
            import jax
            return "pallas" if jax.default_backend() != "cpu" else "numpy"
        except Exception:
            return "numpy"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from "
                         f"{_BACKENDS + ('auto',)}")
    return backend


def evaluate_grid(
    jobs: list[ChainJob],
    policies: Sequence[Policy],
    markets: SpotMarket | Sequence[SpotMarket],
    r_total: int = 0,
    *,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool: str = "dedicated",
    availability: Callable | Sequence[Callable] | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
) -> EngineResult:
    """Evaluate every job under every policy in every market scenario.

    Returns an ``EngineResult`` whose ``unit_cost[s]`` is the (J, P) TOLA
    cost matrix for scenario s; per-cell cost decompositions and per-policy
    self-owned stats ride along. ``markets`` may be one ``SpotMarket`` or a
    sequence of scenario markets sharing a slot grid (see
    ``engine.scenarios``).

    ``pool`` selects the self-owned semantics: "dedicated" is the
    counterfactual evaluator (TOLA / Alg. 4 scoring, optionally against a
    realized ``availability`` query — one callable, or a list of S
    per-scenario callables for scenario-batched pool refinement, in which
    case the self-owned stats gain a leading scenario axis), "shared"
    replays the chronological shared-pool allocation per policy
    (fixed-policy sweep semantics of ``run_jobs``). ``interpret``
    forces/forbids pallas interpret mode (default: interpret off-TPU).
    """
    if not jobs:
        raise ValueError("need at least one job")
    policies = list(policies)
    if not policies:
        raise ValueError("need at least one policy")
    single = isinstance(markets, SpotMarket)
    market_list = [markets] if single else list(markets)
    if not market_list:
        raise ValueError("need at least one market scenario")
    check_scenarios(market_list)
    if isinstance(availability, (list, tuple)) \
            and len(availability) != len(market_list):
        raise ValueError(
            f"per-scenario availability needs one query per scenario "
            f"({len(availability)} queries, {len(market_list)} scenarios)")

    backend = resolve_backend(backend)
    gplan = build_grid_plan(
        jobs, policies, r_total, windows=windows, selfowned=selfowned,
        pool=pool, availability=availability,
        slots_per_unit=market_list[0].slots_per_unit)

    S, J, P = len(market_list), gplan.n_jobs, gplan.n_policies
    out = {k: np.zeros((S, J, P)) for k in
           ("spot_cost", "ondemand_cost", "spot_work", "ondemand_work")}
    t0 = time.perf_counter()
    if backend == "numpy":
        from repro.engine import backend_numpy
        backend_numpy.run(gplan, market_list, early_start, out)
    elif backend == "jax":
        from repro.engine import backend_jax
        backend_jax.run(gplan, market_list, early_start, out)
    else:
        from repro.engine import backend_pallas
        backend_pallas.run(gplan, market_list, early_start, out,
                           interpret=interpret)
    eval_seconds = time.perf_counter() - t0

    per_scenario = gplan.per_scenario
    so_shape = (S, J, P) if per_scenario else (J, P)
    selfowned_work = np.zeros(so_shape)
    selfowned_reserved = np.zeros(so_shape)
    for g in gplan.groups:
        sw, sr = g.selfowned_work, g.selfowned_reserved
        if per_scenario and not g.per_scenario:
            sw, sr = np.broadcast_to(sw, (S, J)), np.broadcast_to(sr, (S, J))
        selfowned_work[..., g.policy_idx] = sw[..., None]
        selfowned_reserved[..., g.policy_idx] = sr[..., None]

    total = out["spot_cost"] + out["ondemand_cost"]
    unit = total / np.maximum(gplan.workload, 1e-12)[None, :, None]
    return EngineResult(
        unit_cost=unit,
        spot_cost=out["spot_cost"],
        ondemand_cost=out["ondemand_cost"],
        spot_work=out["spot_work"],
        ondemand_work=out["ondemand_work"],
        workload=gplan.workload.copy(),
        selfowned_work=selfowned_work,
        selfowned_reserved=selfowned_reserved,
        backend=backend,
        single_market=single,
        timings={"plan": gplan.plan_seconds, "pool": gplan.pool_seconds,
                 "eval": eval_seconds},
    )
