"""``evaluate_grid`` — the single entry point of the evaluation engine.

Batches the TOLA counterfactual cost matrix (and every fixed-policy sweep)
across policies x bids x market scenarios and dispatches to a backend:

* ``numpy``  — float64 closed-form simulators from ``core/`` (exact oracle);
* ``jax``    — vectorized jnp (``kernels/ref.py``), scenario axis vmapped;
* ``pallas`` — the ``policy_cost_chain`` TPU kernel, ONE launch covering
  the whole (bid x scenario x policy x job) sweep;
* ``auto``   — pallas on TPU/GPU, numpy otherwise.

All backends consume the same deduplicated ``GridPlan`` (see ``plan.py``)
and fill the same (S, J, P) result tensors, so parity is testable cell by
cell (tests/test_engine.py).

The PLAN layer is backend-parametric too (``plan_backend``): ``"host"`` is
the float64 numpy oracle, ``"device"`` builds the plan tensors as one
fused jit program whose outputs the jax/pallas cost kernels consume
without a host staging copy. ``"auto"`` pairs the device plan with the
jax/pallas eval backends and the host plan with numpy.

The SCENARIO axis is a chunked stream (``scenarios.py``): ``scenarios``
may be a materialized market (list) or a declarative ``ScenarioSpec`` /
``ScenarioStream``, and ``scenario_chunk=K`` evaluates S >> host memory by
synthesizing+consuming K scenarios per pass against ONE grid plan — the
plan layer's dedup structure and the backends' compiled programs are
reused across chunks, and no per-scenario Python object exists on the
jax/pallas hot path (the spec synthesizes price paths on device).
``evaluate_grid_chunks`` exposes the same stream one chunk at a time
(the online-learning replay consumes it without ever materializing the
full (S, J, P) tensor, and adaptive-adversary feedback happens between
chunks).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Iterator, Sequence

import numpy as np

from repro.obs import METRICS, maybe_snapshot, span

from repro.core.market import SpotMarket
from repro.core.scheduler import Policy
from repro.core.types import ChainJob
from repro.engine.mesh import as_scenario_mesh
from repro.engine.plan import build_grid_plan
from repro.engine.result import EngineResult
from repro.engine.scenarios import as_source

__all__ = ["evaluate_grid", "evaluate_grid_chunks", "GridChunk",
           "available_backends", "resolve_backend", "resolve_plan_backend"]

_BACKENDS = ("numpy", "jax", "pallas")
_PLAN_BACKENDS = ("host", "device")
_REDUCES = ("stack", "mean")
_OUT_KEYS = ("spot_cost", "ondemand_cost", "spot_work", "ondemand_work")


def available_backends() -> list[str]:
    """Backends usable in this process.

    ``"jax"`` needs an importable jax; ``"pallas"`` additionally needs
    ``jax.experimental.pallas`` — probed for real (some jax builds ship
    without it), so ``--backend pallas`` fails at selection time with a
    clear message instead of mid-run.
    """
    out = ["numpy"]
    try:
        import jax  # noqa: F401
    except Exception:
        return out
    out.append("jax")
    try:
        import jax.experimental.pallas  # noqa: F401
        out.append("pallas")
    except Exception:
        pass
    return out


def resolve_backend(backend: str) -> str:
    """Resolve "auto" (env override REPRO_ENGINE_BACKEND honored first)."""
    if backend == "auto":
        env = os.environ.get("REPRO_ENGINE_BACKEND", "auto")
        if env not in _BACKENDS + ("auto",):
            # Validated separately from the caller's argument: the generic
            # "unknown backend" error below would blame the caller's
            # "auto" for a bad environment value.
            raise ValueError(
                f"invalid REPRO_ENGINE_BACKEND={env!r} environment "
                f"override; pick from {_BACKENDS + ('auto',)}")
        backend = env
    if backend == "auto":
        avail = available_backends()
        try:
            import jax
            on_accel = jax.default_backend() != "cpu"
        except Exception:
            return "numpy"
        if on_accel:
            return "pallas" if "pallas" in avail else "jax"
        return "numpy"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from "
                         f"{_BACKENDS + ('auto',)}")
    avail = available_backends()
    if backend not in avail:
        why = ("jax imports but jax.experimental.pallas does not"
               if backend == "pallas" and "jax" in avail
               else "jax is not importable in this environment")
        raise ValueError(f"backend {backend!r} is unavailable ({why}); "
                         f"available backends: {avail}")
    return backend


def resolve_plan_backend(plan_backend: str, backend: str,
                         pool: str = "dedicated") -> str:
    """Resolve the plan-layer backend.

    ``"auto"`` follows the (already resolved) eval backend: device plan
    tensors for jax/pallas, host float64 for numpy. The shared-pool replay
    and environments without jax stay on host. Explicit ``"device"`` with
    an incompatible combination raises instead of silently degrading.
    """
    if plan_backend == "auto":
        if backend in ("jax", "pallas") and pool != "shared" \
                and "jax" in available_backends():
            return "device"
        return "host"
    if plan_backend not in _PLAN_BACKENDS:
        raise ValueError(f"unknown plan backend {plan_backend!r}; pick from "
                         f"{_PLAN_BACKENDS + ('auto',)}")
    if plan_backend == "device":
        if backend == "numpy":
            raise ValueError(
                "plan_backend='device' feeds device tensors to the "
                "jax/pallas eval backends; the numpy oracle is host-only "
                "(use plan_backend='host')")
        if pool == "shared":
            raise ValueError(
                "plan_backend='device' supports pool='dedicated' only (the "
                "chronological shared-pool replay is host code)")
        if "jax" not in available_backends():
            raise ValueError("plan_backend='device' requires importable jax")
    return plan_backend


def _check_scenario_chunk(scenario_chunk) -> None:
    """API-boundary validation of ``scenario_chunk`` (same care the
    ``REPRO_ENGINE_BACKEND`` override got: fail HERE, naming the argument,
    not deep in a backend with an opaque shape error)."""
    if scenario_chunk is None:
        return
    if isinstance(scenario_chunk, bool) \
            or not isinstance(scenario_chunk, (int, np.integer)):
        raise ValueError(
            f"scenario_chunk must be an int >= 1 or None "
            f"(got {scenario_chunk!r})")
    if scenario_chunk < 1:
        raise ValueError(
            f"scenario_chunk must be >= 1 (got {scenario_chunk}); pass "
            f"None to evaluate all scenarios in one pass")


def _prepare_stream(jobs, policies, scenarios, r_total, windows, selfowned,
                    pool, availability, backend, plan_backend,
                    scenario_chunk, mesh=None, overlap=None):
    """Shared validation + plan build of the chunked evaluation paths.

    Returns ``(source, gplan, backend, chunk, single, mesh, overlap)`` —
    the grid plan is built ONCE and reused across every scenario chunk (it
    is scenario-independent apart from the per-scenario availability case,
    which requires a single full-batch chunk)."""
    if not jobs:
        raise ValueError("need at least one job")
    policies = list(policies)
    if not policies:
        raise ValueError("need at least one policy")
    single = isinstance(scenarios, SpotMarket)
    source = as_source(scenarios)
    S = source.n_scenarios
    _check_scenario_chunk(scenario_chunk)
    chunk = S if scenario_chunk is None else min(int(scenario_chunk), S)
    if chunk < S and isinstance(availability, (list, tuple)):
        raise ValueError(
            "scenario_chunk cannot split a batch with per-scenario "
            "availability queries (the plan's self-owned tensors are "
            "indexed by the full scenario axis); evaluate in one chunk")

    mesh = as_scenario_mesh(mesh)
    if mesh is not None:
        # The sharded scenario axis is a jax-backend feature: "auto"
        # resolves straight to jax; explicit numpy/pallas cannot consume a
        # mesh and fail here, at the argument that names the conflict.
        backend = "jax" if backend == "auto" else backend
        backend = resolve_backend(backend)
        if backend != "jax":
            raise ValueError(
                f"mesh= shards the scenario axis of the jax backend; "
                f"backend {backend!r} cannot consume a ScenarioMesh "
                f"(drop mesh= or pass backend='jax'/'auto')")
        # Per-scenario availability (refined plans) IS shardable: the
        # (S, R, L) self-owned stacks shard over "data" alongside the
        # views, group rows over "model" — see backend_jax's ps path.
    else:
        backend = resolve_backend(backend)

    if overlap is None:
        overlap = backend != "numpy" and not source.reactive
    elif overlap and source.reactive:
        raise ValueError(
            "overlap=True cannot double-buffer a reactive (adaptive) "
            "scenario stream: chunk k+1's spikes are planned from feedback "
            "about chunk k, so its synthesis cannot be dispatched early")
    overlap = bool(overlap)

    plan_backend = resolve_plan_backend(plan_backend, backend, pool)
    gplan = build_grid_plan(
        jobs, policies, r_total, windows=windows, selfowned=selfowned,
        pool=pool, availability=availability,
        slots_per_unit=source.slots_per_unit,
        n_scenarios=S, plan_backend=plan_backend, mesh=mesh)
    return source, gplan, backend, chunk, single, mesh, overlap


def _dispatch(backend, gplan, batch, early_start, out, interpret,
              mesh=None) -> None:
    if backend == "numpy":
        from repro.engine import backend_numpy
        backend_numpy.run(gplan, batch, early_start, out)
    elif backend == "jax":
        from repro.engine import backend_jax
        backend_jax.run(gplan, batch, early_start, out, mesh=mesh)
    else:
        from repro.engine import backend_pallas
        backend_pallas.run(gplan, batch, early_start, out,
                           interpret=interpret)


def _prefetched(stream):
    """Double-buffer a chunk stream: DISPATCH chunk k+1's (async, device)
    synthesis before yielding chunk k, so it computes while the consumer
    evaluates k. Lookahead depth 1 — at most two chunks of synthesis
    output are live at once, keeping the chunk-sized-memory contract."""
    prev = None
    for item in stream:
        item[2].dispatch()
        if prev is not None:
            yield prev
        prev = item
    if prev is not None:
        yield prev


@dataclasses.dataclass
class GridChunk:
    """One scenario chunk of a streamed grid evaluation.

    ``unit_cost[k]`` is the (J, P) cost matrix of GLOBAL scenario
    ``s0 + k``; ``out`` carries the per-cell cost decomposition of the
    chunk. The arrays are chunk-sized — a consumer that only folds them
    (regret accumulation, scenario-mean reduction) never holds the full
    (S, J, P) tensor.
    """

    s0: int
    s1: int
    unit_cost: np.ndarray          # (s1 - s0, J, P)
    out: dict                      # per-cell cost decomposition, chunk-sized
    workload: np.ndarray           # (J,)
    timings: dict                  # {"synth": s, "eval": s, "overlap": bool}


def evaluate_grid_chunks(
    jobs: list[ChainJob],
    policies: Sequence[Policy],
    scenarios,
    r_total: int = 0,
    *,
    scenario_chunk: int | None = None,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool: str = "dedicated",
    availability: Callable | Sequence[Callable] | None = None,
    backend: str = "auto",
    plan_backend: str = "auto",
    interpret: bool | None = None,
    mesh=None,
    overlap: bool | None = None,
) -> Iterator[GridChunk]:
    """Stream the grid evaluation one scenario chunk at a time.

    Same contract as :func:`evaluate_grid` (one grid plan, same backends,
    same per-scenario results), but yields ``GridChunk`` objects instead of
    assembling the (S, J, P) tensor — peak memory is chunk-sized. Between
    ``next()`` calls the caller may invoke ``source.observe(...)`` on an
    adaptive ``ScenarioStream``: the generator builds each chunk lazily
    AFTER the previous one was consumed, which is exactly the chunk
    boundary the adaptive adversary's feedback round-trip is defined at.

    ``mesh`` shards the scenario axis over a device mesh (jax backend
    only — see :func:`evaluate_grid`); ``overlap`` double-buffers chunk
    synthesis (default: on for non-numpy backends, off for reactive
    adaptive streams, whose chunks cannot be prefetched).

    Validation (and the plan build) runs EAGERLY at the call, not at the
    first ``next()`` — a bad ``scenario_chunk`` fails here, at the call
    site it names.
    """
    with span("prepare_stream"):
        source, gplan, backend, chunk, _, mesh, overlap = _prepare_stream(
            jobs, policies, scenarios, r_total, windows, selfowned, pool,
            availability, backend, plan_backend, scenario_chunk, mesh,
            overlap)

    def _iter():
        J, P = gplan.n_jobs, gplan.n_policies
        wl = np.maximum(gplan.workload, 1e-12)
        stream = source.chunks(chunk, device=(backend != "numpy"),
                               mesh=mesh)
        if overlap:
            stream = _prefetched(stream)
        for ci, (s0, s1, batch) in enumerate(stream):
            with span("chunk", index=ci, s0=s0, s1=s1, backend=backend):
                with span("synth", s0=s0, s1=s1, overlap=overlap) as sp_s:
                    batch.prepare()
                out = {k: np.zeros((s1 - s0, J, P)) for k in _OUT_KEYS}
                with span("eval", s0=s0, s1=s1, backend=backend) as sp_e:
                    _dispatch(backend, gplan, batch, early_start, out,
                              interpret, mesh)
            synth_t, eval_t = sp_s.seconds, sp_e.seconds
            _chunk_metrics(backend, synth_t, eval_t)
            unit = (out["spot_cost"] + out["ondemand_cost"]) \
                / wl[None, :, None]
            yield GridChunk(s0=s0, s1=s1, unit_cost=unit, out=out,
                            workload=gplan.workload.copy(),
                            timings={"synth": synth_t, "eval": eval_t,
                                     "overlap": overlap})

    return _iter()


def _chunk_metrics(backend, synth_t, eval_t):
    if METRICS.enabled:
        h = METRICS.histogram("engine.chunk_seconds")
        h.observe(synth_t, phase="synth", backend=backend)
        h.observe(eval_t, phase="eval", backend=backend)


def evaluate_grid(
    jobs: list[ChainJob],
    policies: Sequence[Policy],
    scenarios,
    r_total: int = 0,
    *,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool: str = "dedicated",
    availability: Callable | Sequence[Callable] | None = None,
    backend: str = "auto",
    plan_backend: str = "auto",
    interpret: bool | None = None,
    scenario_chunk: int | None = None,
    reduce: str = "stack",
    mesh=None,
    overlap: bool | None = None,
) -> EngineResult:
    """Evaluate every job under every policy in every market scenario.

    Returns an ``EngineResult`` whose ``unit_cost[s]`` is the (J, P) TOLA
    cost matrix for scenario s; per-cell cost decompositions and per-policy
    self-owned stats ride along. ``scenarios`` may be one ``SpotMarket``, a
    sequence of scenario markets sharing a slot grid, or a declarative
    ``ScenarioSpec`` / ``ScenarioStream`` (see ``engine.scenarios``) whose
    price paths are synthesized on demand — on device for the jax/pallas
    backends, with no per-scenario Python objects on the hot path.

    ``scenario_chunk=K`` evaluates the scenario axis K scenarios per pass
    against one shared grid plan (chunk results are bit-identical to the
    monolithic pass — chunking changes memory, not arithmetic);
    ``reduce="mean"`` folds the chunks into the scenario-mean cost tensor
    (shape (1, J, P), ``n_scenarios_total`` keeps S) so peak host memory is
    independent of S. ``timings["synth"]`` reports scenario-synthesis
    seconds and ``timings["chunks"]`` the per-chunk split.

    ``pool`` selects the self-owned semantics: "dedicated" is the
    counterfactual evaluator (TOLA / Alg. 4 scoring, optionally against a
    realized ``availability`` query — one callable, or a list of S
    per-scenario callables for scenario-batched pool refinement, in which
    case the self-owned stats gain a leading scenario axis and the batch
    cannot be chunked), "shared" replays the chronological shared-pool
    allocation per policy (fixed-policy sweep semantics of ``run_jobs``).
    ``plan_backend`` selects where the plan tensors are built (see
    :func:`resolve_plan_backend`); ``timings["plan_device"]`` reports the
    device-build seconds (0.0 on the host plan path). ``interpret``
    forces/forbids pallas interpret mode (default: interpret off-TPU).

    ``mesh`` shards the SCENARIO axis across a device mesh (DESIGN.md §9):
    pass a ``ScenarioMesh``, an int shard count (clamped to available
    devices with a warning), or a jax ``Mesh`` with a ``"data"`` axis.
    Mesh evaluation is a jax-backend feature ("auto" resolves to jax;
    numpy/pallas raise) — each shard synthesizes and scores only its own
    scenario slice, with no cross-device traffic in the compiled programs;
    a chunk whose scenario count is not divisible by the shard count is
    padded (last scenario repeated) and sliced back before results reach
    the caller, so results are independent of the mesh size (1-device mesh
    bitwise-identical to unsharded jax). ``overlap`` double-buffers chunk
    synthesis on the device paths: chunk k+1's synthesis is dispatched
    (async) before chunk k's evaluation blocks. Default: on for non-numpy
    backends, forced off for reactive adaptive streams (their chunks
    cannot be prefetched); ``timings["overlap"]`` records the resolved
    flag, and the per-chunk ``synth`` entries then measure the RESIDUAL
    wait, not the full synthesis time.
    """
    if reduce not in _REDUCES:
        raise ValueError(f"unknown reduce {reduce!r}; pick from {_REDUCES}")
    if reduce == "mean" and isinstance(availability, (list, tuple)):
        raise ValueError("reduce='mean' cannot fold per-scenario "
                         "availability results; use reduce='stack'")
    with span("evaluate_grid", reduce=reduce) as root:
        with span("prepare_stream"):
            source, gplan, backend, chunk, single, mesh, overlap = \
                _prepare_stream(
                    jobs, policies, scenarios, r_total, windows, selfowned,
                    pool, availability, backend, plan_backend,
                    scenario_chunk, mesh, overlap)
        S, J, P = source.n_scenarios, gplan.n_jobs, gplan.n_policies
        root.set(backend=backend, scenarios=S, overlap=overlap)

        if reduce == "stack":
            out = {k: np.zeros((S, J, P)) for k in _OUT_KEYS}
        else:
            acc = {k: np.zeros((J, P)) for k in _OUT_KEYS}
            buf = {k: np.zeros((chunk, J, P)) for k in _OUT_KEYS}
        chunk_timings: list[dict] = []
        synth_total = eval_total = 0.0
        # Mirrors evaluate_grid_chunks' loop ON PURPOSE: the stack path
        # writes backend output straight into the (S, J, P) slices —
        # layering on GridChunk would pay a full extra tensor copy per
        # chunk.
        stream = source.chunks(chunk, device=(backend != "numpy"),
                               mesh=mesh)
        if overlap:
            stream = _prefetched(stream)
        for ci, (s0, s1, batch) in enumerate(stream):
            with span("chunk", index=ci, s0=s0, s1=s1, backend=backend):
                with span("synth", s0=s0, s1=s1, overlap=overlap) as sp_s:
                    batch.prepare()
                synth_t = sp_s.seconds
                if reduce == "stack":
                    out_chunk = {k: v[s0:s1] for k, v in out.items()}
                else:
                    out_chunk = {k: v[:s1 - s0] for k, v in buf.items()}
                with span("eval", s0=s0, s1=s1, backend=backend) as sp_e:
                    _dispatch(backend, gplan, batch, early_start, out_chunk,
                              interpret, mesh)
                eval_t = sp_e.seconds
            if reduce == "mean":
                for k in _OUT_KEYS:
                    acc[k] += out_chunk[k].sum(axis=0)
            synth_total += synth_t
            eval_total += eval_t
            _chunk_metrics(backend, synth_t, eval_t)
            chunk_timings.append({"scenarios": [s0, s1], "synth": synth_t,
                                  "eval": eval_t})
        if reduce == "mean":
            out = {k: v[None] / S for k, v in acc.items()}
    if METRICS.enabled:
        METRICS.gauge("engine.scenarios_per_sec").set(
            S / max(root.seconds, 1e-12), backend=backend)

    per_scenario = gplan.per_scenario
    so_shape = (S, J, P) if per_scenario else (J, P)
    selfowned_work = np.zeros(so_shape)
    selfowned_reserved = np.zeros(so_shape)
    for g in gplan.groups:
        sw = np.asarray(g.selfowned_work)
        sr = np.asarray(g.selfowned_reserved)
        if per_scenario and not g.per_scenario:
            sw, sr = np.broadcast_to(sw, (S, J)), np.broadcast_to(sr, (S, J))
        selfowned_work[..., g.policy_idx] = sw[..., None]
        selfowned_reserved[..., g.policy_idx] = sr[..., None]

    # Delta-evaluation handle: recorded whenever the inputs have a
    # cross-call identity (fingerprintable scenarios, no availability
    # queries) and the full (S, J, P) stack is present to splice from.
    delta_state = None
    if reduce == "stack" and availability is None \
            and gplan.group_keys is not None:
        from repro.engine import cache as _cache
        sfp = _cache.scenario_fingerprint(scenarios)
        if sfp is not None:
            delta_state = {
                "jobs_fp": gplan.jobs_fp,
                "scenario_fp": sfp,
                "n_scenarios": S,
                "config": {"r_total": float(r_total), "windows": windows,
                           "selfowned": selfowned, "pool": pool,
                           "early_start": bool(early_start),
                           "backend": backend,
                           "plan_backend": gplan.plan_backend},
                "group_rep": {key: int(g.policy_idx[0])
                              for key, g in zip(gplan.group_keys,
                                                gplan.groups)},
            }

    total = out["spot_cost"] + out["ondemand_cost"]
    unit = total / np.maximum(gplan.workload, 1e-12)[None, :, None]
    return EngineResult(
        unit_cost=unit,
        spot_cost=out["spot_cost"],
        ondemand_cost=out["ondemand_cost"],
        spot_work=out["spot_work"],
        ondemand_work=out["ondemand_work"],
        workload=gplan.workload.copy(),
        selfowned_work=selfowned_work,
        selfowned_reserved=selfowned_reserved,
        backend=backend,
        single_market=single and reduce == "stack",
        n_scenarios_total=S,
        # plan_device: the jit plan-build seconds alone — on the staged
        # device path the pool phase is dominated by HOST work (the
        # availability-query callables), which must not masquerade as
        # device-build time.
        timings={"plan": gplan.plan_seconds, "pool": gplan.pool_seconds,
                 "eval": eval_total, "synth": synth_total,
                 "chunks": chunk_timings, "overlap": overlap,
                 "plan_cached": gplan.plan_cached,
                 "plan_device": (gplan.plan_seconds
                                 if gplan.device else 0.0)},
        obs=maybe_snapshot(),
        delta_state=delta_state,
    )
