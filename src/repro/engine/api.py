"""``evaluate_grid`` — the single entry point of the evaluation engine.

Batches the TOLA counterfactual cost matrix (and every fixed-policy sweep)
across policies x bids x market scenarios and dispatches to a backend:

* ``numpy``  — float64 closed-form simulators from ``core/`` (exact oracle);
* ``jax``    — vectorized jnp (``kernels/ref.py``), scenario axis vmapped;
* ``pallas`` — the ``policy_cost_chain`` TPU kernel, ONE launch covering
  the whole (bid x scenario x policy x job) sweep;
* ``auto``   — pallas on TPU/GPU, numpy otherwise.

All backends consume the same deduplicated ``GridPlan`` (see ``plan.py``)
and fill the same (S, J, P) result tensors, so parity is testable cell by
cell (tests/test_engine.py).

The PLAN layer is backend-parametric too (``plan_backend``): ``"host"`` is
the float64 numpy oracle, ``"device"`` builds the plan tensors as one
fused jit program whose outputs the jax/pallas cost kernels consume
without a host staging copy. ``"auto"`` pairs the device plan with the
jax/pallas eval backends and the host plan with numpy.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.market import SpotMarket
from repro.core.scheduler import Policy
from repro.core.types import ChainJob
from repro.engine.plan import build_grid_plan
from repro.engine.result import EngineResult
from repro.engine.scenarios import check_scenarios

__all__ = ["evaluate_grid", "available_backends", "resolve_backend",
           "resolve_plan_backend"]

_BACKENDS = ("numpy", "jax", "pallas")
_PLAN_BACKENDS = ("host", "device")


def available_backends() -> list[str]:
    """Backends usable in this process.

    ``"jax"`` needs an importable jax; ``"pallas"`` additionally needs
    ``jax.experimental.pallas`` — probed for real (some jax builds ship
    without it), so ``--backend pallas`` fails at selection time with a
    clear message instead of mid-run.
    """
    out = ["numpy"]
    try:
        import jax  # noqa: F401
    except Exception:
        return out
    out.append("jax")
    try:
        import jax.experimental.pallas  # noqa: F401
        out.append("pallas")
    except Exception:
        pass
    return out


def resolve_backend(backend: str) -> str:
    """Resolve "auto" (env override REPRO_ENGINE_BACKEND honored first)."""
    if backend == "auto":
        env = os.environ.get("REPRO_ENGINE_BACKEND", "auto")
        if env not in _BACKENDS + ("auto",):
            # Validated separately from the caller's argument: the generic
            # "unknown backend" error below would blame the caller's
            # "auto" for a bad environment value.
            raise ValueError(
                f"invalid REPRO_ENGINE_BACKEND={env!r} environment "
                f"override; pick from {_BACKENDS + ('auto',)}")
        backend = env
    if backend == "auto":
        avail = available_backends()
        try:
            import jax
            on_accel = jax.default_backend() != "cpu"
        except Exception:
            return "numpy"
        if on_accel:
            return "pallas" if "pallas" in avail else "jax"
        return "numpy"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from "
                         f"{_BACKENDS + ('auto',)}")
    avail = available_backends()
    if backend not in avail:
        why = ("jax imports but jax.experimental.pallas does not"
               if backend == "pallas" and "jax" in avail
               else "jax is not importable in this environment")
        raise ValueError(f"backend {backend!r} is unavailable ({why}); "
                         f"available backends: {avail}")
    return backend


def resolve_plan_backend(plan_backend: str, backend: str,
                         pool: str = "dedicated") -> str:
    """Resolve the plan-layer backend.

    ``"auto"`` follows the (already resolved) eval backend: device plan
    tensors for jax/pallas, host float64 for numpy. The shared-pool replay
    and environments without jax stay on host. Explicit ``"device"`` with
    an incompatible combination raises instead of silently degrading.
    """
    if plan_backend == "auto":
        if backend in ("jax", "pallas") and pool != "shared" \
                and "jax" in available_backends():
            return "device"
        return "host"
    if plan_backend not in _PLAN_BACKENDS:
        raise ValueError(f"unknown plan backend {plan_backend!r}; pick from "
                         f"{_PLAN_BACKENDS + ('auto',)}")
    if plan_backend == "device":
        if backend == "numpy":
            raise ValueError(
                "plan_backend='device' feeds device tensors to the "
                "jax/pallas eval backends; the numpy oracle is host-only "
                "(use plan_backend='host')")
        if pool == "shared":
            raise ValueError(
                "plan_backend='device' supports pool='dedicated' only (the "
                "chronological shared-pool replay is host code)")
        if "jax" not in available_backends():
            raise ValueError("plan_backend='device' requires importable jax")
    return plan_backend


def evaluate_grid(
    jobs: list[ChainJob],
    policies: Sequence[Policy],
    markets: SpotMarket | Sequence[SpotMarket],
    r_total: int = 0,
    *,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool: str = "dedicated",
    availability: Callable | Sequence[Callable] | None = None,
    backend: str = "auto",
    plan_backend: str = "auto",
    interpret: bool | None = None,
) -> EngineResult:
    """Evaluate every job under every policy in every market scenario.

    Returns an ``EngineResult`` whose ``unit_cost[s]`` is the (J, P) TOLA
    cost matrix for scenario s; per-cell cost decompositions and per-policy
    self-owned stats ride along. ``markets`` may be one ``SpotMarket`` or a
    sequence of scenario markets sharing a slot grid (see
    ``engine.scenarios``).

    ``pool`` selects the self-owned semantics: "dedicated" is the
    counterfactual evaluator (TOLA / Alg. 4 scoring, optionally against a
    realized ``availability`` query — one callable, or a list of S
    per-scenario callables for scenario-batched pool refinement, in which
    case the self-owned stats gain a leading scenario axis), "shared"
    replays the chronological shared-pool allocation per policy
    (fixed-policy sweep semantics of ``run_jobs``). ``plan_backend``
    selects where the plan tensors are built (see
    :func:`resolve_plan_backend`); ``timings["plan_device"]`` reports the
    device-build seconds (0.0 on the host plan path). ``interpret``
    forces/forbids pallas interpret mode (default: interpret off-TPU).
    """
    if not jobs:
        raise ValueError("need at least one job")
    policies = list(policies)
    if not policies:
        raise ValueError("need at least one policy")
    single = isinstance(markets, SpotMarket)
    market_list = [markets] if single else list(markets)
    if not market_list:
        raise ValueError("need at least one market scenario")
    check_scenarios(market_list)

    backend = resolve_backend(backend)
    plan_backend = resolve_plan_backend(plan_backend, backend, pool)
    gplan = build_grid_plan(
        jobs, policies, r_total, windows=windows, selfowned=selfowned,
        pool=pool, availability=availability,
        slots_per_unit=market_list[0].slots_per_unit,
        n_scenarios=len(market_list), plan_backend=plan_backend)

    S, J, P = len(market_list), gplan.n_jobs, gplan.n_policies
    out = {k: np.zeros((S, J, P)) for k in
           ("spot_cost", "ondemand_cost", "spot_work", "ondemand_work")}
    t0 = time.perf_counter()
    if backend == "numpy":
        from repro.engine import backend_numpy
        backend_numpy.run(gplan, market_list, early_start, out)
    elif backend == "jax":
        from repro.engine import backend_jax
        backend_jax.run(gplan, market_list, early_start, out)
    else:
        from repro.engine import backend_pallas
        backend_pallas.run(gplan, market_list, early_start, out,
                           interpret=interpret)
    eval_seconds = time.perf_counter() - t0

    per_scenario = gplan.per_scenario
    so_shape = (S, J, P) if per_scenario else (J, P)
    selfowned_work = np.zeros(so_shape)
    selfowned_reserved = np.zeros(so_shape)
    for g in gplan.groups:
        sw = np.asarray(g.selfowned_work)
        sr = np.asarray(g.selfowned_reserved)
        if per_scenario and not g.per_scenario:
            sw, sr = np.broadcast_to(sw, (S, J)), np.broadcast_to(sr, (S, J))
        selfowned_work[..., g.policy_idx] = sw[..., None]
        selfowned_reserved[..., g.policy_idx] = sr[..., None]

    total = out["spot_cost"] + out["ondemand_cost"]
    unit = total / np.maximum(gplan.workload, 1e-12)[None, :, None]
    return EngineResult(
        unit_cost=unit,
        spot_cost=out["spot_cost"],
        ondemand_cost=out["ondemand_cost"],
        spot_work=out["spot_work"],
        ondemand_work=out["ondemand_work"],
        workload=gplan.workload.copy(),
        selfowned_work=selfowned_work,
        selfowned_reserved=selfowned_reserved,
        backend=backend,
        single_market=single,
        # plan_device: the jit plan-build seconds alone — on the staged
        # device path the pool phase is dominated by HOST work (the
        # availability-query callables), which must not masquerade as
        # device-build time.
        timings={"plan": gplan.plan_seconds, "pool": gplan.pool_seconds,
                 "eval": eval_seconds,
                 "plan_device": (gplan.plan_seconds
                                 if gplan.device else 0.0)},
    )
