"""Cross-call reuse layer of the evaluation engine (DESIGN.md §11).

The production workload is *many near-identical grids over time*: the
paper's parametric policy family is re-scored continually as the market
moves, so successive ``evaluate_grid`` calls share most of their
(Dealloc param, beta_0, bid) evaluation groups, their scenario views and
their compiled programs. This module makes the repeated call the fast
path:

* ``PLAN_CACHE`` — cross-call LRU of built ``EvalGroup`` records, keyed on
  the SAME dedup signature the plan layer uses within one grid
  (window key, rounded beta_0, ``round(bid, 12)``) plus the jobs
  fingerprint, pool configuration, and — when a ``GridMesh`` is in play —
  the mesh's (data, model) shard partition, so a warm hit only ever hands
  back buffers built for the identical sharding and stays bitwise.
  ``plan.build_grid_plan`` consults it per *group*, so a second call with
  an overlapping grid rebuilds only the new groups (and a
  fully-overlapping one rebuilds nothing).
* ``VIEW_CACHE`` — cross-call LRU of stacked scenario views keyed on
  (spec, chunk range, device, ``round(bid, 12)``); the per-batch memo in
  ``scenarios.ScenarioBatch.stacked`` dies with the batch, this one
  survives across ``evaluate_grid`` / ``replay_stream`` invocations.
  Feedback-driven (adaptive) chunks and meshed batches bypass it by
  construction — their views depend on state outside the key.
* ``evaluate_grid_delta`` — incremental evaluation: diff the new policy
  grid against the group signatures recorded on a previous
  ``EngineResult`` and re-score ONLY the new/changed groups, splicing the
  cached cost columns for the rest (bitwise-equal to a full re-eval on the
  numpy oracle; the scored groups are independent cells by construction).
* ``setup_persistent_cache`` — wires jax's persistent compilation cache so
  warm-DISK restarts skip XLA compiles too (used by ``launch/serve.py``
  and the benchmark harness; never enabled implicitly — a cold-vs-warm
  benchmark must stay honest).

Keys never hold raw floats that the plan layer would round: the bid enters
every key through ``plan._bid_key`` (``round(bid, 12)``), so two bids
differing below 1e-12 hit the SAME entry bitwise — the cross-call twin of
the PR 4 in-grid dedup rule.

``REPRO_ENGINE_CACHE=0`` (or ``configure(enabled=False)`` /
``disabled()``) turns the cross-call caches off; cache-on and cache-off
results are bitwise-identical per backend (tests/test_cache.py).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import os

import numpy as np

from repro.obs import METRICS, maybe_snapshot

__all__ = [
    "PLAN_CACHE", "VIEW_CACHE", "enabled", "configure", "disabled",
    "clear_caches", "jobs_fingerprint", "scenario_fingerprint",
    "evaluate_grid_delta", "setup_persistent_cache",
]

_CacheInfo = collections.namedtuple(
    "CacheInfo", ["hits", "misses", "maxsize", "currsize"])


class _LRU:
    """Bounded insertion/recency-ordered cache with eviction stats.

    Exposes ``cache_info()`` with the ``functools.lru_cache`` field layout
    (plus an ``evictions`` attribute) so ``obs.compiled.factory_caches``
    can report it through the same duck-typed hook as the jit factory
    caches. When ``metric`` is set, evictions emit
    ``<metric>{event=evict}`` through ``obs.METRICS``.
    """

    def __init__(self, maxsize: int, metric: str | None = None):
        self.maxsize = int(maxsize)
        self.metric = metric
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            return default
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        if self.maxsize <= 0:
            return
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1
            if self.metric and METRICS.enabled:
                METRICS.counter(self.metric).inc(event="evict")

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def cache_info(self) -> _CacheInfo:
        return _CacheInfo(self.hits, self.misses, self.maxsize,
                          len(self._data))

    def clear(self) -> None:
        """Drop entries AND counters — a cleared cache reports like a
        fresh one (tests rely on counting from zero)."""
        self._data.clear()
        self.hits = self.misses = self.evictions = 0

    def resize(self, maxsize: int) -> None:
        self.maxsize = int(maxsize)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1


# A worst-case EvalGroup at J=512 is a few hundred KB of plan/pool
# tensors; 1024 entries bound the plan cache to a few hundred MB while
# covering many concurrent policy grids. Stacked views are
# (chunk, L)-sized per bid; 128 chunk-range entries cover a steady-state
# serving loop replaying the same spec windows.
PLAN_CACHE = _LRU(1024, metric="engine.plan_cache")
VIEW_CACHE = _LRU(128, metric="engine.view_cache")

_ENABLED_OVERRIDE: bool | None = None


def enabled() -> bool:
    """Cross-call caching on? ``configure(enabled=...)`` wins over the
    ``REPRO_ENGINE_CACHE`` environment toggle (``0`` disables)."""
    if _ENABLED_OVERRIDE is not None:
        return _ENABLED_OVERRIDE
    return os.environ.get("REPRO_ENGINE_CACHE", "1") != "0"


def configure(enabled: bool | None = None, plan_maxsize: int | None = None,
              view_maxsize: int | None = None) -> None:
    """Adjust the cross-call cache layer in-process.

    ``enabled=None`` leaves the current toggle; maxsize changes evict LRU
    entries immediately (counted as evictions).
    """
    global _ENABLED_OVERRIDE
    if enabled is not None:
        _ENABLED_OVERRIDE = bool(enabled)
    if plan_maxsize is not None:
        PLAN_CACHE.resize(plan_maxsize)
    if view_maxsize is not None:
        VIEW_CACHE.resize(view_maxsize)


@contextlib.contextmanager
def disabled():
    """Scoped cache-off (the cache-on/off parity tests run their oracle
    leg under this)."""
    global _ENABLED_OVERRIDE
    prev = _ENABLED_OVERRIDE
    _ENABLED_OVERRIDE = False
    try:
        yield
    finally:
        _ENABLED_OVERRIDE = prev


def clear_caches() -> None:
    """Drop every cross-call entry (plan groups and scenario views)."""
    PLAN_CACHE.clear()
    VIEW_CACHE.clear()


def plan_cache_events(hits: int = 0, misses: int = 0) -> None:
    """Emit the plan-cache hit/miss counters (one labeled series,
    DESIGN.md §11; evictions are emitted by the cache itself)."""
    if not METRICS.enabled or not (hits or misses):
        return
    c = METRICS.counter("engine.plan_cache")
    if hits:
        c.inc(float(hits), event="hit")
    if misses:
        c.inc(float(misses), event="miss")


# --------------------------------------------------------------------------
# Fingerprints: the invalidation half of the cache key contract.
# --------------------------------------------------------------------------

def _hash_arrays(h, arrays) -> None:
    for f in dataclasses.fields(arrays):
        v = getattr(arrays, f.name)
        h.update(f.name.encode())
        if isinstance(v, np.ndarray):
            h.update(str(v.dtype).encode())
            h.update(np.ascontiguousarray(v).tobytes())
        else:
            h.update(repr(v).encode())


def fingerprint_job_arrays(arrays) -> str:
    """Content hash of a ``JobArrays`` batch — every field the plan layer
    reads, so any change to the job set invalidates its cache entries."""
    h = hashlib.sha1()
    _hash_arrays(h, arrays)
    return h.hexdigest()


def jobs_fingerprint(jobs) -> str:
    """Content hash of a job list (via its canonical array form)."""
    from repro.core.scheduler import job_arrays

    return fingerprint_job_arrays(job_arrays(jobs))


def scenario_fingerprint(scenarios):
    """Hashable identity of a scenario input, or None when it has none.

    A ``ScenarioSpec`` is its own fingerprint (frozen dataclass — equal
    specs synthesize equal markets). Materialized markets hash their price
    paths and slot grid. Reactive/adaptive streams return None: their
    chunks depend on feedback, so no cross-call identity exists and delta
    evaluation refuses them.
    """
    from repro.core.market import SpotMarket
    from repro.engine.scenarios import ScenarioSpec

    if isinstance(scenarios, ScenarioSpec):
        return scenarios
    if isinstance(scenarios, SpotMarket):
        scenarios = [scenarios]
    if isinstance(scenarios, (list, tuple)) and scenarios \
            and all(isinstance(m, SpotMarket) for m in scenarios):
        h = hashlib.sha1()
        for m in scenarios:
            h.update(np.ascontiguousarray(m.price, np.float64).tobytes())
            h.update(np.float64(m.slot).tobytes())
            h.update(np.int64(m.slots_per_unit).tobytes())
            h.update(np.float64(m.p_ondemand).tobytes())
        return h.hexdigest()
    return None


# --------------------------------------------------------------------------
# Incremental (delta) grid evaluation.
# --------------------------------------------------------------------------

def evaluate_grid_delta(prev, jobs, policies, scenarios, r_total: int = 0, *,
                        windows: str = "dealloc", selfowned: str = "prop12",
                        early_start: bool = True, pool: str = "dedicated",
                        backend: str | None = None,
                        plan_backend: str | None = None,
                        interpret: bool | None = None,
                        scenario_chunk: int | None = None,
                        mesh=None, overlap: bool | None = None):
    """Re-evaluate a policy grid incrementally against a previous result.

    Diffs the new grid's evaluation groups (the plan layer's
    (window key, beta_0, ``round(bid, 12)``) dedup signature) against the
    groups recorded on ``prev.delta_state``, re-scores ONLY the new/changed
    groups through :func:`repro.engine.evaluate_grid`, and splices the
    unchanged cost columns straight out of ``prev``'s tensors. The result
    is bitwise-equal to a full re-eval on the numpy oracle (each group is
    an independent evaluation cell) and float-level (<=1e-5) on jax/pallas.

    ``prev`` must come from a ``reduce="stack"`` ``evaluate_grid`` call
    over the SAME jobs, scenarios and pool configuration (validated via
    the fingerprints on ``prev.delta_state``; mismatches raise naming the
    offending input). The number of re-scored groups is emitted as the
    ``engine.delta_groups_rescored`` counter and returned in
    ``timings["delta_groups_rescored"]``.
    """
    from repro.engine.api import evaluate_grid
    from repro.engine.plan import _grid_structure
    from repro.engine.result import EngineResult

    st = getattr(prev, "delta_state", None)
    if st is None:
        raise ValueError(
            "prev carries no delta_state: delta evaluation needs a "
            "reduce='stack' evaluate_grid result over a fingerprintable "
            "scenario input (ScenarioSpec or materialized markets) with "
            "availability=None")
    cfg = st["config"]
    mismatches = [
        f"{name}: prev {cfg[name]!r} vs call {got!r}"
        for name, got in (("r_total", float(r_total)), ("windows", windows),
                          ("selfowned", selfowned), ("pool", pool),
                          ("early_start", bool(early_start)))
        if cfg[name] != got]
    if mismatches:
        raise ValueError(
            "delta evaluation config differs from prev's; re-scoring only "
            "changed groups would be wrong for: " + "; ".join(mismatches))
    if jobs_fingerprint(jobs) != st["jobs_fp"]:
        raise ValueError(
            "jobs changed since prev was computed (fingerprint mismatch); "
            "every group depends on the job set — run a full evaluate_grid")
    sfp = scenario_fingerprint(scenarios)
    if sfp is None or sfp != st["scenario_fp"]:
        raise ValueError(
            "scenarios changed since prev was computed (or are not "
            "fingerprintable); every group depends on the market "
            "realizations — run a full evaluate_grid")
    backend = cfg["backend"] if backend is None else backend
    plan_backend = cfg["plan_backend"] if plan_backend is None else \
        plan_backend

    policies = list(policies)
    s = _grid_structure(policies, r_total, windows)
    n_groups = len(s.g_bid)
    rep = st["group_rep"]
    changed = [gi for gi in range(n_groups) if s.g_key[gi] not in rep]

    S = prev.n_scenarios_total
    J = prev.unit_cost.shape[1]
    P = len(policies)
    keys = ("spot_cost", "ondemand_cost", "spot_work", "ondemand_work")
    out = {k: np.zeros((S, J, P)) for k in keys}
    so_work = np.zeros((J, P))
    so_res = np.zeros((J, P))

    for gi in range(n_groups):
        key = s.g_key[gi]
        if key not in rep:
            continue
        col = rep[key]
        pols = s.g_pols[gi]
        for k in keys:
            out[k][:, :, pols] = getattr(prev, k)[:, :, col][:, :, None]
        so_work[:, pols] = prev.selfowned_work[:, col][:, None]
        so_res[:, pols] = prev.selfowned_reserved[:, col][:, None]

    timings = {"delta_groups_rescored": len(changed),
               "delta_groups_total": n_groups}
    if changed:
        # One representative policy per changed group: the group tensors
        # depend on the policy only through its dedup signature, so the
        # representative's columns are every member's columns.
        rep_pols = [policies[s.g_pols[gi][0]] for gi in changed]
        inner = evaluate_grid(
            jobs, rep_pols, scenarios, r_total, windows=windows,
            selfowned=selfowned, early_start=early_start, pool=pool,
            backend=backend, plan_backend=plan_backend, interpret=interpret,
            scenario_chunk=scenario_chunk, reduce="stack", mesh=mesh,
            overlap=overlap)
        for i, gi in enumerate(changed):
            pols = s.g_pols[gi]
            for k in keys:
                out[k][:, :, pols] = getattr(inner, k)[:, :, i][:, :, None]
            so_work[:, pols] = inner.selfowned_work[:, i][:, None]
            so_res[:, pols] = inner.selfowned_reserved[:, i][:, None]
        backend = inner.backend
        for k in ("plan", "pool", "eval", "synth"):
            timings[k] = inner.timings.get(k, 0.0)
        timings["plan_cached"] = inner.timings.get("plan_cached", 0)
    if METRICS.enabled:
        METRICS.counter("engine.delta_groups_rescored").inc(
            float(len(changed)))

    workload = prev.workload.copy()
    total = out["spot_cost"] + out["ondemand_cost"]
    unit = total / np.maximum(workload, 1e-12)[None, :, None]
    return EngineResult(
        unit_cost=unit,
        spot_cost=out["spot_cost"],
        ondemand_cost=out["ondemand_cost"],
        spot_work=out["spot_work"],
        ondemand_work=out["ondemand_work"],
        workload=workload,
        selfowned_work=so_work,
        selfowned_reserved=so_res,
        backend=backend,
        single_market=prev.single_market,
        n_scenarios_total=S,
        timings=timings,
        obs=maybe_snapshot(),
        delta_state={
            "jobs_fp": st["jobs_fp"],
            "scenario_fp": st["scenario_fp"],
            "n_scenarios": S,
            "config": dict(cfg, backend=backend,
                           plan_backend=plan_backend),
            "group_rep": {s.g_key[gi]: int(s.g_pols[gi][0])
                          for gi in range(n_groups)},
        },
    )


# --------------------------------------------------------------------------
# Persistent (warm-disk) XLA compilation cache.
# --------------------------------------------------------------------------

def setup_persistent_cache(path: str | None = None) -> str | None:
    """Point jax's persistent compilation cache at ``path`` and enable it.

    Resolution order: explicit argument, ``REPRO_JAX_CACHE_DIR``, then
    ``~/.cache/repro-jax``. Thresholds are lowered so even the small CPU
    programs of the test grids persist. Best-effort by design: returns the
    cache directory on success and None when jax is missing or too old —
    a numpy-only environment must not crash on import of its launcher.
    """
    path = path or os.environ.get("REPRO_JAX_CACHE_DIR") \
        or os.path.join(os.path.expanduser("~"), ".cache", "repro-jax")
    try:
        import jax

        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_enable_compilation_cache", True)
    except Exception:
        return None
    # Persist-everything thresholds (absent on some jax versions).
    for key, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                     ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(key, val)
        except Exception:
            pass
    return path
