"""Exact numpy backend — the oracle path.

Reuses the float64 closed-form simulators in ``repro.core.simulate``
verbatim, one call per (scenario, evaluation group). Bit-identical to the
legacy per-policy ``evaluate_policy_fullpool`` / ``run_jobs`` loops (same
code, same order of operations); the jax and pallas backends are tested
against it.
"""

from __future__ import annotations

import numpy as np

from repro.core.simulate import simulate_chains_early, simulate_tasks

__all__ = ["run"]


def run(gplan, batch, early_start: bool, out) -> None:
    """Fill the (S, J, P) arrays in ``out`` for every scenario and group.

    ``batch`` is a ``ScenarioBatch`` (one chunk of the scenario stream);
    the oracle consumes its materialized ``markets`` — for a spec chunk
    these are lazily wrapped from the f64 oracle prices, bit-exact with the
    fully materialized list path.
    """
    if getattr(gplan, "device", False):
        raise ValueError(
            "the numpy oracle backend requires a host (float64) grid plan; "
            "build it with plan_backend='host'")
    for s, market in enumerate(batch.markets):
        for g in gplan.groups:
            view = market.view(float(g.bid))
            plan = g.plan
            # Self-owned arrays are (S, J, L) when the caller supplied
            # per-scenario availability queries; scenario s sees slice s.
            z_t = g.z_t[s] if g.per_scenario else g.z_t
            d_eff = g.d_eff[s] if g.per_scenario else g.d_eff
            pins = g.pins[s] if g.per_scenario else g.pins
            if early_start:
                sim = simulate_chains_early(
                    view, plan.arrival, plan.ends, z_t, d_eff,
                    selfowned_pins=pins, p_ondemand=market.p_ondemand)
                sc, oc = sim.spot_cost, sim.ondemand_cost
                sw, ow = sim.spot_work, sim.ondemand_work
            else:
                fl = plan.mask.ravel()
                sim = simulate_tasks(
                    view, plan.starts.ravel()[fl], plan.ends.ravel()[fl],
                    z_t.ravel()[fl], d_eff.ravel()[fl],
                    market.p_ondemand)
                owner = np.repeat(np.arange(gplan.n_jobs),
                                  plan.mask.sum(axis=1))
                sc = np.zeros(gplan.n_jobs); oc = np.zeros(gplan.n_jobs)
                sw = np.zeros(gplan.n_jobs); ow = np.zeros(gplan.n_jobs)
                np.add.at(sc, owner, sim.spot_cost)
                np.add.at(oc, owner, sim.ondemand_cost)
                np.add.at(sw, owner, sim.spot_work)
                np.add.at(ow, owner, sim.ondemand_work)
            cols = g.policy_idx
            out["spot_cost"][s][:, cols] = sc[:, None]
            out["ondemand_cost"][s][:, cols] = oc[:, None]
            out["spot_work"][s][:, cols] = sw[:, None]
            out["ondemand_work"][s][:, cols] = ow[:, None]
