"""Scenario x policy-group device mesh (DESIGN.md §9).

The scenario axis is embarrassingly parallel — each scenario's price path,
per-bid views, and counterfactual costs are independent; only the regret
fold crosses scenarios — so sharding it is pure data parallelism along a
mesh axis named ``"data"``.  The eval-group axis (bid x policy-group rows
of the grid plan) is *also* independent per group, so grids whose group
axis dwarfs S (exp1's 175-policy sweeps) shard it along a second mesh axis
named ``"model"`` — the same data/model two-axis decomposition as
``launch/mesh.py``'s production meshes.  Logical axes ``scenario ->
"data"`` and ``group -> "model"`` are routed through the
``distributed/sharding.py`` rule table; a 1-wide ``"model"`` axis
reproduces the 1-D behavior bitwise.

``GridMesh`` is hashable (it keys the backends' compiled-program caches)
and owns the padding contract for BOTH axes:

* scenario axis — a chunk of K scenarios is padded to ``pad(K)`` rows,
  the LAST row repeated, so every ``"data"`` shard holds the same row
  count;
* group axis — the eval-group list is padded to ``pad_groups(G)`` entries,
  the LAST group repeated, so every ``"model"`` shard owns the same number
  of whole groups.

Padded lanes carry real (duplicated) data, are masked out of every
reduction, and are sliced off at the splice before results reach the
caller (:func:`edge_repeat` / the ``[:K]`` and ``[:, :G]`` slices).  See
DESIGN.md §9 for the placement diagram.

This module imports jax lazily so ``repro.engine`` stays importable in
environments without it (the numpy oracle path).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import numpy as np

__all__ = [
    "GridMesh", "ScenarioMesh", "as_scenario_mesh", "pad_to", "edge_repeat",
]

_OVERRIDES = {"scenario": "data", "group": "model", "bid": None}

# Once-per-process clamp-warning keys: (requested data, requested model,
# visible devices).  A sweep that builds the same over-subscribed mesh in
# every cell warns exactly once per distinct request shape.
_CLAMP_WARNED: set[tuple[int, int, int]] = set()


def pad_to(k: int, n: int) -> int:
    """Smallest multiple of ``n`` that is ``>= k`` (the padded lane count)."""
    return -(-k // n) * n


def edge_repeat(a: np.ndarray, rows: int) -> np.ndarray:
    """Pad the leading axis to ``rows`` by repeating the last entry.

    The padding contract for both mesh axes: padded lanes are real
    (duplicated) data, never NaN/zero filler, so every shard computes a
    well-posed problem and the splice just drops the extra lanes.
    """
    k = a.shape[0]
    if rows == k:
        return a
    if rows < k:
        raise ValueError(f"cannot pad {k} rows down to {rows}")
    reps = np.repeat(a[-1:], rows - k, axis=0)
    return np.concatenate([a, reps], axis=0)


@dataclasses.dataclass(frozen=True)
class GridMesh:
    """A 2-D ``("data", "model")`` mesh plus its logical-axis rule table.

    Frozen and hashable — ``backend_jax`` and the learn-fold cache one
    compiled ``shard_map`` program per (mesh, shape) key.  A 1-D raw
    ``"data"`` mesh (or ``model_devices=1``) degrades to pure scenario
    data-parallelism, bitwise identical to the pre-2-D behavior.
    """

    mesh: Any                 # jax.sharding.Mesh (hashable)
    rules: Any                # distributed.sharding.ShardingRules

    @classmethod
    def create(cls, n_devices: int | None = None,
               model_devices: int = 1) -> "GridMesh":
        """Mesh of ``n_devices x model_devices``, clamped to what exists.

        ``n_devices`` (default: all remaining after the model axis) shards
        the scenario axis as ``"data"``; ``model_devices`` shards the
        eval-group axis as ``"model"``.  Clamping warns (once per process
        per request shape) rather than raises so ``--mesh 8`` scripts run
        unchanged on a 1-device box (the 1x1 mesh is bit-identical to the
        unsharded path).
        """
        import jax

        from repro.distributed.sharding import ShardingRules
        from repro.launch.mesh import make_mesh

        avail = len(jax.devices())
        m = int(model_devices)
        if m < 1:
            raise ValueError(
                f"mesh needs >= 1 model device (got {model_devices})")
        n = max(avail // m, 1) if n_devices is None else int(n_devices)
        if n < 1:
            raise ValueError(f"mesh needs >= 1 device (got {n_devices})")
        if n * m > avail:
            key = (n, m, avail)
            if key not in _CLAMP_WARNED:
                _CLAMP_WARNED.add(key)
                warnings.warn(
                    f"requested a {n}x{m} ({n * m}-device) scenario x group "
                    f"mesh but only {avail} device(s) are visible — "
                    f"clamping to {avail} (set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=N to "
                    f"fake N host devices on CPU)", stacklevel=2)
            m = min(m, avail)
            n = max(avail // m, 1)
        shape, axes = ((n, m), ("data", "model")) if m > 1 else \
            ((n,), ("data",))
        mesh = make_mesh(shape, axes)
        rules = ShardingRules.create(mesh, overrides=_OVERRIDES)
        return cls(mesh=mesh, rules=rules)

    @property
    def n_shards(self) -> int:
        return self.mesh.devices.size

    @property
    def data_shards(self) -> int:
        """Shards along the scenario (``"data"``) axis."""
        return self.mesh.shape["data"]

    @property
    def model_shards(self) -> int:
        """Shards along the eval-group (``"model"``) axis (1 on 1-D meshes)."""
        return self.mesh.shape.get("model", 1)

    def pad(self, k: int) -> int:
        """Rows after padding k scenarios to a multiple of ``data_shards``."""
        return pad_to(k, self.data_shards)

    def pad_groups(self, g: int) -> int:
        """Entries after padding g eval groups to a multiple of
        ``model_shards`` (whole groups per ``"model"`` shard)."""
        return pad_to(g, self.model_shards)

    def spec(self, *logical_axes: str | None):
        """PartitionSpec through the rule table (``"scenario" -> "data"``,
        ``"group" -> "model"``)."""
        return self.rules.spec(*logical_axes)

    def sharding(self, *logical_axes: str | None):
        """NamedSharding placing the named logical axes on this mesh."""
        from jax.sharding import NamedSharding

        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def pad_rows(self, a: np.ndarray) -> np.ndarray:
        """Pad a leading-scenario host array to ``pad(len)`` rows (repeat
        the last row — real data, masked/sliced away downstream)."""
        return edge_repeat(a, self.pad(a.shape[0]))

    def put_rows(self, a):
        """Pad + device_put a leading-scenario array sharded over the mesh
        (``"data"`` only; replicated over ``"model"``)."""
        import jax

        return jax.device_put(self.pad_rows(np.asarray(a)),
                              self.sharding("scenario"))


# PR 6 name; every ``mesh=`` call site accepts both.  The 1-D scenario
# mesh IS a GridMesh with a 1-wide (absent) "model" axis.
ScenarioMesh = GridMesh


def as_scenario_mesh(mesh) -> GridMesh | None:
    """Normalize every accepted ``mesh=`` argument.

    Accepts ``None`` (unsharded), a ``GridMesh``/``ScenarioMesh``, an int
    (scenario-shard count, clamped to available devices), or a raw jax
    ``Mesh`` whose axes include ``"data"`` (a ``"model"`` axis, when
    present, shards the eval-group axis).
    """
    if mesh is None or isinstance(mesh, GridMesh):
        return mesh
    if isinstance(mesh, bool):
        raise ValueError(f"mesh must be None, an int shard count, a "
                         f"GridMesh, or a jax Mesh (got {mesh!r})")
    if isinstance(mesh, (int, np.integer)):
        return GridMesh.create(int(mesh))
    try:
        from jax.sharding import Mesh
    except Exception as e:  # pragma: no cover - jax-less environment
        raise ValueError(
            "mesh= requires importable jax (the sharded scenario axis is "
            "a jax-backend feature)") from e
    if isinstance(mesh, Mesh):
        if "data" not in mesh.axis_names:
            raise ValueError(
                f"scenario mesh needs a 'data' axis (got axes "
                f"{tuple(mesh.axis_names)}); build one with "
                f"GridMesh.create(n) or make_mesh((n,), ('data',))")
        from repro.distributed.sharding import ShardingRules

        rules = ShardingRules.create(mesh, overrides=_OVERRIDES)
        return GridMesh(mesh=mesh, rules=rules)
    raise ValueError(f"mesh must be None, an int shard count, a "
                     f"GridMesh, or a jax Mesh (got {type(mesh)})")
