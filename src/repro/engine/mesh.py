"""Scenario-axis device mesh (DESIGN.md §9).

The scenario axis is embarrassingly parallel — each scenario's price path,
per-bid views, and counterfactual costs are independent; only the regret
fold crosses scenarios — so sharding it is pure data parallelism: a 1-D
mesh whose single axis is named ``"data"`` (matching ``launch/mesh.py``'s
production meshes, where a future 2-D scenario x bid layout would add the
``"model"`` axis), with the logical axis ``scenario -> "data"`` routed
through the ``distributed/sharding.py`` rule table.

``ScenarioMesh`` is hashable (it keys the backends' compiled-program
caches) and owns the padding contract: a chunk of K scenarios is padded to
``pad(K)`` rows — the LAST row repeated — so every shard holds the same
row count; padded rows carry real (duplicated) scenario data, are masked
out of every reduction, and are sliced off before results reach the
caller. See DESIGN.md §9 for the placement diagram.

This module imports jax lazily so ``repro.engine`` stays importable in
environments without it (the numpy oracle path).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import numpy as np

__all__ = ["ScenarioMesh", "as_scenario_mesh"]


@dataclasses.dataclass(frozen=True)
class ScenarioMesh:
    """A 1-D ``"data"`` mesh over devices plus its logical-axis rule table.

    Frozen and hashable — ``backend_jax`` and the learn-fold cache one
    compiled ``shard_map`` program per (mesh, shape) key.
    """

    mesh: Any                 # jax.sharding.Mesh (hashable)
    rules: Any                # distributed.sharding.ShardingRules

    @classmethod
    def create(cls, n_devices: int | None = None) -> "ScenarioMesh":
        """Mesh over ``n_devices`` (default: all), clamped to what exists.

        Clamping warns rather than raises so ``--mesh 8`` scripts run
        unchanged on a 1-device box (the 1-device mesh is bit-identical to
        the unsharded path).
        """
        import jax

        from repro.distributed.sharding import ShardingRules
        from repro.launch.mesh import make_mesh

        avail = len(jax.devices())
        n = avail if n_devices is None else int(n_devices)
        if n < 1:
            raise ValueError(f"mesh needs >= 1 device (got {n_devices})")
        if n > avail:
            warnings.warn(
                f"requested a {n}-way scenario mesh but only {avail} "
                f"device(s) are visible — clamping to {avail} (set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N to "
                f"fake N host devices on CPU)", stacklevel=2)
            n = avail
        mesh = make_mesh((n,), ("data",))
        rules = ShardingRules.create(
            mesh, overrides={"scenario": "data", "bid": None})
        return cls(mesh=mesh, rules=rules)

    @property
    def n_shards(self) -> int:
        return self.mesh.devices.size

    def pad(self, k: int) -> int:
        """Rows after padding k scenarios to a multiple of the shard count."""
        n = self.n_shards
        return -(-k // n) * n

    def spec(self, *logical_axes: str | None):
        """PartitionSpec through the rule table (``"scenario" -> "data"``)."""
        return self.rules.spec(*logical_axes)

    def sharding(self, *logical_axes: str | None):
        """NamedSharding placing the named logical axes on this mesh."""
        from jax.sharding import NamedSharding

        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def pad_rows(self, a: np.ndarray) -> np.ndarray:
        """Pad a leading-scenario host array to ``pad(len)`` rows (repeat
        the last row — real data, masked/sliced away downstream)."""
        k = a.shape[0]
        kp = self.pad(k)
        if kp == k:
            return a
        reps = np.repeat(a[-1:], kp - k, axis=0)
        return np.concatenate([a, reps], axis=0)

    def put_rows(self, a):
        """Pad + device_put a leading-scenario array sharded over the mesh."""
        import jax

        return jax.device_put(self.pad_rows(np.asarray(a)),
                              self.sharding("scenario"))


def as_scenario_mesh(mesh) -> ScenarioMesh | None:
    """Normalize every accepted ``mesh=`` argument.

    Accepts ``None`` (unsharded), a ``ScenarioMesh``, an int (shard count,
    clamped to available devices), or a raw jax ``Mesh`` whose axes include
    ``"data"``.
    """
    if mesh is None or isinstance(mesh, ScenarioMesh):
        return mesh
    if isinstance(mesh, bool):
        raise ValueError(f"mesh must be None, an int shard count, a "
                         f"ScenarioMesh, or a jax Mesh (got {mesh!r})")
    if isinstance(mesh, (int, np.integer)):
        return ScenarioMesh.create(int(mesh))
    try:
        from jax.sharding import Mesh
    except Exception as e:  # pragma: no cover - jax-less environment
        raise ValueError(
            "mesh= requires importable jax (the sharded scenario axis is "
            "a jax-backend feature)") from e
    if isinstance(mesh, Mesh):
        if "data" not in mesh.axis_names:
            raise ValueError(
                f"scenario mesh needs a 'data' axis (got axes "
                f"{tuple(mesh.axis_names)}); build one with "
                f"ScenarioMesh.create(n) or make_mesh((n,), ('data',))")
        from repro.distributed.sharding import ShardingRules

        rules = ShardingRules.create(
            mesh, overrides={"scenario": "data", "bid": None})
        return ScenarioMesh(mesh=mesh, rules=rules)
    raise ValueError(f"mesh must be None, an int shard count, a "
                     f"ScenarioMesh, or a jax Mesh (got {type(mesh)})")
