"""Result container for the batched evaluation engine."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scheduler import StreamCosts

__all__ = ["EngineResult"]


@dataclasses.dataclass
class EngineResult:
    """Batched (scenario x job x policy) evaluation output.

    ``unit_cost[s, j, p]`` is the per-unit-workload cost of job j under
    policy p in market scenario s — the TOLA counterfactual cost matrix is
    ``unit_cost[s]``. The cost decomposition is kept per cell so callers can
    reconstruct full ``StreamCosts`` for any (scenario, policy) without
    re-simulating.
    """

    unit_cost: np.ndarray          # (S, J, P)
    spot_cost: np.ndarray          # (S, J, P)
    ondemand_cost: np.ndarray      # (S, J, P)
    spot_work: np.ndarray          # (S, J, P)
    ondemand_work: np.ndarray      # (S, J, P)
    workload: np.ndarray           # (J,)
    selfowned_work: np.ndarray     # (J, P); (S, J, P) with per-scenario
    selfowned_reserved: np.ndarray  # availability queries
    backend: str = "numpy"
    single_market: bool = False    # True when the caller passed one market
    # Scenarios EVALUATED — differs from the leading axis length only under
    # reduce="mean", where the arrays hold the scenario-mean (axis 1).
    n_scenarios_total: int | None = None
    # Phase wall seconds, derived from the repro.obs span tree (every
    # value IS some span's ``.seconds``; under an active ``obs.trace()``
    # the same floats appear in the exported trace, so the dict and the
    # span-derived totals agree bit-for-bit): "plan" (window tensors),
    # "pool" (self-owned + residuals; host availability queries on the
    # staged device path), "eval" (backend market realization, summed over
    # scenario chunks), "synth" (scenario price-path synthesis/
    # materialization, summed), "plan_device" (seconds the plan tensors
    # were built on device — 0.0 on the host plan path), "chunks" (the
    # per-chunk synth/eval split; the per-phase entries sum EXACTLY to the
    # phase totals), "overlap" (whether chunk synthesis was
    # double-buffered: chunk k+1 dispatched async before chunk k's eval
    # blocked — when True, "synth" measures only the RESIDUAL wait, so the
    # CONTRACT is synth(overlap=True) <= synth(overlap=False) on the same
    # workload, enforced by tests/test_obs.py; the win is the difference).
    # Always a dict — empty only for results built outside the engine.
    timings: dict = dataclasses.field(default_factory=dict)
    # Observability snapshot ({"metrics": ..., "compiled": ...}) captured
    # when an ``repro.obs`` collection context was active; None otherwise.
    obs: dict | None = None
    # Delta-evaluation handle (DESIGN.md §11): the jobs/scenario
    # fingerprints, resolved config and per-group dedup signatures this
    # result was computed under, consumed by ``evaluate_grid_delta`` to
    # re-score only changed groups. None when the inputs have no
    # cross-call identity (adaptive streams, availability queries,
    # reduce="mean").
    delta_state: dict | None = None

    @property
    def n_scenarios(self) -> int:
        return self.unit_cost.shape[0]

    @property
    def total_cost(self) -> np.ndarray:
        return self.spot_cost + self.ondemand_cost

    @property
    def matrix(self) -> np.ndarray:
        """(J, P) unit-cost matrix — requires a single scenario."""
        if self.unit_cost.shape[0] != 1:
            raise ValueError(
                f"matrix is ambiguous over {self.unit_cost.shape[0]} "
                "scenarios; index unit_cost[s] explicitly")
        return self.unit_cost[0]

    def avg_unit_cost(self) -> np.ndarray:
        """alpha[s, p] = sum_j c_j / sum_j Z_j (paper Section 6.1)."""
        return self.total_cost.sum(axis=1) / self.workload.sum()

    def best(self, s: int | None = None) -> tuple[int, float]:
        """(policy index, alpha) minimizing the (scenario-mean) stream cost."""
        alpha = self.avg_unit_cost()
        a = alpha.mean(axis=0) if s is None else alpha[s]
        p = int(np.argmin(a))
        return p, float(a[p])

    def stream_costs(self, p: int, s: int = 0) -> StreamCosts:
        """Per-job StreamCosts of policy p in scenario s."""
        so_w = self.selfowned_work if self.selfowned_work.ndim == 2 \
            else self.selfowned_work[s]
        so_r = self.selfowned_reserved if self.selfowned_reserved.ndim == 2 \
            else self.selfowned_reserved[s]
        return StreamCosts(
            spot_cost=self.spot_cost[s, :, p].copy(),
            ondemand_cost=self.ondemand_cost[s, :, p].copy(),
            spot_work=self.spot_work[s, :, p].copy(),
            ondemand_work=self.ondemand_work[s, :, p].copy(),
            selfowned_work=so_w[:, p].copy(),
            workload=self.workload.copy(),
            selfowned_reserved=so_r[:, p].copy(),
        )
