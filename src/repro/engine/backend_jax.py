"""Vectorized jnp backend.

One fused computation per bid: every evaluation group sharing the bid is
stacked into a (G*J,) row batch, the S market scenarios are vmapped over
the stacked cumulative arrays, and the chain recurrence runs as a
``lax.scan`` over the L planned windows (``kernels/ref.py::chain_costs_ref``).
Float32 (matches the pallas kernel); the numpy backend is the float64
oracle.

When the grid plan was built against per-scenario availability queries
(TOLA's batched pool refinement) the self-owned arrays (z_t, d_eff, pins)
are (S, R, L) stacks and the ``_ps`` entry points vmap them alongside the
market arrays; the common scenario-shared case keeps them closed over
(one host->device copy, no S-fold broadcast).

Device grid plans (``plan_backend="device"``) arrive as jax arrays and are
consumed directly — ``concat_rows``/``scenario_cat`` stack them with jnp,
so the plan tensors never take a host round trip between the plan jit and
the cost jit.

The jitted entry points live at module scope and take every plan array as
a traced argument, so repeated ``evaluate_grid`` calls reuse the compile
cache (one compilation per distinct batch shape, not per call).

Donation note (DESIGN.md §11): the eval entry points deliberately do NOT
use ``donate_argnums``. Their inputs are exactly the tensors the
cross-call caches keep alive — device plan arrays in ``PLAN_CACHE``
groups, stacked views in ``VIEW_CACHE`` — and the f32 conversions below
are aliases (``jnp.asarray`` on an already-f32 device array is a no-op),
so donating them would invalidate cached buffers mid-cache-lifetime.
There is also nothing to donate INTO: no output shares a donatable
input's shape+dtype (outputs are (S, R)-shaped cost dicts). The streamed
regret fold in ``learn/replay.py`` is where donation pays — its
accumulator is a genuine same-shape carry.

Sharded path (DESIGN.md §9): with a ``ScenarioMesh`` the same two batch
bodies are ``shard_map``ed over the scenario axis — stacked views arrive
padded and sharded (``ScenarioBatch.n_rows`` rows), plan arrays are
replicated, every shard scores only its own scenario slice, and the
compiled program contains ZERO cross-device collectives (the scenario
axis never reduces inside the cost tensor). Results are sliced back to
the valid scenario count on the host side of the scatter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.plan import concat_rows, scenario_cat
from repro.kernels.ref import chain_costs_ref, policy_cost_ref
from repro.obs import record_jit, span

__all__ = ["run"]


def _chain_body(A, C, arrival, ends, z_t, d_eff, pins, p_od, slot):
    """(S, n+1) stacked views x (R, L) row batch -> dict of (S, R)."""
    fn = jax.vmap(
        lambda a, c: chain_costs_ref(a, c, arrival, ends, z_t, d_eff, pins,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


def _task_body(A, C, starts, ends, z_t, d_eff, p_od, slot):
    """Planned-start (per-task) edition -> dict of (S, R*L)."""
    fn = jax.vmap(
        lambda a, c: policy_cost_ref(a, c, starts, ends, z_t, d_eff,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


_chain_batch = jax.jit(_chain_body)
_task_batch = jax.jit(_task_body)


@jax.jit
def _chain_batch_ps(A, C, arrival, ends, z_t, d_eff, pins, p_od, slot):
    """Per-scenario-plan edition: z_t/d_eff/pins are (S, R, L) stacks."""
    fn = jax.vmap(
        lambda a, c, z, d, p: chain_costs_ref(a, c, arrival, ends, z, d, p,
                                              p_od=p_od, slot=slot),
        in_axes=(0, 0, 0, 0, 0))
    return fn(A, C, z_t, d_eff, pins)


@jax.jit
def _task_batch_ps(A, C, starts, ends, z_t, d_eff, p_od, slot):
    """Planned-start with per-scenario (S, R*L) cloud workloads."""
    fn = jax.vmap(
        lambda a, c, z, d: policy_cost_ref(a, c, starts, ends, z, d,
                                           p_od=p_od, slot=slot),
        in_axes=(0, 0, 0, 0))
    return fn(A, C, z_t, d_eff)


@functools.lru_cache(maxsize=8)   # bounded: one entry per live mesh
def _sharded_fns(mesh):
    """The two batch bodies shard_map'ed over a ``ScenarioMesh``.

    Views (leading scenario axis) shard over ``"data"``; plan arrays and
    scalars replicate. Cached per mesh so repeated calls reuse the
    compiled program exactly like the unsharded module-scope jits.
    """
    from jax.experimental.shard_map import shard_map

    dp = mesh.spec("scenario")   # P("data")
    rp = mesh.spec()             # empty P(): replicated, any rank
    chain = jax.jit(shard_map(
        _chain_body, mesh=mesh.mesh,
        in_specs=(dp, dp, rp, rp, rp, rp, rp, rp, rp), out_specs=dp))
    task = jax.jit(shard_map(
        _task_body, mesh=mesh.mesh,
        in_specs=(dp, dp, rp, rp, rp, rp, rp, rp), out_specs=dp))
    return {"chain": chain, "task": task}


def run(gplan, batch, early_start: bool, out, mesh=None) -> None:
    slot = batch.slot
    p_od = batch.p_ondemand
    J = gplan.n_jobs
    S = batch.n_scenarios
    rows = batch.n_rows if mesh is not None else S
    ps = gplan.per_scenario
    if mesh is not None and ps:
        # api.py guards this combination; keep the invariant loud here too.
        raise ValueError("sharded evaluation does not support per-scenario "
                         "availability plans (full-batch, unsharded only)")
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    if mesh is not None:
        fns = _sharded_fns(mesh)
        chain_fn, task_fn = fns["chain"], fns["task"]
        scalar = jnp.float32
    else:
        chain_fn, task_fn = _chain_batch, _task_batch
        scalar = lambda x: x

    sfx = ":sharded" if mesh is not None else ""
    for bid in gplan.bids:
        groups = gplan.groups_for_bid(bid)
        with span("eval.bid", bid=bid, groups=len(groups)):
            # (rows, n_slots+1) stacked views, cached on the batch per
            # bid — already-f32 device tensors when the chunk was
            # synthesized on device (a spec source), host f64 otherwise;
            # padded + sharded under a mesh.
            A, C = batch.stacked(bid)
            A, C = f32(A), f32(C)
            ends = concat_rows([g.plan.ends for g in groups])
            if ps:
                z_t = scenario_cat(groups, "z_t", S)
                d_eff = scenario_cat(groups, "d_eff", S)
            else:
                z_t = concat_rows([g.z_t for g in groups])
                d_eff = concat_rows([g.d_eff for g in groups])
            if early_start:
                arrival = np.tile(gplan.arrival, len(groups))
                if ps:
                    pins = scenario_cat(groups, "pins", S)
                    args = (A, C, f32(arrival), f32(ends), f32(z_t),
                            f32(d_eff), jnp.asarray(pins), p_od, slot)
                    record_jit("engine.eval.chain_ps", _chain_batch_ps,
                               *args)
                    res = _chain_batch_ps(*args)
                else:
                    pins = concat_rows([g.pins for g in groups])
                    args = (A, C, f32(arrival), f32(ends), f32(z_t),
                            f32(d_eff), jnp.asarray(pins), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.chain" + sfx, chain_fn, *args)
                    res = chain_fn(*args)
            else:
                starts = concat_rows([g.plan.starts for g in groups])
                R, L = ends.shape
                if ps:
                    args = (A, C, f32(starts.ravel()), f32(ends.ravel()),
                            f32(z_t.reshape(S, R * L)),
                            f32(d_eff.reshape(S, R * L)), p_od, slot)
                    record_jit("engine.eval.task_ps", _task_batch_ps, *args)
                    res = _task_batch_ps(*args)
                else:
                    args = (A, C, f32(starts.ravel()), f32(ends.ravel()),
                            f32(z_t.reshape(R * L)),
                            f32(d_eff.reshape(R * L)), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.task" + sfx, task_fn, *args)
                    res = task_fn(*args)
                res = {k: v.reshape(rows, R, L).sum(axis=2)
                       for k, v in res.items() if k != "finish"}
            shape = (S, len(groups), J)
            for key in ("spot_cost", "ondemand_cost", "spot_work",
                        "ondemand_work"):
                # [:S] drops the mesh padding rows (duplicates of the last
                # scenario) before the host scatter.
                vals = np.asarray(res[key], np.float64)[:S].reshape(shape)
                for gi, g in enumerate(groups):
                    out[key][:, :, g.policy_idx] = vals[:, gi, :, None]
