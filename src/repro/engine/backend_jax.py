"""Vectorized jnp backend.

One fused computation per bid: every evaluation group sharing the bid is
stacked into a (G*J,) row batch, the S market scenarios are vmapped over
the stacked cumulative arrays, and the chain recurrence runs as a
``lax.scan`` over the L planned windows (``kernels/ref.py::chain_costs_ref``).
Float32 (matches the pallas kernel); the numpy backend is the float64
oracle.

The jitted entry points live at module scope and take every plan array as
a traced argument, so repeated ``evaluate_grid`` calls reuse the compile
cache (one compilation per distinct batch shape, not per call).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.scenarios import stack_views
from repro.kernels.ref import chain_costs_ref, policy_cost_ref

__all__ = ["run"]


@jax.jit
def _chain_batch(A, C, arrival, ends, z_t, d_eff, pins, p_od, slot):
    """(S, n+1) stacked views x (R, L) row batch -> dict of (S, R)."""
    fn = jax.vmap(
        lambda a, c: chain_costs_ref(a, c, arrival, ends, z_t, d_eff, pins,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


@jax.jit
def _task_batch(A, C, starts, ends, z_t, d_eff, p_od, slot):
    """Planned-start (per-task) edition -> dict of (S, R*L)."""
    fn = jax.vmap(
        lambda a, c: policy_cost_ref(a, c, starts, ends, z_t, d_eff,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


def run(gplan, markets, early_start: bool, out) -> None:
    slot = markets[0].slot
    p_od = markets[0].p_ondemand
    J = gplan.n_jobs
    f32 = lambda a: jnp.asarray(a, jnp.float32)

    for bid in gplan.bids:
        groups = gplan.groups_for_bid(bid)
        A, C = stack_views(markets, bid)        # (S, n_slots+1)
        A, C = f32(A), f32(C)
        ends = np.concatenate([g.plan.ends for g in groups])
        z_t = np.concatenate([g.z_t for g in groups])
        d_eff = np.concatenate([g.d_eff for g in groups])
        if early_start:
            pins = np.concatenate([g.pins for g in groups])
            arrival = np.tile(gplan.arrival, len(groups))
            res = _chain_batch(A, C, f32(arrival), f32(ends), f32(z_t),
                               f32(d_eff), jnp.asarray(pins), p_od, slot)
        else:
            starts = np.concatenate([g.plan.starts for g in groups])
            R, L = ends.shape
            flat = lambda a: f32(a).reshape(R * L)
            res = _task_batch(A, C, flat(starts), flat(ends), flat(z_t),
                              flat(d_eff), p_od, slot)
            res = {k: v.reshape(len(markets), R, L).sum(axis=2)
                   for k, v in res.items() if k != "finish"}
        shape = (len(markets), len(groups), J)
        for key in ("spot_cost", "ondemand_cost", "spot_work",
                    "ondemand_work"):
            vals = np.asarray(res[key], np.float64).reshape(shape)
            for gi, g in enumerate(groups):
                out[key][:, :, g.policy_idx] = vals[:, gi, :, None]
