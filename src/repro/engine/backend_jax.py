"""Vectorized jnp backend.

One fused computation per bid: every evaluation group sharing the bid is
stacked into a (G*J,) row batch, the S market scenarios are vmapped over
the stacked cumulative arrays, and the chain recurrence runs as a
``lax.scan`` over the L planned windows (``kernels/ref.py::chain_costs_ref``).
Float32 (matches the pallas kernel); the numpy backend is the float64
oracle.

When the grid plan was built against per-scenario availability queries
(TOLA's batched pool refinement) the self-owned arrays (z_t, d_eff, pins)
are (S, R, L) stacks and the ``_ps`` entry points vmap them alongside the
market arrays; the common scenario-shared case keeps them closed over
(one host->device copy, no S-fold broadcast).

Device grid plans (``plan_backend="device"``) arrive as jax arrays and are
consumed directly — ``concat_rows``/``scenario_cat`` stack them with jnp,
so the plan tensors never take a host round trip between the plan jit and
the cost jit.

The jitted entry points live at module scope and take every plan array as
a traced argument, so repeated ``evaluate_grid`` calls reuse the compile
cache (one compilation per distinct batch shape, not per call).

Donation note (DESIGN.md §11): the eval entry points deliberately do NOT
use ``donate_argnums``. Their inputs are exactly the tensors the
cross-call caches keep alive — device plan arrays in ``PLAN_CACHE``
groups, stacked views in ``VIEW_CACHE`` — and the f32 conversions below
are aliases (``jnp.asarray`` on an already-f32 device array is a no-op),
so donating them would invalidate cached buffers mid-cache-lifetime.
There is also nothing to donate INTO: no output shares a donatable
input's shape+dtype (outputs are (S, R)-shaped cost dicts). The streamed
regret fold in ``learn/replay.py`` is where donation pays — its
accumulator is a genuine same-shape carry.

Sharded path (DESIGN.md §9): with a ``GridMesh`` the same four batch
bodies are ``shard_map``ed over the 2-D (scenario x group) mesh — stacked
views arrive padded and sharded over ``"data"`` (``ScenarioBatch.n_rows``
rows), plan row batches are padded to whole groups and sharded over
``"model"`` (edge-repeat group padding, ``pad_groups``), per-scenario
self-owned stacks shard over BOTH axes, and scalars replicate. Every
(data, model) shard scores only its own scenario-slab x group-block and
the compiled program contains ZERO cross-device collectives (neither axis
reduces inside the cost tensor). Results come back through one unpermute
gather (the ``np.asarray`` below) and padded lanes are masked at the
splice: ``[:S]`` drops scenario padding, indexing only the real groups
drops group padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.plan import concat_rows, scenario_cat
from repro.kernels.ref import chain_costs_ref, policy_cost_ref
from repro.obs import record_jit, span

__all__ = ["run", "SHARDED_PS"]

# Per-scenario (refined) plans evaluate sharded since the 2-D mesh landed.
# ``core/tola.py`` probes this flag before threading ``mesh=`` into a
# refinement round and falls back (with a UserWarning) when it is False —
# the escape hatch if a jax regression ever forces the ps shard path off.
SHARDED_PS = True


def _chain_body(A, C, arrival, ends, z_t, d_eff, pins, p_od, slot):
    """(S, n+1) stacked views x (R, L) row batch -> dict of (S, R)."""
    fn = jax.vmap(
        lambda a, c: chain_costs_ref(a, c, arrival, ends, z_t, d_eff, pins,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


def _task_body(A, C, starts, ends, z_t, d_eff, p_od, slot):
    """Planned-start (per-task) edition -> dict of (S, R*L)."""
    fn = jax.vmap(
        lambda a, c: policy_cost_ref(a, c, starts, ends, z_t, d_eff,
                                     p_od=p_od, slot=slot),
        in_axes=(0, 0))
    return fn(A, C)


def _chain_body_ps(A, C, arrival, ends, z_t, d_eff, pins, p_od, slot):
    """Per-scenario-plan edition: z_t/d_eff/pins are (S, R, L) stacks."""
    fn = jax.vmap(
        lambda a, c, z, d, p: chain_costs_ref(a, c, arrival, ends, z, d, p,
                                              p_od=p_od, slot=slot),
        in_axes=(0, 0, 0, 0, 0))
    return fn(A, C, z_t, d_eff, pins)


def _task_body_ps(A, C, starts, ends, z_t, d_eff, p_od, slot):
    """Planned-start with per-scenario (S, R*L) cloud workloads."""
    fn = jax.vmap(
        lambda a, c, z, d: policy_cost_ref(a, c, starts, ends, z, d,
                                           p_od=p_od, slot=slot),
        in_axes=(0, 0, 0, 0))
    return fn(A, C, z_t, d_eff)


_chain_batch = jax.jit(_chain_body)
_task_batch = jax.jit(_task_body)
_chain_batch_ps = jax.jit(_chain_body_ps)
_task_batch_ps = jax.jit(_task_body_ps)


@functools.lru_cache(maxsize=8)   # bounded: one entry per live mesh
def _sharded_fns(mesh):
    """The four batch bodies shard_map'ed over a ``GridMesh``.

    Views (leading scenario axis) shard over ``"data"``; plan row batches
    (leading group-row axis) shard over ``"model"``; per-scenario
    self-owned stacks shard over both; scalars replicate. On a 1-D mesh
    ``spec("group")`` degrades to replicated and this is exactly the PR 6
    scenario-only placement. Cached per mesh so repeated calls reuse the
    compiled program exactly like the unsharded module-scope jits.
    """
    from jax.experimental.shard_map import shard_map

    dp = mesh.spec("scenario")            # P("data")
    gp = mesh.spec("group")               # P("model"); P(None) on 1-D mesh
    dgp = mesh.spec("scenario", "group")  # P("data", "model")
    rp = mesh.spec()                      # empty P(): replicated, any rank
    sm = functools.partial(shard_map, mesh=mesh.mesh)
    chain = jax.jit(sm(
        _chain_body,
        in_specs=(dp, dp, gp, gp, gp, gp, gp, rp, rp), out_specs=dgp))
    task = jax.jit(sm(
        _task_body,
        in_specs=(dp, dp, gp, gp, gp, gp, rp, rp), out_specs=dgp))
    chain_ps = jax.jit(sm(
        _chain_body_ps,
        in_specs=(dp, dp, gp, gp, dgp, dgp, dgp, rp, rp), out_specs=dgp))
    task_ps = jax.jit(sm(
        _task_body_ps,
        in_specs=(dp, dp, gp, gp, dgp, dgp, rp, rp), out_specs=dgp))
    return {"chain": chain, "task": task,
            "chain_ps": chain_ps, "task_ps": task_ps}


def _scen_rows(a, rows: int):
    """Edge-repeat a leading-scenario stack to the mesh-padded row count
    (device arrays stay on device; the padded rows duplicate the last
    scenario and are sliced off at the splice)."""
    k = a.shape[0]
    if rows == k:
        return a
    xp = np if isinstance(a, np.ndarray) else jnp
    return xp.concatenate([a, xp.repeat(a[-1:], rows - k, axis=0)], axis=0)


def run(gplan, batch, early_start: bool, out, mesh=None) -> None:
    slot = batch.slot
    p_od = batch.p_ondemand
    J = gplan.n_jobs
    S = batch.n_scenarios
    rows = batch.n_rows if mesh is not None else S
    ps = gplan.per_scenario
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    if mesh is not None:
        fns = _sharded_fns(mesh)
        chain_fn, task_fn = fns["chain"], fns["task"]
        chain_ps_fn, task_ps_fn = fns["chain_ps"], fns["task_ps"]
        scalar = jnp.float32
    else:
        chain_fn, task_fn = _chain_batch, _task_batch
        chain_ps_fn, task_ps_fn = _chain_batch_ps, _task_batch_ps
        scalar = lambda x: x

    sfx = ":sharded" if mesh is not None else ""
    for bid in gplan.bids:
        groups = gplan.groups_for_bid(bid)
        G = len(groups)
        # Group padding for the "model" axis: repeat the LAST group so
        # every model shard owns the same number of whole groups. Padded
        # groups are real (duplicated) work, masked at the splice below.
        Gp = mesh.pad_groups(G) if mesh is not None else G
        gpad = groups if Gp == G else groups + [groups[-1]] * (Gp - G)
        with span("eval.bid", bid=bid, groups=G):
            # (rows, n_slots+1) stacked views, cached on the batch per
            # bid — already-f32 device tensors when the chunk was
            # synthesized on device (a spec source), host f64 otherwise;
            # padded + sharded over "data" under a mesh.
            A, C = batch.stacked(bid)
            A, C = f32(A), f32(C)
            ends = concat_rows([g.plan.ends for g in gpad])
            if ps:
                z_t = _scen_rows(scenario_cat(gpad, "z_t", S), rows)
                d_eff = _scen_rows(scenario_cat(gpad, "d_eff", S), rows)
            else:
                z_t = concat_rows([g.z_t for g in gpad])
                d_eff = concat_rows([g.d_eff for g in gpad])
            if early_start:
                arrival = np.tile(gplan.arrival, Gp)
                if ps:
                    pins = _scen_rows(scenario_cat(gpad, "pins", S), rows)
                    args = (A, C, f32(arrival), f32(ends), f32(z_t),
                            f32(d_eff), jnp.asarray(pins), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.chain_ps" + sfx, chain_ps_fn,
                               *args)
                    res = chain_ps_fn(*args)
                else:
                    pins = concat_rows([g.pins for g in gpad])
                    args = (A, C, f32(arrival), f32(ends), f32(z_t),
                            f32(d_eff), jnp.asarray(pins), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.chain" + sfx, chain_fn, *args)
                    res = chain_fn(*args)
            else:
                starts = concat_rows([g.plan.starts for g in gpad])
                R, L = ends.shape
                if ps:
                    args = (A, C, f32(starts.ravel()), f32(ends.ravel()),
                            f32(z_t).reshape(rows, R * L),
                            f32(d_eff).reshape(rows, R * L), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.task_ps" + sfx, task_ps_fn,
                               *args)
                    res = task_ps_fn(*args)
                else:
                    args = (A, C, f32(starts.ravel()), f32(ends.ravel()),
                            f32(z_t.reshape(R * L)),
                            f32(d_eff.reshape(R * L)), scalar(p_od),
                            scalar(slot))
                    record_jit("engine.eval.task" + sfx, task_fn, *args)
                    res = task_fn(*args)
                res = {k: v.reshape(rows, R, L).sum(axis=2)
                       for k, v in res.items() if k != "finish"}
            shape = (S, Gp, J)
            for key in ("spot_cost", "ondemand_cost", "spot_work",
                        "ondemand_work"):
                # [:S] drops the mesh padding rows (duplicates of the last
                # scenario) before the host scatter; indexing only the
                # real ``groups`` below masks the padded group lanes.
                vals = np.asarray(res[key], np.float64)[:S].reshape(shape)
                for gi, g in enumerate(groups):
                    out[key][:, :, g.policy_idx] = vals[:, gi, :, None]
