"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state mirrors the param tree (m, v in f32) and inherits the
parameters' sharding (ZeRO-style: fsdp-sharded params => fsdp-sharded
moments; no extra annotation needed under GSPMD).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, grad_norm)."""
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            new_p = p - lr * (mh / (jnp.sqrt(vh) + self.eps)
                              + self.weight_decay * p)
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, m=new_m, v=new_v), gnorm
