from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["AdamW", "OptState", "cosine_schedule", "linear_warmup"]
