"""Deterministic sharded data pipeline.

Synthetic LM tokens generated from a counter-mode hash (splitmix64) — fully
deterministic in (seed, step, position), so any host can materialize exactly
its shard without coordination, restarts resume bit-identically from the
step counter alone (no data-state in checkpoints), and elastic re-sharding
is trivial (the shard is a pure function of host rank). A background thread
prefetches the next batch while the current step runs.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np

__all__ = ["SyntheticTokens", "make_batches"]


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class SyntheticTokens:
    """Deterministic synthetic token stream for a (possibly multi-host) job.

    Emits the host's slice of the global batch: rows
    [host_rank * per_host, (host_rank + 1) * per_host).
    """

    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_rank: int | None = None,
                 host_count: int | None = None, extras: dict | None = None):
        self.vocab = int(vocab)
        self.global_batch = int(global_batch)
        self.seq_len = int(seq_len)
        self.seed = np.uint64(seed)
        self.rank = jax.process_index() if host_rank is None else host_rank
        self.count = jax.process_count() if host_count is None else host_count
        assert self.global_batch % self.count == 0
        self.per_host = self.global_batch // self.count
        self.extras = extras or {}

    def batch(self, step: int) -> dict:
        rows = (self.rank * self.per_host
                + np.arange(self.per_host, dtype=np.uint64))
        pos = np.arange(self.seq_len + 1, dtype=np.uint64)
        key = (self.seed * np.uint64(0x100000001)
               + np.uint64(step) * np.uint64(0x51_7CC1B7)
               + rows[:, None] * np.uint64(0x2545F491_4F6CDD1D)
               + pos[None, :])
        noise = _splitmix64(key)
        # Learnable Markov source: t_{i+1} = (5 t_i + 7) mod V with prob 7/8,
        # uniform noise otherwise — a bigram permutation the models can
        # actually fit (pure hash noise has no signal, so training-loss
        # regressions would be invisible).
        V = np.uint64(self.vocab)
        toks = np.empty((self.per_host, self.seq_len + 1), np.int32)
        toks[:, 0] = (noise[:, 0] % V).astype(np.int32)
        rnd_tok = (noise % V).astype(np.int32)
        use_rnd = ((noise >> np.uint64(33)) % np.uint64(8)) == 0
        for i in range(1, self.seq_len + 1):
            pred = (toks[:, i - 1].astype(np.int64) * 5 + 7) % self.vocab
            toks[:, i] = np.where(use_rnd[:, i], rnd_tok[:, i],
                                  pred.astype(np.int32))
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        # Frontend stubs (vision patches / audio frames) are deterministic
        # pseudo-embeddings as well.
        for name, (length, dim) in self.extras.items():
            g = np.arange(length * dim, dtype=np.uint64).reshape(length, dim)
            e = _splitmix64(g + np.uint64(step)).astype(np.float64)
            e = (e / 2**64 - 0.5).astype(np.float32) * 0.02
            out[name] = np.broadcast_to(e, (self.per_host, length, dim)).copy()
            if name == "vision":
                out["tokens"] = out["tokens"][:, :-length]
                out["labels"] = out["labels"][:, :-length]
        return out


def make_batches(ds: SyntheticTokens, start_step: int, n_steps: int,
                 prefetch: int = 2):
    """Prefetching iterator over [start_step, start_step + n_steps)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)

    def producer():
        for s in range(start_step, start_step + n_steps):
            q.put((s, ds.batch(s)))
        q.put(None)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is None:
            return
        yield item
