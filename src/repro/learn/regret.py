"""Regret accounting for replayed learners (mirrors ``engine/result.py``).

Everything here is float64 numpy on the backends' OUTPUTS (sampled traces,
final weights) plus the original float64 cost tensor — so the regret curves
of a jax/pallas replay are computed with exactly the same arithmetic as the
numpy oracle's, and backend parity reduces to the sampled trace and
weights.

Conventions: all per-job costs are per-unit-workload (the engine's
``unit_cost``); aggregates weight jobs by Z_j, matching the paper's stream
metric ``alpha = sum_j c_j / sum_j Z_j`` and ``TolaResult``'s
``regret_per_job``. "Best fixed" is best-in-hindsight over the FULL
horizon, so a regret curve can dip negative early when the eventual winner
starts poorly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LearnResult", "StreamLearnResult", "prop_b1_bound"]


@dataclasses.dataclass
class LearnResult:
    """Batched (scenario x learner) replay output.

    Axes: S scenarios x K learner instances (specs order) x J jobs x P
    policies. ``expected_unit`` is the prob-weighted per-job cost at sample
    time (sampling-noise-free — what the Prop. B.1 bound controls);
    ``p_chosen`` the sampled policy's probability (the bandit learners'
    importance weights).
    """

    specs: list
    chosen: np.ndarray         # (S, K, J) sampled policy index
    p_chosen: np.ndarray       # (S, K, J)
    expected_unit: np.ndarray  # (S, K, J)
    weights: np.ndarray        # (S, K, P) final sampling distribution
    unit_cost: np.ndarray      # (S, J, P) the replayed cost tensor (f64)
    arrivals: np.ndarray       # (J,)
    workload: np.ndarray       # (J,) Z_j
    feedback_delay: float      # d — max relative deadline
    backend: str = "numpy"

    @property
    def n_scenarios(self) -> int:
        return self.unit_cost.shape[0]

    @property
    def labels(self) -> list[str]:
        return [sp.label for sp in self.specs]

    def realized_unit(self) -> np.ndarray:
        """(S, K) realized counterfactual stream cost of the sampled trace."""
        c = np.take_along_axis(
            self.unit_cost[:, None], self.chosen[..., None], axis=3)[..., 0]
        return (c * self.workload).sum(axis=2) / self.workload.sum()

    def fixed_unit_costs(self) -> np.ndarray:
        """(S, P) stream cost of every fixed policy."""
        return ((self.unit_cost * self.workload[None, :, None]).sum(axis=1)
                / self.workload.sum())

    def best_fixed(self) -> np.ndarray:
        """(S,) best-fixed-policy-in-hindsight stream cost."""
        return self.fixed_unit_costs().min(axis=1)

    def regret_per_job(self, expected: bool = False) -> np.ndarray:
        """(S, K) average excess unit cost vs the best fixed policy."""
        if expected:
            real = ((self.expected_unit * self.workload).sum(axis=2)
                    / self.workload.sum())
        else:
            real = self.realized_unit()
        return real - self.best_fixed()[:, None]

    def regret_curve(self, expected: bool = False) -> np.ndarray:
        """(S, K, J) running realized regret per unit workload.

        ``curve[s, k, t] = (cum cost of the sampled trace - cum cost of the
        hindsight-best fixed policy) / cum workload`` after t+1 jobs.
        """
        Z = self.workload
        if expected:
            per_job = self.expected_unit
        else:
            per_job = np.take_along_axis(
                self.unit_cost[:, None], self.chosen[..., None],
                axis=3)[..., 0]
        cum_real = np.cumsum(per_job * Z, axis=2)
        fixed = (self.unit_cost * Z[None, :, None]).cumsum(axis=1)  # (S,J,P)
        p_star = fixed[:, -1].argmin(axis=1)                        # (S,)
        cum_best = np.take_along_axis(
            fixed, p_star[:, None, None], axis=2)[..., 0]           # (S, J)
        return (cum_real - cum_best[:, None]) / np.cumsum(Z)

    def confidence_bands(self, z: float = 1.96, expected: bool = False):
        """Per-learner regret-curve bands across scenarios.

        Returns ``(mean, lo, hi)``, each (K, J): scenario mean +- z standard
        errors (the S market scenarios are the independent replicates).
        """
        curves = self.regret_curve(expected=expected)
        mean = curves.mean(axis=0)
        se = curves.std(axis=0) / np.sqrt(max(self.n_scenarios, 1))
        return mean, mean - z * se, mean + z * se

    def summary(self) -> list[dict]:
        """Scenario-mean headline numbers per learner (bench/table rows)."""
        realized = self.realized_unit().mean(axis=0)
        regret = self.regret_per_job().mean(axis=0)
        exp_regret = self.regret_per_job(expected=True).mean(axis=0)
        top_w = self.weights.max(axis=2).mean(axis=0)
        return [
            {"learner": sp.label, "realized_unit": float(realized[k]),
             "regret": float(regret[k]),
             "expected_regret": float(exp_regret[k]),
             "top_weight": float(top_w[k])}
            for k, sp in enumerate(self.specs)
        ]


@dataclasses.dataclass
class StreamLearnResult:
    """Streaming regret accumulator — ``LearnResult`` folded chunk by chunk.

    Built by ``replay_stream``: every scenario chunk's ``LearnResult`` is
    folded into per-learner sums and sums-of-squares over the SCENARIO
    axis, so regret means, curves and confidence bands over S = 10^4-10^5
    scenarios come out without ever holding the (S, J, P) cost tensor (or
    any other S-sized array — peak memory is (K, J), independent of S).
    Scenario-mean statistics match the materialized ``LearnResult``'s to
    float-summation tolerance (the per-scenario terms are identical; only
    the summation grouping differs).
    """

    specs: list
    feedback_delay: float
    backend: str = "numpy"
    n_scenarios: int = 0
    n_chunks: int = 0
    realized_sum: np.ndarray | None = None     # (K,) realized stream cost
    expected_sum: np.ndarray | None = None     # (K,) expected stream cost
    regret_sum: np.ndarray | None = None       # (K,)
    regret_sq: np.ndarray | None = None        # (K,)
    best_fixed_sum: float = 0.0
    curve_sum: np.ndarray | None = None        # (K, J) realized regret curve
    curve_sq: np.ndarray | None = None         # (K, J)
    weights_sum: np.ndarray | None = None      # (K, P) final distributions
    top_weight_sum: np.ndarray | None = None   # (K,)
    # repro.obs snapshot ({"metrics": ..., "compiled": ...}) captured by
    # replay_stream when an observability context was active; None otherwise.
    obs: dict | None = None

    @property
    def labels(self) -> list[str]:
        return [sp.label for sp in self.specs]

    def fold(self, lr: LearnResult) -> np.ndarray:
        """Fold one chunk's ``LearnResult``; returns the chunk's
        per-scenario realized regret of learner 0 (the adaptive
        adversary's feedback signal)."""
        if self.n_scenarios == 0:
            K, J = len(lr.specs), lr.unit_cost.shape[1]
            P = lr.weights.shape[-1]
            self.realized_sum = np.zeros(K)
            self.expected_sum = np.zeros(K)
            self.regret_sum = np.zeros(K)
            self.regret_sq = np.zeros(K)
            self.curve_sum = np.zeros((K, J))
            self.curve_sq = np.zeros((K, J))
            self.weights_sum = np.zeros((K, P))
            self.top_weight_sum = np.zeros(K)
        realized = lr.realized_unit()                    # (S_c, K)
        regret = lr.regret_per_job()                     # (S_c, K)
        curves = lr.regret_curve()                       # (S_c, K, J)
        self.realized_sum += realized.sum(axis=0)
        self.expected_sum += ((lr.expected_unit * lr.workload).sum(axis=2)
                              / lr.workload.sum()).sum(axis=0)
        self.regret_sum += regret.sum(axis=0)
        self.regret_sq += (regret ** 2).sum(axis=0)
        self.best_fixed_sum += float(lr.best_fixed().sum())
        self.curve_sum += curves.sum(axis=0)
        self.curve_sq += (curves ** 2).sum(axis=0)
        self.weights_sum += lr.weights.sum(axis=0)
        self.top_weight_sum += lr.weights.max(axis=2).sum(axis=0)
        self.n_scenarios += lr.n_scenarios
        self.n_chunks += 1
        return regret[:, 0]

    def fold_sums(self, n: int, realized, expected, regret, regret_sq,
                  best_fixed: float, curve, curve_sq, weights,
                  top_weight) -> None:
        """Fold one chunk's PRE-REDUCED sums (specs order, already summed
        over the chunk's scenario axis — the sharded replay fold's psum
        output). Same accumulator state as ``fold``, without ever holding
        the chunk's per-scenario arrays on the host."""
        if self.n_scenarios == 0:
            K, J = np.shape(curve)
            P = np.shape(weights)[-1]
            self.realized_sum = np.zeros(K)
            self.expected_sum = np.zeros(K)
            self.regret_sum = np.zeros(K)
            self.regret_sq = np.zeros(K)
            self.curve_sum = np.zeros((K, J))
            self.curve_sq = np.zeros((K, J))
            self.weights_sum = np.zeros((K, P))
            self.top_weight_sum = np.zeros(K)
        self.realized_sum += realized
        self.expected_sum += expected
        self.regret_sum += regret
        self.regret_sq += regret_sq
        self.best_fixed_sum += float(best_fixed)
        self.curve_sum += curve
        self.curve_sq += curve_sq
        self.weights_sum += weights
        self.top_weight_sum += top_weight
        self.n_scenarios += int(n)
        self.n_chunks += 1

    # -- scenario-mean statistics (match LearnResult's .mean(axis=0)) ------
    def realized_unit(self) -> np.ndarray:
        return self.realized_sum / self.n_scenarios

    def best_fixed(self) -> float:
        return self.best_fixed_sum / self.n_scenarios

    def regret_per_job(self, expected: bool = False) -> np.ndarray:
        if expected:
            return (self.expected_sum / self.n_scenarios) - self.best_fixed()
        return self.regret_sum / self.n_scenarios

    def regret_std(self) -> np.ndarray:
        """(K,) across-scenario std of the per-scenario realized regret."""
        mean = self.regret_sum / self.n_scenarios
        var = self.regret_sq / self.n_scenarios - mean ** 2
        return np.sqrt(np.maximum(var, 0.0))

    def weights(self) -> np.ndarray:
        """(K, P) scenario-mean final sampling distributions."""
        return self.weights_sum / self.n_scenarios

    def confidence_bands(self, z: float = 1.96):
        """(mean, lo, hi) regret-curve bands, each (K, J), across the S
        streamed scenarios (same contract as LearnResult.confidence_bands)."""
        S = self.n_scenarios
        mean = self.curve_sum / S
        var = np.maximum(self.curve_sq / S - mean ** 2, 0.0)
        se = np.sqrt(var) / np.sqrt(max(S, 1))
        return mean, mean - z * se, mean + z * se

    def summary(self) -> list[dict]:
        """Scenario-mean headline numbers per learner (bench/table rows)."""
        realized = self.realized_unit()
        regret = self.regret_per_job()
        exp_regret = self.regret_per_job(expected=True)
        top_w = self.top_weight_sum / self.n_scenarios
        return [
            {"learner": sp.label, "realized_unit": float(realized[k]),
             "regret": float(regret[k]),
             "expected_regret": float(exp_regret[k]),
             "top_weight": float(top_w[k])}
            for k, sp in enumerate(self.specs)
        ]


def prop_b1_bound(arrivals, d: float, m: int, c_max: float = 1.0) -> float:
    """Prop. B.1-style regret bound for delayed-feedback Hedge.

    With losses in [0, c_max] and feedback delayed until ``a_j + d``, at
    most ``D = max_j #{k != j : a_k in [a_j, a_j + d)}`` other samples are
    drawn between a job's sample and its update, and exponentiated weights
    suffer regret at most ``c_max * (sqrt(2 (D + 1) n log m) + (D + 1))``
    over n jobs (the ``+ (D + 1)`` absorbs the un-updated prefix). The test
    suite checks the SCALING of this bound on synthetic cost matrices; the
    constant is not tight.
    """
    a = np.asarray(arrivals, dtype=np.float64)
    n = len(a)
    # a is arrival-ordered: jobs in [a_j, a_j + d) form a contiguous run.
    hi = np.searchsorted(a, a + d, side="left")
    D = int((hi - np.arange(n) - 1).max()) if n else 0
    return float(c_max * (np.sqrt(2.0 * (D + 1) * n * np.log(m)) + D + 1))
