"""Regret accounting for replayed learners (mirrors ``engine/result.py``).

Everything here is float64 numpy on the backends' OUTPUTS (sampled traces,
final weights) plus the original float64 cost tensor — so the regret curves
of a jax/pallas replay are computed with exactly the same arithmetic as the
numpy oracle's, and backend parity reduces to the sampled trace and
weights.

Conventions: all per-job costs are per-unit-workload (the engine's
``unit_cost``); aggregates weight jobs by Z_j, matching the paper's stream
metric ``alpha = sum_j c_j / sum_j Z_j`` and ``TolaResult``'s
``regret_per_job``. "Best fixed" is best-in-hindsight over the FULL
horizon, so a regret curve can dip negative early when the eventual winner
starts poorly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LearnResult", "prop_b1_bound"]


@dataclasses.dataclass
class LearnResult:
    """Batched (scenario x learner) replay output.

    Axes: S scenarios x K learner instances (specs order) x J jobs x P
    policies. ``expected_unit`` is the prob-weighted per-job cost at sample
    time (sampling-noise-free — what the Prop. B.1 bound controls);
    ``p_chosen`` the sampled policy's probability (the bandit learners'
    importance weights).
    """

    specs: list
    chosen: np.ndarray         # (S, K, J) sampled policy index
    p_chosen: np.ndarray       # (S, K, J)
    expected_unit: np.ndarray  # (S, K, J)
    weights: np.ndarray        # (S, K, P) final sampling distribution
    unit_cost: np.ndarray      # (S, J, P) the replayed cost tensor (f64)
    arrivals: np.ndarray       # (J,)
    workload: np.ndarray       # (J,) Z_j
    feedback_delay: float      # d — max relative deadline
    backend: str = "numpy"

    @property
    def n_scenarios(self) -> int:
        return self.unit_cost.shape[0]

    @property
    def labels(self) -> list[str]:
        return [sp.label for sp in self.specs]

    def realized_unit(self) -> np.ndarray:
        """(S, K) realized counterfactual stream cost of the sampled trace."""
        c = np.take_along_axis(
            self.unit_cost[:, None], self.chosen[..., None], axis=3)[..., 0]
        return (c * self.workload).sum(axis=2) / self.workload.sum()

    def fixed_unit_costs(self) -> np.ndarray:
        """(S, P) stream cost of every fixed policy."""
        return ((self.unit_cost * self.workload[None, :, None]).sum(axis=1)
                / self.workload.sum())

    def best_fixed(self) -> np.ndarray:
        """(S,) best-fixed-policy-in-hindsight stream cost."""
        return self.fixed_unit_costs().min(axis=1)

    def regret_per_job(self, expected: bool = False) -> np.ndarray:
        """(S, K) average excess unit cost vs the best fixed policy."""
        if expected:
            real = ((self.expected_unit * self.workload).sum(axis=2)
                    / self.workload.sum())
        else:
            real = self.realized_unit()
        return real - self.best_fixed()[:, None]

    def regret_curve(self, expected: bool = False) -> np.ndarray:
        """(S, K, J) running realized regret per unit workload.

        ``curve[s, k, t] = (cum cost of the sampled trace - cum cost of the
        hindsight-best fixed policy) / cum workload`` after t+1 jobs.
        """
        Z = self.workload
        if expected:
            per_job = self.expected_unit
        else:
            per_job = np.take_along_axis(
                self.unit_cost[:, None], self.chosen[..., None],
                axis=3)[..., 0]
        cum_real = np.cumsum(per_job * Z, axis=2)
        fixed = (self.unit_cost * Z[None, :, None]).cumsum(axis=1)  # (S,J,P)
        p_star = fixed[:, -1].argmin(axis=1)                        # (S,)
        cum_best = np.take_along_axis(
            fixed, p_star[:, None, None], axis=2)[..., 0]           # (S, J)
        return (cum_real - cum_best[:, None]) / np.cumsum(Z)

    def confidence_bands(self, z: float = 1.96, expected: bool = False):
        """Per-learner regret-curve bands across scenarios.

        Returns ``(mean, lo, hi)``, each (K, J): scenario mean +- z standard
        errors (the S market scenarios are the independent replicates).
        """
        curves = self.regret_curve(expected=expected)
        mean = curves.mean(axis=0)
        se = curves.std(axis=0) / np.sqrt(max(self.n_scenarios, 1))
        return mean, mean - z * se, mean + z * se

    def summary(self) -> list[dict]:
        """Scenario-mean headline numbers per learner (bench/table rows)."""
        realized = self.realized_unit().mean(axis=0)
        regret = self.regret_per_job().mean(axis=0)
        exp_regret = self.regret_per_job(expected=True).mean(axis=0)
        top_w = self.weights.max(axis=2).mean(axis=0)
        return [
            {"learner": sp.label, "realized_unit": float(realized[k]),
             "regret": float(regret[k]),
             "expected_regret": float(exp_regret[k]),
             "top_weight": float(top_w[k])}
            for k, sp in enumerate(self.specs)
        ]


def prop_b1_bound(arrivals, d: float, m: int, c_max: float = 1.0) -> float:
    """Prop. B.1-style regret bound for delayed-feedback Hedge.

    With losses in [0, c_max] and feedback delayed until ``a_j + d``, at
    most ``D = max_j #{k != j : a_k in [a_j, a_j + d)}`` other samples are
    drawn between a job's sample and its update, and exponentiated weights
    suffer regret at most ``c_max * (sqrt(2 (D + 1) n log m) + (D + 1))``
    over n jobs (the ``+ (D + 1)`` absorbs the un-updated prefix). The test
    suite checks the SCALING of this bound on synthetic cost matrices; the
    constant is not tight.
    """
    a = np.asarray(arrivals, dtype=np.float64)
    n = len(a)
    # a is arrival-ordered: jobs in [a_j, a_j + d) form a contiguous run.
    hi = np.searchsorted(a, a + d, side="left")
    D = int((hi - np.arange(n) - 1).max()) if n else 0
    return float(c_max * (np.sqrt(2.0 * (D + 1) * n * np.log(m)) + D + 1))
