"""Batched replay of the online-learning recurrence over a cost tensor.

The paper's Alg. 4 is a sequential recurrence over a merged event stream:
when job j ARRIVES a policy is sampled from the learner's current
distribution; once its window has fully ELAPSED (``t = a_j + d``) its
counterfactual costs become observable and the learner state is updated.
The engine (``repro.engine``) already produces the full (scenarios x jobs x
policies) counterfactual cost tensor in one batched pass; this module
replays ANY learner of ``learners.py`` over that tensor:

* ``backend="numpy"`` — the sequential float64 event loop, the exact
  oracle. For ``hedge`` with the ``alg4`` schedule it is bit-compatible
  with the pre-subsystem ``run_tola`` loop (same logw arithmetic, same
  uniform-stream consumption as ``rng.choice`` — see ``_sample_cdf``).
* ``backend="jax"``  — the same event stream as ONE ``jax.lax.scan``,
  compiled once per learner kind and vmapped across scenarios x (learner,
  schedule-grid) instances, so an entire learner-comparison sweep is a
  single compiled call.
* ``backend="pallas"`` — hedge-family instances route to the fused
  ``kernels/weight_update.py`` TPU kernel (trajectory pass + one-hot-matmul
  sample gather); other kinds fall back to the jax scan.

Sampling is inverse-CDF against a per-scenario uniform stream drawn up
front in numpy: ``searchsorted(cdf, u, side="right")`` is exactly what
``np.random.Generator.choice(m, p=w)`` computes internally, so all
backends consume the SAME randomness and produce the SAME sampled-policy
trace (up to float ties) — and all learners of a sweep share the stream
(common random numbers, which is what makes their comparison low-variance).
"""

from __future__ import annotations

import functools

import numpy as np

from repro.learn.learners import (
    FULL_INFO_KINDS,
    LearnerSpec,
    as_spec,
    init_state,
    sample_probs,
    update_state,
)
from repro.learn.regret import LearnResult, StreamLearnResult
from repro.obs import METRICS, maybe_snapshot, record_jit, span

__all__ = ["replay", "replay_stream", "build_events", "available_backends",
           "resolve_backend"]


def available_backends() -> list[str]:
    """Replay backends usable in this process (same probe as the engine)."""
    from repro.engine import available_backends as engine_backends

    return engine_backends()


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "jax" if "jax" in available_backends() else "numpy"
    if backend not in ("numpy", "jax", "pallas"):
        raise ValueError(f"unknown replay backend {backend!r}")
    return backend


def build_events(arrivals: np.ndarray, d: float):
    """Merged (sample, update) event stream, exactly as Alg. 4 orders it.

    Returns ``(ev_kind, ev_j, n_done)``: per-event kind (0 = sample at
    ``a_j``, 1 = update at ``a_j + d``) and job index, in the same
    lexicographic (t, kind, j) order the legacy loop used — at equal times
    samples precede updates — plus ``n_done[j]``, the number of updates
    already applied when job j samples (the delayed-feedback offsets the
    trajectory-based kernels consume).
    """
    n = len(arrivals)
    events = sorted(
        [(float(arrivals[j]), 0, j) for j in range(n)]
        + [(float(arrivals[j] + d), 1, j) for j in range(n)]
    )
    ev_kind = np.array([k for _, k, _ in events], dtype=np.int32)
    ev_j = np.array([j for _, _, j in events], dtype=np.int32)
    upd_before = np.concatenate([[0], np.cumsum(ev_kind)])[:-1]
    n_done = np.zeros(n, dtype=np.int32)
    sample_pos = ev_kind == 0
    n_done[ev_j[sample_pos]] = upd_before[sample_pos]
    return ev_kind, ev_j, n_done


def _sample_cdf(p: np.ndarray, u: float) -> int:
    """What ``np.random.Generator.choice(m, p)`` does with one uniform."""
    cdf = np.cumsum(p)
    cdf /= cdf[-1]
    return min(int(np.searchsorted(cdf, u, side="right")), len(p) - 1)


def _replay_numpy_one(C, spec, u, ev_kind, ev_j, etas, gammas):
    """Sequential float64 event loop for one (scenario, learner) instance."""
    n, m = C.shape
    st = init_state(m, np)
    chosen = np.zeros(n, dtype=np.int64)
    p_sel = np.zeros(n)
    e_cost = np.zeros(n)
    for kind, j in zip(ev_kind, ev_j):
        if kind == 0:
            p = sample_probs(spec.kind, st, gammas[j], np)
            c = _sample_cdf(p, u[j])
            chosen[j] = c
            p_sel[j] = p[c]
            e_cost[j] = float(p @ C[j])
        else:
            oh = np.where(np.arange(m) == chosen[j], 1.0, 0.0)
            st = update_state(spec.kind, st, C[j], oh, p_sel[j], etas[j], np)
    weights = sample_probs(spec.kind, st, gammas[-1], np)
    return chosen, p_sel, e_cost, weights


def _scan_one(kind: str, ring: int):
    """The single-(scenario, instance) event scan — the traceable core
    shared by the unsharded ``_compiled_scan`` jit and the sharded fold.

    The scan carry holds only the learner state plus a small ring buffer of
    in-flight (chosen, p_chosen) pairs — the sample of job j and its
    delayed update are at most ``ring`` jobs apart, so ``j % ring`` slots
    never collide; per-job outputs leave through the scan's stacked ys
    instead of (J,)-sized carries (which would cost a dynamic-update copy
    per event).
    """
    import jax
    import jax.numpy as jnp

    def one(C2, u1, eta1, gamma1, ev_kind, ev_j):
        m = C2.shape[-1]

        def step(carry, x):
            st, rb_c, rb_p = carry
            ev_k, j = x
            slot = j % ring
            c_row = C2[j]
            p = sample_probs(kind, st, gamma1[j], jnp)
            cdf = jnp.cumsum(p)
            cdf = cdf / cdf[-1]
            c = jnp.minimum(
                jnp.searchsorted(cdf, u1[j], side="right"), m - 1)
            is_sample = ev_k == 0
            rb_c = rb_c.at[slot].set(jnp.where(is_sample, c, rb_c[slot]))
            rb_p = rb_p.at[slot].set(jnp.where(is_sample, p[c], rb_p[slot]))
            oh = jnp.where(jnp.arange(m) == rb_c[slot], 1.0, 0.0)
            new = update_state(kind, st, c_row, oh, rb_p[slot], eta1[j], jnp)
            st = jax.tree_util.tree_map(
                lambda a, b: jnp.where(is_sample, a, b), st, new)
            return (st, rb_c, rb_p), (rb_c[slot], rb_p[slot], p @ c_row)

        carry0 = (init_state(m, jnp), jnp.zeros(ring, jnp.int32),
                  jnp.zeros(ring))
        (st, _, _), ys = jax.lax.scan(step, carry0, (ev_kind, ev_j))
        weights = sample_probs(kind, st, gamma1[-1], jnp)
        return ys[0], ys[1], ys[2], weights

    return one


def _event_ring(ev_kind: np.ndarray) -> int:
    """Max jobs simultaneously sampled-but-not-updated (+1 so the sample
    event itself fits): update j reads slot j % ring strictly before any
    sample j' >= j + ring could overwrite it."""
    inflight = np.cumsum(np.where(ev_kind == 0, 1, -1))
    return int(inflight.max(initial=0)) + 1


@functools.lru_cache(maxsize=64)   # bounded: one entry per (kind, ring)
def _compiled_scan(kind: str, ring: int):
    """Jitted vmapped event scan for one learner kind, cached across replay
    calls (a fresh closure per call would force an XLA recompile per call).
    Retraces only on new (kind, ring) or new array shapes."""
    import jax

    f = jax.vmap(_scan_one(kind, ring),
                 in_axes=(None, None, 0, 0, None, None))       # grid axis
    f = jax.vmap(f, in_axes=(0, 0, None, None, None, None))    # scenarios
    return jax.jit(f)


def _replay_jax_kind(kind, C, u, etas_k, gammas_k, ev_kind, ev_j):
    """One compiled scan per learner kind, vmapped over S scenarios x K
    schedule-grid instances. C: (S, J, P); u: (S, J); etas/gammas: (K, J)."""
    import jax.numpy as jnp

    ring = _event_ring(ev_kind)
    fn = _compiled_scan(kind, ring)
    args = (jnp.asarray(C, jnp.float32), jnp.asarray(u),
            jnp.asarray(etas_k), jnp.asarray(gammas_k),
            jnp.asarray(ev_kind), jnp.asarray(ev_j))
    record_jit("learn.scan:" + kind, fn, *args)
    with span("replay.scan", kind=kind):
        ch_e, ps_e, ec_e, weights = fn(*args)
    # Sample events occur in job order: selecting them from the per-event
    # ys yields the per-job traces.
    sample_pos = np.nonzero(ev_kind == 0)[0]
    return (np.asarray(ch_e)[..., sample_pos],
            np.asarray(ps_e)[..., sample_pos],
            np.asarray(ec_e)[..., sample_pos], weights)


@functools.lru_cache(maxsize=16)   # bounded: one entry per fold config
def _sharded_fold(smesh, kinds_sig: tuple, ring: int, k0_pos: int):
    """Sharded replay-and-fold program: scan + regret stats + ONE psum.

    Every shard replays the learners over ITS scenario slice of the padded
    cost block (grouped by learner kind in ``kinds_sig`` order — tuples of
    ``(kind, n_instances)``), computes the per-scenario regret statistics
    locally, masks the padding rows via ``valid``, reduces over its local
    scenario axis, and packs every per-learner sum into ONE flat vector so
    the chunk's entire cross-device traffic is a single ``lax.psum`` over
    the ``"data"`` axis — the one collective the DESIGN.md §9 contract
    allows per chunk. On a 2-D ``GridMesh`` the ``"model"`` axis sees
    replicated inputs (specs below never mention it), so every model
    column computes identical sums and the psum stays ONE all-reduce over
    ``"data"`` only — the group axis adds no traffic. The second
    output (per-scenario realized regret of original learner 0, position
    ``k0_pos`` in grouped order) stays sharded — it is the adaptive
    adversary's feedback signal and never crosses devices.

    ``acc`` is the running flat accumulator CARRIED across chunks and
    DONATED (``donate_argnums=(0,)``): the returned ``acc + sums`` vector
    reuses the input's device buffer — an exact shape+dtype alias, so the
    donation is warning-free and the per-chunk accumulator costs zero
    allocations. The host reads the running value back each chunk and
    differences consecutive readings to recover the per-chunk sums
    (``replay_stream`` below), keeping the adaptive feedback loop and the
    per-chunk telemetry identical in structure to the undonated fold.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map

    def fold(acc, C, u, valid, etas, gammas, ev_kind, ev_j, sample_pos, Z):
        parts = []
        i = 0
        for kind, cnt in kinds_sig:
            f = jax.vmap(_scan_one(kind, ring),
                         in_axes=(None, None, 0, 0, None, None))
            f = jax.vmap(f, in_axes=(0, 0, None, None, None, None))
            parts.append(f(C, u, etas[i:i + cnt], gammas[i:i + cnt],
                           ev_kind, ev_j))
            i += cnt
        ch = jnp.concatenate([p[0] for p in parts], axis=1)
        ec = jnp.concatenate([p[2] for p in parts], axis=1)
        w = jnp.concatenate([p[3] for p in parts], axis=1)
        # Sample events occur in job order: selecting them from the
        # per-event ys yields the (S_l, K, J) per-job traces.
        ch = jnp.take(ch, sample_pos, axis=2)
        ec = jnp.take(ec, sample_pos, axis=2)
        zsum = Z.sum()
        per_job = jnp.take_along_axis(
            C[:, None], ch[..., None], axis=3)[..., 0]          # (S_l, K, J)
        realized = (per_job * Z).sum(axis=2) / zsum             # (S_l, K)
        expected = (ec * Z).sum(axis=2) / zsum
        fixed_cum = (C * Z[:, None]).cumsum(axis=1)             # (S_l, J, P)
        best_fixed = fixed_cum[:, -1].min(axis=1) / zsum        # (S_l,)
        regret = realized - best_fixed[:, None]                 # (S_l, K)
        cum_real = jnp.cumsum(per_job * Z, axis=2)              # (S_l, K, J)
        p_star = jnp.argmin(fixed_cum[:, -1], axis=1)           # (S_l,)
        cum_best = jnp.take_along_axis(
            fixed_cum, p_star[:, None, None], axis=2)[..., 0]   # (S_l, J)
        curve = (cum_real - cum_best[:, None]) / jnp.cumsum(Z)
        top_w = w.max(axis=2)                                   # (S_l, K)
        v = valid.astype(C.dtype)
        v1 = v[:, None]
        v2 = v[:, None, None]
        sums = jnp.concatenate([
            (realized * v1).sum(0),
            (expected * v1).sum(0),
            (regret * v1).sum(0),
            (regret ** 2 * v1).sum(0),
            (best_fixed * v).sum()[None],
            (curve * v2).sum(0).ravel(),
            (curve ** 2 * v2).sum(0).ravel(),
            (w * v2).sum(0).ravel(),
            (top_w * v1).sum(0),
            v.sum()[None],
        ])
        sums = jax.lax.psum(sums, "data")   # the one collective per chunk
        return acc + sums, regret[:, k0_pos]

    dp = smesh.spec("scenario")
    rp = smesh.spec()
    # check_rep=False: shard_map's replication checker can't see through
    # the lax.scan carry (state touches the sharded C rows) and rejects an
    # otherwise-valid program; the specs above are the contract.
    return jax.jit(shard_map(
        fold, mesh=smesh.mesh,
        in_specs=(rp, dp, dp, dp, rp, rp, rp, rp, rp, rp),
        out_specs=(rp, dp), check_rep=False),
        donate_argnums=(0,))


def fold_acc_size(K: int, J: int, P: int) -> int:
    """Length of the packed fold vector (the ``_unpack_fold`` layout)."""
    return 5 * K + 2 * K * J + K * P + 2


def _unpack_fold(flat: np.ndarray, K: int, J: int, P: int):
    """Split the psum'd flat vector back into the named per-learner sums
    (grouped-learner order — callers reindex by the inverse permutation)."""
    o = 0

    def take(n):
        nonlocal o
        v = flat[o:o + n]
        o += n
        return v

    out = {
        "realized": take(K), "expected": take(K), "regret": take(K),
        "regret_sq": take(K), "best_fixed": float(take(1)[0]),
        "curve": take(K * J).reshape(K, J),
        "curve_sq": take(K * J).reshape(K, J),
        "weights": take(K * P).reshape(K, P),
        "top_weight": take(K), "n": int(round(float(take(1)[0]))),
    }
    assert o == len(flat)
    return out


def _weight_metrics(specs, weights_mean) -> None:
    """Per-chunk learner telemetry: Shannon entropy (nats) of the mean
    weight posterior and the heaviest expert's share, one labeled series
    per learner instance. No-op unless the metrics registry is collecting."""
    if not METRICS.enabled:
        return
    w = np.maximum(np.asarray(weights_mean, np.float64), 0.0)
    w = w / np.maximum(w.sum(axis=1, keepdims=True), 1e-300)
    ent = -(w * np.log(np.maximum(w, 1e-300))).sum(axis=1)
    top = w.max(axis=1)
    hist = METRICS.histogram("learn.weight_entropy")
    gauge = METRICS.gauge("learn.top_weight")
    for k, sp in enumerate(specs):
        label = f"{k}:{sp.kind}"
        hist.observe(float(ent[k]), learner=label)
        gauge.set(float(top[k]), learner=label)


def replay(
    C,
    arrivals,
    d: float,
    workload=None,
    learners=("hedge",),
    seed: int = 0,
    rng: np.random.Generator | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
) -> LearnResult:
    """Replay a batch of learners over a (S, J, P) counterfactual tensor.

    ``C`` is the engine's cost tensor (an ``EngineResult``, its
    ``unit_cost``, or a raw (J, P) / (S, J, P) array); ``arrivals`` the
    arrival-ordered job times, ``d`` the max relative deadline (feedback
    delay), ``workload`` the per-job Z_j used by the regret accounting
    (defaults to 1). ``learners`` is a flat list of kinds / ``LearnerSpec``s
    — a schedule grid is expressed as more specs; the result keeps their
    order. ``rng`` (single-scenario only) draws the uniform stream from a
    live generator — the hook ``run_tola`` uses to stay bit-compatible with
    its legacy sampling stream; otherwise scenario s uses ``seed + s``.
    """
    if hasattr(C, "unit_cost"):
        if workload is None:
            workload = C.workload
        C = C.unit_cost
    # Device (jax) tensors stay on device: the compiled scan consumes them
    # directly without the numpy float64 staging copy; only the numpy
    # oracle and the result container force a host copy. (The engine still
    # emits host tensors, so this path serves callers that already hold the
    # cost tensor on device.)
    on_device = type(C).__module__.split(".")[0] in ("jax", "jaxlib")
    if not on_device:
        C = np.asarray(C, dtype=np.float64)
    if C.ndim == 2:
        C = C[None]
    if C.ndim != 3:
        raise ValueError(f"cost tensor must be (S, J, P); got {C.shape}")
    S, n, m = C.shape
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if len(arrivals) != n:
        raise ValueError("arrivals length != n_jobs axis of C")
    Z = np.ones(n) if workload is None else np.asarray(workload, np.float64)
    specs = [as_spec(l) for l in learners]
    if not specs:
        raise ValueError("need at least one learner")
    backend = resolve_backend(backend)

    ev_kind, ev_j, n_done = build_events(arrivals, d)
    etas = np.stack([sp.eta.values(arrivals, d, m) for sp in specs])
    gammas = np.stack([sp.explore.values(arrivals, d, m) for sp in specs])
    if rng is not None:
        if S != 1:
            raise ValueError("rng streams are single-scenario only")
        u = rng.random(n)[None]
    else:
        u = np.stack([np.random.default_rng(seed + s).random(n)
                      for s in range(S)])

    K = len(specs)
    chosen = np.zeros((S, K, n), dtype=np.int64)
    p_sel = np.zeros((S, K, n))
    e_cost = np.zeros((S, K, n))
    weights = np.zeros((S, K, m))

    with span("replay", backend=backend, scenarios=S, learners=K):
        if backend == "numpy":
            if on_device:
                C = np.asarray(C, dtype=np.float64)
                on_device = False
            for s in range(S):
                for k, sp in enumerate(specs):
                    out = _replay_numpy_one(C[s], sp, u[s], ev_kind, ev_j,
                                            etas[k], gammas[k])
                    chosen[s, k], p_sel[s, k], e_cost[s, k], \
                        weights[s, k] = out
        else:
            pallas_ks: list[int] = []
            if backend == "pallas":
                # The fused kernel implements the full-information
                # exponentiated-weights trajectory — hedge instances only.
                pallas_ks = [k for k, sp in enumerate(specs)
                             if sp.kind == "hedge"]
                if pallas_ks:
                    from repro.kernels.weight_update import hedge_replay
                    out = hedge_replay(C, etas[pallas_ks], u, n_done,
                                       interpret=interpret)
                    for i, k in enumerate(pallas_ks):
                        chosen[:, k] = out["chosen"][:, i]
                        p_sel[:, k] = out["p_chosen"][:, i]
                        e_cost[:, k] = out["expected_cost"][:, i]
                        weights[:, k] = out["weights"][:, i]
            by_kind: dict[str, list[int]] = {}
            for k, sp in enumerate(specs):
                if k not in pallas_ks:
                    by_kind.setdefault(sp.kind, []).append(k)
            for kind, ks in by_kind.items():
                out = _replay_jax_kind(kind, C, u, etas[ks], gammas[ks],
                                       ev_kind, ev_j)
                ch, ps, ec, wf = (np.asarray(o, np.float64) for o in out)
                for i, k in enumerate(ks):
                    chosen[:, k] = ch[:, i].astype(np.int64)
                    p_sel[:, k] = ps[:, i]
                    e_cost[:, k] = ec[:, i]
                    weights[:, k] = wf[:, i]

    return LearnResult(
        specs=specs, chosen=chosen, p_chosen=p_sel, expected_unit=e_cost,
        weights=weights, unit_cost=np.asarray(C, dtype=np.float64),
        arrivals=arrivals, workload=Z,
        feedback_delay=float(d), backend=backend)


def replay_stream(
    jobs,
    policies,
    scenarios,
    r_total: int = 0,
    *,
    learners=("hedge",),
    seed: int = 0,
    scenario_chunk: int | None = None,
    backend: str = "auto",
    engine_backend: str = "auto",
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    interpret: bool | None = None,
    mesh=None,
    overlap: bool | None = None,
) -> StreamLearnResult:
    """Regret curves straight from a scenario stream — no (S, J, P) tensor.

    The engine evaluates ``scenario_chunk`` scenarios per pass
    (``evaluate_grid_chunks`` — one shared grid plan, device-synthesized
    price paths for the jax/pallas engine backends, no per-scenario Python
    market objects on the hot path), each chunk's counterfactual cost
    tensor is replayed by every learner in ``learners`` (scenario s keeps
    replay seed ``seed + s``, so the sampled traces are identical to a
    monolithic ``replay`` over the materialized tensor), and the per-chunk
    ``LearnResult`` is folded into a ``StreamLearnResult`` — running at
    S = 10^4-10^6 scenarios with chunk-sized peak memory.

    ``mesh`` (a ``GridMesh`` / shard count / ``None``) shards the
    scenario axis across a device mesh: the engine chunk is evaluated
    sharded (DESIGN.md §9 — over BOTH axes of a 2-D mesh) AND the replay
    fold runs as a ``shard_map`` program whose only cross-device
    communication is one ``psum`` of the packed per-learner sums per
    chunk, over ``"data"`` only (``_sharded_fold``). The fold's
    device arithmetic is float32, so its statistics agree with the host
    fold to ~1e-4 rather than bitwise. Requires jax replay and engine
    backends. ``overlap`` double-buffers chunk synthesis (see
    ``evaluate_grid``); it is rejected for adaptive sources, whose next
    chunk depends on this chunk's feedback.

    When ``scenarios`` is an adaptive ``ScenarioSpec`` / ``ScenarioStream``
    the chunk's realized regret of ``learners[0]`` is fed back through
    ``ScenarioStream.observe`` BEFORE the next chunk is synthesized: the
    adversary watches the learner at chunk boundaries and concentrates its
    spikes on the most harmful period (the ROADMAP adaptive-adversary
    round trip).
    """
    from repro.engine.api import evaluate_grid_chunks
    from repro.engine.mesh import as_scenario_mesh
    from repro.engine.scenarios import as_source

    if not jobs:
        raise ValueError("need jobs")
    arrivals = np.array([j.arrival for j in jobs])
    if np.any(np.diff(arrivals) < -1e-9):
        raise ValueError("jobs must be arrival-ordered")
    d = max(j.deadline - j.arrival for j in jobs)
    Z = np.array([j.total_work for j in jobs])
    specs = [as_spec(l) for l in learners]
    if not specs:
        raise ValueError("need at least one learner")
    backend = resolve_backend(backend)
    mesh = as_scenario_mesh(mesh)
    if mesh is not None and backend != "jax":
        raise ValueError(
            f"mesh= shards the jax replay fold; replay backend resolved to "
            f"{backend!r} (pass backend='jax' or leave it 'auto' with jax "
            f"installed)")

    source = as_source(scenarios)
    acc = StreamLearnResult(specs=specs, feedback_delay=float(d),
                            backend=backend)
    stream = evaluate_grid_chunks(
        jobs, policies, source, r_total,
        scenario_chunk=scenario_chunk, windows=windows,
        selfowned=selfowned, early_start=early_start, pool="dedicated",
        backend=engine_backend, interpret=interpret, mesh=mesh,
        overlap=overlap)
    if mesh is None:
        with span("replay_stream", backend=backend):
            for ci, ch in enumerate(stream):
                with span("fold", chunk=ci, s0=ch.s0, s1=ch.s1):
                    lr = replay(ch.unit_cost, arrivals, d, workload=Z,
                                learners=specs, seed=seed + ch.s0,
                                backend=backend, interpret=interpret)
                    feedback = acc.fold(lr)
                _weight_metrics(specs, lr.weights.mean(axis=0))
                # The chunk-boundary round trip: a no-op for every
                # non-adaptive source; the generator builds the NEXT chunk
                # only after this returns, so the adversary's state is
                # current when spikes land.
                source.observe(feedback)
        acc.obs = maybe_snapshot()
        return acc

    import jax.numpy as jnp

    # Everything chunk-invariant, once: the event stream, the (K, J)
    # schedule grids REORDERED so instances of one kind are contiguous
    # (``_sharded_fold`` runs one scan program per kind group), and the
    # inverse permutation that puts the folded sums back in specs order.
    J, m = len(jobs), len(policies)
    ev_kind, ev_j, _ = build_events(arrivals, d)
    sample_pos = np.nonzero(ev_kind == 0)[0].astype(np.int32)
    ring = _event_ring(ev_kind)
    by_kind: dict[str, list[int]] = {}
    for k, sp in enumerate(specs):
        by_kind.setdefault(sp.kind, []).append(k)
    perm = np.array([k for ks in by_kind.values() for k in ks])
    inv_perm = np.argsort(perm)
    kinds_sig = tuple((kind, len(ks)) for kind, ks in by_kind.items())
    etas = np.stack([sp.eta.values(arrivals, d, m) for sp in specs])[perm]
    gammas = np.stack([sp.explore.values(arrivals, d, m)
                       for sp in specs])[perm]
    fold_fn = _sharded_fold(mesh, kinds_sig, ring, int(inv_perm[0]))
    consts = (jnp.asarray(etas, jnp.float32), jnp.asarray(gammas,
              jnp.float32), jnp.asarray(ev_kind), jnp.asarray(ev_j),
              jnp.asarray(sample_pos), jnp.asarray(Z, jnp.float32))
    # The donated accumulator carry: the device keeps ONE running f32
    # vector whose buffer is recycled every chunk (donate_argnums above);
    # the host differences consecutive readings to recover the per-chunk
    # sums the telemetry and the adaptive feedback consume.
    dev_acc = jnp.zeros(fold_acc_size(len(specs), J, m), jnp.float32)
    prev_acc = np.zeros(dev_acc.shape[0], np.float64)

    with span("replay_stream", backend=backend, sharded=True):
        for ci, ch in enumerate(stream):
            Sc = ch.unit_cost.shape[0]
            u = np.stack([np.random.default_rng(seed + ch.s0 + s).random(J)
                          for s in range(Sc)])
            valid = np.zeros(mesh.pad(Sc), bool)
            valid[:Sc] = True
            with span("fold", chunk=ci, s0=ch.s0, s1=ch.s1):
                args = (dev_acc,
                        mesh.put_rows(np.asarray(ch.unit_cost, np.float32)),
                        mesh.put_rows(np.asarray(u, np.float32)),
                        mesh.put_rows(valid)) + consts
                record_jit("learn.fold:sharded", fold_fn, *args)
                dev_acc, regret_s = fold_fn(*args)
                cur_acc = np.asarray(dev_acc, np.float64)
                g = _unpack_fold(cur_acc - prev_acc, len(specs), J, m)
                prev_acc = cur_acc
                acc.fold_sums(
                    g["n"], g["realized"][inv_perm], g["expected"][inv_perm],
                    g["regret"][inv_perm], g["regret_sq"][inv_perm],
                    g["best_fixed"], g["curve"][inv_perm],
                    g["curve_sq"][inv_perm], g["weights"][inv_perm],
                    g["top_weight"][inv_perm])
            _weight_metrics(specs,
                            g["weights"][inv_perm] / max(g["n"], 1))
            source.observe(np.asarray(regret_s, np.float64)[:Sc])
    acc.obs = maybe_snapshot()
    return acc
