"""Batched online-learning subsystem (DESIGN.md §7).

Separates *learner* from *replay engine*: ``learners.py`` defines the
state/update interface (Hedge = the paper's Alg. 4, EXP3, UCB1,
epsilon-greedy, follow-the-leader, each with pluggable eta/exploration
schedules); ``replay.py`` runs the sequential sample -> observe -> reweight
recurrence over the evaluation engine's (scenarios x jobs x policies) cost
tensor — sequential float64 numpy as the exact oracle, one ``jax.lax.scan``
per learner kind vmapped across scenarios x schedule-grid instances, or the
fused Pallas weight-update kernel; ``regret.py`` turns the sampled traces
into realized/expected regret curves with per-scenario confidence bands.

    from repro.engine import evaluate_grid
    from repro.learn import replay
    res = evaluate_grid(jobs, policies, markets, r)
    lr = replay(res, arrivals, d, learners=["hedge", "exp3"], backend="jax")
    lr.regret_curve()     # (S, K, J) running regret per learner

``repro.core.tola.run_tola`` delegates its Alg. 4 loop to the numpy oracle
here, bit-compatibly with the pre-subsystem implementation.
"""

from repro.learn.learners import (
    FULL_INFO_KINDS,
    LEARNER_KINDS,
    LearnerSpec,
    Schedule,
    as_spec,
)
from repro.learn.regret import LearnResult, StreamLearnResult, prop_b1_bound
from repro.learn.replay import (
    available_backends,
    build_events,
    replay,
    replay_stream,
    resolve_backend,
)

__all__ = [
    "LEARNER_KINDS", "FULL_INFO_KINDS", "LearnerSpec", "Schedule", "as_spec",
    "LearnResult", "StreamLearnResult", "prop_b1_bound",
    "replay", "replay_stream", "build_events", "available_backends",
    "resolve_backend",
]
