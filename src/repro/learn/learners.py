"""Learner definitions for the online-learning subsystem.

One learner = a *state layout* shared by every algorithm (``logw`` for the
exponentiated-weights family, ``sums``/``counts`` for the index policies)
plus two pure functions:

* ``sample_probs(kind, state, gamma, xp)`` — the distribution a policy is
  drawn from when a job arrives;
* ``update_state(kind, state, c_row, chosen_oh, p_chosen, eta, xp)`` — the
  reweighting applied once the job's window has elapsed and its
  (counterfactual) costs are observable.

Both are written against an array-module parameter ``xp`` (numpy or
jax.numpy) and are branchless in the array ops, so the SAME code runs the
sequential float64 numpy oracle and the ``lax.scan`` replay — backends can
only disagree through float precision, never through logic. Feedback model
per kind:

* ``hedge``   — the paper's Alg. 4: full information (the whole cost row
  enters the update), exponentiated weights, log-space renormalization
  every step so long horizons cannot flush the weights to zero.
* ``exp3``    — bandit feedback: only the sampled policy's cost is observed;
  the importance-weighted estimate ``c/p`` drives the same exponential
  update, and sampling mixes in ``gamma`` uniform exploration.
* ``ucb1``    — bandit feedback, deterministic index policy on the
  lower-confidence bound (costs, so LCB not UCB).
* ``egreedy`` — bandit feedback, greedy on the empirical mean with
  ``gamma``-uniform exploration.
* ``ftl``     — follow-the-leader: full information, plays the policy with
  the smallest cumulative cost so far (no regularization — the unstable
  baseline the regret curves are plotted against).

Schedules (``eta`` for learning rates, ``explore`` for gamma/epsilon) are
evaluated up front into per-job arrays — "pluggable" means swapping a (J,)
vector, which is what makes a schedule grid batchable under vmap.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "LEARNER_KINDS",
    "FULL_INFO_KINDS",
    "Schedule",
    "LearnerSpec",
    "as_spec",
    "init_state",
    "sample_probs",
    "update_state",
]

LEARNER_KINDS = ("hedge", "exp3", "ucb1", "egreedy", "ftl")
# Learners whose update consumes the whole cost row (vs the sampled entry).
FULL_INFO_KINDS = frozenset({"hedge", "ftl"})

_NEG = 3.0e38  # "minus infinity" that stays finite in float32


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A per-job scalar schedule (learning rate or exploration rate).

    ``alg4``    — the paper's Alg. 4 line 16: at the update event of job j
                  (time ``t = a_j + d``), ``eta = sqrt(2 log m / (d *
                  max(t - d, d)))``; reproduced operation-for-operation so
                  the numpy replay stays bit-compatible with the pre-learn
                  ``run_tola`` loop.
    ``const``   — a constant ``c`` (the eta-grid axis of the sweeps).
    ``invsqrt`` — ``c / sqrt(j + 1)`` over the job index.
    """

    kind: str = "alg4"
    c: float = 0.1

    def values(self, arrivals: np.ndarray, d: float, m: int) -> np.ndarray:
        n = len(arrivals)
        if self.kind == "alg4":
            # t - d recomputed from t = a_j + d (NOT simplified to a_j):
            # (a + d) - d can differ from a in float64, and bit-compat with
            # the legacy event loop is part of the numpy oracle's contract.
            t = arrivals + d
            return np.sqrt(2.0 * np.log(m) / (d * np.maximum(t - d, d)))
        if self.kind == "const":
            return np.full(n, float(self.c))
        if self.kind == "invsqrt":
            return self.c / np.sqrt(1.0 + np.arange(n))
        raise ValueError(f"unknown schedule kind {self.kind!r}")

    @property
    def label(self) -> str:
        return "alg4" if self.kind == "alg4" else f"{self.kind}:{self.c:g}"


@dataclasses.dataclass(frozen=True)
class LearnerSpec:
    """One learner instance of a replay sweep: algorithm + schedules."""

    kind: str
    eta: Schedule = Schedule()
    explore: Schedule = Schedule("const", 0.1)

    def __post_init__(self):
        if self.kind not in LEARNER_KINDS:
            raise ValueError(
                f"unknown learner {self.kind!r}; pick from {LEARNER_KINDS}")

    @property
    def label(self) -> str:
        parts = [self.kind]
        if self.kind in ("hedge", "exp3") and self.eta != Schedule():
            parts.append(f"eta={self.eta.label}")
        if self.kind in ("exp3", "egreedy") and \
                self.explore != Schedule("const", 0.1):
            parts.append(f"g={self.explore.label}")
        return "[" + ",".join(parts) + "]" if len(parts) > 1 else self.kind


def as_spec(learner) -> LearnerSpec:
    return learner if isinstance(learner, LearnerSpec) else LearnerSpec(learner)


def init_state(m: int, xp=np) -> dict:
    """Common state layout (every kind carries all fields; scan-friendly)."""
    return {
        "logw": xp.full(m, -float(np.log(m))),
        "sums": xp.zeros(m),
        "counts": xp.zeros(m),
    }


def _softmax(logw, xp):
    w = xp.exp(logw - logw.max())
    return w / w.sum()


def _onehot(idx, m, xp):
    return xp.where(xp.arange(m) == idx, 1.0, 0.0)


def sample_probs(kind: str, state: dict, gamma, xp=np):
    """Sampling distribution over the m policies at a job's arrival."""
    m = state["logw"].shape[0]
    if kind == "hedge":
        return _softmax(state["logw"], xp)
    if kind == "exp3":
        return (1.0 - gamma) * _softmax(state["logw"], xp) + gamma / m
    counts, sums = state["counts"], state["sums"]
    cnt_safe = xp.maximum(counts, 1.0)
    mean = sums / cnt_safe
    untried = counts < 0.5
    if kind == "ftl":
        return _onehot(xp.argmin(sums), m, xp)
    if kind == "ucb1":
        t = xp.maximum(counts.sum(), 1.0)
        lcb = mean - xp.sqrt(2.0 * xp.log(t) / cnt_safe)
        # Untried arms score -inf -> argmin visits them first (numpy and jnp
        # both break ties toward the lowest index).
        return _onehot(xp.argmin(xp.where(untried, -_NEG, lcb)), m, xp)
    if kind == "egreedy":
        greedy = _onehot(xp.argmin(xp.where(untried, -_NEG, mean)), m, xp)
        return (1.0 - gamma) * greedy + gamma / m
    raise ValueError(f"unknown learner kind {kind!r}")


def update_state(kind: str, state: dict, c_row, chosen_oh, p_chosen, eta,
                 xp=np) -> dict:
    """Observe job j's cost row (full info) or sampled entry (bandit).

    ``chosen_oh`` is the one-hot of the policy sampled for this job and
    ``p_chosen`` its probability at sample time (the importance weight).
    The exponentiated-weights updates renormalize in LOG SPACE every step
    (``logw -= logw.max()``) — the max weight is pinned at exp(0) = 1, so no
    horizon length can flush the whole vector to zero (float32 exp
    underflows at logw < -88; a 5k-job stream drifts far past that without
    the rescale).
    """
    logw, sums, counts = state["logw"], state["sums"], state["counts"]
    if kind == "hedge":
        logw = logw - eta * c_row
        logw = logw - logw.max()
    elif kind == "exp3":
        c_hat = chosen_oh * ((c_row * chosen_oh).sum() / p_chosen)
        logw = logw - eta * c_hat
        logw = logw - logw.max()
    elif kind == "ftl":
        sums = sums + c_row
    elif kind in ("ucb1", "egreedy"):
        sums = sums + chosen_oh * (c_row * chosen_oh).sum()
        counts = counts + chosen_oh
    else:
        raise ValueError(f"unknown learner kind {kind!r}")
    return {"logw": logw, "sums": sums, "counts": counts}
