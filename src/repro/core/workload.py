"""Paper Section 6.1 workload generator.

* Job arrivals: Poisson process, rate 4 per unit time.
* Tasks per job: l drawn uniformly from {7, 49}.
* DAG edges: each (i1 < i2) pair independently with probability 0.5; tasks
  without successors/predecessors get one random connection to keep the DAG
  connected (paper's exact construction — generation order IS the topological
  order).
* Parallelism bound: delta_i uniform over {8, 64}.
* Minimum execution time e_i: bounded (generalized) Pareto, shape 7/8,
  scale 7/32, location 1/4, truncated to [2, 10] via exact inverse CDF.
* Task size: z_i = e_i * delta_i.
* Relative deadline: x * e_c (critical path), x uniform on [1, x0] with
  x0 in {1.5, 2, 2.5, 3} for job types 1..4.
"""

from __future__ import annotations

import numpy as np

from repro.core.transform import transform
from repro.core.types import ChainJob, DAGJob, Task

__all__ = ["JOB_TYPE_X0", "sample_bounded_pareto", "generate_dag_jobs", "generate_chain_jobs"]

JOB_TYPE_X0 = {1: 1.5, 2: 2.0, 3: 2.5, 4: 3.0}

# Bounded-Pareto parameters for e_i (paper Section 6.1).
PARETO_SHAPE = 7.0 / 8.0
PARETO_SCALE = 7.0 / 32.0
PARETO_LOC = 1.0 / 4.0
E_MIN, E_MAX = 2.0, 10.0

ARRIVAL_RATE = 4.0          # jobs per unit time
TASK_COUNTS = (7, 49)
PARALLELISM = (8.0, 64.0)


def _gpd_cdf(x: np.ndarray, xi: float, sigma: float, mu: float) -> np.ndarray:
    return 1.0 - np.power(1.0 + xi * (x - mu) / sigma, -1.0 / xi)


def _gpd_icdf(u: np.ndarray, xi: float, sigma: float, mu: float) -> np.ndarray:
    return mu + sigma / xi * (np.power(1.0 - u, -xi) - 1.0)


def sample_bounded_pareto(rng: np.random.Generator, n: int) -> np.ndarray:
    """e_i ~ generalized Pareto truncated to [E_MIN, E_MAX], exact inverse CDF."""
    lo = _gpd_cdf(np.array(E_MIN), PARETO_SHAPE, PARETO_SCALE, PARETO_LOC)
    hi = _gpd_cdf(np.array(E_MAX), PARETO_SHAPE, PARETO_SCALE, PARETO_LOC)
    u = lo + rng.random(n) * (hi - lo)
    return _gpd_icdf(u, PARETO_SHAPE, PARETO_SCALE, PARETO_LOC)


def _random_dag_edges(rng: np.random.Generator, l: int) -> list[list[int]]:
    """preds[i] per the paper's construction; indices are topological."""
    adj = rng.random((l, l)) < 0.5
    adj = np.triu(adj, k=1)  # adj[i1, i2] edge i1 -> i2, i1 < i2
    # Connectivity fixes: childless non-terminal tasks get a random successor;
    # parentless non-initial tasks get a random predecessor.
    for i in range(l - 1):
        if not adj[i, i + 1:].any():
            adj[i, rng.integers(i + 1, l)] = True
    for i in range(1, l):
        if not adj[:i, i].any():
            adj[rng.integers(0, i), i] = True
    return [list(np.nonzero(adj[:, i])[0]) for i in range(l)]


def generate_dag_jobs(
    n_jobs: int,
    job_type: int,
    seed: int = 0,
) -> list[DAGJob]:
    rng = np.random.default_rng(seed)
    x0 = JOB_TYPE_X0[job_type]
    arrivals = np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE, n_jobs))
    jobs: list[DAGJob] = []
    for j in range(n_jobs):
        l = int(rng.choice(TASK_COUNTS))
        e = sample_bounded_pareto(rng, l)
        delta = rng.choice(PARALLELISM, l)
        tasks = tuple(Task(z=float(e[i] * delta[i]), delta=float(delta[i]))
                      for i in range(l))
        preds = tuple(tuple(p) for p in _random_dag_edges(rng, l))
        job = DAGJob(arrival=float(arrivals[j]), deadline=float(arrivals[j]) + 1.0,
                     tasks=tasks, preds=preds)
        x = rng.uniform(1.0, x0)
        job = DAGJob(arrival=job.arrival,
                     deadline=job.arrival + x * job.critical_path,
                     tasks=tasks, preds=preds)
        jobs.append(job)
    return jobs


def generate_chain_jobs(
    n_jobs: int,
    job_type: int,
    seed: int = 0,
) -> list[ChainJob]:
    """DAG jobs passed through the Nagarajan transform (Algorithm 3)."""
    return [transform(j) for j in generate_dag_jobs(n_jobs, job_type, seed)]
