"""Core data types for the paper's scheduling problem.

A *job* is a DAG of malleable tasks (Section 3.2 of the paper). Each task i has
a workload ``z_i`` (instance-time), a parallelism bound ``delta_i`` (max number
of instances usable simultaneously) and therefore a minimum execution time
``e_i = z_i / delta_i`` (Eq. 1). A job arrives at ``a_j`` and must finish by its
deadline ``d_j``.

After the Nagarajan transform (Appendix B.1) every job becomes a *chain* of
pseudo-tasks executed strictly in order; the chain is what the deadline
allocator (Algorithm 1) and the instance policies (Section 4) operate on.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Task",
    "ChainJob",
    "DAGJob",
    "Allocation",
    "TaskCost",
    "JobCost",
]


@dataclasses.dataclass(frozen=True)
class Task:
    """A malleable task (paper Section 3.2)."""

    z: float      # workload, in instance-time
    delta: float  # parallelism bound (max simultaneous instances)

    def __post_init__(self) -> None:
        if self.z < 0:
            raise ValueError(f"task workload must be >= 0, got {self.z}")
        if self.delta <= 0:
            raise ValueError(f"parallelism bound must be > 0, got {self.delta}")

    @property
    def e(self) -> float:
        """Minimum execution time e_i = z_i / delta_i (Eq. 1)."""
        return self.z / self.delta


@dataclasses.dataclass(frozen=True)
class ChainJob:
    """A job with a chain precedence constraint: task k+1 starts after task k.

    ``arrival`` and ``deadline`` delimit the window [a_j, d_j] in which all
    tasks must run (Eq. 4).
    """

    arrival: float
    deadline: float
    tasks: tuple[Task, ...]

    def __post_init__(self) -> None:
        if self.deadline < self.arrival:
            raise ValueError("deadline before arrival")
        if not self.tasks:
            raise ValueError("job must have at least one task")

    @property
    def l(self) -> int:
        return len(self.tasks)

    @property
    def window(self) -> float:
        return self.deadline - self.arrival

    @property
    def total_work(self) -> float:
        return float(sum(t.z for t in self.tasks))

    @property
    def min_makespan(self) -> float:
        """Sum of minimum execution times — the chain's critical path."""
        return float(sum(t.e for t in self.tasks))

    @property
    def slack(self) -> float:
        """omega = (d_j - a_j) - sum_i e_i; must be >= 0 for feasibility."""
        return self.window - self.min_makespan

    def feasible(self) -> bool:
        return self.slack >= -1e-9

    def z_array(self) -> np.ndarray:
        return np.array([t.z for t in self.tasks], dtype=np.float64)

    def delta_array(self) -> np.ndarray:
        return np.array([t.delta for t in self.tasks], dtype=np.float64)

    def e_array(self) -> np.ndarray:
        return np.array([t.e for t in self.tasks], dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class DAGJob:
    """A general DAG job. ``preds[i]`` lists the predecessors of task i.

    Tasks are indexed in a topological order (the generator of Section 6.1
    emits them that way; ``validate`` checks it).
    """

    arrival: float
    deadline: float
    tasks: tuple[Task, ...]
    preds: tuple[tuple[int, ...], ...]

    def __post_init__(self) -> None:
        if len(self.preds) != len(self.tasks):
            raise ValueError("preds length must match tasks length")
        for i, ps in enumerate(self.preds):
            for p in ps:
                if not (0 <= p < i):
                    raise ValueError(
                        f"predecessor {p} of task {i} violates topological order"
                    )

    @property
    def l(self) -> int:
        return len(self.tasks)

    @property
    def window(self) -> float:
        return self.deadline - self.arrival

    @property
    def total_work(self) -> float:
        return float(sum(t.z for t in self.tasks))

    def earliest_starts(self) -> np.ndarray:
        """Earliest start q_i when every task runs at full parallelism
        (the pseudo-schedule of Appendix B.1): q_i = max_{i' < i} (q_i' + e_i').
        """
        q = np.zeros(self.l, dtype=np.float64)
        e = np.array([t.e for t in self.tasks], dtype=np.float64)
        for i in range(self.l):
            if self.preds[i]:
                q[i] = max(q[p] + e[p] for p in self.preds[i])
        return q

    @property
    def critical_path(self) -> float:
        """e_j^c — the minimum time to finish the whole DAG (Section 6.1)."""
        q = self.earliest_starts()
        e = np.array([t.e for t in self.tasks], dtype=np.float64)
        return float(np.max(q + e)) if self.l else 0.0


@dataclasses.dataclass(frozen=True)
class Allocation:
    """The scheduler's decision for one chain job.

    ``windows[i] = (start_i, deadline_i)`` — task i executes in this window;
    start_0 = arrival, start_i = deadline_{i-1} (planned starts, Alg. 2).
    ``r[i]`` — self-owned instances reserved for task i over its whole window.
    """

    job: ChainJob
    windows: tuple[tuple[float, float], ...]
    r: tuple[float, ...]

    def __post_init__(self) -> None:
        if len(self.windows) != self.job.l or len(self.r) != self.job.l:
            raise ValueError("allocation arity mismatch")

    @property
    def sizes(self) -> np.ndarray:
        """hat-sigma_i — window sizes."""
        return np.array([b - a for a, b in self.windows], dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class TaskCost:
    """Realized cost decomposition for one task under one policy."""

    spot_cost: float
    ondemand_cost: float
    spot_work: float       # workload processed by spot instances
    ondemand_work: float   # workload processed by on-demand instances
    selfowned_work: float  # workload processed by self-owned instances
    finish_time: float     # realized completion time
    turning_point: float | None  # None if the task never lost flexibility

    @property
    def total(self) -> float:
        return self.spot_cost + self.ondemand_cost


@dataclasses.dataclass(frozen=True)
class JobCost:
    """Aggregate over a job's tasks."""

    tasks: tuple[TaskCost, ...]

    @property
    def total(self) -> float:
        return float(sum(t.total for t in self.tasks))

    @property
    def spot_cost(self) -> float:
        return float(sum(t.spot_cost for t in self.tasks))

    @property
    def ondemand_cost(self) -> float:
        return float(sum(t.ondemand_cost for t in self.tasks))

    @property
    def spot_work(self) -> float:
        return float(sum(t.spot_work for t in self.tasks))

    @property
    def selfowned_work(self) -> float:
        return float(sum(t.selfowned_work for t in self.tasks))


def chain_from_arrays(
    arrival: float,
    deadline: float,
    z: Sequence[float],
    delta: Sequence[float],
) -> ChainJob:
    return ChainJob(
        arrival=float(arrival),
        deadline=float(deadline),
        tasks=tuple(Task(z=float(a), delta=float(b)) for a, b in zip(z, delta, strict=True)),
    )
