"""Spot-market model (paper Sections 3.1 and 6.1).

Each unit of time is divided into ``SLOTS_PER_UNIT`` equal slots; the spot
price is re-drawn per slot from a *bounded (truncated) exponential*
distribution with mean 0.13 on [0.12, 1] (Section 6.1, following [31]).
On-demand instances cost ``p_od`` (normalized to 1) per instance-unit-time and
are billed continuously — a user pays for exactly the period consumed.

A user bidding ``b`` holds spot instances during a slot iff ``price <= b``
(paper: the request succeeds only when the bid exceeds the spot price); while
holding them it pays the *spot price*. From the user's perspective the spot
service is therefore a piecewise-constant availability process ``a(t)`` with
a piecewise-constant payment rate ``price(t) * a(t)``.

The whole simulation is closed-form on top of three cumulative integrals per
bid (DESIGN.md Section 5):

    A(t) = integral of a           (cumulative available time)
    H(t) = t - A(t)                (cumulative UNavailable time)
    C(t) = integral of price * a   (cumulative spot payment per instance)

All three are monotone piecewise-linear with slopes in {0, 1} (or price), so
"first time A reaches x" / "first time H reaches x" are exact
searchsorted-plus-interpolation queries, vectorized over tasks.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "SLOTS_PER_UNIT",
    "SpotMarket",
    "BidView",
    "stacked_view_arrays",
    "truncated_exp_rate",
    "sample_truncated_exp",
]

SLOTS_PER_UNIT = 12  # paper Section 6.1

# Spot price distribution parameters (paper Section 6.1).
PRICE_MEAN = 0.13
PRICE_LO = 0.12
PRICE_HI = 1.0
P_ONDEMAND = 1.0


@functools.lru_cache(maxsize=1024)  # bounded: (mean, lo, hi) triples
def truncated_exp_rate(mean: float, lo: float, hi: float) -> float:
    """Rate lambda of an exponential truncated to [lo, hi] with given mean.

    Solved by bisection on the monotone map lambda -> truncated mean.
    """
    if not lo < mean < hi:
        raise ValueError(f"mean {mean} outside ({lo}, {hi})")
    span = hi - lo

    def trunc_mean(lam: float) -> float:
        # E[X] = lo + 1/lam - span * q / (1 - q), q = exp(-lam * span)
        q = np.exp(-lam * span)
        return lo + 1.0 / lam - span * q / (1.0 - q)

    lo_l, hi_l = 1e-9, 1e6
    for _ in range(200):
        mid = 0.5 * (lo_l + hi_l)
        if trunc_mean(mid) > mean:
            lo_l = mid  # mean too high -> need larger rate
        else:
            hi_l = mid
    return 0.5 * (lo_l + hi_l)


def sample_truncated_exp(
    rng: np.random.Generator, n: int, mean: float, lo: float, hi: float
) -> np.ndarray:
    """Exact inverse-CDF sampling of the truncated exponential."""
    lam = truncated_exp_rate(mean, lo, hi)
    u = rng.random(n)
    # F(x) on [lo, hi]: (1 - exp(-lam (x - lo))) / (1 - exp(-lam (hi - lo)))
    tail = 1.0 - np.exp(-lam * (hi - lo))
    return lo - np.log1p(-u * tail) / lam


@dataclasses.dataclass(frozen=True)
class BidView:
    """Cumulative integrals of the availability process for one bid price."""

    slot: float           # slot length in time units (1 / SLOTS_PER_UNIT)
    avail: np.ndarray     # (n_slots,) bool — instance held during slot k
    boundaries: np.ndarray  # (n_slots + 1,) slot boundary times
    A_cum: np.ndarray     # (n_slots + 1,) cumulative available time
    C_cum: np.ndarray     # (n_slots + 1,) cumulative spot payment (1 instance)

    @property
    def horizon(self) -> float:
        return float(self.boundaries[-1])

    @property
    def H_cum(self) -> np.ndarray:
        return self.boundaries - self.A_cum

    # -- point evaluations (vectorized over t) ---------------------------------
    def _locate(self, t: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        t = np.clip(np.asarray(t, dtype=np.float64), 0.0, self.horizon)
        k = np.clip((t / self.slot).astype(np.int64), 0, len(self.avail) - 1)
        frac = t - self.boundaries[k]
        return k, frac

    def A(self, t: np.ndarray) -> np.ndarray:
        """Cumulative available time at t (piecewise linear, slope = avail)."""
        k, frac = self._locate(t)
        return self.A_cum[k] + self.avail[k] * frac

    def H(self, t: np.ndarray) -> np.ndarray:
        return np.asarray(t, dtype=np.float64) - self.A(t)

    def C(self, t: np.ndarray) -> np.ndarray:
        """Cumulative spot payment for one continuously-requested instance."""
        k, frac = self._locate(t)
        rate = np.where(self.avail[k], self._price[k], 0.0)
        return self.C_cum[k] + rate * frac

    # set post-init by SpotMarket (price array shared across bids)
    @property
    def _price(self) -> np.ndarray:
        return self.__dict__["price"]

    # -- inverse queries (vectorized over targets) -----------------------------
    def t_for_A(self, target: np.ndarray) -> np.ndarray:
        """Earliest t with A(t) >= target; +inf if never within horizon."""
        return _invert_monotone(self.boundaries, self.A_cum, target)

    def t_for_H(self, target: np.ndarray) -> np.ndarray:
        """Earliest t with H(t) >= target; +inf if never within horizon."""
        return _invert_monotone(self.boundaries, self.H_cum, target)


def stacked_view_arrays(prices, avail, slot: float, xp=np):
    """(A_cum, C_cum) cumulative view arrays from per-slot prices + availability.

    The traceable twin of ``SpotMarket.view``: ``prices``/``avail`` may carry
    leading batch axes (``(..., n_slots)`` -> ``(..., n_slots + 1)``), and
    ``xp=jax.numpy`` traces the same arithmetic into a jit program (the
    scenario subsystem's on-device synthesis path). With ``xp=np`` on a 1-D
    f64 row this is bit-identical to the per-bid view construction — the
    host path stays the exact oracle by routing through this function.
    """
    step_a = xp.where(avail, slot, 0.0)
    step_c = xp.where(avail, prices * slot, 0.0)
    pad = xp.zeros(step_a.shape[:-1] + (1,), dtype=step_a.dtype)
    A_cum = xp.concatenate([pad, xp.cumsum(step_a, axis=-1)], axis=-1)
    C_cum = xp.concatenate([pad, xp.cumsum(step_c, axis=-1)], axis=-1)
    return A_cum, C_cum


def _invert_monotone(
    boundaries: np.ndarray, cum: np.ndarray, target: np.ndarray
) -> np.ndarray:
    """Invert a nondecreasing piecewise-linear f with slopes in {0, 1}.

    ``cum[k] = f(boundaries[k])``. Returns the earliest t with f(t) >= target
    (exactly: f(t) == target at the returned t unless target <= f(0)).
    """
    target = np.asarray(target, dtype=np.float64)
    k = np.searchsorted(cum, target, side="left")
    out = np.full(target.shape, np.inf)
    ok = k <= len(cum) - 1
    # k == 0 -> target <= f(0): crossing at t = 0.
    kz = ok & (k == 0)
    out[kz] = boundaries[0]
    ki = ok & (k > 0)
    kk = k[ki]
    # Crossing inside slot kk-1 where the slope must be 1.
    out[ki] = boundaries[kk - 1] + (target[ki] - cum[kk - 1])
    return out


class SpotMarket:
    """A realized spot-price path plus per-bid cumulative views.

    The price path is drawn once per (seed, horizon); ``view(bid)`` builds and
    caches the cumulative integrals for a bid. All downstream cost math is
    exact (no per-slot loops) given these arrays.
    """

    def __init__(
        self,
        horizon_units: float,
        seed: int = 0,
        slots_per_unit: int = SLOTS_PER_UNIT,
        price_mean: float = PRICE_MEAN,
        price_lo: float = PRICE_LO,
        price_hi: float = PRICE_HI,
        p_ondemand: float = P_ONDEMAND,
        price_model: str = "shifted",
    ) -> None:
        self.slots_per_unit = slots_per_unit
        self.slot = 1.0 / slots_per_unit
        self.n_slots = int(np.ceil(horizon_units * slots_per_unit)) + 1
        self.p_ondemand = float(p_ondemand)
        rng = np.random.default_rng(seed)
        if price_model == "shifted":
            # "Bounded exponential, mean 0.13, bounds [0.12, 1]" read as
            # price = lo + Exp(mean 0.13), clipped above at 1. This is the
            # only reading whose realized per-bid availabilities (0.37-0.75
            # across B = {0.18..0.30}) bracket the paper's beta grid
            # C2 = {0.45..0.77, 1} — i.e. the regime the paper's policy grid
            # was designed for. See DESIGN.md Section 4 and the ablation in
            # EXPERIMENTS.md (the truncated reading degenerates to
            # availability ~0.995 at every bid, erasing the paper's spot
            # dynamics entirely).
            self.price = np.minimum(
                price_lo + rng.exponential(price_mean, self.n_slots), price_hi
            )
        elif price_model == "clip":
            # Exponential with mean 0.13 clipped to the bounds (availability
            # 0.75-0.90 across B) — kept as an ablation.
            self.price = np.clip(
                rng.exponential(price_mean, self.n_slots), price_lo, price_hi
            )
        elif price_model == "truncate":
            self.price = sample_truncated_exp(
                rng, self.n_slots, price_mean, price_lo, price_hi
            )
        else:
            raise ValueError(f"unknown price_model {price_model!r}")
        self.boundaries = np.arange(self.n_slots + 1, dtype=np.float64) * self.slot
        self._views: dict[float, BidView] = {}

    @classmethod
    def from_prices(
        cls,
        prices: np.ndarray,
        slots_per_unit: int = SLOTS_PER_UNIT,
        p_ondemand: float = P_ONDEMAND,
    ) -> "SpotMarket":
        """Replay adapter: wrap a realized per-slot price trace.

        The engine's scenario layer uses this to evaluate policy grids
        against recorded (or adversarial) spot-price paths instead of the
        synthetic price law — all downstream cumulative-array machinery is
        identical.
        """
        prices = np.asarray(prices, dtype=np.float64)
        if prices.ndim != 1 or len(prices) == 0:
            raise ValueError("prices must be a non-empty 1-D per-slot trace")
        m = cls.__new__(cls)
        m.slots_per_unit = slots_per_unit
        m.slot = 1.0 / slots_per_unit
        m.n_slots = len(prices)
        m.p_ondemand = float(p_ondemand)
        m.price = prices.copy()
        m.boundaries = np.arange(m.n_slots + 1, dtype=np.float64) * m.slot
        m._views = {}
        return m

    @property
    def horizon(self) -> float:
        return float(self.boundaries[-1])

    def availability(self, bid: float) -> np.ndarray:
        return self.price <= bid + 1e-12

    def view(self, bid: float) -> BidView:
        key = round(float(bid), 12)
        if key not in self._views:
            avail = self.availability(bid)
            A_cum, C_cum = stacked_view_arrays(self.price, avail, self.slot)
            view = BidView(
                slot=self.slot,
                avail=avail,
                boundaries=self.boundaries,
                A_cum=A_cum,
                C_cum=C_cum,
            )
            view.__dict__["price"] = self.price
            self._views[key] = view
        return self._views[key]

    def beta_realized(self, bid: float) -> float:
        """Realized average availability for a bid — the market's true beta."""
        return float(np.mean(self.availability(bid)))
