"""Benchmark policies (paper Section 6.1) + the policy grids.

* ``Greedy``  — head task bids full-parallelism spot until the remaining
  critical path hits the remaining window, then everything on-demand
  (sequential global state; executed by ``oracle_greedy_chain``).
* ``Even``    — window slack split evenly across tasks, per-task composition
  still per Prop 4.1 (realized by ``run_jobs(windows='even')``).
* ``NaiveSelfOwned`` — r_i = min{N(window), delta_i}, first-come-first-served
  (``selfowned='naive'``).

Policy grids C1 (beta_0), C2 (beta), B (bid) exactly as in Section 6.1.
"""

from __future__ import annotations

import numpy as np

from repro.core.market import SpotMarket
from repro.core.oracle import oracle_greedy_chain
from repro.core.scheduler import Policy, StreamCosts, run_jobs
from repro.core.types import ChainJob

__all__ = [
    "C1_BETA0", "C2_BETA", "B_BIDS",
    "spot_od_policies", "selfowned_policies", "benchmark_bid_policies",
    "run_greedy", "run_even", "sweep_policies",
]

C1_BETA0 = (2 / 12, 4 / 14, 6 / 16, 8 / 18, 1 / 2, 0.6, 0.7)
C2_BETA = (1.0, 1 / 1.3, 1 / 1.6, 1 / 1.9, 1 / 2.2)
B_BIDS = (0.18, 0.21, 0.24, 0.27, 0.30)


def spot_od_policies() -> list[Policy]:
    """P = {(beta, b)} — 25 policies (Experiment 1)."""
    return [Policy(beta=b2, bid=b) for b2 in C2_BETA for b in B_BIDS]


def selfowned_policies() -> list[Policy]:
    """P = {(beta_0, beta, b)} — 175 policies (Experiments 2-4)."""
    return [Policy(beta=b2, bid=b, beta0=b0)
            for b0 in C1_BETA0 for b2 in C2_BETA for b in B_BIDS]


def benchmark_bid_policies(beta: float = 0.5, beta0: float | None = None) -> list[Policy]:
    """P' = {b} — the benchmarks are parameterized by bid only."""
    return [Policy(beta=beta, bid=b, beta0=beta0) for b in B_BIDS]


def sweep_policies(
    jobs: list[ChainJob],
    policies: list[Policy],
    markets,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    backend: str = "auto",
    scenario_chunk: int | None = None,
    mesh=None,
) -> "tuple[Policy, float, StreamCosts, EngineResult]":  # noqa: F821
    """min over a policy grid of the realized average unit cost.

    One batched engine pass with shared-pool (run_jobs) semantics across all
    policies x bids x scenarios; returns (best policy, its alpha —
    scenario-mean when several markets are given, its StreamCosts in
    scenario 0, the full EngineResult). ``markets`` accepts everything
    ``evaluate_grid`` does (a market, a list, a ``ScenarioSpec`` /
    source); ``scenario_chunk`` streams the scenario axis K per pass;
    ``mesh`` shards the scenario axis across devices (DESIGN.md §9).
    """
    from repro.engine import evaluate_grid

    res = evaluate_grid(jobs, policies, markets, r_total, windows=windows,
                        selfowned=selfowned, early_start=early_start,
                        pool="shared", backend=backend,
                        scenario_chunk=scenario_chunk, mesh=mesh)
    p, alpha = res.best()
    return policies[p], alpha, res.stream_costs(p, 0), res


def run_greedy(
    jobs: list[ChainJob], bid: float, market: SpotMarket, batch: bool = True
) -> StreamCosts:
    """Greedy benchmark over a job stream (spot + on-demand only).

    ``batch=True`` uses the slot-synchronous vectorized engine (cross-checked
    in tests against the sequential ``oracle_greedy_chain``)."""
    n = len(jobs)
    out = StreamCosts.zeros(n)
    out.workload[:] = [j.total_work for j in jobs]
    if batch:
        res = _greedy_batch(jobs, bid, market)
        out.spot_cost[:] = res["spot_cost"]
        out.ondemand_cost[:] = res["ondemand_cost"]
        out.spot_work[:] = res["spot_work"]
        out.ondemand_work[:] = res["ondemand_work"]
        return out
    for ji, job in enumerate(jobs):
        res = oracle_greedy_chain(
            market, bid, job.arrival, job.deadline,
            job.z_array(), job.delta_array())
        out.spot_cost[ji] = res["spot_cost"]
        out.ondemand_cost[ji] = res["ondemand_cost"]
        out.spot_work[ji] = res["spot_work"]
        out.ondemand_work[ji] = res["ondemand_work"]
    return out


def _greedy_batch(jobs: list[ChainJob], bid: float, market: SpotMarket) -> dict:
    """Slot-synchronous vectorized Greedy over all jobs at once.

    Invariants exploited (same as the sequential oracle):
      * while spot is available the head task runs at full parallelism, so
        both the remaining critical path and the remaining window shrink at
        rate 1 — the switch margin is CONSTANT inside available slots and
        only task-completion events occur there;
      * while spot is unavailable nothing runs, so the margin shrinks at
        rate 1 and the switch can fire mid-slot — at which point the
        on-demand cost is exactly the remaining workload (back-to-back
        full-parallelism on-demand fills the window).
    """
    J = len(jobs)
    L = max(j.l for j in jobs)
    rem = np.zeros((J, L)); delta = np.ones((J, L))
    for ji, job in enumerate(jobs):
        rem[ji, :job.l] = job.z_array(); delta[ji, :job.l] = job.delta_array()
    arrival = np.array([j.arrival for j in jobs])
    deadline = np.array([j.deadline for j in jobs])
    head = np.zeros(J, dtype=np.int64)
    lmax = np.array([j.l for j in jobs])
    crit = (rem / delta).sum(axis=1)
    spot_cost = np.zeros(J); spot_work = np.zeros(J); od_work = np.zeros(J)
    done = np.zeros(J, dtype=bool)

    avail = market.availability(bid)
    price = market.price
    slot = market.slot
    k_lo = int(np.floor(arrival.min() / slot))
    k_hi = min(int(np.ceil(deadline.max() / slot)) + 1, len(avail))
    rows = np.arange(J)

    for k in range(k_lo, k_hi):
        t0, t1 = k * slot, (k + 1) * slot
        live = (~done) & (arrival < t1 - 1e-15) & (head < lmax)
        if not live.any():
            continue
        span = np.minimum(t1, deadline) - np.maximum(t0, arrival)
        if avail[k]:
            # Completion events only; a few carry iterations handle chains of
            # short pseudo-tasks completing inside one slot.
            left = np.where(live, np.maximum(span, 0.0), 0.0)
            for _ in range(64):
                act = left > 1e-15
                if not act.any():
                    break
                h = np.minimum(head, L - 1)
                d_h = delta[rows, h]
                r_h = rem[rows, h]
                dt = np.minimum(left, np.where(act, r_h / d_h, 0.0))
                work = d_h * dt
                spot_cost += np.where(act, d_h * price[k] * dt, 0.0)
                spot_work += np.where(act, work, 0.0)
                crit -= np.where(act, dt, 0.0)
                rem[rows, h] = np.where(act, r_h - work, r_h)
                finished = act & (rem[rows, h] <= 1e-12)
                rem[rows[finished], h[finished]] = 0.0
                head = np.where(finished, head + 1, head)
                done |= finished & (head >= lmax)
                left = np.where(act, left - dt, 0.0)
                left = np.where(done, 0.0, left)
        else:
            margin = (deadline - np.maximum(t0, arrival)) - crit
            fire = live & (margin <= span + 1e-15) & (span > 0)
            if fire.any():
                # Switch: remaining work all on-demand; job leaves the pool.
                leftover = rem[fire].sum(axis=1)
                od_work[fire] += leftover
                done[fire] = True
                rem[fire] = 0.0
    # Any stragglers past the horizon (fp slack): on-demand them.
    tail = rem.sum(axis=1)
    od_work += np.where(tail > 1e-9, tail, 0.0)
    return {
        "spot_cost": spot_cost,
        "ondemand_cost": market.p_ondemand * od_work,
        "spot_work": spot_work,
        "ondemand_work": od_work,
    }


def run_even(
    jobs: list[ChainJob],
    policy: Policy,
    market: SpotMarket,
    r_total: int = 0,
    selfowned: str = "naive",
) -> StreamCosts:
    """Even-window benchmark (optionally with the naive self-owned policy)."""
    return run_jobs(jobs, policy, market, r_total=r_total,
                    windows="even", selfowned=selfowned)
