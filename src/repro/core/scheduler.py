"""Algorithm 2 — deadline + instance allocation over an arriving job stream.

Events (paper Alg. 2):

  * ``t = a_j``  — allocate deadlines to the job's chain (lines 1-5):
                   Dealloc(beta) when r = 0 or beta < beta_0,
                   Dealloc(beta_0) when r > 0 and beta_0 <= beta.
  * task start   — allocate self-owned instances r_i by policy (12)
                   (lines 6-10). Reservations live on the PLANNED windows
                   [s_{i-1}, s_i] (policy (12) is defined on them), so all
                   pool events are known at arrival and are processed in
                   global chronological order across overlapping jobs.
  * in-window    — spot while flexibility holds (Def. 3.1), on-demand after
                   the turning point (lines 11-15), realized exactly by
                   ``simulate_tasks``. Execution is *early-start* by default
                   (paper Table 1: a task begins at its predecessor's
                   realized finish); ``early_start=False`` gives the
                   planned-start variant used by the Even benchmark, whose
                   windows are prescriptive ("tasks are executed and
                   finished in the specified windows", Section 6.1).

``run_jobs`` is the realized system (shared-pool contention included);
``evaluate_policy_fullpool`` is the counterfactual evaluator used by TOLA's
weight updates and fixed-policy sweeps — each candidate policy sees the pool
as if dedicated, the same simplification [10]/[12] make when scoring
policies offline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dealloc import window_sizes
from repro.core.market import SpotMarket
from repro.core.policy import f_selfowned
from repro.core.pool import SelfOwnedPool
from repro.core.simulate import simulate_chains_early, simulate_tasks
from repro.core.types import ChainJob

__all__ = [
    "Policy",
    "StreamCosts",
    "PlanBatch",
    "build_plans",
    "run_jobs",
    "evaluate_policy_fullpool",
]


@dataclasses.dataclass(frozen=True)
class Policy:
    """One parametric policy {beta, b, beta_0} (paper Section 5)."""

    beta: float
    bid: float
    beta0: float | None = None  # None <=> no self-owned instances considered

    def dealloc_param(self, r_total: int) -> float:
        """Lines 1-5 of Algorithm 2: which parameter drives Dealloc."""
        if r_total > 0 and self.beta0 is not None and self.beta0 <= self.beta:
            return self.beta0
        return self.beta


@dataclasses.dataclass
class StreamCosts:
    """Per-job realized costs for a processed stream (all arrays (n_jobs,))."""

    spot_cost: np.ndarray
    ondemand_cost: np.ndarray
    spot_work: np.ndarray
    ondemand_work: np.ndarray
    selfowned_work: np.ndarray
    workload: np.ndarray       # Z_j
    selfowned_reserved: np.ndarray

    @classmethod
    def zeros(cls, n: int) -> "StreamCosts":
        return cls(*(np.zeros(n) for _ in range(7)))

    @property
    def total_cost(self) -> np.ndarray:
        return self.spot_cost + self.ondemand_cost

    def average_unit_cost(self) -> float:
        """alpha = sum_j c_j / sum_j Z_j (paper Section 6.1)."""
        return float(self.total_cost.sum() / self.workload.sum())


@dataclasses.dataclass
class PlanBatch:
    """Padded (n_jobs, L_max) plan of windows/workloads for a job stream."""

    arrival: np.ndarray    # (J,)
    starts: np.ndarray     # (J, L) planned window starts
    ends: np.ndarray       # (J, L) planned window ends (task deadlines)
    z: np.ndarray          # (J, L) task workloads (0 on padding)
    delta: np.ndarray      # (J, L) parallelism bounds (1 on padding)
    mask: np.ndarray       # (J, L) real-task mask
    bid: np.ndarray        # (J,) per-job bid price
    beta0: np.ndarray      # (J,) per-job beta_0 (nan = none)

    @property
    def sizes(self) -> np.ndarray:
        return self.ends - self.starts

    @property
    def workload(self) -> np.ndarray:
        return self.z.sum(axis=1)


def _job_windows(job: ChainJob, policy: Policy, r_total: int, mode: str) -> np.ndarray:
    if mode == "dealloc":
        return window_sizes(job, policy.dealloc_param(r_total))
    if mode == "even":
        e = job.e_array()
        return e + max(job.slack, 0.0) / job.l
    raise ValueError(f"unknown window mode {mode!r}")


def build_plans(
    jobs: list[ChainJob],
    policies: Policy | list[Policy],
    r_total: int = 0,
    windows: str = "dealloc",
) -> PlanBatch:
    """Lines 1-5 for every job: padded window/workload matrices."""
    J = len(jobs)
    pol_list = policies if isinstance(policies, list) else [policies] * J
    L = max(j.l for j in jobs)
    starts = np.zeros((J, L)); ends = np.zeros((J, L))
    z = np.zeros((J, L)); delta = np.ones((J, L))
    mask = np.zeros((J, L), dtype=bool)
    arrival = np.zeros(J); bid = np.zeros(J); beta0 = np.full(J, np.nan)
    for ji, (job, pol) in enumerate(zip(jobs, pol_list)):
        sizes = _job_windows(job, pol, r_total, windows)
        bounds = job.arrival + np.concatenate([[0.0], np.cumsum(sizes)])
        l = job.l
        starts[ji, :l] = bounds[:-1]; ends[ji, :l] = bounds[1:]
        # Padding keeps ends monotone so the early-start scan stays trivial.
        if l < L:
            starts[ji, l:] = bounds[-1]; ends[ji, l:] = bounds[-1]
        z[ji, :l] = job.z_array(); delta[ji, :l] = job.delta_array()
        mask[ji, :l] = True
        arrival[ji] = job.arrival
        bid[ji] = pol.bid
        beta0[ji] = pol.beta0 if pol.beta0 is not None else np.nan
    return PlanBatch(arrival=arrival, starts=starts, ends=ends, z=z,
                     delta=delta, mask=mask, bid=bid, beta0=beta0)


def _selfowned_counts_vec(
    z: np.ndarray, delta: np.ndarray, sizes: np.ndarray,
    beta0: np.ndarray | float | None, available, mode: str,
) -> np.ndarray:
    """Integral r_i (policy (12) or the naive benchmark), vectorized."""
    if mode == "prop12":
        if beta0 is None:
            return np.zeros_like(z)
        b0 = np.broadcast_to(np.asarray(beta0, dtype=np.float64), z.shape)
        safe_b0 = np.where(np.isnan(b0), 1.0, b0)
        f = np.ceil(f_selfowned(z, delta, np.maximum(sizes, 1e-12), safe_b0) - 1e-9)
        f = np.where(np.isnan(b0), 0.0, f)
        useful = np.ceil(np.where(sizes > 0, z / np.maximum(sizes, 1e-12), 0.0) - 1e-9)
        avail = np.broadcast_to(np.asarray(available, dtype=np.float64), z.shape)
        return np.maximum(0.0, np.minimum.reduce([f, avail, delta, useful]))
    if mode == "naive":
        avail = np.broadcast_to(np.asarray(available, dtype=np.float64), z.shape)
        return np.maximum(0.0, np.minimum(avail, delta))
    raise ValueError(f"unknown self-owned mode {mode!r}")


_POOL_CHUNK = 256  # tasks per optimistic batch of the chronological alloc


def _allocate_pool(
    plan: PlanBatch, r_total: int, selfowned: str,
    slots_per_unit: int,
) -> tuple[np.ndarray, SelfOwnedPool | None]:
    """Chronological shared-pool allocation on the planned windows.

    Tasks are processed in chronological start order, but in *optimistic
    batches*: every task of a chunk is tentatively granted
    ``min(cap, total - rangemax(used))`` against the occupancy at chunk
    entry (one vectorized sparse-table query for the whole chunk), the
    chunk's combined occupancy delta is built as one diff-array cumsum, and
    if the pool stays within capacity everywhere the chunk commits with a
    single batched slot-grid write. That outcome is exactly what the
    sequential scan would produce: each task's own grant is part of the
    checked final occupancy, so feasibility pins every prefix grant to the
    tentative value from both sides (the entry-occupancy grant is an upper
    bound on the sequential grant, and a feasible total leaves each prefix
    at least that much room). Only chunks whose members genuinely interact
    (their combined writes would overfill some slot) fall back to the
    per-task scan — allocation there is inherently order-dependent.
    """
    J, L = plan.z.shape
    r_alloc = np.zeros((J, L))
    if r_total <= 0:
        return r_alloc, None
    flat = np.nonzero(plan.mask.ravel())[0]
    starts = plan.starts.ravel()[flat]
    ends = plan.ends.ravel()[flat]
    zf = plan.z.ravel()[flat]
    df = plan.delta.ravel()[flat]
    b0f = np.repeat(plan.beta0, L)[flat]
    sizes = np.maximum(ends - starts, 1e-12)
    # Pool-independent cap of policy (12) (or the naive benchmark),
    # vectorized up front; the chronological pass only intersects it with
    # the pool's live availability.
    cap = _selfowned_counts_vec(zf, df, sizes, b0f, np.inf, selfowned)
    horizon = max(float(ends.max()), 1.0)
    pool = SelfOwnedPool(r_total, horizon, slots_per_unit)
    out = np.zeros(len(flat))
    # Conservative slot coverage (matches SelfOwnedPool._span).
    slot = pool.slot
    k1s = np.maximum(np.floor(starts / slot + 1e-9).astype(np.int64), 0)
    k2s = np.minimum(np.ceil(ends / slot - 1e-9).astype(np.int64), pool.n_slots)
    k2s = np.maximum(k2s, k1s + 1)
    used = pool.used
    total = pool.total
    spans = ends - starts
    live = (cap > 0.0) & (spans > 1e-12)
    order = np.argsort(starts, kind="stable")
    # Python-native scalars for the contended scan (numpy scalar boxing is
    # the dominant per-task cost there).
    k1l, k2l = k1s.tolist(), k2s.tolist()
    capl, spanl, zfl = cap.tolist(), spans.tolist(), zf.tolist()
    reserved_t = worked_t = 0.0
    cooldown = 0  # chunks to run sequentially after a failed batch attempt
    from repro.core.pool import RangeMax

    for pos in range(0, len(order), _POOL_CHUNK):
        sel = order[pos:pos + _POOL_CHUNK]
        sel = sel[live[sel]]
        if len(sel) == 0:
            continue
        run = sel
        if cooldown > 0:
            cooldown -= 1
        else:
            lo = int(k1s[sel].min())
            hi = int(k2s[sel].max())
            m0 = RangeMax(used[lo:hi]).query(k1s[sel] - lo, k2s[sel] - lo)
            r0 = np.floor(np.minimum(cap[sel], total - m0)).astype(np.int64)
            r0 = np.maximum(r0, 0)
            diff = np.zeros(hi - lo + 1, dtype=np.int64)
            np.add.at(diff, k1s[sel] - lo, r0)
            np.add.at(diff, k2s[sel] - lo, -r0)
            add = np.cumsum(diff[:-1])
            if (used[lo:hi] + add).max(initial=0) <= total:
                used[lo:hi] += add
                out[sel] = r0
                reserved = r0 * spans[sel]
                reserved_t += reserved.sum()
                worked_t += np.minimum(reserved, zf[sel]).sum()
                continue
            # Contended chunk: tasks the entry occupancy leaves no room for
            # provably get r == 0 (occupancy only grows within the chunk),
            # so the exact scan below only visits the rest; back off from
            # batch attempts while the stream stays saturated.
            run = sel[m0 <= total - 1]
            cooldown = 4
        for i in run.tolist():
            k1, k2 = k1l[i], k2l[i]
            avail = total - int(used[k1:k2].max())
            c = capl[i]
            r = int(c) if c <= avail else avail
            if r > 0:
                used[k1:k2] += r
                span = spanl[i]
                reserved_t += r * span
                worked = r * span
                zfi = zfl[i]
                worked_t += zfi if zfi < worked else worked
                out[i] = r
    pool.reserved_instance_time += reserved_t
    pool.worked_instance_time += worked_t
    r_alloc.ravel()[flat] = out
    return r_alloc, pool


def _simulate_plan(
    plan: PlanBatch, r_alloc: np.ndarray, market: SpotMarket,
    early_start: bool,
) -> StreamCosts:
    """Spot/on-demand realization of a planned batch (per-bid grouping)."""
    J, L = plan.z.shape
    sizes = plan.sizes
    z_t = np.maximum(plan.z - r_alloc * sizes, 0.0)
    # Kill float dust (z - r*size ~ 1e-13 on fully-self-owned tasks).
    z_t[z_t <= 1e-9 * (plan.z + 1.0)] = 0.0
    d_eff = np.maximum(plan.delta - r_alloc, 0.0)
    selfowned_work = np.minimum(r_alloc * sizes, plan.z)

    out = StreamCosts.zeros(J)
    out.workload[:] = plan.workload
    out.selfowned_work[:] = selfowned_work.sum(axis=1)
    out.selfowned_reserved[:] = (r_alloc * sizes).sum(axis=1)

    for bid in np.unique(plan.bid):
        jm = plan.bid == bid
        view = market.view(float(bid))
        if early_start:
            sim = simulate_chains_early(
                view, plan.arrival[jm], plan.ends[jm], z_t[jm], d_eff[jm],
                selfowned_pins=(r_alloc[jm] > 0), p_ondemand=market.p_ondemand)
            out.spot_cost[jm] = sim.spot_cost
            out.ondemand_cost[jm] = sim.ondemand_cost
            out.spot_work[jm] = sim.spot_work
            out.ondemand_work[jm] = sim.ondemand_work
        else:
            rows = np.nonzero(jm)[0]
            fl = plan.mask[jm].ravel()
            sim = simulate_tasks(
                view, plan.starts[jm].ravel()[fl], plan.ends[jm].ravel()[fl],
                z_t[jm].ravel()[fl], d_eff[jm].ravel()[fl], market.p_ondemand)
            owner = np.repeat(rows, plan.mask[jm].sum(axis=1))
            np.add.at(out.spot_cost, owner, sim.spot_cost)
            np.add.at(out.ondemand_cost, owner, sim.ondemand_cost)
            np.add.at(out.spot_work, owner, sim.spot_work)
            np.add.at(out.ondemand_work, owner, sim.ondemand_work)
    return out


def run_jobs(
    jobs: list[ChainJob],
    policy: Policy | list[Policy],
    market: SpotMarket,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    return_pool: bool = False,
) -> StreamCosts | tuple[StreamCosts, np.ndarray, SelfOwnedPool | None]:
    """Realized processing of a job stream (shared pool, chronological)."""
    plan = build_plans(jobs, policy, r_total, windows)
    r_alloc, pool = _allocate_pool(plan, r_total, selfowned, market.slots_per_unit)
    costs = _simulate_plan(plan, r_alloc, market, early_start)
    if return_pool:
        return costs, r_alloc, pool
    return costs


def evaluate_policy_fullpool(
    jobs: list[ChainJob],
    policy: Policy,
    market: SpotMarket,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    availability=None,
    backend: str = "numpy",
) -> StreamCosts:
    """Counterfactual per-job costs with a dedicated (uncontended) pool.

    Fully vectorized: one Dealloc pass per job (cheap greedy waterfill), one
    policy-(12) evaluation on the padded matrix, then a batched realization.
    This is the hot path TOLA scores policies with (n_policies x n_jobs
    cells) — the workload the `policy_cost` Pallas kernel targets on TPU.

    ``availability``: optional callable ``(starts, ends) -> (J, L) array`` of
    per-task self-owned availability. Defaults to the dedicated pool
    (``r_total`` everywhere); TOLA's pool-aware refinement passes the
    realized residual-occupancy query instead.

    Routed through the evaluation engine as a 1-policy grid; grids should
    call ``repro.engine.evaluate_grid`` directly (one batched pass over
    policies x bids x scenarios with backend dispatch).
    """
    from repro.engine import evaluate_grid  # engine depends on this module

    res = evaluate_grid(
        jobs, [policy], market, r_total, windows=windows,
        selfowned=selfowned, early_start=early_start,
        availability=availability, pool="dedicated", backend=backend)
    return res.stream_costs(0, 0)
