"""Algorithm 2 — deadline + instance allocation over an arriving job stream.

Events (paper Alg. 2):

  * ``t = a_j``  — allocate deadlines to the job's chain (lines 1-5):
                   Dealloc(beta) when r = 0 or beta < beta_0,
                   Dealloc(beta_0) when r > 0 and beta_0 <= beta.
  * task start   — allocate self-owned instances r_i by policy (12)
                   (lines 6-10). Reservations live on the PLANNED windows
                   [s_{i-1}, s_i] (policy (12) is defined on them), so all
                   pool events are known at arrival and are processed in
                   global chronological order across overlapping jobs.
  * in-window    — spot while flexibility holds (Def. 3.1), on-demand after
                   the turning point (lines 11-15), realized exactly by
                   ``simulate_tasks``. Execution is *early-start* by default
                   (paper Table 1: a task begins at its predecessor's
                   realized finish); ``early_start=False`` gives the
                   planned-start variant used by the Even benchmark, whose
                   windows are prescriptive ("tasks are executed and
                   finished in the specified windows", Section 6.1).

``run_jobs`` is the realized system (shared-pool contention included);
``evaluate_policy_fullpool`` is the counterfactual evaluator used by TOLA's
weight updates and fixed-policy sweeps — each candidate policy sees the pool
as if dedicated, the same simplification [10]/[12] make when scoring
policies offline.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.dealloc import window_sizes, window_sizes_batch
from repro.core.market import SpotMarket
from repro.core.policy import f_selfowned
from repro.core.pool import LazySegmentTree, SelfOwnedPool
from repro.core.simulate import simulate_chains_early, simulate_tasks
from repro.core.types import ChainJob

__all__ = [
    "Policy",
    "StreamCosts",
    "PlanBatch",
    "JobArrays",
    "job_arrays",
    "build_plans",
    "build_plans_batch",
    "selfowned_counts_vec_jax",
    "run_jobs",
    "evaluate_policy_fullpool",
]


@dataclasses.dataclass(frozen=True)
class Policy:
    """One parametric policy {beta, b, beta_0} (paper Section 5)."""

    beta: float
    bid: float
    beta0: float | None = None  # None <=> no self-owned instances considered

    def dealloc_param(self, r_total: int) -> float:
        """Lines 1-5 of Algorithm 2: which parameter drives Dealloc."""
        if r_total > 0 and self.beta0 is not None and self.beta0 <= self.beta:
            return self.beta0
        return self.beta


@dataclasses.dataclass
class StreamCosts:
    """Per-job realized costs for a processed stream (all arrays (n_jobs,))."""

    spot_cost: np.ndarray
    ondemand_cost: np.ndarray
    spot_work: np.ndarray
    ondemand_work: np.ndarray
    selfowned_work: np.ndarray
    workload: np.ndarray       # Z_j
    selfowned_reserved: np.ndarray

    @classmethod
    def zeros(cls, n: int) -> "StreamCosts":
        return cls(*(np.zeros(n) for _ in range(7)))

    @property
    def total_cost(self) -> np.ndarray:
        return self.spot_cost + self.ondemand_cost

    def average_unit_cost(self) -> float:
        """alpha = sum_j c_j / sum_j Z_j (paper Section 6.1)."""
        return float(self.total_cost.sum() / self.workload.sum())


@dataclasses.dataclass
class PlanBatch:
    """Padded (n_jobs, L_max) plan of windows/workloads for a job stream."""

    arrival: np.ndarray    # (J,)
    starts: np.ndarray     # (J, L) planned window starts
    ends: np.ndarray       # (J, L) planned window ends (task deadlines)
    z: np.ndarray          # (J, L) task workloads (0 on padding)
    delta: np.ndarray      # (J, L) parallelism bounds (1 on padding)
    mask: np.ndarray       # (J, L) real-task mask
    bid: np.ndarray        # (J,) per-job bid price
    beta0: np.ndarray      # (J,) per-job beta_0 (nan = none)

    @property
    def sizes(self) -> np.ndarray:
        return self.ends - self.starts

    @property
    def workload(self) -> np.ndarray:
        return self.z.sum(axis=1)


def _job_windows(job: ChainJob, policy: Policy, r_total: int, mode: str) -> np.ndarray:
    if mode == "dealloc":
        return window_sizes(job, policy.dealloc_param(r_total))
    if mode == "even":
        e = job.e_array()
        return e + max(job.slack, 0.0) / job.l
    raise ValueError(f"unknown window mode {mode!r}")


def build_plans(
    jobs: list[ChainJob],
    policies: Policy | list[Policy],
    r_total: int = 0,
    windows: str = "dealloc",
) -> PlanBatch:
    """Lines 1-5 for every job: padded window/workload matrices."""
    J = len(jobs)
    pol_list = policies if isinstance(policies, list) else [policies] * J
    L = max(j.l for j in jobs)
    starts = np.zeros((J, L)); ends = np.zeros((J, L))
    z = np.zeros((J, L)); delta = np.ones((J, L))
    mask = np.zeros((J, L), dtype=bool)
    arrival = np.zeros(J); bid = np.zeros(J); beta0 = np.full(J, np.nan)
    for ji, (job, pol) in enumerate(zip(jobs, pol_list)):
        sizes = _job_windows(job, pol, r_total, windows)
        bounds = job.arrival + np.concatenate([[0.0], np.cumsum(sizes)])
        l = job.l
        starts[ji, :l] = bounds[:-1]; ends[ji, :l] = bounds[1:]
        # Padding keeps ends monotone so the early-start scan stays trivial.
        if l < L:
            starts[ji, l:] = bounds[-1]; ends[ji, l:] = bounds[-1]
        z[ji, :l] = job.z_array(); delta[ji, :l] = job.delta_array()
        mask[ji, :l] = True
        arrival[ji] = job.arrival
        bid[ji] = pol.bid
        beta0[ji] = pol.beta0 if pol.beta0 is not None else np.nan
    return PlanBatch(arrival=arrival, starts=starts, ends=ends, z=z,
                     delta=delta, mask=mask, bid=bid, beta0=beta0)


@dataclasses.dataclass
class JobArrays:
    """Padded per-job task arrays — the policy-independent half of a plan.

    Extracted ONCE per job stream (one cheap padding pass) and shared by
    every window plan of a grid; ``omega`` is the Dealloc slack
    ``window - e.sum()`` and ``slack_even`` the Even-benchmark slack
    (``job.slack``, a Python-sum of e_i) — kept separate because the two
    sequential paths reduce e differently and bit-compatibility requires
    reproducing each exactly.
    """

    arrival: np.ndarray   # (J,)
    z: np.ndarray         # (J, L) task workloads (0 on padding)
    delta: np.ndarray     # (J, L) parallelism bounds (1 on padding)
    e: np.ndarray         # (J, L) min execution times (0 on padding)
    mask: np.ndarray      # (J, L) real-task mask
    omega: np.ndarray     # (J,) Dealloc slack
    l: np.ndarray         # (J,) chain lengths
    jobs: list[ChainJob] | None = None  # source stream (Even-slack fallback)

    def slack_even(self) -> np.ndarray:
        """Even-benchmark slack per job (``job.slack``, the Python-sum
        variant — reduced lazily because only the Even window mode needs it
        and its per-task property walk is the costliest part of padding)."""
        return np.array([j.slack for j in self.jobs])


def job_arrays(jobs: list[ChainJob]) -> JobArrays:
    """One flat extraction pass over the stream.

    Task attributes come out as two flat list comprehensions (one array
    construction each, not one per job) and scatter into the padded (J, L)
    layout through the mask; ``e`` is the same IEEE divide as ``Task.e``
    element for element, and ``omega`` reduces each job's own contiguous
    e-row (identical length, identical pairwise sum) so everything stays
    bit-compatible with the per-job ``build_plans`` path.
    """
    J = len(jobs)
    ls = np.array([j.l for j in jobs], dtype=np.int64)
    L = int(ls.max())
    flat_z = np.array([t.z for j in jobs for t in j.tasks])
    flat_d = np.array([t.delta for j in jobs for t in j.tasks])
    mask = np.arange(L)[None, :] < ls[:, None]
    z = np.zeros((J, L)); delta = np.ones((J, L))
    z[mask] = flat_z
    delta[mask] = flat_d
    e = np.where(mask, z / delta, 0.0)
    flat_e = flat_z / flat_d
    off = np.concatenate([[0], np.cumsum(ls)])
    arrival = np.array([j.arrival for j in jobs])
    window = np.array([j.window for j in jobs])
    omega = np.array([window[ji] - float(flat_e[off[ji]:off[ji + 1]].sum())
                      for ji in range(J)])
    return JobArrays(arrival=arrival, z=z, delta=delta, e=e, mask=mask,
                     omega=omega, l=ls, jobs=jobs)


def _plans_from_sizes(arrays: JobArrays, sizes: np.ndarray) -> list[PlanBatch]:
    """(G, J, L) window sizes -> G padded PlanBatches (shared job arrays).

    Padded sizes are exactly 0, so the cumulative bounds stay flat past the
    chain end — starts == ends == the job deadline on padding, the same
    invariant ``build_plans`` writes explicitly.
    """
    G, J, L = sizes.shape
    cum = np.cumsum(sizes, axis=2)
    ends = arrays.arrival[None, :, None] + cum
    starts = np.empty_like(ends)
    starts[:, :, 0] = arrays.arrival[None, :]
    starts[:, :, 1:] = arrays.arrival[None, :, None] + cum[:, :, :-1]
    nan = np.full(J, np.nan)
    return [PlanBatch(arrival=arrays.arrival, starts=starts[g], ends=ends[g],
                      z=arrays.z, delta=arrays.delta, mask=arrays.mask,
                      bid=nan, beta0=nan)
            for g in range(G)]


def build_plans_batch(
    jobs: list[ChainJob],
    xs=(),
    windows: str = "dealloc",
    arrays: JobArrays | None = None,
) -> list[PlanBatch]:
    """Vectorized ``build_plans`` over a whole deduplicated parameter grid.

    ``windows="dealloc"``: one PlanBatch per Dealloc parameter in ``xs``,
    computed as a single (G, J, L) array pass (``window_sizes_batch``) —
    bit-identical to looping ``build_plans`` per parameter.
    ``windows="even"``: the parameter-free Even benchmark plan (``xs``
    ignored, one PlanBatch). The returned plans carry NaN ``bid``/``beta0``
    placeholders — they are window plans, not policy plans; callers supply
    the policy-dependent fields (the engine's plan layer does).
    """
    a = arrays if arrays is not None else job_arrays(jobs)
    if windows == "dealloc":
        xs = np.atleast_1d(np.asarray(xs, dtype=np.float64))
        if xs.size == 0:
            raise ValueError("need at least one Dealloc parameter")
        sizes = window_sizes_batch(a.e, a.delta, a.mask, a.omega, xs)
    elif windows == "even":
        per_task = np.maximum(a.slack_even(), 0.0) / a.l
        sizes = np.where(a.mask, a.e + per_task[:, None], 0.0)[None]
    else:
        raise ValueError(f"unknown window mode {windows!r}")
    return _plans_from_sizes(a, sizes)


def _selfowned_counts_vec(
    z: np.ndarray, delta: np.ndarray, sizes: np.ndarray,
    beta0: np.ndarray | float | None, available, mode: str,
) -> np.ndarray:
    """Integral r_i (policy (12) or the naive benchmark), vectorized.

    ``available`` may carry extra leading axes (e.g. a scenario axis for
    per-scenario residual-availability queries); everything broadcasts and
    the result takes the combined shape.
    """
    avail = np.asarray(available, dtype=np.float64)
    if mode == "prop12":
        if beta0 is None:
            return np.zeros_like(z)
        b0 = np.broadcast_to(np.asarray(beta0, dtype=np.float64), z.shape)
        safe_b0 = np.where(np.isnan(b0), 1.0, b0)
        f = np.ceil(f_selfowned(z, delta, np.maximum(sizes, 1e-12), safe_b0) - 1e-9)
        f = np.where(np.isnan(b0), 0.0, f)
        useful = np.ceil(np.where(sizes > 0, z / np.maximum(sizes, 1e-12), 0.0) - 1e-9)
        return np.maximum(0.0, np.minimum(np.minimum(f, avail),
                                          np.minimum(delta, useful)))
    if mode == "naive":
        return np.maximum(0.0, np.minimum(avail, delta))
    raise ValueError(f"unknown self-owned mode {mode!r}")


# Integral-count rounding guard of the DEVICE twin: the host path ceils
# with a 1e-9 absolute epsilon (f64 noise floor); device arithmetic is f32,
# whose ~1e-7 relative noise would push exact-integer f values (e.g. the
# zero-slack case f(beta_0) = delta) across the ceil boundary. 1e-5 absorbs
# that; the remaining knife edge (an f64 value within (1e-9, 1e-5) above an
# integer) is measure-zero on the paper's continuous workload draws, and
# the min(..., delta) clamp already pins the common exact-integer cases.
_DEVICE_CEIL_EPS = 1e-5

# _BETA_ONE_EPS: the beta_0 == 1 knife edge of Eq. (11) — beta_0 arrives as
# an exact 1.0 from the grid builder, so 1e-12 only absorbs f64 parsing /
# arithmetic blur, never a real beta_0 < 1.
_BETA_ONE_EPS = 1e-12
# _SPAN_EPS: zero-length allocation windows (ends == starts to f64
# round-off) carry no work and must not claim pool slots.
_SPAN_EPS = 1e-12
# _HOST_DUST: host twin of plan.py's _DEVICE_DUST — kill z - r*size residue
# (~1e-13 on fully-self-owned tasks) before it reaches the cost kernels.
_HOST_DUST = 1e-9


@functools.lru_cache(maxsize=2)   # bounded: one entry per self-owned mode
def _selfowned_counts_impl(mode: str):
    """Traceable jnp twin of :func:`_selfowned_counts_vec` (policy (12)).

    Broadcast-generic exactly like the host version: any of the arguments
    may carry extra leading axes (parameter-grid / scenario axes of the
    device plan builder) and the result takes the combined shape. NaN
    ``beta0`` means "no self-owned instances" (count 0), mirroring the host
    NaN contract.
    """
    import jax.numpy as jnp

    if mode == "prop12":
        def counts(z, delta, sizes, beta0, avail):
            s = jnp.maximum(sizes, 1e-12)
            safe_b0 = jnp.where(jnp.isnan(beta0), 1.0, beta0)
            one = safe_b0 >= 1.0 - _BETA_ONE_EPS
            den = s * jnp.where(one, 1.0, 1.0 - safe_b0)
            # Eq.-(11) numerator z - delta*size*beta_0 is EXACTLY zero for
            # every task the Dealloc waterfill fills to its cap (there
            # size = e/beta_0, so delta*size*beta_0 = z by construction) —
            # a systematic knife edge, not a measure-zero one. Snap the
            # f32 blur around it to the f = 0 the f64 oracle computes.
            num = z - delta * s * safe_b0
            f = jnp.where(one | (num <= _DEVICE_CEIL_EPS * (z + 1.0)), 0.0,
                          num / jnp.maximum(den, 1e-30))
            f = jnp.ceil(f - _DEVICE_CEIL_EPS)
            f = jnp.where(jnp.isnan(beta0), 0.0, f)
            useful = jnp.ceil(jnp.where(sizes > 0, z / s, 0.0)
                              - _DEVICE_CEIL_EPS)
            return jnp.maximum(0.0, jnp.minimum(jnp.minimum(f, avail),
                                                jnp.minimum(delta, useful)))
        return counts
    if mode == "naive":
        def counts(z, delta, sizes, beta0, avail):
            return jnp.maximum(0.0, jnp.minimum(avail, delta))
        return counts
    raise ValueError(f"unknown self-owned mode {mode!r}")


@functools.lru_cache(maxsize=2)   # bounded: one entry per self-owned mode
def _selfowned_counts_jit(mode: str):
    import jax

    return jax.jit(_selfowned_counts_impl(mode))


def selfowned_counts_vec_jax(z, delta, sizes, beta0, available,
                             mode: str = "prop12"):
    """Jitted device twin of :func:`_selfowned_counts_vec`.

    Device dtype (usually f32) with a widened ceil epsilon
    (``_DEVICE_CEIL_EPS``); the f64 host path stays the exact oracle.
    """
    import jax.numpy as jnp

    return _selfowned_counts_jit(mode)(
        jnp.asarray(z), jnp.asarray(delta), jnp.asarray(sizes),
        jnp.asarray(beta0), jnp.asarray(available))


_POOL_CHUNK = 256  # tasks per optimistic batch of the chronological alloc


def _allocate_pool(
    plan: PlanBatch, r_total: int, selfowned: str,
    slots_per_unit: int,
) -> tuple[np.ndarray, SelfOwnedPool | None]:
    """Chronological shared-pool allocation on the planned windows.

    Tasks are processed in chronological start order, but in *optimistic
    batches*: every task of a chunk is tentatively granted
    ``min(cap, total - rangemax(used))`` against the occupancy at chunk
    entry (one vectorized sparse-table query for the whole chunk), the
    chunk's combined occupancy delta is built as one diff-array cumsum, and
    if the pool stays within capacity everywhere the chunk commits with a
    single batched slot-grid write. That outcome is exactly what the
    sequential scan would produce: each task's own grant is part of the
    checked final occupancy, so feasibility pins every prefix grant to the
    tentative value from both sides (the entry-occupancy grant is an upper
    bound on the sequential grant, and a feasible total leaves each prefix
    at least that much room). Only chunks whose members genuinely interact
    (their combined writes would overfill some slot) fall back to the exact
    per-task order — allocation there is inherently order-dependent — which
    runs on a lazy-add segment tree (``pool.LazySegmentTree``): each task is
    one O(log n) range-max query + one O(log n) range-add instead of an
    O(span) occupancy rescan, so a fully saturated stream costs O(n log n)
    total. Grants are exact integers either way; the tree's pending deltas
    are flushed back into the slot grid before any batched attempt reads it.
    """
    J, L = plan.z.shape
    r_alloc = np.zeros((J, L))
    if r_total <= 0:
        return r_alloc, None
    flat = np.nonzero(plan.mask.ravel())[0]
    starts = plan.starts.ravel()[flat]
    ends = plan.ends.ravel()[flat]
    zf = plan.z.ravel()[flat]
    df = plan.delta.ravel()[flat]
    b0f = np.repeat(plan.beta0, L)[flat]
    sizes = np.maximum(ends - starts, 1e-12)
    # Pool-independent cap of policy (12) (or the naive benchmark),
    # vectorized up front; the chronological pass only intersects it with
    # the pool's live availability.
    cap = _selfowned_counts_vec(zf, df, sizes, b0f, np.inf, selfowned)
    horizon = max(float(ends.max()), 1.0)
    pool = SelfOwnedPool(r_total, horizon, slots_per_unit)
    out = np.zeros(len(flat))
    # Conservative slot coverage (matches SelfOwnedPool._span).
    slot = pool.slot
    k1s = np.maximum(np.floor(starts / slot + 1e-9).astype(np.int64), 0)
    k2s = np.minimum(np.ceil(ends / slot - 1e-9).astype(np.int64), pool.n_slots)
    k2s = np.maximum(k2s, k1s + 1)
    used = pool.used
    total = pool.total
    spans = ends - starts
    live = (cap > 0.0) & (spans > _SPAN_EPS)
    order = np.argsort(starts, kind="stable")
    # Python-native scalars for the contended scan (numpy scalar boxing is
    # the dominant per-task cost there).
    k1l, k2l = k1s.tolist(), k2s.tolist()
    capl, spanl, zfl = cap.tolist(), spans.tolist(), zf.tolist()
    reserved_t = worked_t = 0.0
    cooldown = 0  # chunks to run sequentially after a failed batch attempt
    tree: LazySegmentTree | None = None
    tdiff: np.ndarray | None = None  # grants pending flush into `used`
    from repro.core.pool import RangeMax

    def _flush() -> None:
        """Fold the tree stretch's grants back into the slot grid."""
        nonlocal tree, tdiff
        if tree is not None:
            used[:] += np.cumsum(tdiff[:-1])
            tree = None
            tdiff = None

    for pos in range(0, len(order), _POOL_CHUNK):
        sel = order[pos:pos + _POOL_CHUNK]
        sel = sel[live[sel]]
        if len(sel) == 0:
            continue
        run = sel
        if cooldown > 0:
            cooldown -= 1
        else:
            _flush()
            lo = int(k1s[sel].min())
            hi = int(k2s[sel].max())
            m0 = RangeMax(used[lo:hi]).query(k1s[sel] - lo, k2s[sel] - lo)
            r0 = np.floor(np.minimum(cap[sel], total - m0)).astype(np.int64)
            r0 = np.maximum(r0, 0)
            diff = np.zeros(hi - lo + 1, dtype=np.int64)
            np.add.at(diff, k1s[sel] - lo, r0)
            np.add.at(diff, k2s[sel] - lo, -r0)
            add = np.cumsum(diff[:-1])
            if (used[lo:hi] + add).max(initial=0) <= total:
                used[lo:hi] += add
                out[sel] = r0
                reserved = r0 * spans[sel]
                reserved_t += reserved.sum()
                worked_t += np.minimum(reserved, zf[sel]).sum()
                continue
            # Contended chunk: tasks the entry occupancy leaves no room for
            # provably get r == 0 (occupancy only grows within the chunk),
            # so the exact order below only visits the rest; back off from
            # batch attempts while the stream stays saturated.
            run = sel[m0 <= total - 1]
            cooldown = 4
        if len(run) and tree is None:
            tree = LazySegmentTree(used)
            tdiff = np.zeros(len(used) + 1, dtype=np.int64)
        for i in run.tolist():
            k1, k2 = k1l[i], k2l[i]
            avail = total - tree.max(k1, k2)
            c = capl[i]
            r = int(c) if c <= avail else avail
            if r > 0:
                tree.add(k1, k2, r)
                tdiff[k1] += r
                tdiff[k2] -= r
                span = spanl[i]
                reserved_t += r * span
                worked = r * span
                zfi = zfl[i]
                worked_t += zfi if zfi < worked else worked
                out[i] = r
    _flush()
    pool.reserved_instance_time += reserved_t
    pool.worked_instance_time += worked_t
    r_alloc.ravel()[flat] = out
    return r_alloc, pool


def _simulate_plan(
    plan: PlanBatch, r_alloc: np.ndarray, market: SpotMarket,
    early_start: bool,
) -> StreamCosts:
    """Spot/on-demand realization of a planned batch (per-bid grouping)."""
    J, L = plan.z.shape
    sizes = plan.sizes
    z_t = np.maximum(plan.z - r_alloc * sizes, 0.0)
    # Kill float dust (z - r*size ~ 1e-13 on fully-self-owned tasks).
    z_t[z_t <= _HOST_DUST * (plan.z + 1.0)] = 0.0
    d_eff = np.maximum(plan.delta - r_alloc, 0.0)
    selfowned_work = np.minimum(r_alloc * sizes, plan.z)

    out = StreamCosts.zeros(J)
    out.workload[:] = plan.workload
    out.selfowned_work[:] = selfowned_work.sum(axis=1)
    out.selfowned_reserved[:] = (r_alloc * sizes).sum(axis=1)

    for bid in np.unique(plan.bid):
        jm = plan.bid == bid
        view = market.view(float(bid))
        if early_start:
            sim = simulate_chains_early(
                view, plan.arrival[jm], plan.ends[jm], z_t[jm], d_eff[jm],
                selfowned_pins=(r_alloc[jm] > 0), p_ondemand=market.p_ondemand)
            out.spot_cost[jm] = sim.spot_cost
            out.ondemand_cost[jm] = sim.ondemand_cost
            out.spot_work[jm] = sim.spot_work
            out.ondemand_work[jm] = sim.ondemand_work
        else:
            rows = np.nonzero(jm)[0]
            fl = plan.mask[jm].ravel()
            sim = simulate_tasks(
                view, plan.starts[jm].ravel()[fl], plan.ends[jm].ravel()[fl],
                z_t[jm].ravel()[fl], d_eff[jm].ravel()[fl], market.p_ondemand)
            owner = np.repeat(rows, plan.mask[jm].sum(axis=1))
            np.add.at(out.spot_cost, owner, sim.spot_cost)
            np.add.at(out.ondemand_cost, owner, sim.ondemand_cost)
            np.add.at(out.spot_work, owner, sim.spot_work)
            np.add.at(out.ondemand_work, owner, sim.ondemand_work)
    return out


def run_jobs(
    jobs: list[ChainJob],
    policy: Policy | list[Policy],
    market: SpotMarket,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    return_pool: bool = False,
) -> StreamCosts | tuple[StreamCosts, np.ndarray, SelfOwnedPool | None]:
    """Realized processing of a job stream (shared pool, chronological)."""
    plan = build_plans(jobs, policy, r_total, windows)
    r_alloc, pool = _allocate_pool(plan, r_total, selfowned, market.slots_per_unit)
    costs = _simulate_plan(plan, r_alloc, market, early_start)
    if return_pool:
        return costs, r_alloc, pool
    return costs


def evaluate_policy_fullpool(
    jobs: list[ChainJob],
    policy: Policy,
    market: SpotMarket,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    availability=None,
    backend: str = "numpy",
) -> StreamCosts:
    """Counterfactual per-job costs with a dedicated (uncontended) pool.

    Fully vectorized: one Dealloc pass per job (cheap greedy waterfill), one
    policy-(12) evaluation on the padded matrix, then a batched realization.
    This is the hot path TOLA scores policies with (n_policies x n_jobs
    cells) — the workload the `policy_cost` Pallas kernel targets on TPU.

    ``availability``: optional callable ``(starts, ends) -> (J, L) array`` of
    per-task self-owned availability. Defaults to the dedicated pool
    (``r_total`` everywhere); TOLA's pool-aware refinement passes the
    realized residual-occupancy query instead.

    Routed through the evaluation engine as a 1-policy grid; grids should
    call ``repro.engine.evaluate_grid`` directly (one batched pass over
    policies x bids x scenarios with backend dispatch).
    """
    from repro.engine import evaluate_grid  # engine depends on this module

    res = evaluate_grid(
        jobs, [policy], market, r_total, windows=windows,
        selfowned=selfowned, early_start=early_start,
        availability=availability, pool="dedicated", backend=backend)
    return res.stream_costs(0, 0)
