"""DAG -> chain transform of Nagarajan et al. (paper Appendix B.1).

The *pseudo-schedule* runs every task at its full parallelism bound as early as
its predecessors allow. Slicing the pseudo-schedule's makespan at every task
start/finish produces intervals I_1..I_l'; interval k becomes pseudo-task k of
a chain job with

    delta(k) = sum of instances running during I_k
    z(k)     = delta(k) * |I_k|        (hence e(k) = |I_k|)

Any feasible schedule of the chain is feasible for the DAG (tasks' work is only
ever moved *later*, and within an interval the original tasks run side by side
at rates proportional to their instance shares).
"""

from __future__ import annotations

import numpy as np

from repro.core.types import ChainJob, DAGJob, Task

__all__ = ["transform", "pseudo_schedule_intervals"]

_EPS = 1e-12


def pseudo_schedule_intervals(job: DAGJob) -> tuple[np.ndarray, np.ndarray]:
    """Return (boundaries, load) of the pseudo-schedule.

    ``boundaries`` is the sorted array of unique event times (task starts and
    finishes, relative to the job arrival); ``load[k]`` is the total number of
    instances running in interval [boundaries[k], boundaries[k+1]).
    """
    q = job.earliest_starts()
    e = np.array([t.e for t in job.tasks], dtype=np.float64)
    d = np.array([t.delta for t in job.tasks], dtype=np.float64)

    events = np.unique(np.concatenate([q, q + e]))
    # Filter zero-length artifacts caused by floating point.
    keep = np.ones(len(events), dtype=bool)
    keep[1:] = np.diff(events) > _EPS
    events = events[keep]

    load = np.zeros(max(len(events) - 1, 0), dtype=np.float64)
    for k in range(len(load)):
        lo, hi = events[k], events[k + 1]
        running = (q < hi - _EPS) & (q + e > lo + _EPS)
        load[k] = float(np.sum(d[running]))
    return events, load


def transform(job: DAGJob) -> ChainJob:
    """j' <- transform(j): build the chain pseudo-job (Eq. 19)."""
    events, load = pseudo_schedule_intervals(job)
    tasks = []
    for k in range(len(load)):
        length = events[k + 1] - events[k]
        if length <= _EPS or load[k] <= _EPS:
            continue  # idle gap (cannot happen with earliest starts, but safe)
        tasks.append(Task(z=float(load[k] * length), delta=float(load[k])))
    if not tasks:
        # Degenerate: all tasks empty. Keep a single zero-ish task.
        tasks = [Task(z=0.0, delta=1.0)]
    return ChainJob(arrival=job.arrival, deadline=job.deadline, tasks=tuple(tasks))


def chain_of(job: ChainJob | DAGJob) -> ChainJob:
    """Algorithm 3: pass chains through, transform DAGs."""
    if isinstance(job, ChainJob):
        return job
    return transform(job)
