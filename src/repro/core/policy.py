"""Single-task instance policies (paper Section 4.1.2 / 4.2.1).

* Prop 4.1 — expected-optimal spot/on-demand composition for a task in a
  window: all-spot until the *turning point*, then all-on-demand.
* Eq. (11) — f(x): the minimum number of self-owned instances that lets the
  task finish on spot alone when spot availability is x.
* Eq. (12) — the self-owned allocation policy
  r_i = min{f(beta_0), N(window), delta_i}.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "f_selfowned",
    "selfowned_allocation",
    "spot_ondemand_split",
    "flexibility",
    "turning_point_expected",
]


def f_selfowned(
    z: np.ndarray | float,
    delta: np.ndarray | float,
    size: np.ndarray | float,
    x: np.ndarray | float,
) -> np.ndarray:
    """f(x) of Eq. (11), vectorized (including over x).

    f(x) = max{ (z - delta*size*x) / (size*(1-x)), 0 }.

    Monotone non-increasing in x (Prop 4.4); f(beta) is the minimum self-owned
    count after which the task is expected to finish without on-demand usage.
    For x >= 1 the numerator z - delta*size <= 0 whenever the window is
    feasible (size >= e), so f(1) = 0.
    """
    z = np.asarray(z, dtype=np.float64)
    delta = np.asarray(delta, dtype=np.float64)
    size = np.asarray(size, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    one = x >= 1.0 - 1e-12
    den = size * np.where(one, 1.0, 1.0 - x)
    val = (z - delta * size * x) / np.maximum(den, 1e-300)
    return np.where(one, 0.0, np.maximum(val, 0.0))


def selfowned_allocation(
    z: float,
    delta: float,
    size: float,
    beta0: float,
    available: float,
    integral: bool = True,
) -> float:
    """Policy (12): r_i = min{f(beta_0), N(window), delta_i}.

    ``available`` is N(window) — the minimum pool level across the window.
    With ``integral`` the paper's rounding note applies: we round f up (more
    self-owned is never costlier under Assumption 1) but never above the pool
    or the parallelism bound, and never above ceil(z/size) (instances beyond
    z/size would sit idle the whole window).
    """
    f = float(f_selfowned(z, delta, size, beta0))
    if integral:
        f = float(np.ceil(f - 1e-9))
        available = float(np.floor(available + 1e-9))
    # Never allocate instances that cannot possibly have work in the window.
    useful = z / size if size > 0 else 0.0
    if integral:
        useful = float(np.ceil(useful - 1e-9))
    return max(0.0, min(f, available, delta, useful))


def flexibility(z_rem: float, delta_eff: float, deadline: float, t: float) -> bool:
    """Definition 3.1: task still has flexibility to use spot at time t."""
    if z_rem <= 0.0:
        return False
    if delta_eff <= 0.0:
        return False
    return z_rem / delta_eff < (deadline - t)


@dataclasses.dataclass(frozen=True)
class SpotOndemandSplit:
    """Expected composition per Prop 4.1 for a window of size ``size``."""

    s: float        # spot instances requested in phase 1
    o: float        # on-demand instances in phase 1
    phase2: bool    # whether a phase-2 (all on-demand) is expected
    turning: float | None  # expected turning point offset from window start


def spot_ondemand_split(z: float, delta: float, size: float, beta: float) -> SpotOndemandSplit:
    """Prop 4.1 cases. ``size`` is hat_s_i; ``beta`` the spot availability."""
    e = z / delta
    if size < e - 1e-12:
        raise ValueError(f"window {size} below minimum execution time {e}")
    if beta >= 1.0 or size >= e / beta - 1e-12:
        # Expected to finish on spot alone; no turning point.
        return SpotOndemandSplit(s=delta, o=0.0, phase2=False, turning=None)
    if size <= e + 1e-12:
        # Turning point at the window start: all on-demand.
        return SpotOndemandSplit(s=0.0, o=delta, phase2=True, turning=0.0)
    return SpotOndemandSplit(
        s=delta, o=0.0, phase2=True, turning=turning_point_expected(z, delta, size, beta)
    )


def turning_point_expected(z: float, delta: float, size: float, beta: float) -> float:
    """Expected turning point offset tau from the window start (Appendix A.1).

    In expectation spot processes work at rate beta*delta; remaining work
    z(t) = z - beta*delta*t; the turning point solves
    z - beta*delta*tau = (size - tau) * delta  =>
    tau = (size*delta - z) / (delta * (1 - beta)).
    """
    tau = (size * delta - z) / (delta * (1.0 - beta))
    return float(np.clip(tau, 0.0, size))
