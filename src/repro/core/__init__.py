"""The paper's contribution: (near-)optimal deadline + instance allocation
for chain/DAG jobs over self-owned, spot and on-demand instances, plus the
TOLA online-learning layer (paper Sections 4-5)."""

from repro.core.baselines import (
    B_BIDS,
    C1_BETA0,
    C2_BETA,
    benchmark_bid_policies,
    run_even,
    run_greedy,
    selfowned_policies,
    spot_od_policies,
    sweep_policies,
)
from repro.core.dealloc import (
    dealloc,
    expected_spot_work,
    window_sizes,
    window_sizes_batch,
)
from repro.core.market import SpotMarket
from repro.core.policy import f_selfowned, selfowned_allocation, spot_ondemand_split
from repro.core.pool import LazySegmentTree, SelfOwnedPool
from repro.core.scheduler import (
    Policy,
    StreamCosts,
    build_plans_batch,
    evaluate_policy_fullpool,
    job_arrays,
    run_jobs,
)
from repro.core.simulate import simulate_tasks
from repro.core.tola import cost_matrix, run_tola, run_tola_scenarios
from repro.core.transform import chain_of, transform
from repro.core.types import Allocation, ChainJob, DAGJob, Task, chain_from_arrays
from repro.core.workload import generate_chain_jobs, generate_dag_jobs

__all__ = [
    "Allocation", "ChainJob", "DAGJob", "Task", "chain_from_arrays",
    "SpotMarket", "SelfOwnedPool", "Policy", "StreamCosts",
    "dealloc", "window_sizes", "window_sizes_batch", "expected_spot_work",
    "build_plans_batch", "job_arrays", "LazySegmentTree",
    "f_selfowned", "selfowned_allocation", "spot_ondemand_split",
    "simulate_tasks", "run_jobs", "evaluate_policy_fullpool",
    "run_tola", "run_tola_scenarios", "cost_matrix", "transform", "chain_of",
    "generate_chain_jobs", "generate_dag_jobs",
    "spot_od_policies", "selfowned_policies", "benchmark_bid_policies",
    "run_greedy", "run_even", "sweep_policies", "C1_BETA0", "C2_BETA",
    "B_BIDS",
]
