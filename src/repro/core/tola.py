"""TOLA / OptiLearning — the online-learning layer (paper Alg. 4, App. B.2).

Exponentiated-weights over a finite policy grid. When job j arrives at
``a_j`` a policy is sampled from the current weight distribution and drives
the job's actual allocation. Once a job's window has fully elapsed
(``t = a_j + d`` with d the max relative deadline, so all spot prices inside
every window are known), its cost under EVERY policy of the grid is computed
counterfactually and the weights are re-scaled with
``w <- w * exp(-eta_t * c_j(pi))``.

Implementation notes (faithful, but vectorized):

* The counterfactual cost matrix ``C[j, pi]`` does not depend on the weight
  evolution, so it is precomputed with one batched engine pass
  (``repro.engine.evaluate_grid``); the sequential sample/update replay is
  delegated to the online-learning subsystem ``repro.learn`` — the numpy
  backend there is the exact float64 oracle, bit-compatible with the
  original in-module event loop (same logw arithmetic, same uniform-stream
  consumption as ``rng.choice``), and ``learner`` swaps in the bandit
  learners (EXP3/UCB1/epsilon-greedy/FTL) of ``repro.learn.learners``.
* Per-job losses are normalized by the job workload Z_j (the paper's own
  performance metric is cost per unit workload); unnormalized costs reach
  O(10^4) and exp(-eta*c) would underflow the weight update. This keeps
  losses in [0, p_od], as the regret bound of Prop. B.1 assumes.
* The realized pass replays the sampled policies chronologically against the
  shared self-owned pool (same plan machinery as ``run_jobs``).
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.market import SpotMarket
from repro.core.scheduler import (
    Policy,
    StreamCosts,
    _allocate_pool,
    _simulate_plan,
    build_plans,
)
from repro.core.types import ChainJob

__all__ = ["TolaResult", "cost_matrix", "run_tola", "run_tola_scenarios"]


@dataclasses.dataclass
class TolaResult:
    chosen: np.ndarray          # (n_jobs,) sampled policy index per job
    weights: np.ndarray         # (n_policies,) final distribution
    realized: StreamCosts       # realized costs under the sampled policies
    cost_matrix: np.ndarray     # (n_jobs, n_policies) counterfactual unit costs
    fixed_unit_costs: np.ndarray  # (n_policies,) stream alpha per fixed policy
    learn: "object | None" = None  # repro.learn.LearnResult of the last iter

    def average_unit_cost(self) -> float:
        return self.realized.average_unit_cost()

    @property
    def best_fixed_unit_cost(self) -> float:
        return float(self.fixed_unit_costs.min())

    @property
    def regret_per_job(self) -> float:
        """Realized average excess unit cost vs the best fixed policy."""
        return self.average_unit_cost() - self.best_fixed_unit_cost


def cost_matrix(
    jobs: list[ChainJob],
    policies: list[Policy],
    market: SpotMarket,
    r_total: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    availability=None,
    backend: str = "auto",
) -> np.ndarray:
    """C[j, pi] — per-unit-workload counterfactual cost of job j under pi.

    Routed through the batched evaluation engine: the whole grid is one
    ``evaluate_grid`` call (deduplicated policy groups, backend-dispatched to
    numpy / jax / the pallas kernel — see ``repro.engine``).
    """
    from repro.engine import evaluate_grid  # engine depends on core

    res = evaluate_grid(
        jobs, policies, market, r_total, windows=windows,
        selfowned=selfowned, early_start=early_start,
        availability=availability, pool="dedicated", backend=backend)
    return res.matrix


def _residual_availability(pool, r_total: int, slot: float):
    """Query fn: realized residual pool capacity over planned windows."""
    from repro.core.pool import RangeMax

    rmax = RangeMax(pool.used)

    def query(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
        lo = np.floor(starts / slot + 1e-9).astype(np.int64)
        hi = np.ceil(ends / slot - 1e-9).astype(np.int64)
        return np.maximum(r_total - rmax.query(lo, np.maximum(hi, lo + 1)), 0.0)

    return query


def _stream_meta(jobs: list[ChainJob]):
    """(arrivals, d, Z) of an arrival-ordered stream, validated."""
    arrivals = np.array([j.arrival for j in jobs])
    if np.any(np.diff(arrivals) < -1e-9):
        raise ValueError("jobs must be arrival-ordered")
    d = max(j.deadline - j.arrival for j in jobs)
    Z = np.array([j.total_work for j in jobs])
    return arrivals, d, Z


def _tola_round(jobs, policies, C, arrivals, d, Z, spec, rng, market,
                r_total, windows, selfowned, early_start):
    """One Alg.-4 round for one scenario: replay the learner over C, run the
    sampled policies against the shared pool, return the realized residual-
    availability query for the next refinement."""
    from repro.learn import replay as learn_replay

    lr = learn_replay(C, arrivals, d, workload=Z, learners=[spec],
                      rng=rng, backend="numpy")
    chosen = lr.chosen[0, 0]
    plan = build_plans(jobs, [policies[c] for c in chosen], r_total, windows)
    r_alloc, pool = _allocate_pool(plan, r_total, selfowned,
                                   market.slots_per_unit)
    realized = _simulate_plan(plan, r_alloc, market, early_start)
    availability = None if pool is None else \
        _residual_availability(pool, r_total, market.slot)
    return lr, chosen, realized, availability


def run_tola(
    jobs: list[ChainJob],
    policies: list[Policy],
    market: SpotMarket,
    r_total: int = 0,
    seed: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool_iters: int = 1,
    backend: str = "auto",
    learner="hedge",
    _C0: np.ndarray | None = None,
) -> TolaResult:
    """Full Algorithm 4 over an arrival-ordered job list.

    ``pool_iters``: number of pool-aware refinements of the counterfactual
    cost matrix. Iteration 0 scores policies against a dedicated pool (the
    [10]/[12] simplification); each refinement re-scores them against the
    residual availability realized by the previous iteration's run — without
    this, the learner never sees self-owned scarcity and over-rewards
    pool-hogging (small beta_0) policies.

    ``backend`` selects the engine backend for the cost-matrix evaluations
    (the learner replay itself always runs the float64 numpy oracle of
    ``repro.learn`` — Hedge there is bit-compatible with the original
    in-module loop); ``learner`` is a kind name or ``LearnerSpec`` from
    ``repro.learn.learners``. ``_C0`` optionally injects a precomputed
    iteration-0 matrix (used to share a batched engine pass).
    """
    from repro.learn import as_spec

    if not jobs or not policies:
        raise ValueError("need jobs and policies")
    arrivals, d, Z = _stream_meta(jobs)
    spec = as_spec(learner)
    rng = np.random.default_rng(seed)

    availability = None
    iters = 1 + (pool_iters if r_total > 0 else 0)
    for it in range(iters):
        if it == 0 and _C0 is not None:
            C = _C0
        else:
            C = cost_matrix(jobs, policies, market, r_total, windows,
                            selfowned, early_start, availability, backend)
        lr, chosen, realized, availability = _tola_round(
            jobs, policies, C, arrivals, d, Z, spec, rng, market,
            r_total, windows, selfowned, early_start)

    fixed = (C * Z[:, None]).sum(axis=0) / Z.sum()
    return TolaResult(chosen=chosen, weights=lr.weights[0, 0],
                      realized=realized, cost_matrix=C,
                      fixed_unit_costs=fixed, learn=lr)


def _round_mesh(mesh, avails):
    """The mesh an evaluation round actually gets to use.

    Since the 2-D GridMesh landed, refinement rounds (per-scenario
    ``avails``) shard like round 0 does. The one remaining fallback —
    ``engine.backend_jax.SHARDED_PS`` switched off — is NEVER silent: the
    round drops to unsharded evaluation with a ``UserWarning`` naming the
    reason, so a sweep cannot quietly lose its device mesh mid-run.
    """
    if mesh is None or avails is None:
        return mesh
    from repro.engine import backend_jax

    if getattr(backend_jax, "SHARDED_PS", False):
        return mesh
    warnings.warn(
        "run_tola_scenarios: dropping mesh= for this refinement round — "
        "the sharded per-scenario availability path is disabled "
        "(engine.backend_jax.SHARDED_PS is False); evaluating unsharded",
        UserWarning, stacklevel=3)
    return None


def run_tola_scenarios(
    jobs: list[ChainJob],
    policies: list[Policy],
    markets: list[SpotMarket],
    r_total: int = 0,
    seed: int = 0,
    windows: str = "dealloc",
    selfowned: str = "prop12",
    early_start: bool = True,
    pool_iters: int = 1,
    backend: str = "auto",
    learner="hedge",
    mesh=None,
) -> list[TolaResult]:
    """Algorithm 4 across S market scenarios, cost matrices batched.

    Exactly ONE ``evaluate_grid`` call per refinement round, covering every
    scenario: round 0 is the engine's ordinary scenario axis; each pool
    refinement re-scores the grid against the S realized residual-
    availability queries in a single per-scenario-availability pass (the
    engine stacks the refined plan tensors along the scenario axis).
    The sequential sample/update replay runs per scenario with seed
    ``seed + s`` — bit-identical to looping single-market ``run_tola``
    (Table 6 output included), just without the per-scenario engine calls.

    ``mesh`` shards the scenario axis across a device mesh (DESIGN.md §9)
    in EVERY round: round 0 shards the ordinary scenario axis, and the
    refinement rounds shard the per-scenario-availability pass — the
    (S, R, L) refined plan stacks ride the ``"data"`` axis next to the
    views, group rows the ``"model"`` axis, with zero collectives in the
    eval hot loop. If the sharded per-scenario path is ever disabled
    (``engine.backend_jax.SHARDED_PS`` False), the refinement rounds fall
    back to unsharded evaluation WITH a ``UserWarning`` naming the reason
    — never silently.
    """
    from repro.engine import evaluate_grid
    from repro.learn import as_spec

    if not jobs or not policies:
        raise ValueError("need jobs and policies")
    S = len(markets)
    arrivals, d, Z = _stream_meta(jobs)
    spec = as_spec(learner)
    rngs = [np.random.default_rng(seed + s) for s in range(S)]

    avails: list | None = None
    iters = 1 + (pool_iters if r_total > 0 else 0)
    for it in range(iters):
        res = evaluate_grid(
            jobs, policies, markets, r_total, windows=windows,
            selfowned=selfowned, early_start=early_start, pool="dedicated",
            availability=avails, backend=backend,
            mesh=_round_mesh(mesh, avails))
        C = res.unit_cost
        rounds = [
            _tola_round(jobs, policies, C[s], arrivals, d, Z, spec, rngs[s],
                        markets[s], r_total, windows, selfowned, early_start)
            for s in range(S)
        ]
        avails = [r[3] for r in rounds]
        if any(a is None for a in avails):
            avails = None  # r_total == 0: nothing to refine against

    return [
        TolaResult(chosen=chosen, weights=lr.weights[0, 0],
                   realized=realized, cost_matrix=C[s],
                   fixed_unit_costs=(C[s] * Z[:, None]).sum(axis=0) / Z.sum(),
                   learn=lr)
        for s, (lr, chosen, realized, _) in enumerate(rounds)
    ]
