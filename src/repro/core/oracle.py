"""Slot-stepping event oracle — an independent re-derivation of simulate.py.

Walks the market slot by slot, maintaining the remaining workload and testing
the flexibility condition (Definition 3.1) directly, with within-slot events
(task completion, turning point) solved by local linear algebra. Used only in
tests (hypothesis property: matches the closed-form simulator to 1e-9) and as
the execution engine for the *Greedy* baseline whose global state does not
decompose per task.
"""

from __future__ import annotations

import numpy as np

from repro.core.market import SpotMarket

__all__ = ["oracle_task", "oracle_greedy_chain"]

_EPS = 1e-12


def oracle_task(
    market: SpotMarket,
    bid: float,
    start: float,
    end: float,
    z_t: float,
    d_eff: float,
) -> dict:
    """Sequentially simulate one task per Definition 3.2. Returns cost dict."""
    avail = market.availability(bid)
    price = market.price
    slot = market.slot
    p_od = market.p_ondemand

    rem = max(float(z_t), 0.0)
    out = {
        "spot_cost": 0.0,
        "ondemand_cost": 0.0,
        "spot_work": 0.0,
        "finish": start,
        "turning": np.inf,
    }
    if rem <= _EPS:
        return out
    if d_eff <= 0.0:
        raise ValueError("remaining work but no cloud instances")

    t = float(start)
    while t < end - _EPS:
        # Flexibility test at the current instant (Def. 3.1).
        if rem / d_eff >= (end - t) - _EPS:
            # Turning point: finish the remainder on on-demand instances.
            out["turning"] = t
            out["ondemand_cost"] += p_od * rem
            out["finish"] = end
            rem = 0.0
            return out
        k = min(int(t / slot + 1e-9), len(avail) - 1)
        slot_end = min((k + 1) * slot, end)
        span = slot_end - t
        if span <= _EPS:
            t = slot_end
            continue
        if avail[k]:
            # Spot available: work accrues at rate d_eff, margin constant.
            done = d_eff * span
            if done >= rem - _EPS:
                dt = rem / d_eff
                out["spot_cost"] += d_eff * price[k] * dt
                out["spot_work"] += rem
                out["finish"] = t + dt
                return out
            out["spot_cost"] += d_eff * price[k] * span
            out["spot_work"] += done
            rem -= done
            t = slot_end
        else:
            # Unavailable: no work; flexibility margin shrinks at rate 1.
            margin = (end - t) - rem / d_eff
            if margin <= span + _EPS:
                # Turning point inside this slot.
                t_star = t + margin
                out["turning"] = t_star
                out["ondemand_cost"] += p_od * rem
                out["finish"] = end
                return out
            t = slot_end
    # Window exhausted (only reachable through accumulated fp slack).
    if rem > _EPS:
        out["ondemand_cost"] += p_od * rem
        out["finish"] = end
    return out


def oracle_greedy_chain(
    market: SpotMarket,
    bid: float,
    arrival: float,
    deadline: float,
    z: np.ndarray,
    delta: np.ndarray,
) -> dict:
    """The paper's *Greedy* benchmark (Section 6.1) on a chain job.

    Bid delta_i spot instances for the head task until the critical path of
    the REMAINING workload reaches the remaining window; then finish every
    task with delta_i on-demand instances back-to-back (which exactly fills
    the window). Global state — simulated sequentially.
    """
    avail = market.availability(bid)
    price = market.price
    slot = market.slot
    p_od = market.p_ondemand

    rem = np.array(z, dtype=np.float64).copy()
    delta = np.asarray(delta, dtype=np.float64)
    head = 0
    l = len(rem)
    spot_cost = 0.0
    spot_work = 0.0
    t = float(arrival)

    def crit() -> float:
        return float(np.sum(rem[head:] / delta[head:]))

    while head < l and t < deadline - _EPS:
        # Greedy switch test: remaining critical path >= remaining window.
        slack = (deadline - t) - crit()
        if slack <= _EPS:
            break
        k = min(int(t / slot + 1e-9), len(avail) - 1)
        slot_end = min((k + 1) * slot, deadline)
        span = slot_end - t
        if span <= _EPS:
            t = slot_end
            continue
        if avail[k]:
            # Head task works at full parallelism; margin is constant while
            # available, so only completion events can occur inside the slot.
            while span > _EPS and head < l:
                d = delta[head]
                done = d * span
                if done >= rem[head] - _EPS:
                    dt = rem[head] / d
                    spot_cost += d * price[k] * dt
                    spot_work += rem[head]
                    rem[head] = 0.0
                    span -= dt
                    head += 1
                else:
                    spot_cost += d * price[k] * span
                    spot_work += done
                    rem[head] -= done
                    span = 0.0
            t = slot_end
        else:
            # Unavailable: slack shrinks at rate 1; switch may fire mid-slot.
            if slack <= span + _EPS:
                t = t + slack
                break
            t = slot_end

    od_work = float(np.sum(rem[head:])) if head < l else 0.0
    return {
        "spot_cost": spot_cost,
        "ondemand_cost": p_od * od_work,
        "spot_work": spot_work,
        "ondemand_work": od_work,
        "finish": deadline if od_work > _EPS else t,
    }
