"""Algorithm 1 — Dealloc(x): optimal deadline (time-window) allocation.

Given a chain job and a parameter x (the spot availability ``beta``, or the
self-owned sufficiency index ``beta_0`` when self-owned instances are
sufficient — Alg. 2 lines 1–5), distribute the slack
``omega = (d_j - a_j) - sum_i e_i`` greedily to tasks in non-increasing order
of parallelism bound ``delta_i``, capping each task's extra time at
``e_i/x - e_i`` (beyond which its spot-processed workload saturates at z_i,
Prop 4.2). This solves ILP (10) exactly (Prop 4.3), in O(l log l).

The expected spot-processed workload for a window size ``hat_s = e + x_slack``
is (Prop 4.2 / 4.5):

    z_o(hat_s) = min(z, x/(1-x) * delta * x_slack)        for x < 1
    z_o(hat_s) = z  for any hat_s >= e                     for x == 1
"""

from __future__ import annotations

import numpy as np

from repro.core.types import Allocation, ChainJob

__all__ = ["dealloc", "window_sizes", "expected_spot_work", "allocation_windows"]


def window_sizes(job: ChainJob, x: float) -> np.ndarray:
    """Return optimal window sizes hat_s_i for every task (Algorithm 1).

    x in (0, 1]. With x == 1 every task is expected to finish on spot alone in
    its minimum window, so all slack is parked on the highest-delta task
    (cost-neutral in expectation; keeps windows well formed).
    """
    if not 0.0 < x <= 1.0:
        raise ValueError(f"Dealloc parameter must be in (0, 1], got {x}")
    e = job.e_array()
    delta = job.delta_array()
    l = job.l
    omega = job.window - float(e.sum())
    if omega < -1e-9:
        raise ValueError(
            f"infeasible job: window {job.window} < critical path {e.sum()}"
        )
    omega = max(omega, 0.0)

    sizes = e.copy()  # line 1: hat_s_i* = e_i
    # line 3: consider tasks in non-increasing order of parallelism bound.
    order = np.argsort(-delta, kind="stable")
    # Cap per task: e_i/x - e_i (zero when x == 1).
    cap = e / x - e
    for idx in order:
        if omega <= 0.0:
            break
        give = min(cap[idx], omega)
        sizes[idx] += give
        omega -= give
    if omega > 0.0:
        # All tasks saturated; park the residual slack on the task with the
        # largest delta (it changes nothing in expectation — z_o stays z).
        sizes[order[0]] += omega
    return sizes


def expected_spot_work(
    z: np.ndarray | float,
    delta: np.ndarray | float,
    sizes: np.ndarray | float,
    x: float,
) -> np.ndarray:
    """Vectorized z_o of Prop 4.2/4.5 for window sizes ``sizes``."""
    z = np.asarray(z, dtype=np.float64)
    delta = np.asarray(delta, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    e = z / delta
    if x >= 1.0:
        return np.where(sizes >= e - 1e-12, z, 0.0)
    slack = np.maximum(sizes - e, 0.0)
    return np.minimum(z, x / (1.0 - x) * delta * slack)


def allocation_windows(job: ChainJob, sizes: np.ndarray) -> tuple[tuple[float, float], ...]:
    """Chain windows from sizes: task i runs in [s_{i-1}, s_i] (Eq. 4)."""
    bounds = job.arrival + np.concatenate([[0.0], np.cumsum(sizes)])
    return tuple((float(bounds[i]), float(bounds[i + 1])) for i in range(job.l))


def dealloc(job: ChainJob, x: float, r: np.ndarray | None = None) -> Allocation:
    """Full Allocation from Algorithm 1 (self-owned counts default to zero)."""
    sizes = window_sizes(job, x)
    windows = allocation_windows(job, sizes)
    if r is None:
        r_t = tuple(0.0 for _ in range(job.l))
    else:
        r_t = tuple(float(v) for v in r)
    return Allocation(job=job, windows=windows, r=r_t)
