"""Algorithm 1 — Dealloc(x): optimal deadline (time-window) allocation.

Given a chain job and a parameter x (the spot availability ``beta``, or the
self-owned sufficiency index ``beta_0`` when self-owned instances are
sufficient — Alg. 2 lines 1–5), distribute the slack
``omega = (d_j - a_j) - sum_i e_i`` greedily to tasks in non-increasing order
of parallelism bound ``delta_i``, capping each task's extra time at
``e_i/x - e_i`` (beyond which its spot-processed workload saturates at z_i,
Prop 4.2). This solves ILP (10) exactly (Prop 4.3), in O(l log l).

The expected spot-processed workload for a window size ``hat_s = e + x_slack``
is (Prop 4.2 / 4.5):

    z_o(hat_s) = min(z, x/(1-x) * delta * x_slack)        for x < 1
    z_o(hat_s) = z  for any hat_s >= e                     for x == 1
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.types import Allocation, ChainJob

__all__ = [
    "dealloc",
    "window_sizes",
    "window_sizes_batch",
    "window_sizes_batch_jax",
    "expected_spot_work",
    "expected_spot_work_jax",
    "allocation_windows",
]

# Named epsilon guards (DESIGN.md §5/§6, enforced by analysis rule RPR004).
# _FEAS_EPS: f64 noise floor on the slack omega = window - sum(e) — windows
# are sums of the same task arrays, so a truly infeasible job sits well
# below -1e-9 while round-off sits within it.
_FEAS_EPS = 1e-9
# _CAP_EPS: the x == 1 / fully-capped knife edge of Prop 4.5. sizes == e
# holds exactly in f64 when x == 1 (cap = e/x - e = 0), so 1e-12 only
# absorbs the one-ulp blur of the waterfill's subtract-compare.
_CAP_EPS = 1e-12


def window_sizes(job: ChainJob, x: float) -> np.ndarray:
    """Return optimal window sizes hat_s_i for every task (Algorithm 1).

    x in (0, 1]. With x == 1 every task is expected to finish on spot alone in
    its minimum window, so all slack is parked on the highest-delta task
    (cost-neutral in expectation; keeps windows well formed).
    """
    if not 0.0 < x <= 1.0:
        raise ValueError(f"Dealloc parameter must be in (0, 1], got {x}")
    e = job.e_array()
    delta = job.delta_array()
    l = job.l
    omega = job.window - float(e.sum())
    if omega < -_FEAS_EPS:
        raise ValueError(
            f"infeasible job: window {job.window} < critical path {e.sum()}"
        )
    omega = max(omega, 0.0)

    sizes = e.copy()  # line 1: hat_s_i* = e_i
    # line 3: consider tasks in non-increasing order of parallelism bound.
    order = np.argsort(-delta, kind="stable")
    # Cap per task: e_i/x - e_i (zero when x == 1).
    cap = e / x - e
    for idx in order:
        if omega <= 0.0:
            break
        give = min(cap[idx], omega)
        sizes[idx] += give
        omega -= give
    if omega > 0.0:
        # All tasks saturated; park the residual slack on the task with the
        # largest delta (it changes nothing in expectation — z_o stays z).
        sizes[order[0]] += omega
    return sizes


def window_sizes_batch(
    e: np.ndarray,
    delta: np.ndarray,
    mask: np.ndarray,
    omega: np.ndarray,
    xs: np.ndarray,
) -> np.ndarray:
    """Algorithm 1 over a whole (params x jobs) grid in one array pass.

    ``e``/``delta``/``mask``: (J, L) padded task arrays (e = 0 off-mask);
    ``omega``: (J,) per-job slack, computed by the caller exactly as the
    sequential path does (``job.window - float(e.sum())``); ``xs``: (G,)
    Dealloc parameters. Returns (G, J, L) window sizes, **bit-identical** to
    looping ``window_sizes`` — the greedy waterfill runs as a short loop over
    sorted task positions so every job sees the same float operations in the
    same order as the sequential scan (a closed-form prefix-sum variant would
    drift in the last ulp).
    """
    e = np.asarray(e, dtype=np.float64)
    delta = np.asarray(delta, dtype=np.float64)
    xs = np.asarray(xs, dtype=np.float64)
    J, L = e.shape
    G = len(xs)
    if np.any((xs <= 0.0) | (xs > 1.0)):
        bad = xs[(xs <= 0.0) | (xs > 1.0)][0]
        raise ValueError(f"Dealloc parameter must be in (0, 1], got {bad}")
    if np.any(omega < -_FEAS_EPS):
        raise ValueError("infeasible job: window < critical path")
    omega = np.maximum(np.asarray(omega, dtype=np.float64), 0.0)

    # Non-increasing delta among real tasks (stable, matching the sequential
    # argsort(-delta)); padding sorts last and has cap 0 so it never takes
    # slack — the residual parks on sorted position 0, the max-delta task.
    order = np.argsort(np.where(mask, -delta, np.inf), axis=1, kind="stable")
    e_s = np.take_along_axis(e, order, axis=1)                 # (J, L)
    cap = e_s[None, :, :] / xs[:, None, None] - e_s[None, :, :]  # (G, J, L)
    sizes_s = np.broadcast_to(e_s, (G, J, L)).copy()
    rem = np.broadcast_to(omega, (G, J)).copy()
    for k in range(L):
        if not rem.any():
            break  # slack exhausted everywhere: the rest is give = 0.0
        give = np.minimum(cap[:, :, k], rem)
        sizes_s[:, :, k] += give
        rem -= give
    sizes_s[:, :, 0] += rem  # all caps saturated: park residual on max delta
    out = np.empty((G, J, L))
    np.put_along_axis(out, np.broadcast_to(order[None], (G, J, L)), sizes_s,
                      axis=2)
    return out


@functools.lru_cache(maxsize=1)
def _jax_impls():
    """Traceable jnp twins of the plan-layer pieces living in this module.

    Exposed un-jitted so the engine's device plan builder can fuse them into
    ONE jit program (plan.py); the public ``*_jax`` wrappers jit them
    standalone for direct use and parity testing.
    """
    import jax
    import jax.numpy as jnp

    def batch(e, delta, mask, omega, xs):
        G = xs.shape[0]
        J, L = e.shape
        order = jnp.argsort(jnp.where(mask, -delta, jnp.inf), axis=1,
                            stable=True)
        e_s = jnp.take_along_axis(e, order, axis=1)
        cap = e_s[None] / xs[:, None, None] - e_s[None]

        def give_one(rem, k):
            give = jnp.minimum(cap[:, :, k], rem)
            return rem - give, e_s[None, :, k] + give

        rem0 = jnp.maximum(jnp.broadcast_to(omega, (G, J)), 0.0)
        rem, cols = jax.lax.scan(give_one, rem0, jnp.arange(L))
        sizes_s = jnp.moveaxis(cols, 0, 2)
        sizes_s = sizes_s.at[:, :, 0].add(rem)
        inv = jnp.argsort(order, axis=1)
        return jnp.take_along_axis(
            sizes_s, jnp.broadcast_to(inv[None], (G, J, L)), axis=2)

    def spot_work(z, delta, sizes, x):
        e = z / delta
        # x >= 1: any feasible window finishes on spot alone (Prop 4.5).
        # The x < 1 branch guards the 1/(1-x) pole so it stays finite (and
        # irrelevant) when the predicate selects the saturated branch.
        frac = x / jnp.maximum(1.0 - x, 1e-30)
        capped = jnp.minimum(z, frac * delta * jnp.maximum(sizes - e, 0.0))
        return jnp.where(x >= 1.0 - _CAP_EPS,
                         jnp.where(sizes >= e - _CAP_EPS, z, 0.0), capped)

    return {"window_sizes_batch": batch,
            "window_sizes_batch_jit": jax.jit(batch),
            "expected_spot_work": spot_work,
            "expected_spot_work_jit": jax.jit(spot_work)}


def window_sizes_batch_jax(e, delta, mask, omega, xs):
    """Jitted twin of :func:`window_sizes_batch` (device dtype, usually f32).

    Same greedy waterfill as the numpy canonical version, expressed as a
    ``lax.scan`` over sorted task positions; used when the plan tensor is
    built on-device. Parity with the f64 canonical path is float-level, not
    bitwise (tested to ~1e-5 relative in tests/test_plan_batch.py).
    """
    import jax.numpy as jnp

    return _jax_impls()["window_sizes_batch_jit"](
        jnp.asarray(e), jnp.asarray(delta), jnp.asarray(mask),
        jnp.asarray(omega), jnp.asarray(xs))


def expected_spot_work_jax(z, delta, sizes, x):
    """Jitted device twin of :func:`expected_spot_work` (Prop 4.2/4.5).

    Unlike the host version, ``x`` may be an array and broadcasts (the
    device plan path evaluates whole parameter grids at once). Device dtype
    (usually f32): parity with the f64 canonical path is float-level, not
    bitwise.
    """
    import jax.numpy as jnp

    return _jax_impls()["expected_spot_work_jit"](
        jnp.asarray(z), jnp.asarray(delta), jnp.asarray(sizes),
        jnp.asarray(x))


def expected_spot_work(
    z: np.ndarray | float,
    delta: np.ndarray | float,
    sizes: np.ndarray | float,
    x: float,
) -> np.ndarray:
    """Vectorized z_o of Prop 4.2/4.5 for window sizes ``sizes``."""
    z = np.asarray(z, dtype=np.float64)
    delta = np.asarray(delta, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    e = z / delta
    if x >= 1.0:
        return np.where(sizes >= e - _CAP_EPS, z, 0.0)
    slack = np.maximum(sizes - e, 0.0)
    return np.minimum(z, x / (1.0 - x) * delta * slack)


def allocation_windows(job: ChainJob, sizes: np.ndarray) -> tuple[tuple[float, float], ...]:
    """Chain windows from sizes: task i runs in [s_{i-1}, s_i] (Eq. 4)."""
    bounds = job.arrival + np.concatenate([[0.0], np.cumsum(sizes)])
    return tuple((float(bounds[i]), float(bounds[i + 1])) for i in range(job.l))


def dealloc(job: ChainJob, x: float, r: np.ndarray | None = None) -> Allocation:
    """Full Allocation from Algorithm 1 (self-owned counts default to zero)."""
    sizes = window_sizes(job, x)
    windows = allocation_windows(job, sizes)
    if r is None:
        r_t = tuple(0.0 for _ in range(job.l))
    else:
        r_t = tuple(float(v) for v in r)
    return Allocation(job=job, windows=windows, r=r_t)
