"""Closed-form realized-cost simulator (Definition 3.2 over a realized market).

Given a task executing in a window [start, end] with ``d_eff = delta - r``
cloud instances and remaining workload ``z_t = z - r * (end - start)``, the
realized allocation process of Algorithm 2 (lines 11-15) is:

  * while the task has *flexibility* (Def. 3.1), request ``d_eff`` spot
    instances — work accrues at rate ``d_eff`` whenever the bid clears the
    spot price, i.e. work done by time t is ``d_eff * (A(t) - A(start))``;
  * at the *turning point* (flexibility exhausted) switch to ``d_eff``
    on-demand instances for the remaining work.

The flexibility margin g(t) = (end - t) - z_rem(t)/d_eff changes at rate
``-(1 - a(t))`` — it only shrinks while spot is UNavailable — hence the
turning point is the unique root of the monotone map H(t) = t - A(t)
(DESIGN.md Section 5):

    t* = earliest t with  H(t) >= H(start) + (end - start) - z_t / d_eff

and the task instead finishes on spot alone at

    t_fin = earliest t with  A(t) >= A(start) + z_t / d_eff

whichever comes first. Both are exact searchsorted queries on the market's
cumulative arrays; no per-slot loop anywhere. ``core/oracle.py`` re-derives
the same quantities by sequential slot stepping and is property-tested to
match to 1e-9.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.market import BidView

__all__ = ["TaskSim", "simulate_tasks", "FLEX_REL", "FLEX_ABS"]

# Definition 3.1 requires STRICTLY positive flexibility to use spot. Tasks
# whose window exactly equals their minimum execution time (z == d * size —
# an atom under Dealloc, which leaves unselected tasks with zero slack) sit
# exactly on the turning-point guard, where the cost is discontinuous
# (ride-spot vs all-on-demand). An epsilon makes the branch deterministic
# under floating-point rounding: slack <= max(FLEX_REL * window,
# FLEX_ABS * end) counts as "no flexibility". FLEX_REL handles reassociation
# noise on the window itself; FLEX_ABS dominates the ABSOLUTE f32 rounding
# of the chain clock (~1.2e-7 * t), which exceeds the relative term for
# short windows late in the horizon. The SAME thresholds are used by the
# f64 oracle and the f32 jax/pallas backends so every backend takes the
# same branch everywhere except a thin sliver around the threshold
# (DESIGN.md §5).
FLEX_REL = 1e-4
FLEX_ABS = 1e-5
# _WORK_EPS: "is there any cloud work left" predicate on z_t. Residual
# workloads are differences of f64 sums, so true zeros land within one ulp;
# 1e-15 is far below any real task's workload (O(1) units).
_WORK_EPS = 1e-15


@dataclasses.dataclass(frozen=True)
class TaskSim:
    """Vectorized realized outcome for a batch of tasks (all arrays (n,))."""

    spot_cost: np.ndarray
    ondemand_cost: np.ndarray
    spot_work: np.ndarray
    ondemand_work: np.ndarray
    finish: np.ndarray          # realized completion time
    turning: np.ndarray         # turning point, +inf if none

    @property
    def total_cost(self) -> np.ndarray:
        return self.spot_cost + self.ondemand_cost


def simulate_tasks(
    view: BidView,
    start: np.ndarray,
    end: np.ndarray,
    z_t: np.ndarray,
    d_eff: np.ndarray,
    p_ondemand: float = 1.0,
) -> TaskSim:
    """Exact realized costs for tasks run per Definition 3.2 under one bid.

    Parameters
    ----------
    view:   the market's cumulative arrays for the policy's bid price.
    start, end: window [start_i, end_i] per task (planned or realized starts).
    z_t:    workload left for cloud instances (z - r * window), >= 0.
    d_eff:  cloud parallelism delta - r, >= 0. ``z_t > 0`` requires
            ``d_eff > 0`` (guaranteed by policy (12): r = delta forces
            z_t <= 0).
    """
    start = np.asarray(start, dtype=np.float64)
    end = np.asarray(end, dtype=np.float64)
    z_t = np.maximum(np.asarray(z_t, dtype=np.float64), 0.0)
    d_eff = np.asarray(d_eff, dtype=np.float64)

    n = start.shape[0]
    active = z_t > _WORK_EPS
    if np.any(active & (d_eff <= 0.0)):
        raise ValueError("task with remaining cloud work but no cloud instances")
    # Avoid 0/0 on inactive tasks.
    d_safe = np.where(d_eff > 0.0, d_eff, 1.0)
    need = z_t / d_safe  # instance-availability time needed

    A0 = view.A(start)
    H0 = start - A0
    C0 = view.C(start)

    # Turning point: first t with H(t) >= H0 + (end - start) - need.
    h_target = H0 + (end - start) - need
    # If need >= window (up to the relative flexibility epsilon) the task has
    # no flexibility at start: turn immediately.
    no_flex = (end - start) - need <= np.maximum(
        1e-15, np.maximum(FLEX_REL * (end - start), FLEX_ABS * end))
    t_turn = np.where(no_flex, start, view.t_for_H(h_target))
    # Spot-alone finish: first t with A(t) >= A0 + need.
    t_fin = view.t_for_A(A0 + need)

    # Exactly one of the two events lands inside [start, end]; compare.
    finish_on_spot = t_fin <= t_turn
    t_spot_end = np.where(finish_on_spot, t_fin, t_turn)
    # Defensive clamp (horizon overruns map to end; callers size the market
    # so this never truncates real windows).
    t_spot_end = np.minimum(t_spot_end, end)

    spot_avail = np.maximum(view.A(t_spot_end) - A0, 0.0)
    spot_work = np.minimum(d_eff * spot_avail, z_t)
    spot_cost = d_eff * np.maximum(view.C(t_spot_end) - C0, 0.0)
    od_work = z_t - spot_work
    od_cost = p_ondemand * od_work

    finish = np.where(finish_on_spot, t_fin, end)
    turning = np.where(finish_on_spot, np.inf, t_spot_end)

    # Inactive tasks: nothing happens.
    zeros = np.zeros(n)
    return TaskSim(
        spot_cost=np.where(active, spot_cost, zeros),
        ondemand_cost=np.where(active, od_cost, zeros),
        spot_work=np.where(active, spot_work, zeros),
        ondemand_work=np.where(active, od_work, zeros),
        finish=np.where(active, finish, start),
        turning=np.where(active, turning, np.inf),
    )


def simulate_chains_early(
    view: BidView,
    arrival: np.ndarray,      # (J,) job arrivals
    ends: np.ndarray,         # (J, L) planned task deadlines (padded)
    z_t: np.ndarray,          # (J, L) cloud workload per task (0 = padding)
    d_eff: np.ndarray,        # (J, L) cloud parallelism per task
    selfowned_pins: np.ndarray | None = None,  # (J, L) bool: r_i > 0
    p_ondemand: float = 1.0,
) -> TaskSim:
    """Early-start chain execution, vectorized over jobs.

    Task k of each chain begins at its predecessor's *realized* finish
    (paper Table 1: s~_i is "the earliest time at which the execution of
    task i can begin") and must still finish by its planned Dealloc deadline
    ``ends[:, k]``. Tasks holding self-owned instances are pinned: their
    self-owned share completes exactly at the planned window end (the
    reservation is the planned window), so their realized finish is the
    planned deadline.

    Returns a TaskSim with per-JOB aggregates (shape (J,)); ``finish`` is the
    realized completion of the whole chain and ``turning`` the count of tasks
    that lost flexibility.
    """
    J, L = z_t.shape
    cur = arrival.astype(np.float64).copy()
    agg = {k: np.zeros(J) for k in
           ("spot_cost", "ondemand_cost", "spot_work", "ondemand_work")}
    turn_count = np.zeros(J)
    for k in range(L):
        end_k = ends[:, k]
        live = end_k > cur - _WORK_EPS
        start_k = np.minimum(cur, end_k)
        sim = simulate_tasks(
            view, start_k, end_k, np.where(live, z_t[:, k], 0.0),
            np.maximum(d_eff[:, k], 0.0), p_ondemand)
        agg["spot_cost"] += sim.spot_cost
        agg["ondemand_cost"] += sim.ondemand_cost
        agg["spot_work"] += sim.spot_work
        agg["ondemand_work"] += sim.ondemand_work
        turn_count += np.isfinite(sim.turning)
        finish_k = sim.finish
        if selfowned_pins is not None:
            finish_k = np.where(selfowned_pins[:, k], end_k, finish_k)
        # Padding tasks (z_t == 0, no pin) leave `cur` untouched.
        moved = (z_t[:, k] > _WORK_EPS) | (
            selfowned_pins[:, k] if selfowned_pins is not None else False)
        cur = np.where(moved, finish_k, cur)
    return TaskSim(
        spot_cost=agg["spot_cost"], ondemand_cost=agg["ondemand_cost"],
        spot_work=agg["spot_work"], ondemand_work=agg["ondemand_work"],
        finish=cur, turning=turn_count,
    )
