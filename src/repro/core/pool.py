"""Self-owned instance pool — N(t) and N(t1, t2) tracking (paper Section 4.2).

``N(t)`` is the number of self-owned instances idle at time t and
``N(t1, t2) = min_{t in [t1, t2]} N(t)`` is what policy (12) consumes.
Reservations are half-open intervals [t1, t2) at integer instance counts.

Tracking is on the market's slot grid: a reservation occupies every slot it
overlaps (conservative — a partially covered slot counts as fully used when
answering availability queries, so a feasible answer is always truly
feasible; the slot is 1/12 of a time unit, making the rounding loss
negligible — quantified in tests). Range updates and range-min queries are
vectorized numpy on the occupancy array.
"""

from __future__ import annotations

import numpy as np

__all__ = ["SelfOwnedPool", "LazySegmentTree"]


class SelfOwnedPool:
    def __init__(self, total: int, horizon_units: float, slots_per_unit: int = 12):
        self.total = int(total)
        self.slot = 1.0 / slots_per_unit
        self.n_slots = int(np.ceil(horizon_units * slots_per_unit)) + 1
        self.used = np.zeros(self.n_slots, dtype=np.int64)
        # Exact continuous accounting for utilization metrics.
        self.reserved_instance_time = 0.0
        self.worked_instance_time = 0.0

    def _span(self, t1: float, t2: float) -> tuple[int, int]:
        """Slots overlapping [t1, t2) — conservative full-slot coverage."""
        k1 = max(int(np.floor(t1 / self.slot + 1e-9)), 0)
        k2 = min(int(np.ceil(t2 / self.slot - 1e-9)), self.n_slots)
        return k1, max(k2, k1 + 1)

    def available(self, t1: float, t2: float) -> int:
        """N(t1, t2): instances free throughout the window."""
        if self.total == 0:
            return 0
        k1, k2 = self._span(t1, t2)
        return int(self.total - int(self.used[k1:k2].max(initial=0)))

    def reserve(self, t1: float, t2: float, count: int, worked: float | None = None):
        """Commit ``count`` instances over [t1, t2).

        ``worked`` is the instance-time actually used for task workload
        (min(count * window, z)); defaults to the full reservation.
        """
        count = int(count)
        if count <= 0:
            return
        k1, k2 = self._span(t1, t2)
        if int(self.used[k1:k2].max(initial=0)) + count > self.total:
            raise ValueError("over-reservation of self-owned pool")
        self.used[k1:k2] += count
        span = max(t2 - t1, 0.0)
        self.reserved_instance_time += count * span
        self.worked_instance_time += count * span if worked is None else worked

    def utilization(self, horizon: float) -> float:
        """Fraction of the pool's capacity that processed real workload."""
        cap = self.total * horizon
        return self.worked_instance_time / cap if cap > 0 else 0.0


class LazySegmentTree:
    """Range-add / range-max over integer occupancy, O(log n) per operation.

    The saturated-regime workhorse of ``scheduler._allocate_pool``: when the
    pool is deeply oversubscribed (r << demand) almost every optimistic chunk
    fails and allocation degenerates into a per-task scan whose
    ``used[k1:k2].max()`` rescans are O(span) each. This tree answers the
    same query and commits the same grant in O(log n) exact integer
    arithmetic, making the contended pass O(n log n) overall.

    Iterative (bottom-up) lazy propagation over a flat 2n array of Python
    ints — exactness matters more than numpy here: grants are integers, so
    tree answers are bit-identical to the sequential occupancy scan, and the
    per-op constant (~2 log n list reads) beats boxing numpy scalars.
    """

    def __init__(self, values: np.ndarray):
        vals = [int(v) for v in values]
        n = len(vals)
        if n == 0:
            raise ValueError("empty occupancy array")
        self.n = n
        self.h = n.bit_length()
        self.t = t = [0] * n + vals
        self.d = [0] * n
        for i in range(n - 1, 0, -1):
            l, r = 2 * i, 2 * i + 1
            t[i] = t[l] if t[l] >= t[r] else t[r]

    def _apply(self, x: int, v: int) -> None:
        self.t[x] += v
        if x < self.n:
            self.d[x] += v

    def _rebuild(self, p: int) -> None:
        t, d = self.t, self.d
        while p > 1:
            p >>= 1
            l, r = t[2 * p], t[2 * p + 1]
            t[p] = (l if l >= r else r) + d[p]

    def _push(self, p: int) -> None:
        d = self.d
        for s in range(self.h, 0, -1):
            i = p >> s
            if i >= 1 and d[i] != 0:
                v = d[i]
                self._apply(2 * i, v)
                self._apply(2 * i + 1, v)
                d[i] = 0

    def add(self, lo: int, hi: int, v: int) -> None:
        """Add ``v`` on slots [lo, hi)."""
        if lo >= hi or v == 0:
            return
        l = lo + self.n
        r = hi + self.n
        ll, rr = l, r - 1
        while l < r:
            if l & 1:
                self._apply(l, v)
                l += 1
            if r & 1:
                r -= 1
                self._apply(r, v)
            l >>= 1
            r >>= 1
        self._rebuild(ll)
        self._rebuild(rr)

    def max(self, lo: int, hi: int) -> int:
        """Max over slots [lo, hi); empty ranges give 0 (idle pool)."""
        if lo >= hi:
            return 0
        l = lo + self.n
        r = hi + self.n
        self._push(l)
        self._push(r - 1)
        res = None
        t = self.t
        while l < r:
            if l & 1:
                if res is None or t[l] > res:
                    res = t[l]
                l += 1
            if r & 1:
                r -= 1
                if res is None or t[r] > res:
                    res = t[r]
            l >>= 1
            r >>= 1
        return res


class RangeMax:
    """O(1) range-max over a fixed array via a sparse table (O(n log n) build).

    Used to answer "max pool occupancy over [t1, t2]" for every task of every
    candidate policy when TOLA re-scores policies against the *realized*
    occupancy trace (pool-aware counterfactuals)."""

    def __init__(self, values: np.ndarray):
        v = np.asarray(values, dtype=np.float64)
        n = len(v)
        levels = max(int(np.floor(np.log2(max(n, 1)))) + 1, 1)
        table = [v]
        for k in range(1, levels):
            half = 1 << (k - 1)
            prev = table[-1]
            if len(prev) <= half:
                break
            table.append(np.maximum(prev[:-half], prev[half:]))
        self.table = table
        self.n = n

    def query(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """Vectorized max over [lo, hi) slot indices; empty ranges give 0."""
        lo = np.clip(np.asarray(lo, dtype=np.int64), 0, self.n)
        hi = np.clip(np.asarray(hi, dtype=np.int64), 0, self.n)
        length = hi - lo
        out = np.zeros(lo.shape)
        ok = length > 0
        if not np.any(ok):
            return out
        k = np.zeros(lo.shape, dtype=np.int64)
        k[ok] = np.floor(np.log2(length[ok])).astype(np.int64)
        k = np.minimum(k, len(self.table) - 1)
        for kk in np.unique(k[ok]):
            m = ok & (k == kk)
            t = self.table[kk]
            a = np.minimum(lo[m], len(t) - 1)
            b = np.clip(hi[m] - (1 << kk), 0, len(t) - 1)
            out[m] = np.maximum(t[a], t[b])
        return out
