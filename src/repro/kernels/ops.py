"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-detects: compiled kernels on TPU, interpret mode
(Python-evaluated kernel bodies) elsewhere — which is how the CPU-only test
environment validates the TPU kernels against the jnp oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.policy_cost import policy_cost as _policy_cost
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan

__all__ = ["flash_attention", "ssd", "policy_cost_batch", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(interpret):
    return (not on_tpu()) if interpret is None else interpret


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "prefix", "block_q", "block_k", "interpret"))
def _flash_jit(q, k, v, causal, window, prefix, block_q, block_k, interpret):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, prefix=prefix,
        block_q=block_q, block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    prefix: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, dh); k/v: (B, Sk, K, dh) -> (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, k.shape[1], dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, v.shape[1], dh)
    of = _flash_jit(qf, kf, vf, causal, window, prefix, block_q, block_k,
                    _auto(interpret))
    return of.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, A, B, C, chunk, interpret):
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool | None = None):
    """Chunked SSD scan. Shapes as in kernels/ssd_scan.py."""
    return _ssd_jit(x, dt, A, B, C, chunk, _auto(interpret))


def policy_cost_batch(A_cum, C_cum, start, end, z_t, d_eff, *,
                      slot: float = 1.0 / 12.0, p_od: float = 1.0,
                      interpret: bool | None = None):
    """Batched closed-form task costs (the TOLA scoring hot loop)."""
    return _policy_cost(
        jnp.asarray(A_cum, jnp.float32), jnp.asarray(C_cum, jnp.float32),
        jnp.asarray(start, jnp.float32), jnp.asarray(end, jnp.float32),
        jnp.asarray(z_t, jnp.float32), jnp.asarray(d_eff, jnp.float32),
        slot=slot, p_od=p_od, interpret=_auto(interpret))
