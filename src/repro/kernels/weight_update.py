"""Pallas TPU kernel for the online-learning hot loop: the fused Hedge
weight-update replay (paper Alg. 4 over a precomputed cost tensor).

The recurrence is tiny per step (an (m,)-vector exponentiated-weights
update) but strictly sequential over jobs, and the learn subsystem replays
it for every (scenario x learner x schedule-grid) instance. The TPU
formulation exploits that the FULL-INFORMATION update does not depend on
the sampled trace, so the replay factors into two in-kernel passes over
VMEM-resident data (one grid cell per replay instance):

1. *Trajectory pass* — ``fori_loop`` over the J update events in order:
   ``logw <- logw - eta_j * C[j]`` followed by the log-space
   renormalization ``logw <- logw - max(logw)`` (the exp-rescale that pins
   the top weight at exp(0) = 1 so long horizons cannot flush the weights
   to zero), each state written to a (J+1, P) VMEM scratch trajectory.
2. *Sample pass* — jobs in blocks of ``block_jobs``: the delayed-feedback
   offset ``n_done[j]`` (how many updates had been applied when job j
   sampled) selects each job's trajectory row via a one-hot MATMUL (MXU
   work instead of serial gathers, the same trick ``policy_cost.py`` uses
   for searchsorted); normalize to probabilities, inverse-CDF sample
   against the precomputed uniform stream (cumsum as a triangular-ones
   matmul, then a comparison count), and read off the chosen index, its
   probability and the expected cost.

Oracle: ``kernels/ref.py::hedge_replay_ref`` (vectorized numpy, same
two-pass factorization) and the sequential event loop in
``repro.learn.replay`` (float64, structurally different) — see
tests/test_learn.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["hedge_replay"]

_NEG = -3.0e38  # "minus infinity" that stays finite in float32


def _hedge_kernel(C_ref, eta_ref, u_ref, nd_ref,
                  ch_ref, ps_ref, ec_ref, wf_ref, traj, *,
                  J: int, n_rows: int, Pp: int, m: int, BJ: int):
    # Zero the scratch so padded trajectory rows contribute exact zeros to
    # the one-hot matmuls (uninitialized VMEM could hold NaNs).
    traj[...] = jnp.zeros((n_rows, Pp), jnp.float32)
    lane1 = jax.lax.broadcasted_iota(jnp.int32, (1, Pp), 1)
    init = jnp.where(lane1 < m, jnp.float32(-np.log(m)), jnp.float32(_NEG))
    traj[pl.dslice(0, 1), :] = init

    def stepA(i, logw):
        c_row = C_ref[0, pl.dslice(i, 1), :]          # (1, Pp)
        eta = eta_ref[:, pl.dslice(i, 1)]             # (1, 1)
        logw = logw - eta * c_row
        logw = logw - jnp.max(logw)                   # exp-rescale, log space
        traj[pl.dslice(i + 1, 1), :] = logw
        return logw

    logw_f = jax.lax.fori_loop(0, J, stepA, init)
    wf_ref[...] = logw_f

    rows = jax.lax.broadcasted_iota(jnp.int32, (BJ, n_rows), 1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (BJ, Pp), 1)
    # tri[i, k] = 1 iff i <= k: p @ tri is an inclusive cumsum along lanes.
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Pp, Pp), 0)
           <= jax.lax.broadcasted_iota(jnp.int32, (Pp, Pp), 1)
           ).astype(jnp.float32)

    def stepB(c, carry):
        base = c * BJ
        nd = nd_ref[0, pl.dslice(base, BJ)]                  # (BJ,) int32
        oh = (rows == nd[:, None]).astype(jnp.float32)       # (BJ, n_rows)
        logw_s = jnp.dot(oh, traj[...],
                         preferred_element_type=jnp.float32)  # (BJ, Pp)
        logw_s = logw_s - jnp.max(logw_s, axis=1, keepdims=True)
        p = jnp.exp(logw_s)
        p = p / jnp.sum(p, axis=1, keepdims=True)
        cdf = jnp.dot(p, tri, preferred_element_type=jnp.float32)
        uu = u_ref[0, pl.dslice(base, BJ)]                   # (BJ,)
        total = cdf[:, Pp - 1:Pp]
        cnt = jnp.sum((cdf <= uu[:, None] * total).astype(jnp.int32), axis=1)
        chosen = jnp.minimum(cnt, m - 1)
        oh_c = (lanes == chosen[:, None]).astype(jnp.float32)
        c_blk = C_ref[0, pl.dslice(base, BJ), :]             # (BJ, Pp)
        ch_ref[0, pl.dslice(base, BJ)] = chosen
        ps_ref[0, pl.dslice(base, BJ)] = jnp.sum(p * oh_c, axis=1)
        ec_ref[0, pl.dslice(base, BJ)] = jnp.sum(p * c_blk, axis=1)
        return carry

    jax.lax.fori_loop(0, (J + BJ - 1) // BJ, stepB, 0)


def _hedge_call(C_p, eta_p, u_p, nd_p, *, K: int, J: int, n_rows: int,
                Pp: int, m: int, BJ: int, interpret: bool):
    """The traceable pallas launch on the padded (S, Jp, Pp) layout.

    Split from :func:`hedge_replay` (which owns the host-side numpy
    padding) so ``repro.analysis.programs`` can abstract-trace the device
    program on ShapeDtypeStructs without executing it.
    """
    S, Jp = C_p.shape[0], C_p.shape[1]
    kernel = functools.partial(_hedge_kernel, J=J, n_rows=n_rows, Pp=Pp,
                               m=m, BJ=BJ)
    B = S * K
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Jp, Pp), lambda b: (b // K, 0, 0)),
            pl.BlockSpec((1, Jp), lambda b: (b % K, 0)),
            pl.BlockSpec((1, Jp), lambda b: (b // K, 0)),
            pl.BlockSpec((1, Jp), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Jp), lambda b: (b, 0)),
            pl.BlockSpec((1, Jp), lambda b: (b, 0)),
            pl.BlockSpec((1, Jp), lambda b: (b, 0)),
            pl.BlockSpec((1, Pp), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Jp), jnp.int32),
            jax.ShapeDtypeStruct((B, Jp), jnp.float32),
            jax.ShapeDtypeStruct((B, Jp), jnp.float32),
            jax.ShapeDtypeStruct((B, Pp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_rows, Pp), jnp.float32)],
        interpret=interpret,
    )(C_p, eta_p, u_p, nd_p)


def hedge_replay(C, etas, u, n_done, *, block_jobs: int = 128,
                 interpret: bool | None = None):
    """Fused Hedge replay over a (S, J, P) cost tensor.

    ``C``: per-scenario counterfactual unit costs; ``etas``: (K, J)
    per-update learning rates (one row per schedule-grid instance); ``u``:
    (S, J) per-scenario uniform sampling streams; ``n_done``: (J,) updates
    applied before each job's sample (``repro.learn.replay.build_events``).
    One kernel launch covers the whole S x K instance grid. Returns dict of
    ``chosen``/``p_chosen``/``expected_cost`` (S, K, J) and final sampling
    ``weights`` (S, K, P).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    C = np.asarray(C, dtype=np.float32)
    S, J, P = C.shape
    etas = np.atleast_2d(np.asarray(etas, dtype=np.float32))
    K = etas.shape[0]
    BJ = min(block_jobs, max(8, J))
    Jp = -(-J // BJ) * BJ
    Pp = -(-P // 128) * 128
    n_rows = -(-(J + 1) // 8) * 8

    C_p = np.zeros((S, Jp, Pp), dtype=np.float32)
    C_p[:, :J, :P] = C
    eta_p = np.zeros((K, Jp), dtype=np.float32)
    eta_p[:, :J] = etas
    u_p = np.full((S, Jp), 2.0, dtype=np.float32)
    u_p[:, :J] = np.asarray(u, dtype=np.float32)
    nd_p = np.zeros((1, Jp), dtype=np.int32)
    nd_p[0, :J] = np.asarray(n_done, dtype=np.int32)

    ch, ps, ec, wf = _hedge_call(C_p, eta_p, u_p, nd_p, K=K, J=J,
                                 n_rows=n_rows, Pp=Pp, m=P, BJ=BJ,
                                 interpret=interpret)

    logw = np.asarray(wf, dtype=np.float64).reshape(S, K, Pp)[..., :P]
    w = np.exp(logw - logw.max(axis=-1, keepdims=True))
    w /= w.sum(axis=-1, keepdims=True)
    return {
        "chosen": np.asarray(ch, np.int64).reshape(S, K, Jp)[..., :J],
        "p_chosen": np.asarray(ps, np.float64).reshape(S, K, Jp)[..., :J],
        "expected_cost": np.asarray(ec, np.float64).reshape(S, K, Jp)[..., :J],
        "weights": w,
    }
