"""Pallas TPU kernel for the paper's evaluation hot loop: batched
closed-form task-cost evaluation (Definition 3.2 over a realized market).

TOLA (Alg. 4) scores every job under every policy of the grid — O(n_jobs x
n_policies) independent closed-form task simulations, each a pair of
monotone piecewise-linear inversions over the market's cumulative arrays
(A = availability time, C = spot payment, H = t - A; see
core/simulate.py). That inner evaluation is this kernel.

TPU adaptation (vs the numpy searchsorted implementation):
  * the cumulative arrays for one bid (~30k slots, f32) fit comfortably in
    VMEM (~0.4 MB) and are loaded once per task block;
  * searchsorted becomes a comparison-count reduction (monotone array:
    index = #{k : cum[k] < target}) accumulated chunk-by-chunk with a
    fori_loop — no data-dependent control flow;
  * point gathers (cum[k0], cum[k0+1], ...) become one-hot matmuls against
    the chunk — MXU work instead of serial gathers.

Grid = (n_tasks / BT,); everything else is elementwise arithmetic on the
(BT,) task registers. Oracle: kernels/ref.py::policy_cost_ref (vectorized
jnp) and core/simulate.py (numpy, exact) — see tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.simulate import FLEX_ABS as _FLEX_ABS
from repro.core.simulate import FLEX_REL as _FLEX_REL

__all__ = ["policy_cost", "policy_cost_chain"]

_CHUNK = 2048


def _kernel(A_ref, C_ref, H_ref, start_ref, end_ref, z_ref, d_ref,
            sc_ref, oc_ref, sw_ref, fin_ref, *,
            n_slots: int, n_pad: int, slot: float, p_od: float, BT: int):
    start = start_ref[...]
    end = end_ref[...]
    z_t = z_ref[...]
    d_eff = d_ref[...]

    nch = n_pad // _CHUNK
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (BT, _CHUNK), 1)

    def gathers_and_counts(idx_list, count_targets, value_refs):
        """One pass over the slot arrays: gather value_refs[j][idx] for every
        (idx, ref) pair and count {k: ref[k] < target} for every
        (target, ref) pair."""
        def body(c, carry):
            g_acc, c_acc = carry
            base = c * _CHUNK
            chunks = [r[pl.dslice(base * 0 + base, _CHUNK)] for r in value_refs]
            g_new = []
            for (idx, ref_i), acc in zip(idx_list, g_acc):
                oh = jnp.where(iota_c == (idx[:, None] - base), 1.0, 0.0)
                g_new.append(acc + oh @ chunks[ref_i])
            c_new = []
            for (tgt, ref_i), acc in zip(count_targets, c_acc):
                c_new.append(acc + jnp.sum(
                    (chunks[ref_i][None, :] < tgt[:, None]).astype(jnp.int32),
                    axis=1))
            return g_new, c_new
        g0 = [jnp.zeros((BT,), jnp.float32) for _ in idx_list]
        c0 = [jnp.zeros((BT,), jnp.int32) for _ in count_targets]
        return jax.lax.fori_loop(0, nch, body, (g0, c0))

    refs = [A_ref, C_ref, H_ref]

    # Pass 1: interpolated A0/C0 at `start` + the two inverse-query counts.
    k0 = jnp.clip((start / slot).astype(jnp.int32), 0, n_slots - 1)
    d_safe = jnp.where(d_eff > 0, d_eff, 1.0)
    need = z_t / d_safe
    # (we need A0 before computing targets — gather k0/k0+1 first)
    (a_k0, a_k1, c_k0, c_k1), _ = gathers_and_counts(
        [(k0, 0), (k0 + 1, 0), (k0, 1), (k0 + 1, 1)], [], refs)
    frac = start - k0.astype(jnp.float32) * slot
    A0 = a_k0 + (a_k1 - a_k0) / slot * frac
    C0 = c_k0 + (c_k1 - c_k0) / slot * frac
    H0 = start - A0

    h_target = H0 + (end - start) - need
    a_target = A0 + need
    _, (cntH, cntA) = gathers_and_counts([], [(h_target, 2), (a_target, 0)],
                                         refs)

    # Pass 2: invert H and A at the counted indices.
    iH = jnp.clip(cntH, 1, n_slots)
    iA = jnp.clip(cntA, 1, n_slots)
    (h_prev, a_prev), _ = gathers_and_counts([(iH - 1, 2), (iA - 1, 0)], [],
                                             refs)
    # Flexibility epsilon (same constants as core.simulate.FLEX_REL /
    # FLEX_ABS): zero-slack tasks must turn at start deterministically in f32.
    no_flex = (end - start) - need <= jnp.maximum(
        jnp.float32(1e-15),
        jnp.maximum(_FLEX_REL * (end - start), _FLEX_ABS * end))
    t_turn = (iH - 1).astype(jnp.float32) * slot + (h_target - h_prev)
    t_turn = jnp.where(no_flex, start, t_turn)
    t_turn = jnp.where(jnp.logical_and(cntH > n_slots, ~no_flex),
                       jnp.inf, t_turn)
    t_fin = (iA - 1).astype(jnp.float32) * slot + (a_target - a_prev)
    t_fin = jnp.where(a_target <= 0.0, 0.0, t_fin)
    t_fin = jnp.where(cntA > n_slots, jnp.inf, t_fin)

    on_spot = t_fin <= t_turn
    t_end = jnp.minimum(jnp.where(on_spot, t_fin, t_turn), end)

    # Pass 3: A/C at t_end.
    ke = jnp.clip((t_end / slot).astype(jnp.int32), 0, n_slots - 1)
    (a_e0, a_e1, c_e0, c_e1), _ = gathers_and_counts(
        [(ke, 0), (ke + 1, 0), (ke, 1), (ke + 1, 1)], [], refs)
    frace = t_end - ke.astype(jnp.float32) * slot
    A_end = a_e0 + (a_e1 - a_e0) / slot * frace
    C_end = c_e0 + (c_e1 - c_e0) / slot * frace

    active = z_t > 1e-15
    spot_work = jnp.minimum(d_eff * jnp.maximum(A_end - A0, 0.0), z_t)
    spot_cost = d_eff * jnp.maximum(C_end - C0, 0.0)
    od_work = z_t - spot_work
    zeros = jnp.zeros_like(z_t)
    sc_ref[...] = jnp.where(active, spot_cost, zeros)
    oc_ref[...] = jnp.where(active, p_od * od_work, zeros)
    sw_ref[...] = jnp.where(active, spot_work, zeros)
    fin_ref[...] = jnp.where(active, jnp.where(on_spot, t_fin, end), start)


def policy_cost(A_cum, C_cum, start, end, z_t, d_eff, *,
                slot: float = 1.0 / 12.0, p_od: float = 1.0,
                block_tasks: int = 128, interpret: bool = False):
    """Batched closed-form task costs under one bid's market arrays.

    A_cum/C_cum: (n_slots+1,) f32 cumulative availability / payment;
    start/end/z_t/d_eff: (T,) task windows and cloud workloads.
    Returns dict(spot_cost, ondemand_cost, spot_work, finish) of (T,).
    """
    n_slots = A_cum.shape[0] - 1
    T = start.shape[0]
    BT = min(block_tasks, max(T, 8))
    pt = (-T) % BT
    if pt:
        pad1 = lambda a: jnp.pad(a, (0, pt))
        start, end, z_t, d_eff = map(pad1, (start, end, z_t, d_eff))
    boundaries_last = n_slots * slot
    H_cum = jnp.arange(n_slots + 1, dtype=jnp.float32) * slot - A_cum
    n_pad = ((n_slots + 1 + _CHUNK - 1) // _CHUNK) * _CHUNK
    padv = n_pad - (n_slots + 1)
    big = jnp.float32(3.4e38)
    A_p = jnp.pad(A_cum.astype(jnp.float32), (0, padv), constant_values=big)
    C_p = jnp.pad(C_cum.astype(jnp.float32), (0, padv), constant_values=big)
    H_p = jnp.pad(H_cum.astype(jnp.float32), (0, padv), constant_values=big)

    kernel = functools.partial(
        _kernel, n_slots=n_slots, n_pad=n_pad, slot=slot, p_od=p_od, BT=BT)
    n_blocks = (T + pt) // BT
    outs = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),
            pl.BlockSpec((n_pad,), lambda i: (0,)),
            pl.BlockSpec((n_pad,), lambda i: (0,)),
            pl.BlockSpec((BT,), lambda i: (i,)),
            pl.BlockSpec((BT,), lambda i: (i,)),
            pl.BlockSpec((BT,), lambda i: (i,)),
            pl.BlockSpec((BT,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((BT,), lambda i: (i,)) for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((T + pt,), jnp.float32)
                   for _ in range(4)],
        interpret=interpret,
    )(A_p, C_p, H_p, start.astype(jnp.float32), end.astype(jnp.float32),
      z_t.astype(jnp.float32), d_eff.astype(jnp.float32))
    sc, oc, sw, fin = [o[:T] for o in outs]
    del boundaries_last
    return {"spot_cost": sc, "ondemand_cost": oc, "spot_work": sw,
            "finish": fin}


def _chain_kernel(A_ref, C_ref, H_ref, arr_ref, ends_ref, z_ref, d_ref,
                  pin_ref, sc_ref, oc_ref, sw_ref, ow_ref, *,
                  n_slots: int, n_pad: int, L: int, slot: float, p_od: float,
                  BT: int):
    nch = n_pad // _CHUNK
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (BT, _CHUNK), 1)

    def gathers_and_counts(idx_list, count_targets):
        """Same chunked comparison-count / one-hot-gather pass as `_kernel`,
        over the (1, 1, n_pad) (bid, scenario) slice this grid cell owns."""
        def body(c, carry):
            g_acc, c_acc = carry
            base = c * _CHUNK
            chunks = [r[0, 0, pl.dslice(base * 0 + base, _CHUNK)]
                      for r in (A_ref, C_ref, H_ref)]
            g_new = []
            for (idx, ref_i), acc in zip(idx_list, g_acc):
                oh = jnp.where(iota_c == (idx[:, None] - base), 1.0, 0.0)
                g_new.append(acc + oh @ chunks[ref_i])
            c_new = []
            for (tgt, ref_i), acc in zip(count_targets, c_acc):
                c_new.append(acc + jnp.sum(
                    (chunks[ref_i][None, :] < tgt[:, None]).astype(jnp.int32),
                    axis=1))
            return g_new, c_new
        g0 = [jnp.zeros((BT,), jnp.float32) for _ in idx_list]
        c0 = [jnp.zeros((BT,), jnp.int32) for _ in count_targets]
        return jax.lax.fori_loop(0, nch, body, (g0, c0))

    def step(k, carry):
        cur, sc, oc, sw, ow = carry
        end = ends_ref[0, 0, pl.dslice(k, 1), :][0]
        z_raw = z_ref[0, 0, pl.dslice(k, 1), :][0]
        d_eff = jnp.maximum(d_ref[0, 0, pl.dslice(k, 1), :][0], 0.0)
        pin = pin_ref[0, 0, pl.dslice(k, 1), :][0] > 0.5
        # Early-start chain semantics (simulate_chains_early): the task runs
        # in [min(cur, end), end]; tasks whose window already elapsed carry
        # no cloud work.
        live = end > cur - 1e-15
        start = jnp.minimum(cur, end)
        z_t = jnp.where(live, z_raw, 0.0)
        d_safe = jnp.where(d_eff > 0, d_eff, 1.0)
        need = z_t / d_safe

        k0 = jnp.clip((start / slot).astype(jnp.int32), 0, n_slots - 1)
        (a_k0, a_k1, c_k0, c_k1), _ = gathers_and_counts(
            [(k0, 0), (k0 + 1, 0), (k0, 1), (k0 + 1, 1)], [])
        frac = start - k0.astype(jnp.float32) * slot
        A0 = a_k0 + (a_k1 - a_k0) / slot * frac
        C0 = c_k0 + (c_k1 - c_k0) / slot * frac
        H0 = start - A0

        h_target = H0 + (end - start) - need
        a_target = A0 + need
        _, (cntH, cntA) = gathers_and_counts(
            [], [(h_target, 2), (a_target, 0)])
        iH = jnp.clip(cntH, 1, n_slots)
        iA = jnp.clip(cntA, 1, n_slots)
        (h_prev, a_prev), _ = gathers_and_counts(
            [(iH - 1, 2), (iA - 1, 0)], [])
        no_flex = (end - start) - need <= jnp.maximum(
            jnp.float32(1e-15),
            jnp.maximum(_FLEX_REL * (end - start), _FLEX_ABS * end))
        t_turn = (iH - 1).astype(jnp.float32) * slot + (h_target - h_prev)
        t_turn = jnp.where(no_flex, start, t_turn)
        t_turn = jnp.where(jnp.logical_and(cntH > n_slots, ~no_flex),
                           jnp.inf, t_turn)
        t_fin = (iA - 1).astype(jnp.float32) * slot + (a_target - a_prev)
        t_fin = jnp.where(a_target <= 0.0, 0.0, t_fin)
        t_fin = jnp.where(cntA > n_slots, jnp.inf, t_fin)

        on_spot = t_fin <= t_turn
        t_end = jnp.minimum(jnp.where(on_spot, t_fin, t_turn), end)
        ke = jnp.clip((t_end / slot).astype(jnp.int32), 0, n_slots - 1)
        (a_e0, a_e1, c_e0, c_e1), _ = gathers_and_counts(
            [(ke, 0), (ke + 1, 0), (ke, 1), (ke + 1, 1)], [])
        frace = t_end - ke.astype(jnp.float32) * slot
        A_end = a_e0 + (a_e1 - a_e0) / slot * frace
        C_end = c_e0 + (c_e1 - c_e0) / slot * frace

        active = z_t > 1e-15
        spot_work = jnp.minimum(d_eff * jnp.maximum(A_end - A0, 0.0), z_t)
        spot_cost = d_eff * jnp.maximum(C_end - C0, 0.0)
        od_work = z_t - spot_work
        zeros = jnp.zeros_like(z_t)
        sc = sc + jnp.where(active, spot_cost, zeros)
        oc = oc + jnp.where(active, p_od * od_work, zeros)
        sw = sw + jnp.where(active, spot_work, zeros)
        ow = ow + jnp.where(active, od_work, zeros)
        fin = jnp.where(active, jnp.where(on_spot, t_fin, end), start)
        fin = jnp.where(pin, end, fin)
        moved = (z_raw > 1e-15) | pin
        cur = jnp.where(moved, fin, cur)
        return cur, sc, oc, sw, ow

    zeros = jnp.zeros((BT,), jnp.float32)
    carry = (arr_ref[0, :], zeros, zeros, zeros, zeros)
    _, sc, oc, sw, ow = jax.lax.fori_loop(0, L, step, carry)
    sc_ref[0, 0, :] = sc
    oc_ref[0, 0, :] = oc
    sw_ref[0, 0, :] = sw
    ow_ref[0, 0, :] = ow


def policy_cost_chain(A_cum, C_cum, arrival, ends, z_t, d_eff, pins, *,
                      slot: float = 1.0 / 12.0, p_od: float = 1.0,
                      block_rows: int = 128, interpret: bool = False):
    """Batched early-start CHAIN costs over B bids x S market scenarios.

    The grid-evaluation extension of ``policy_cost``: the whole
    (bid x scenario x policy x job) grid of a sweep is ONE kernel launch —
    rows are flattened (policy, job) cells, the chain recurrence over the L
    planned windows runs inside the kernel (fori_loop carrying the realized
    start), and (bid, scenario) are grid dimensions selecting which
    cumulative arrays are resident in VMEM.

    A_cum/C_cum: (B, S, n_slots+1) bid- and scenario-stacked cumulative
    arrays — or (S, n_slots+1) / (n_slots+1,) for a single bid (the original
    per-bid entry point, still supported; the result then drops the bid
    axis). arrival: (B, R); ends: (B, R, L) padded plans; z_t/d_eff/pins:
    (B, R, L), or (B, S, R, L) when the plans are scenario-specific
    (per-scenario availability refinement). Rows may be zero-padded
    (z_t == 0) to equalize row counts across bids. Returns dict of
    (B, S, R) per-row aggregates ((S, R) in single-bid mode).
    """
    A_cum = jnp.atleast_2d(jnp.asarray(A_cum, jnp.float32))
    C_cum = jnp.atleast_2d(jnp.asarray(C_cum, jnp.float32))
    single_bid = A_cum.ndim == 2
    if single_bid:
        A_cum, C_cum = A_cum[None], C_cum[None]
        arrival = jnp.asarray(arrival, jnp.float32)[None]
        ends = jnp.asarray(ends, jnp.float32)[None]
        z_t, d_eff, pins = (jnp.asarray(a, jnp.float32)[None]
                            for a in (z_t, d_eff, pins))
    B, S, n1 = A_cum.shape
    n_slots = n1 - 1
    ends = jnp.asarray(ends, jnp.float32)
    R, L = ends.shape[-2:]
    BT = min(block_rows, max(R, 8))
    pt = (-R) % BT
    arrival = jnp.pad(jnp.asarray(arrival, jnp.float32), ((0, 0), (0, pt)))
    # Plans -> (B, S_p, L, R) layout (the chain loop slices L per step);
    # S_p == S only when the caller passed scenario-specific plans.
    def to_lsr(a):
        a = jnp.asarray(a, jnp.float32)
        if a.ndim == 3:
            a = a[:, None]
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pt), (0, 0)))
        return jnp.swapaxes(a, 2, 3)
    ends_p = to_lsr(ends)
    z_p, d_p, pins_p = map(to_lsr, (z_t, d_eff, pins))
    S_p = z_p.shape[1]

    H_cum = jnp.arange(n1, dtype=jnp.float32)[None, None] * slot - A_cum
    n_pad = ((n1 + _CHUNK - 1) // _CHUNK) * _CHUNK
    padv = n_pad - n1
    big = jnp.float32(3.4e38)
    pad_s = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, padv)),
                              constant_values=big)
    A_p, C_p, H_p = pad_s(A_cum), pad_s(C_cum), pad_s(H_cum)

    kernel = functools.partial(
        _chain_kernel, n_slots=n_slots, n_pad=n_pad, L=L, slot=slot,
        p_od=p_od, BT=BT)
    n_blocks = (R + pt) // BT
    plan_idx = (lambda b, s, i: (b, s, 0, i)) if S_p == S and S > 1 \
        else (lambda b, s, i: (b, 0, 0, i))
    plan_spec = pl.BlockSpec((1, 1, L, BT), plan_idx)
    outs = pl.pallas_call(
        kernel,
        grid=(B, S, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, n_pad), lambda b, s, i: (b, s, 0)),
            pl.BlockSpec((1, 1, n_pad), lambda b, s, i: (b, s, 0)),
            pl.BlockSpec((1, 1, n_pad), lambda b, s, i: (b, s, 0)),
            pl.BlockSpec((1, BT), lambda b, s, i: (b, i)),
            pl.BlockSpec((1, 1, L, BT), lambda b, s, i: (b, 0, 0, i)),
            plan_spec,
            plan_spec,
            plan_spec,
        ],
        out_specs=[pl.BlockSpec((1, 1, BT), lambda b, s, i: (b, s, i))
                   for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((B, S, R + pt), jnp.float32)
                   for _ in range(4)],
        interpret=interpret,
    )(A_p, C_p, H_p, arrival, ends_p, z_p, d_p, pins_p)
    sc, oc, sw, ow = [o[:, :, :R] for o in outs]
    if single_bid:
        sc, oc, sw, ow = sc[0], oc[0], sw[0], ow[0]
    return {"spot_cost": sc, "ondemand_cost": oc, "spot_work": sw,
            "ondemand_work": ow}
