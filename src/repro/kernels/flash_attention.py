"""Pallas TPU flash attention (forward) — the production attention path.

Design (TPU-native, not a CUDA port):
  * grid = (batch*q_heads, Sq/bq, Sk/bk); the TPU grid executes the LAST
    dimension innermost and sequentially per core, so the online-softmax
    state (m, l, acc) lives in VMEM scratch carried across kv steps — the
    role CUDA flash attention gives to shared-memory tiles + thread-block
    loops.
  * GQA without materializing repeated K/V: the kv BlockSpec index map sends
    q-head h to kv-head h // group, so K/V tiles are fetched once per group.
  * causal / sliding-window / meta-prefix handling is a `pl.when` skip on
    whole (q, kv) tiles (compute never issued) + an in-tile iota mask on the
    diagonal — the same static skipping the pure-JAX fallback does with its
    python loop.
  * block shapes default to (128, 128): MXU-aligned (the 128x128 systolic
    array), and VMEM-frugal: q/k/v tiles + f32 accumulators for dh=128 are
    ~0.4 MB, far under the ~16 MB VMEM budget, leaving room for the
    double-buffered pipeline.

Validated on CPU in interpret mode against kernels/ref.py (naive softmax
oracle) over shape/dtype sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, sq: int, sk: int, causal: bool, window: int,
            prefix: int, scale: float, n_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = kj * bk
    # Whole-tile skip: strictly-future tiles (causal) and tiles entirely
    # behind the window that contain no prefix rows.
    run = jnp.bool_(True)
    if causal:
        run &= k_lo <= q_lo + bq - 1
    if window > 0:
        behind = (k_lo + bk - 1) < (q_lo - window + 1)
        is_prefix = k_lo < prefix
        run &= ~behind | is_prefix

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        bad = k_pos >= sk                          # key padding
        if causal:
            bad |= k_pos > q_pos
        if window > 0:
            oow = (q_pos - k_pos) >= window
            if prefix > 0:
                oow &= k_pos >= prefix
            bad |= oow
        s = jnp.where(bad, NEG_INF, s)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q, k, v, *, causal: bool = True, window: int = 0, prefix: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: (BH, Sq, dh) — batch*q_heads flattened; k/v: (BK, Sk, dh) with
    BH % BK == 0 (GQA group = BH // BK). Returns (BH, Sq, dh)."""
    BH, Sq, dh = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0, "q heads must be a multiple of kv heads"
    group = BH // BK
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (Sk + pk) // bk

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, sq=Sq, sk=Sk, causal=causal, window=window,
        prefix=prefix, scale=1.0 / np.sqrt(dh), n_kv=nk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :Sq]
    return out
