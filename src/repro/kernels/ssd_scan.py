"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU-native layout of the state-space duality algorithm:
  * grid = (batch, heads, n_chunks); chunks are the innermost sequential
    grid dim, so the running inter-chunk state (N, P) lives in VMEM scratch —
    the warp-level chunk recurrence of the CUDA implementation becomes a
    grid-carried scratch accumulator.
  * the three intra-chunk contractions (C Bᵀ ⊙ L decay mask, diag @ x·dt,
    state outer-product) are MXU matmuls on (Q, N)/(Q, P) tiles;
    Q = chunk = 128..256 and N, P ∈ {64, 128} keep every tile MXU-shaped
    and the whole working set (~6 tiles) well under VMEM.
  * groups (G < H) are handled by the B/C BlockSpec index maps (head h reads
    group h // (H/G)) — no repeated materialization.

Validated in interpret mode against the token-by-token recurrence oracle
(kernels/ref.py::ssd_ref) — a structurally different algorithm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, st_ref, state_scr, *,
            n_chunks: int, Q: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    A = A_ref[0].astype(jnp.float32)                 # scalar
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)

    xdt = x * dt[:, None]
    Adt = A * dt                                     # (Q,)
    cum = jnp.cumsum(Adt)                            # (Q,)

    # Intra-chunk: Y_diag = (C Bᵀ ⊙ L) xdt, L = exp(segsum) on the lower tri.
    seg = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(row >= col, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    Yd = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # Off-diagonal: Y_off = (C ⊙ exp(cum)) @ state_in  (state is (N, P)).
    state_in = state_scr[...]
    C_scaled = Cm * jnp.exp(cum)[:, None]
    Yoff = jax.lax.dot_general(C_scaled, state_in, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (Yd + Yoff).astype(y_ref.dtype)

    # State update: S_out = exp(cum_end) S_in + (B ⊙ decay)ᵀ xdt.
    decay_states = jnp.exp(cum[-1] - cum)            # (Q,)
    B_scaled = Bm * decay_states[:, None]
    upd = jax.lax.dot_general(B_scaled, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state_scr[...] = state_in * jnp.exp(cum[-1]) + upd

    @pl.when(c == n_chunks - 1)
    def _fin():
        st_ref[0, 0] = state_scr[...].T              # (P, N)


def ssd_scan(x, dt, A, B, C, chunk: int = 128, interpret: bool = False):
    """x: (Bb, S, H, P); dt: (Bb, S, H); A: (H,); B/C: (Bb, S, G, N).
    Returns (y, final_state) — y: (Bb, S, H, P), state: (Bb, H, P, N)."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # dt = 0 padding is exact (identity decay, zero update).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // Q

    kernel = functools.partial(_kernel, n_chunks=nc, Q=Q)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S + pad, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    if pad:
        y = y[:, :S]
    return y, st
