"""Pure-jnp oracles for the Pallas kernels.

Deliberately naive implementations (materialized score matrix; sequential
token-by-token SSD recurrence) — structurally different algorithms from the
kernels, so agreement is meaningful.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulate import FLEX_ABS, FLEX_REL

__all__ = ["attention_ref", "ssd_ref", "policy_cost_ref", "chain_costs_ref",
           "hedge_replay_ref"]


def hedge_replay_ref(C, etas, u, n_done):
    """Vectorized numpy oracle for the fused Hedge replay kernel.

    Same two-pass factorization as ``kernels/weight_update.py`` but exact
    float64 and loop-free: the log-space renormalization cancels inside the
    softmax, so the trajectory is just the running sum ``W[k] = sum_{i<k}
    eta_i * C[i]`` and the state at job j's sample is ``softmax(-W[n_done
    [j]])``. Sampling is inverse-CDF (``searchsorted`` side="right") on the
    shared uniform stream — the exact arithmetic ``Generator.choice`` uses.

    C: (J, P) unit costs; etas/u/n_done: (J,). One replay instance.
    Returns dict(chosen, p_chosen, expected_cost, weights).
    """
    C = np.asarray(C, dtype=np.float64)
    J, P = C.shape
    W = np.concatenate([np.zeros((1, P)),
                        np.cumsum(np.asarray(etas)[:, None] * C, axis=0)])
    logw = -W[np.asarray(n_done)]
    logw -= logw.max(axis=1, keepdims=True)
    p = np.exp(logw)
    p /= p.sum(axis=1, keepdims=True)
    cdf = np.cumsum(p, axis=1)
    cdf /= cdf[:, -1:]
    chosen = np.minimum((cdf <= np.asarray(u)[:, None]).sum(axis=1), P - 1)
    wf = -W[J] + W[J].min()
    w = np.exp(wf)
    w /= w.sum()
    return {
        "chosen": chosen.astype(np.int64),
        "p_chosen": p[np.arange(J), chosen],
        "expected_cost": (p * C).sum(axis=1),
        "weights": w,
    }


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  prefix: int = 0):
    """q: (BH, Sq, dh), k/v: (BK, Sk, dh); naive softmax attention."""
    BH, Sq, dh = q.shape
    BK, Sk, _ = k.shape
    g = BH // BK
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    bad = jnp.zeros((Sq, Sk), bool)
    if causal:
        bad |= k_pos > q_pos
    if window > 0:
        oow = (q_pos - k_pos) >= window
        if prefix > 0:
            oow &= k_pos >= prefix
        bad |= oow
    s = jnp.where(bad[None], -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, init_state=None):
    """Token-by-token SSD recurrence (the definition, not the chunked form).

    x: (Bb, S, H, P); dt: (Bb, S, H); A: (H,); B/C: (Bb, S, G, N).
    Returns (y, final_state) — y: (Bb, S, H, P), state: (Bb, H, P, N).
    """
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)   # (Bb, S, H, N)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(A[None, :] * dt_t)                 # (Bb, H)
        upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], B_t)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


def _task_sim(A_cum, C_cum, start, end, z_t, d_eff, slot, p_od):
    """Closed-form task sim on one bid's cumulative arrays (jnp, batched).

    All task arrays share one shape; ``A_cum``/``C_cum`` are (n_slots+1,).
    Mirrors ``repro.core.simulate.simulate_tasks`` exactly (same targets,
    same tie handling).
    """
    n = A_cum.shape[0] - 1
    horizon = n * slot
    boundaries = jnp.arange(n + 1) * slot
    H_cum = boundaries - A_cum

    def interp(cum, t):
        t = jnp.clip(t, 0.0, horizon)
        k = jnp.clip((t / slot).astype(jnp.int32), 0, n - 1)
        frac = t - k * slot
        slope = (cum[k + 1] - cum[k]) / slot
        return cum[k] + slope * frac

    def invert(cum, target):
        k = jnp.searchsorted(cum, target.ravel(), side="left").reshape(
            target.shape)
        k = jnp.clip(k, 1, n)
        return jnp.where(target <= cum[0], boundaries[0],
                         boundaries[k - 1] + (target - cum[k - 1]))

    active = z_t > 1e-15
    d_safe = jnp.where(d_eff > 0, d_eff, 1.0)
    need = z_t / d_safe
    A0 = interp(A_cum, start)
    C0 = interp(C_cum, start)
    H0 = start - A0
    h_target = H0 + (end - start) - need
    # Flexibility epsilon: zero-slack tasks (z == d * window, an atom under
    # Dealloc) must turn at start in every backend regardless of float
    # rounding — the oracle's constants, applied identically.
    no_flex = (end - start) - need <= jnp.maximum(
        1e-15, jnp.maximum(FLEX_REL * (end - start), FLEX_ABS * end))
    t_turn = jnp.where(no_flex, start, invert(H_cum, h_target))
    t_fin = invert(A_cum, A0 + need)
    on_spot = t_fin <= t_turn
    t_end = jnp.minimum(jnp.where(on_spot, t_fin, t_turn), end)
    spot_avail = jnp.maximum(interp(A_cum, t_end) - A0, 0.0)
    spot_work = jnp.minimum(d_eff * spot_avail, z_t)
    spot_cost = d_eff * jnp.maximum(interp(C_cum, t_end) - C0, 0.0)
    od_work = z_t - spot_work
    zeros = jnp.zeros_like(z_t)
    return {
        "spot_cost": jnp.where(active, spot_cost, zeros),
        "ondemand_cost": jnp.where(active, p_od * od_work, zeros),
        "spot_work": jnp.where(active, spot_work, zeros),
        "ondemand_work": jnp.where(active, od_work, zeros),
        "finish": jnp.where(active, jnp.where(on_spot, t_fin, end), start),
    }


def policy_cost_ref(A_cum, C_cum, start, end, z_t, d_eff, p_od=1.0,
                    slot=1.0 / 12.0):
    """Closed-form per-task spot/on-demand costs (mirrors
    repro.core.simulate.simulate_tasks, jnp edition).

    A_cum/C_cum: (n_slots+1,) cumulative availability / spot-payment arrays
    on the slot grid (slot length = 1/12 by default); boundaries are implicit
    (k * slot). Returns dict of per-task arrays.
    """
    return _task_sim(A_cum, C_cum, start, end, z_t, d_eff, slot, p_od)


def chain_costs_ref(A_cum, C_cum, arrival, ends, z_t, d_eff, pins,
                    p_od=1.0, slot=1.0 / 12.0):
    """Early-start chain execution under one bid, batched over rows (jnp).

    Mirrors ``repro.core.simulate.simulate_chains_early``: task k of each
    row starts at its predecessor's realized finish, pinned tasks (holding
    self-owned reservations) finish at their planned deadline. A *row* is one
    (policy, job) cell of the evaluation grid — the batched policy axis of the
    engine is folded into this leading dimension.

    arrival: (R,); ends/z_t/d_eff: (R, L) padded plans; pins: (R, L) bool.
    Returns per-row aggregates (spot/on-demand cost and work) plus the
    realized chain ``finish``.
    """
    xs = (jnp.moveaxis(jnp.asarray(ends), 1, 0),
          jnp.moveaxis(jnp.asarray(z_t), 1, 0),
          jnp.moveaxis(jnp.asarray(d_eff), 1, 0),
          jnp.moveaxis(jnp.asarray(pins), 1, 0))

    def step(carry, inp):
        cur, sc, oc, sw, ow = carry
        end_k, z_k, d_k, pin_k = inp
        live = end_k > cur - 1e-15
        start_k = jnp.minimum(cur, end_k)
        sim = _task_sim(A_cum, C_cum, start_k, end_k,
                        jnp.where(live, z_k, 0.0),
                        jnp.maximum(d_k, 0.0), slot, p_od)
        fin = jnp.where(pin_k, end_k, sim["finish"])
        moved = (z_k > 1e-15) | pin_k
        cur = jnp.where(moved, fin, cur)
        return (cur, sc + sim["spot_cost"], oc + sim["ondemand_cost"],
                sw + sim["spot_work"], ow + sim["ondemand_work"]), None

    zeros = jnp.zeros_like(jnp.asarray(arrival, jnp.result_type(ends)))
    init = (jnp.asarray(arrival, zeros.dtype), zeros, zeros, zeros, zeros)
    (cur, sc, oc, sw, ow), _ = jax.lax.scan(step, init, xs)
    return {"spot_cost": sc, "ondemand_cost": oc, "spot_work": sw,
            "ondemand_work": ow, "finish": cur}
