"""Pallas TPU kernels for the perf-critical compute layers:

  flash_attention — blockwise online-softmax attention (GQA, causal,
                    sliding window + meta-token prefix)
  ssd_scan        — Mamba-2 SSD chunked scan (grid-carried chunk state)
  policy_cost     — the paper's TOLA scoring hot loop (batched closed-form
                    task-cost evaluation over the market's cumulative
                    arrays); policy_cost_chain extends it to whole
                    (scenario x policy x job) grids — one launch per bid,
                    chain recurrence in-kernel (repro.engine's fast path)
  weight_update   — the online-learning hot loop (repro.learn's pallas
                    path): fused Hedge replay — in-VMEM weight-trajectory
                    pass + one-hot-matmul sample gather, one launch per
                    (scenario x learner x schedule-grid) sweep

Each kernel has a pure-jnp oracle in ref.py (structurally different
algorithm) and a jit'd wrapper in ops.py; validated in interpret mode on CPU.
"""

from repro.kernels.ops import flash_attention, policy_cost_batch, ssd

__all__ = ["flash_attention", "ssd", "policy_cost_batch"]
