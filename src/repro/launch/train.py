"""Elastic trainer CLI.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --smoke --steps 50 [--preempt-at 20] [--resume]

Production behaviors exercised here (at CPU scale with smoke configs):
  * pjit train step with the same sharding trees the dry-run compiles;
  * deterministic sharded data pipeline (restart-safe from the step counter);
  * async checkpoints with atomic commit;
  * PREEMPTION + ELASTIC RESTART: ``--preempt-at k`` kills the mesh at step
    k (the paper's spot-reclaim event) and restarts on a smaller device set,
    restoring the latest committed checkpoint onto the new mesh — this is
    the turning-point migration of Definition 3.2 made concrete: the fleet
    orchestrator (repro.sched) decides WHEN to do this vs. buying on-demand
    capacity.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data import SyntheticTokens, make_batches
from repro.distributed.sharding import ShardingRules
from repro.launch import steps as step_lib
from repro.models import build
from repro.optim import AdamW, cosine_schedule

__all__ = ["train_loop", "main"]


def _mesh_for(devices):
    n = len(devices)
    model = 1
    for m in (4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"),
                         devices=np.asarray(devices))


def train_loop(cfg, steps: int, ckpt_dir: str, global_batch: int = 8,
               seq_len: int = 128, devices=None, resume: bool = False,
               preempt_at: int | None = None, log_every: int = 10,
               ckpt_every: int = 20, microbatches: int = 1):
    devices = devices if devices is not None else jax.devices()
    mesh = _mesh_for(devices)
    rules = ShardingRules.create(mesh)
    model = build(cfg)
    opt = AdamW(lr=cosine_schedule(3e-4, 10, steps))
    mgr = CheckpointManager(ckpt_dir)

    extras = {}
    if cfg.kind == "encdec":
        extras["frames"] = (max(seq_len // 4, 1), cfg.d_model)
    if cfg.kind == "vlm":
        extras["vision"] = (cfg.frontend_len, cfg.d_model)
    ds = SyntheticTokens(cfg.vocab, global_batch, seq_len, extras=extras,
                         host_rank=0, host_count=1)

    with mesh:
        params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        batch_s = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), ds.batch(0))
        in_sh, out_sh = step_lib.train_shardings(
            model, rules, mesh, params_s, opt_s, batch_s)
        step_fn = jax.jit(
            step_lib.make_train_step(model, opt, rules,
                                     n_microbatches=microbatches),
            in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1))

        start = 0
        if resume and mgr.latest_step() is not None:
            tmpl = {"params": params_s, "opt": opt_s}
            shard = {"params": in_sh[0], "opt": in_sh[1]}
            state, start = mgr.restore(tmpl, shardings=shard)
            params, opt_state = state["params"], state["opt"]
            print(f"[train] restored step {start} onto "
                  f"{len(devices)} devices (elastic re-shard)")
        else:
            params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                                    in_sh[0])
            opt_state = jax.device_put(opt.init(params), in_sh[1])

        losses = []
        t0 = time.time()
        for s, host_batch in make_batches(ds, start, steps - start):
            if preempt_at is not None and s == preempt_at:
                mgr.wait()
                print(f"[train] PREEMPTED at step {s} "
                      f"(spot reclaim simulated)")
                return {"status": "preempted", "step": s, "losses": losses}
            batch = jax.device_put(host_batch, in_sh[2])
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (s + 1) % log_every == 0:
                dt = (time.time() - t0) / log_every
                print(f"[train] step {s + 1} loss {losses[-1]:.4f} "
                      f"({dt * 1e3:.0f} ms/step)")
                t0 = time.time()
            if (s + 1) % ckpt_every == 0 or s + 1 == steps:
                mgr.save(s + 1, {"params": params, "opt": opt_state})
        mgr.wait()
        return {"status": "done", "step": steps, "losses": losses,
                "final_loss": losses[-1] if losses else None}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--resume", action="store_true")
    p.add_argument("--preempt-at", type=int, default=None)
    p.add_argument("--elastic-demo", action="store_true",
                   help="preempt mid-run, restart on fewer devices")
    args = p.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.elastic_demo:
        half = args.steps // 2
        r = train_loop(cfg, args.steps, args.ckpt_dir, args.batch, args.seq,
                       preempt_at=half, microbatches=args.microbatches)
        print(f"[train] elastic restart after {r['step']} "
              f"on a reduced device set")
        r = train_loop(cfg, args.steps, args.ckpt_dir, args.batch, args.seq,
                       devices=jax.devices()[:max(1, len(jax.devices()) // 2)],
                       resume=True, microbatches=args.microbatches)
        print(f"[train] finished: {r['status']} at step {r['step']}")
        return r
    r = train_loop(cfg, args.steps, args.ckpt_dir, args.batch, args.seq,
                   resume=args.resume, preempt_at=args.preempt_at,
                   microbatches=args.microbatches)
    print(f"[train] finished: {r['status']} at step {r['step']}")
    return r


if __name__ == "__main__":
    main()
