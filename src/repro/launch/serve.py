"""Batched serving loop (prefill + decode with continuous slot reuse).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --max-new 16

A fixed pool of ``batch`` slots runs lockstep decode; finished sequences
(EOS or token budget) are swapped for queued requests and re-prefilled.
Greedy sampling; the decode step is the same jitted function the dry-run
lowers for the ``decode_*`` cells.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import ShardingRules
from repro.launch import steps as step_lib
from repro.obs import span
from repro.models import build

__all__ = ["serve_requests", "main"]


def serve_requests(cfg, prompts: np.ndarray, batch: int, max_new: int,
                   params=None, seed: int = 0):
    """prompts: (n_requests, prompt_len) int32. Returns (n, max_new) tokens."""
    model = build(cfg)
    rules = ShardingRules.create(None)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    n, S = prompts.shape
    max_len = S + max_new + (cfg.n_meta_tokens or 0)

    decode_fn = jax.jit(step_lib.make_decode_step(model, rules))
    prefill_fn = jax.jit(
        lambda p, b: model.prefill(p, b, rules, max_len=max_len))

    out = np.zeros((n, max_new), np.int32)
    queue = list(range(n))
    done_total = 0
    with span("serve.requests", n=n, batch=batch, max_new=max_new) as sp:
        while queue:
            ids = queue[:batch]
            queue = queue[len(ids):]
            pad = batch - len(ids)
            toks = np.concatenate(
                [prompts[ids], np.zeros((pad, S), np.int32)], axis=0)
            pbatch = {"tokens": jnp.asarray(toks)}
            if cfg.kind == "encdec":  # stub audio frontend
                pbatch["frames"] = jnp.zeros(
                    (batch, max(S // 4, 1), cfg.d_model), jnp.float32)
            if cfg.kind == "vlm":     # stub vision frontend
                pbatch["vision"] = jnp.zeros((batch, cfg.frontend_len,
                                              cfg.d_model), jnp.float32)
            logits, cache = prefill_fn(params, pbatch)
            token = jnp.argmax(logits[:, -1, :],
                               axis=-1)[:, None].astype(jnp.int32)
            pos0 = S + (cfg.n_meta_tokens or 0)
            for t in range(max_new):
                for i, rid in enumerate(ids):
                    out[rid, t] = int(token[i, 0])
                if t + 1 < max_new:
                    token, cache = decode_fn(params, cache, token,
                                             jnp.int32(pos0 + t))
            done_total += len(ids)
    dt = sp.seconds
    tps = done_total * max_new / max(dt, 1e-9)
    return out, {"requests": done_total, "tokens_per_s": tps,
                 "wall_s": dt}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    args = p.parse_args(argv)
    # Serving restarts should not re-pay prefill/decode compiles: hook up
    # jax's persistent compilation cache (DESIGN.md §11) before any jit.
    if os.environ.get("REPRO_JAX_CACHE_DIR") != "0":
        try:
            from repro.engine.cache import setup_persistent_cache

            cache_dir = setup_persistent_cache()
            if cache_dir:
                print(f"[serve] persistent compilation cache: {cache_dir}")
        except Exception:
            pass
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                           dtype=np.int32)
    out, stats = serve_requests(cfg, prompts, args.batch, args.max_new)
    print(f"[serve] {stats['requests']} requests, "
          f"{stats['tokens_per_s']:.1f} tok/s, wall {stats['wall_s']:.1f}s")
    print("[serve] first completion:", out[0][:12].tolist())
    return stats


if __name__ == "__main__":
    main()
