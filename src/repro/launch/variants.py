"""Sharding/config variants for the §Perf hillclimb.

Each variant maps (cfg, shape) -> (rule_overrides, cfg'). ``base`` is the
paper-faithful baseline configuration; the others are the hypothesis-driven
changes logged in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

__all__ = ["VARIANTS"]


def _base(cfg, shape):
    return {}, cfg


def _seq_parallel_prefill(cfg, shape):
    """Shard the sequence over the model axis for long prefill (context
    parallelism): activations (B, S, D) carry S/16 per chip instead of
    replicating 32k-deep activations."""
    return {"seq": "model"}, cfg


def _no_remat(cfg, shape):
    """Disable activation recomputation (memory for compute trade)."""
    import dataclasses
    return {}, dataclasses.replace(cfg, remat=False)


def _fsdp_model_too(cfg, shape):
    """Also shard fsdp params over the model axis (ZeRO-3 across ALL chips,
    not just the data axis) — cuts per-chip param+opt bytes 16x, adds
    all-gathers."""
    return {"fsdp": ("pod", "data", "model")}, cfg


def _batch_over_model_too(cfg, shape):
    """Decode variant: spread the batch over every axis (model included) —
    trades weight replication for batch locality."""
    return {"cache_batch": ("pod", "data", "model")}, cfg


def _flash_train(cfg, shape):
    """Blockwise (flash-style) attention for training sequences too —
    kills the O(S^2) f32 score traffic the memory term is dominated by."""
    import dataclasses
    return {}, dataclasses.replace(cfg, flash_threshold=2048)


def _moe_grouped(cfg, shape):
    """Data-local MoE dispatch: routing gathers/scatters never cross the
    data shards; only expert buffers travel (all-to-all)."""
    import dataclasses
    return {}, dataclasses.replace(cfg, moe_groups=64)


def _flash_and_grouped(cfg, shape):
    import dataclasses
    return {}, dataclasses.replace(cfg, flash_threshold=2048, moe_groups=64)


def _accum8(cfg, shape):
    """8 microbatches instead of 4: halves transient activation peak."""
    return {"_microbatches": 8}, cfg


def _flash_accum8(cfg, shape):
    import dataclasses
    return {"_microbatches": 8}, dataclasses.replace(cfg, flash_threshold=2048)


def _flash_accum16(cfg, shape):
    import dataclasses
    return {"_microbatches": 16}, dataclasses.replace(cfg,
                                                      flash_threshold=2048)


VARIANTS = {
    "base": _base,
    "accum8": _accum8,
    "flash_accum8": _flash_accum8,
    "flash_accum16": _flash_accum16,
    "seqpar": _seq_parallel_prefill,
    "no_remat": _no_remat,
    "fsdp_all": _fsdp_model_too,
    "decode_ball": _batch_over_model_too,
    "flash_train": _flash_train,
    "moe_grouped": _moe_grouped,
    "flash_grouped": _flash_and_grouped,
}
