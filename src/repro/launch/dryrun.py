"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--variant v]

Proves the distribution config is coherent without hardware: 512 placeholder
host devices back the production meshes; inputs are ShapeDtypeStructs (no
allocation); the compiled artifact yields memory_analysis() (fits-per-chip),
cost_analysis() (FLOPs/bytes) and the HLO collective schedule — the three
§Roofline terms. Results append to benchmarks/roofline_cache.json.
"""

# MUST be the very first lines — jax locks the device count on first init.
import os

_XLA_PREV = os.environ.get("XLA_FLAGS", "")
os.environ["XLA_FLAGS"] = (
    _XLA_PREV + " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, get_config, input_specs, supports  # noqa: E402
from repro.distributed.sharding import ShardingRules  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch import steps as step_lib  # noqa: E402
from repro.launch.variants import VARIANTS  # noqa: E402
from repro.models import build  # noqa: E402
from repro.obs import span  # noqa: E402
from repro.optim import AdamW  # noqa: E402

CACHE_PATH = os.path.join(os.path.dirname(__file__),
                          "../../../benchmarks/roofline_cache.json")

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"\b((?:bf|f|s|u)\d+|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (SPMD-partitioned)
    HLO. Per-op-kind breakdown for the §Roofline bottleneck analysis."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1).lower()
        # operands are inside the call parens; result shape precedes " = ".
        call = line[m.end():]
        bytes_ = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(call))
        out[kind] = out.get(kind, 0) + bytes_
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-work estimate."""
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * cfg.active_params * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * cfg.active_params * tokens
    # decode: one token per sequence
    return 2.0 * cfg.active_params * shape.global_batch


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               variant: str = "base", n_microbatches: int = 4) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = supports(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant, "status": "skipped", "reason": why}

    with span("dryrun.lower", arch=arch, shape=shape_name) as lower_sp:
        mesh = make_production_mesh(multi_pod=multi_pod)
        overrides, cfg = VARIANTS[variant](cfg, shape)
        overrides = dict(overrides)
        n_microbatches = int(overrides.pop("_microbatches", n_microbatches))
        rules = ShardingRules.create(mesh, overrides)
        model = build(cfg)

        params_s = jax.eval_shape(lambda k: model.init(k),
                                  jax.random.PRNGKey(0))
        batch_s = input_specs(cfg, shape)

        with jax.set_mesh(mesh):
            if shape.mode == "train":
                opt = AdamW(lr=3e-4)
                opt_s = jax.eval_shape(opt.init, params_s)
                fn = step_lib.make_train_step(model, opt, rules,
                                              n_microbatches=n_microbatches)
                in_sh, out_sh = step_lib.train_shardings(
                    model, rules, mesh, params_s, opt_s, batch_s)
                lowered = jax.jit(fn, in_shardings=in_sh,
                                  out_shardings=out_sh).lower(
                    params_s, opt_s, batch_s)
            elif shape.mode == "prefill":
                fn = step_lib.make_prefill_step(model, rules)
                cache_s = jax.eval_shape(fn, params_s, batch_s)[1]
                in_sh, out_sh = step_lib.prefill_shardings(
                    model, rules, mesh, params_s, batch_s, cache_s)
                lowered = jax.jit(fn, in_shardings=in_sh,
                                  out_shardings=out_sh).lower(params_s,
                                                              batch_s)
            else:  # decode
                if cfg.kind == "encdec":
                    cache_s = jax.eval_shape(
                        lambda: model.init_cache(shape.global_batch,
                                                 shape.seq_len,
                                                 enc_len=4096))
                else:
                    cache_s = jax.eval_shape(
                        lambda: model.init_cache(shape.global_batch,
                                                 shape.seq_len))
                fn = step_lib.make_decode_step(model, rules)
                tok_s = batch_s["token"]
                pos_s = jax.ShapeDtypeStruct((), jnp.int32)
                in_sh, out_sh = step_lib.decode_shardings(
                    model, rules, mesh, params_s, cache_s, tok_s)
                lowered = jax.jit(fn, in_shardings=in_sh,
                                  out_shardings=out_sh).lower(
                    params_s, cache_s, tok_s, pos_s)
    t_lower = lower_sp.seconds
    with jax.set_mesh(mesh):
        with span("dryrun.compile", arch=arch, shape=shape_name) as comp_sp:
            compiled = lowered.compile()
    t_compile = comp_sp.seconds

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # cost_analysis() on the CPU backend counts while bodies ONCE (trip
    # counts ignored) — re-derive flops/bytes/collectives from the scheduled
    # HLO with trip-count multipliers (see hlo_analysis.py). All values are
    # PER DEVICE (the module is the per-partition SPMD program).
    ana = analyze(hlo)
    chips = int(np.prod(list(mesh.shape.values())))

    flops = float(ana["flops"])            # per device
    bytes_acc = float(ana["bytes"])        # per device
    coll = {k: float(v) for k, v in ana["collectives"].items()}
    mf = model_flops(cfg, shape)
    t_comp = flops / HW.PEAK_FLOPS_BF16
    t_mem = bytes_acc / HW.HBM_BW
    t_coll = coll["total"] / HW.ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant, "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "hlo_flops_raw_costanalysis": float(cost.get("flops", 0.0)),
        "collective_bytes": coll,
        "model_flops": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
        "analyzer_warnings": ana["warnings"][:5],
        **{k: v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "fits_hbm": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)) < HW.HBM_BYTES,
    }
    return rec


def append_cache(rec: dict):
    path = os.path.abspath(CACHE_PATH)
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    key = (rec["arch"], rec["shape"], rec["multi_pod"], rec["variant"])
    data = [r for r in data
            if (r["arch"], r["shape"], r["multi_pod"], r["variant"]) != key]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--variant", default="base", choices=list(VARIANTS))
    p.add_argument("--skip-cached", action="store_true")
    args = p.parse_args(argv)

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cached = set()
    if args.skip_cached and os.path.exists(os.path.abspath(CACHE_PATH)):
        with open(os.path.abspath(CACHE_PATH)) as f:
            cached = {(r["arch"], r["shape"], r["multi_pod"], r["variant"])
                      for r in json.load(f) if r.get("status") in ("ok", "skipped")}
    for a in archs:
        for s in shapes:
            for mp in meshes:
                if (a, s, mp, args.variant) in cached:
                    print(f"[cached] {a} x {s} mp={mp}")
                    continue
                cells.append((a, s, mp))

    n_fail = 0
    for a, s, mp in cells:
        label = f"{a} x {s} x {'2x16x16' if mp else '16x16'} [{args.variant}]"
        try:
            rec = lower_cell(a, s, multi_pod=mp, variant=args.variant)
            append_cache(rec)
            if rec["status"] == "skipped":
                print(f"[skip] {label}: {rec['reason'][:60]}...")
            else:
                print(f"[ok]   {label}: flops={rec['hlo_flops']:.3e} "
                      f"coll={rec['collective_bytes']['total']:.3e}B "
                      f"peak={rec['bytes_per_device']['peak']/2**30:.2f}GiB "
                      f"bottleneck={rec['bottleneck']} "
                      f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            n_fail += 1
            print(f"[FAIL] {label}: {type(e).__name__}: {e}")
            traceback.print_exc()
            append_cache({"arch": a, "shape": s, "multi_pod": mp,
                          "variant": args.variant, "status": "fail",
                          "error": f"{type(e).__name__}: {e}"})
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
