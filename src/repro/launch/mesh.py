"""Production meshes (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (no module-level device access), so
importing this module never touches jax device state — required for the
smoke tests which must see 1 device while the dry-run sees 512 placeholder
host devices.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (= 256 chips, one v5e pod) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic re-shard."""
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12     # per chip, FLOP/s
    HBM_BW = 819e9               # bytes/s per chip
    ICI_BW = 50e9                # bytes/s per link (~per chip, one direction)
    HBM_BYTES = 16 * 2 ** 30     # 16 GiB per chip
    CHIPS_PER_POD = 256
