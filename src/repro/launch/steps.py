"""Step factories + sharding trees for train / prefill / decode.

Everything here is mesh-agnostic until ``*_shardings`` binds the logical
rules to a concrete mesh; the dry-run, the trainer, and the server all share
these factories so the compiled artifact analyzed in §Roofline is exactly
what would run on hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules, logical_to_spec
from repro.models.api import Model
from repro.optim.adamw import AdamW, OptState

__all__ = [
    "make_train_step", "make_prefill_step", "make_decode_step",
    "train_shardings", "prefill_shardings", "decode_shardings",
    "named", "batch_axes_tree",
]


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (replicate
    fallback) — jit in/out shardings require exact divisibility, unlike
    in-graph constraints. E.g. kv_heads=4 cannot split over model=16, so the
    K/V projections replicate over the model axis."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept: list[str] = []
        size = 1
        for a in axes:
            n = mesh.shape[a]
            if shape[i] % (size * n) == 0:
                kept.append(a)
                size *= n
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def fitted(mesh: Mesh, spec_tree, shapes_tree):
    """Shape-aware NamedSharding tree (divisibility-safe)."""
    return jax.tree.map(
        lambda s, sh: NamedSharding(mesh, _fit_spec(s, sh.shape, mesh)),
        spec_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_axes_tree(model: Model, mode: str) -> dict:
    """Logical axes for the input batch dict of each mode."""
    cfg = model.cfg
    if mode in ("train", "prefill"):
        t = {"tokens": ("batch", "seq")}
        if cfg.kind == "encdec":
            t["frames"] = ("batch", "seq", None)
        if cfg.kind == "vlm":
            t["vision"] = ("batch", "seq", None)
        if mode == "train":
            t["labels"] = ("batch", "seq")
        return t
    return {"token": ("cache_batch", None)}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(model: Model, optimizer: AdamW, rules: ShardingRules,
                    n_microbatches: int = 1):
    """Training step with gradient accumulation.

    ``n_microbatches > 1`` scans over microbatch slices accumulating f32
    grads — the standard large-scale memory lever: transient activation
    footprint scales with the microbatch, while the optimizer still sees the
    full global batch. (The per-device peak in EXPERIMENTS.md §Dry-run is
    reported with the default microbatching.)
    """
    def grad_fn(params, mb):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, mb, rules))(params)
        return loss, grads

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape(
                        (n_microbatches, x.shape[0] // n_microbatches)
                        + x.shape[1:])[i],
                    batch)

            def body(carry, i):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, slice_mb(i))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), jnp.arange(n_microbatches))
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step


def train_shardings(model: Model, rules: ShardingRules, mesh: Mesh,
                    params_shapes, opt_shapes, batch_shapes):
    p_spec = logical_to_spec(rules, model.axes())
    p_sh = fitted(mesh, p_spec, params_shapes)
    opt_sh = OptState(step=NamedSharding(mesh, P()),
                      m=fitted(mesh, p_spec, opt_shapes.m),
                      v=fitted(mesh, p_spec, opt_shapes.v))
    b_spec = logical_to_spec(rules, batch_axes_tree(model, "train"))
    b_sh = fitted(mesh, b_spec, batch_shapes)
    metrics_sh = named(mesh, {"loss": P(), "grad_norm": P(), "step": P()})
    return (p_sh, opt_sh, b_sh), (p_sh, opt_sh, metrics_sh)


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, rules: ShardingRules,
                      max_len: int | None = None):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, rules, max_len=max_len)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), cache

    return prefill_step


def prefill_shardings(model: Model, rules: ShardingRules, mesh: Mesh,
                      params_shapes, batch_shapes, cache_shapes):
    p_spec = logical_to_spec(rules, model.axes())
    b_spec = logical_to_spec(rules, batch_axes_tree(model, "prefill"))
    cache_spec = logical_to_spec(rules, model.cache_axes())
    B = batch_shapes["tokens"].shape[0]
    tok = fitted(mesh, rules.spec("cache_batch", None),
                 jax.ShapeDtypeStruct((B, 1), jnp.int32))
    in_s = (fitted(mesh, p_spec, params_shapes),
            fitted(mesh, b_spec, batch_shapes))
    out_s = (tok, fitted(mesh, cache_spec, cache_shapes))
    return in_s, out_s


def make_decode_step(model: Model, rules: ShardingRules):
    """One-token greedy serve step: (params, cache, token, pos) ->
    (next_token, cache)."""
    def decode_step(params, cache, token, pos):
        logits, new_cache = model.decode(params, cache, token, pos, rules)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), new_cache

    return decode_step


def decode_shardings(model: Model, rules: ShardingRules, mesh: Mesh,
                     params_shapes, cache_shapes, token_shape):
    p_spec = logical_to_spec(rules, model.axes())
    cache_spec = logical_to_spec(rules, model.cache_axes())
    tok = fitted(mesh, rules.spec("cache_batch", None), token_shape)
    pos = NamedSharding(mesh, P())
    p_sh = fitted(mesh, p_spec, params_shapes)
    c_sh = fitted(mesh, cache_spec, cache_shapes)
    in_s = (p_sh, c_sh, tok, pos)
    out_s = (tok, c_sh)
    return in_s, out_s
