"""Roofline-grade analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` on the CPU backend counts every while-loop BODY
exactly once (trip counts ignored) — useless for scanned-layer models where
>95% of the work lives inside the layer scan. This module re-derives the
three roofline inputs from the scheduled HLO text itself:

  * FLOPs       — from every ``dot`` op: 2 * prod(result_dims) *
                  prod(lhs contracting dim sizes), with operand shapes
                  resolved through a per-computation symbol table (scheduled
                  HLO prints operands without types). Multiplied through the
                  call graph using each while's ``known_trip_count``.
                  Elementwise FLOPs ignored (MXU-roofline convention).
  * HBM bytes   — operand + result bytes of ops that actually move data on
                  TPU (fusions, dots, copies, dynamic slices/updates,
                  gathers/scatters, reduces, sorts, custom calls,
                  collectives). Bitcasts/reshapes/broadcasts/elementwise are
                  excluded: on TPU they fuse into neighbors; counting the
                  CPU backend's materialization of them would overstate HBM
                  traffic ~40x.
  * collectives — operand bytes per collective kind, same multipliers.

Exact for the static-trip-count scans this framework emits (layer stacks,
microbatch accumulation, SSD chunk scans, blockwise-attention kv scans).
"""

from __future__ import annotations

import re

__all__ = ["analyze"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"\b((?:bf|f|s|u)\d+|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([a-z][\w\-]*)\(")
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# Ops whose operands/results count as HBM traffic on TPU.
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "sort",
    "custom-call", "rng", "select-and-scatter", "reduce-window", "cholesky",
    "triangular-solve",
} | set(_COLLECTIVES) | {c + "-start" for c in _COLLECTIVES}


def _bytes_of_type(text: str) -> int:
    return sum(_DTYPE_BYTES.get(d, 4) * _dims_prod(s)
               for d, s in _SHAPE_RE.findall(text))


def _dims_prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _parse_computations(hlo: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(line)
            if m and ("->" in line or line.rstrip().endswith("{")):
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry is None:
        entry = next((c for c in comps if c.startswith("main")),
                     next(iter(comps), None))
    comps["__entry__"] = entry
    return comps


def _operands(line: str, op_end: int) -> list[str]:
    """Operand op-names from the call parens (up to the closing paren)."""
    depth = 1
    i = op_end
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    return _OPERAND_RE.findall(line[op_end:i - 1])


def analyze(hlo: str) -> dict:
    comps = _parse_computations(hlo)
    entry = comps.pop("__entry__")
    warnings: list[str] = []

    # Per-computation symbol tables: op name -> result type text.
    symtab: dict[str, dict[str, str]] = {}
    parsed: dict[str, list] = {}
    for name, lines in comps.items():
        tab: dict[str, str] = {}
        ops = []
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            tab[m.group(1)] = m.group(2)
            ops.append((m.group(1), m.group(2), m.group(3), m.end(), line))
        symtab[name] = tab
        parsed[name] = ops

    memo: dict[str, dict] = {}

    def op_bytes(comp: str, opcode: str, result_t: str,
                 operand_names: list[str], trip: int) -> float:
        tab = symtab[comp]
        if opcode == "dynamic-update-slice":
            # In-place DUS traffic = the update slice (read) + its write,
            # NOT the full buffer (XLA updates in place).
            upd = tab.get(operand_names[1], "") if len(operand_names) > 1 else ""
            return 2.0 * _bytes_of_type(upd or result_t)
        if opcode in ("dynamic-slice", "slice", "gather"):
            return 2.0 * _bytes_of_type(result_t)
        b = _bytes_of_type(result_t)
        for o in operand_names:
            t = tab.get(o)
            if not t:
                continue
            ob = _bytes_of_type(t)
            if trip > 1:
                # Stack heuristic: an operand whose leading dim equals the
                # enclosing loop's trip count is a scan-stacked buffer the
                # fusion slices per iteration (saved residuals / stacked
                # layer weights) — charge one slice, not the whole stack.
                m = _SHAPE_RE.search(t)
                if m:
                    dims = [int(x) for x in m.group(2).split(",") if x]
                    if dims and dims[0] == trip:
                        ob /= trip
            b += ob
        return b

    def dot_flops(comp: str, result_t: str, operand_names: list[str],
                  line: str) -> float:
        res = _SHAPE_RE.search(result_t)
        cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if not res or not cd or not operand_names:
            warnings.append(f"unparseable dot in {comp}")
            return 0.0
        lhs_t = symtab[comp].get(operand_names[0], "")
        lhs = _SHAPE_RE.search(lhs_t)
        if not lhs:
            warnings.append(f"dot lhs shape unresolved in {comp}")
            return 0.0
        lhs_dims = [int(x) for x in lhs.group(2).split(",") if x]
        contract = 1
        for d in (int(x) for x in cd.group(1).split(",") if x):
            if d < len(lhs_dims):
                contract *= lhs_dims[d]
        return 2.0 * _dims_prod(res.group(2)) * contract

    def walk(name: str, in_fusion: bool = False, trip: int = 1) -> dict:
        key = (name, in_fusion, trip)
        if key in memo:
            return memo[key]
        out = {"flops": 0.0, "bytes": 0.0,
               "coll": {k: 0.0 for k in _COLLECTIVES}}
        memo[key] = out
        for op_name, result_t, opcode, op_end, line in parsed.get(name, ()):
            mult = 1.0
            if opcode == "while":
                t = _TRIP_RE.search(line)
                if t:
                    mult = float(t.group(1))
                else:
                    warnings.append(f"while w/o known_trip_count in {name}")
            operands = _operands(line, op_end)
            if opcode == "dot":
                out["flops"] += dot_flops(name, result_t, operands, line)
            elif opcode == "convolution":
                warnings.append("convolution flops not counted")
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in _COLLECTIVES:
                coll_b = 0.0
                tab = symtab[name]
                for o in operands:
                    t = tab.get(o)
                    if t:
                        coll_b += _bytes_of_type(t)
                if coll_b == 0.0:  # operands unresolved: use result size
                    coll_b = _bytes_of_type(result_t)
                out["coll"][base] += coll_b
            if opcode in _MEM_OPS and not in_fusion:
                # Ops inside fusion-called computations live in VMEM/regs —
                # only the fusion's own operands/results touch HBM.
                out["bytes"] += op_bytes(name, opcode, result_t, operands,
                                         trip)
            for c in _CALLEE_RE.findall(line):
                if c not in parsed:
                    continue
                is_while = opcode == "while"
                sub = walk(c,
                           in_fusion=in_fusion or opcode == "fusion",
                           trip=int(mult) if is_while else trip)
                use = mult if is_while else 1.0
                out["flops"] += sub["flops"] * use
                out["bytes"] += sub["bytes"] * use
                for k in _COLLECTIVES:
                    out["coll"][k] += sub["coll"][k] * use
            br = _BRANCH_RE.search(line)
            if br:
                for c in br.group(1).split(","):
                    c = c.strip().lstrip("%")
                    if c in parsed:
                        sub = walk(c, in_fusion=in_fusion, trip=trip)
                        out["flops"] += sub["flops"]
                        out["bytes"] += sub["bytes"]
                        for k in _COLLECTIVES:
                            out["coll"][k] += sub["coll"][k]
        return out

    res = walk(entry) if entry else {"flops": 0, "bytes": 0, "coll": {}}
    coll = dict(res["coll"])
    coll["total"] = sum(coll.values())
    return {"flops": res["flops"], "bytes": res["bytes"],
            "collectives": coll, "warnings": sorted(set(warnings))}
