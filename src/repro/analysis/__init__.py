"""repro.analysis — static contract checker for the jobs->cost->regret
array program (DESIGN.md §12).

Two layers:

- **Layer 1** (``rules``/``engine``): stdlib-``ast`` source rules
  ``RPR0xx`` over the written invariants — timing, cache bounds, f64
  discipline, named epsilon guards, host-sync, donation whitelist,
  callback-free hot path. No code execution, no jax required.
- **Layer 2** (``programs``): the compiled-program verifier —
  abstract-traces the registered jit factories and pallas launchers on
  canonical shapes and asserts the §9 placement contract, callback- and
  f64-free jaxprs, donation aliasing validity and weak-type hygiene.

CLI: ``python -m repro.analysis [--format text|json]
[--baseline analysis-baseline.json] [--programs] [paths...]``;
exits 0 (clean) / 1 (findings) / 2 (internal error).
"""

from .engine import (Baseline, analyze_source, load_baseline,
                     run_source_analysis)
from .rules import RULES, RULES_BY_CODE, Finding

__all__ = [
    "Baseline", "Finding", "RULES", "RULES_BY_CODE", "analyze_source",
    "load_baseline", "run_source_analysis",
]
