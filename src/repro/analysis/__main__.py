"""CLI: ``python -m repro.analysis [opts] [paths...]``.

Exit codes: 0 = clean, 1 = findings (lint or program-contract
violations), 2 = internal error. Default paths: ``src`` (plus
``benchmarks`` when present) under the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

from .engine import load_baseline, run_source_analysis
from .report import render_json, render_text


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/__main__.py -> repo root three levels above src/
    return pathlib.Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="Static contract checker (source rules + compiled-"
                    "program verifier) for the repro array program.")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint (default: src/)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: <root>/analysis-"
                         "baseline.json if present)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths (default: "
                         "autodetected)")
    ap.add_argument("--programs", action="store_true",
                    help="also run the Layer-2 compiled-program verifier "
                         "(requires jax)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the Layer-1 source rules (with --programs: "
                         "verifier only)")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root).resolve() if args.root else _repo_root()
    baseline_path = args.baseline
    if baseline_path is None:
        cand = root / "analysis-baseline.json"
        baseline_path = cand if cand.exists() else None

    active, baselined = [], []
    if not args.no_lint:
        paths = args.paths or [p for p in ("src", "benchmarks")
                               if (root / p).is_dir()]
        baseline = load_baseline(baseline_path)
        active, baselined = run_source_analysis(paths, root, baseline)

    checks = []
    if args.programs:
        from .programs import verify_all

        checks = verify_all()

    failed = [c for c in checks if not c.ok]
    if args.format == "json":
        payload = json.loads(render_json(active, baselined))
        if args.programs:
            payload["programs"] = [c.to_dict() for c in checks]
            payload["counts"]["program_failures"] = len(failed)
        print(json.dumps(payload, indent=2, sort_keys=True))
    else:
        if not args.no_lint:
            print(render_text(active, baselined))
        if args.programs:
            print()
            width = max((len(c.program) for c in checks), default=8)
            for c in checks:
                mark = "ok " if c.ok else "FAIL"
                print(f"[{mark}] {c.program:<{width}} {c.check:<12} "
                      f"{c.detail}")
            print(f"\nprograms: {len({c.program for c in checks})} verified, "
                  f"{len(failed)} failed checks")
    return 1 if (active or failed) else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SystemExit:
        raise
    except Exception:
        traceback.print_exc()
        sys.exit(2)
