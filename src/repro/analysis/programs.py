"""Layer-2: the compiled-program verifier (DESIGN.md §12).

Abstract-traces the registered jit factories — the six ``record_jit``
program families plus the pallas launchers — on canonical small shapes
(``jax.ShapeDtypeStruct`` args: tracing and AOT compilation only, no
device execution) and statically asserts the contracts that runtime
tests used to grep compiled HLO for:

- **placement (§9)**: zero collectives in the sharded synth/views/eval
  hot loop; exactly ONE packed all-reduce (the ``lax.psum``) in
  ``learn.fold:sharded`` — per-kind op counts from
  :func:`repro.obs.compiled.collective_counts` over the compiled text;
- **callback-free hot path**: no ``pure_callback``/``io_callback``/
  ``debug_callback`` primitives anywhere in the jaxpr (recursing into
  sub-jaxprs: pjit bodies, scan/cond branches, shard_map, pallas);
- **dtype lattice (§6)**: no f64/c128 aval anywhere in the jaxpr — the
  f64 oracle is host numpy, never a traced program;
- **donation validity (§11)**: each donated argnum's shape+dtype matches
  an output aval exactly, so the alias is warning-free;
- **weak types**: no weakly-typed OUTPUT aval — a weak output re-enters
  the next program with a different aval than a strong one and
  fragments downstream jit caches.

Program inventory (canonical shapes mirror the real call sites; on a 2-D
``GridMesh`` scenario rows scale with ``data_shards`` and group rows with
``model_shards``, so every axis divides its mesh axis exactly):

============================  ============================================
engine.eval.chain:sharded     ``backend_jax._sharded_fns(mesh)["chain"]``
engine.eval.task:sharded      ``backend_jax._sharded_fns(mesh)["task"]``
engine.eval.chain_ps:sharded  ``_sharded_fns(mesh)["chain_ps"]`` (refined)
engine.eval.task_ps:sharded   ``_sharded_fns(mesh)["task_ps"]`` (refined)
scenarios.synth:fresh:shd     ``scenarios._device_synth_fn(spec, mesh)``
scenarios.views:sharded       ``scenarios._device_views_fn(slot, mesh)``
plan.device.full              ``plan._device_plan_fns("prop12", "dealloc")``
learn.scan:hedge              ``replay._compiled_scan("hedge", ring)``
learn.fold:sharded            ``replay._sharded_fold(mesh, ...)`` (donated)
kernels.policy_cost.chain     ``policy_cost_chain`` (interpret pallas)
kernels.hedge_replay          ``weight_update._hedge_call`` (interpret)
kernels.flash_attention       ``ops._flash_jit`` (interpret pallas)
kernels.ssd_scan              ``ops._ssd_jit`` (interpret pallas)
============================  ============================================

The ``_ps`` (per-scenario availability, i.e. TOLA pool-refinement) eval
programs carry (S, R, L) self-owned stacks sharded over BOTH mesh axes
and, like the plain eval programs, must compile to ZERO collectives —
refinement rounds cost no cross-device traffic either.

The verifier is what ``tests/test_shard.py``'s collective assertions and
``obs.compiled``'s standing §9 check delegate to — one implementation of
the placement contract.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = [
    "CheckResult", "ProgramSpec", "PROGRAM_KEYS", "program_inventory",
    "verify_program", "verify_all",
]

_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
})

# §9 placement contract: exact per-kind collective op counts.
_ZERO = {"total": 0}
_ONE_PSUM = {"all-reduce": 1, "total": 1}

PROGRAM_KEYS = (
    "engine.eval.chain:sharded",
    "engine.eval.task:sharded",
    "engine.eval.chain_ps:sharded",
    "engine.eval.task_ps:sharded",
    "scenarios.synth:fresh:sharded",
    "scenarios.views:sharded",
    "plan.device.full",
    "learn.scan:hedge",
    "learn.fold:sharded",
    "kernels.policy_cost.chain",
    "kernels.hedge_replay",
    "kernels.flash_attention",
    "kernels.ssd_scan",
)


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One contract assertion on one program."""

    program: str
    check: str      # collectives | callbacks | dtype | donation | weak-type | build
    ok: bool
    detail: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ProgramSpec:
    key: str
    fn: object                  # jit-wrapped callable (has .lower)
    args: tuple                 # ShapeDtypeStructs + Python scalars
    collectives: dict           # expected exact counts (subset of kinds)
    donated: tuple = ()         # argnums whose buffers the program donates


# --------------------------------------------------------------------------
# Jaxpr walking (duck-typed: no jax.core imports)
# --------------------------------------------------------------------------

def _subjaxprs(params: dict):
    """Sub-jaxprs hiding in eqn params: pjit/scan/cond/shard_map/pallas."""
    for v in params.values():
        for item in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(item, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner
            elif hasattr(item, "eqns"):
                yield item


def _jaxpr_stats(closed) -> tuple[set, list]:
    """(primitive names, wide-dtype aval descriptions) over all sub-jaxprs."""
    prims: set[str] = set()
    wide: list[str] = []

    def _aval(var):
        aval = getattr(var, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None and str(dt) in ("float64", "complex128", "int64"):
            if str(dt) != "int64":      # int64 indices are canonicalized
                wide.append(f"{str(dt)}{getattr(aval, 'shape', ())}")

    stack = [getattr(closed, "jaxpr", closed)]
    seen: set[int] = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for var in (*j.invars, *j.outvars, *j.constvars):
            _aval(var)
        for eqn in j.eqns:
            prims.add(eqn.primitive.name)
            for var in (*eqn.invars, *eqn.outvars):
                _aval(var)
            stack.extend(_subjaxprs(eqn.params))
    return prims, wide


def _flatten_shapes(tree) -> list:
    import jax

    return jax.tree_util.tree_leaves(tree)


# --------------------------------------------------------------------------
# Per-program verification
# --------------------------------------------------------------------------

def verify_program(fn, args: Sequence, *, key: str = "?",
                   collectives: dict | None = None,
                   donated: Sequence[int] = ()) -> list[CheckResult]:
    """Run every static check on one program; never executes it."""
    import jax

    results: list[CheckResult] = []

    # ---- jaxpr-level checks: callbacks + dtype lattice -------------------
    try:
        jaxpr = jax.make_jaxpr(fn)(*args)
    except Exception as exc:
        return [CheckResult(key, "build", False,
                            f"trace failed: {type(exc).__name__}: {exc}")]
    prims, wide = _jaxpr_stats(jaxpr)
    bad_cb = sorted(prims & _CALLBACK_PRIMS)
    results.append(CheckResult(
        key, "callbacks", not bad_cb,
        f"callback primitives in jaxpr: {bad_cb}" if bad_cb
        else "no callback primitives"))
    results.append(CheckResult(
        key, "dtype", not wide,
        f"wide dtypes in jaxpr: {sorted(set(wide))}" if wide
        else "dtype lattice clean (no f64/c128 avals)"))

    # ---- output avals: donation aliasing + weak-type leakage -------------
    try:
        out = jax.eval_shape(fn, *args)
    except Exception as exc:
        results.append(CheckResult(key, "weak-type", False,
                                   f"eval_shape failed: {exc}"))
        out = None
    if out is not None:
        leaves = _flatten_shapes(out)
        weak = [f"output[{i}] {l.shape} {l.dtype}"
                for i, l in enumerate(leaves)
                if getattr(l, "weak_type", False)]
        results.append(CheckResult(
            key, "weak-type", not weak,
            f"weakly-typed outputs: {weak}" if weak
            else "all outputs strongly typed"))
        for argnum in donated:
            arg = args[argnum]
            aliased = any(
                tuple(l.shape) == tuple(arg.shape) and l.dtype == arg.dtype
                for l in leaves)
            results.append(CheckResult(
                key, "donation", aliased,
                f"donated arg {argnum} shape={tuple(arg.shape)} "
                f"dtype={arg.dtype} "
                + ("aliases an output exactly" if aliased else
                   "matches NO output aval — donation would be dropped "
                   "with a warning")))

    # ---- compiled HLO: §9 collective placement ---------------------------
    if collectives is not None:
        from repro.obs.compiled import collective_counts
        try:
            txt = fn.lower(*args).compile().as_text()
        except Exception as exc:
            results.append(CheckResult(
                key, "collectives", False,
                f"lower/compile failed: {type(exc).__name__}: {exc}"))
            return results
        counts = collective_counts(txt)
        bad = {k: (counts.get(k, 0), v) for k, v in collectives.items()
               if counts.get(k, 0) != v}
        results.append(CheckResult(
            key, "collectives", not bad,
            (f"collective counts off contract: "
             + ", ".join(f"{k}={got} (want {want})"
                         for k, (got, want) in sorted(bad.items()))
             + f"; full counts {counts}") if bad
            else f"placement contract holds: {counts}"))
    return results


# --------------------------------------------------------------------------
# Canonical program inventory
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _build_eval_programs(mesh) -> list[ProgramSpec]:
    import jax.numpy as jnp

    from repro.engine import backend_jax as bj

    # Scenario rows ride "data", group rows ride "model": size each axis
    # by its own shard count so the canonical shapes divide exactly.
    d, m = mesh.data_shards, mesh.model_shards
    fns = bj._sharded_fns(mesh)
    A = _sds((d, 11), jnp.float32)
    R, L = 4 * m, 3
    chain_args = (A, A, _sds((R,), jnp.float32), _sds((R, L), jnp.float32),
                  _sds((R, L), jnp.float32), _sds((R, L), jnp.float32),
                  _sds((R, L), jnp.bool_), 1.0, 1.0)
    F = 12 * m
    task_args = (A, A, _sds((F,), jnp.float32), _sds((F,), jnp.float32),
                 _sds((F,), jnp.float32), _sds((F,), jnp.float32),
                 1.0, 1.0)
    chain_ps_args = (A, A, _sds((R,), jnp.float32),
                     _sds((R, L), jnp.float32),
                     _sds((d, R, L), jnp.float32),
                     _sds((d, R, L), jnp.float32),
                     _sds((d, R, L), jnp.bool_), 1.0, 1.0)
    task_ps_args = (A, A, _sds((F,), jnp.float32), _sds((F,), jnp.float32),
                    _sds((d, F), jnp.float32), _sds((d, F), jnp.float32),
                    1.0, 1.0)
    return [
        ProgramSpec("engine.eval.chain:sharded", fns["chain"], chain_args,
                    dict(_ZERO)),
        ProgramSpec("engine.eval.task:sharded", fns["task"], task_args,
                    dict(_ZERO)),
        ProgramSpec("engine.eval.chain_ps:sharded", fns["chain_ps"],
                    chain_ps_args, dict(_ZERO)),
        ProgramSpec("engine.eval.task_ps:sharded", fns["task_ps"],
                    task_ps_args, dict(_ZERO)),
    ]


def _build_scenario_programs(mesh) -> list[ProgramSpec]:
    import jax.numpy as jnp

    from repro.engine.scenarios import (ScenarioSpec, _device_synth_fn,
                                        _device_views_fn)

    # Synthesis/views shard over "data" only (replicated over "model").
    n = mesh.data_shards
    spec = ScenarioSpec("fresh", 8.0, n, seed=1)
    synth = _device_synth_fn(spec, mesh)
    z = _sds((n, spec.n_slots), jnp.float32)
    idx = _sds((n,), jnp.int32)
    views = _device_views_fn(1.0 / 12.0, mesh)
    h = _sds((n, spec.n_slots), jnp.uint32)
    price = _sds((n, spec.n_slots), jnp.float32)
    spike = _sds((n, spec.n_slots), jnp.bool_)
    thresh = _sds((n,), jnp.uint32)
    return [
        ProgramSpec("scenarios.synth:fresh:sharded", synth,
                    (idx, z, z, z), dict(_ZERO)),
        ProgramSpec("scenarios.views:sharded", views,
                    (h, price, spike, thresh, False), dict(_ZERO)),
    ]


def _build_plan_program() -> list[ProgramSpec]:
    import jax.numpy as jnp

    from repro.engine.plan import _device_plan_fns

    fns = _device_plan_fns("prop12", "dealloc")
    J, L, W, Ga, G = 3, 2, 2, 2, 2
    jl = _sds((J, L), jnp.float32)
    args = (jl, jl, _sds((J, L), jnp.bool_), _sds((J,), jnp.float32),
            _sds((J,), jnp.float32), jl, _sds((W,), jnp.float32),
            _sds((Ga,), jnp.int32), _sds((Ga,), jnp.float32), 1.0,
            _sds((G,), jnp.int32))
    return [ProgramSpec("plan.device.full", fns["full"], args, dict(_ZERO))]


def _canonical_events():
    """Tiny sample/update event stream: 3 jobs, ring 2."""
    import numpy as np

    ev_kind = np.array([0, 0, 1, 0, 1, 1], np.int32)
    ev_j = np.array([0, 1, 0, 2, 1, 2], np.int32)
    return ev_kind, ev_j, 3


def _build_learn_programs(mesh) -> list[ProgramSpec]:
    import jax.numpy as jnp

    from repro.learn.replay import (_compiled_scan, _event_ring,
                                    _sharded_fold, fold_acc_size)

    ev_kind, ev_j, J = _canonical_events()
    ring = _event_ring(ev_kind)
    P = 4
    scan = _compiled_scan("hedge", ring)
    scan_args = (_sds((2, J, P), jnp.float32), _sds((2, J), jnp.float32),
                 _sds((1, J), jnp.float32), _sds((1, J), jnp.float32),
                 _sds(ev_kind.shape, jnp.int32), _sds(ev_j.shape, jnp.int32))
    # The fold shards chunk rows over "data" and psums over "data" only;
    # a 2-D mesh's "model" axis sees replicated inputs and no collective.
    n = mesh.data_shards
    fold = _sharded_fold(mesh, (("hedge", 1),), ring, 0)
    fold_args = (_sds((fold_acc_size(1, J, P),), jnp.float32),
                 _sds((2 * n, J, P), jnp.float32),
                 _sds((2 * n, J), jnp.float32), _sds((2 * n,), jnp.bool_),
                 _sds((1, J), jnp.float32), _sds((1, J), jnp.float32),
                 _sds(ev_kind.shape, jnp.int32), _sds(ev_j.shape, jnp.int32),
                 _sds((J,), jnp.int32), _sds((J,), jnp.float32))
    return [
        ProgramSpec("learn.scan:hedge", scan, scan_args, dict(_ZERO)),
        ProgramSpec("learn.fold:sharded", fold, fold_args, dict(_ONE_PSUM),
                    donated=(0,)),
    ]


def _build_kernel_programs() -> list[ProgramSpec]:
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import _flash_jit, _ssd_jit
    from repro.kernels.policy_cost import policy_cost_chain
    from repro.kernels.weight_update import _hedge_call

    out: list[ProgramSpec] = []
    # policy_cost_chain: single-bid entry, S=2 scenarios, R=4 rows, L=2.
    chain = jax.jit(functools.partial(
        policy_cost_chain, slot=1.0 / 12.0, p_od=1.0, block_rows=8,
        interpret=True))
    S, R, L, n1 = 2, 4, 2, 13
    chain_args = (_sds((S, n1), jnp.float32), _sds((S, n1), jnp.float32),
                  _sds((R,), jnp.float32), _sds((R, L), jnp.float32),
                  _sds((R, L), jnp.float32), _sds((R, L), jnp.float32),
                  _sds((R, L), jnp.float32))
    out.append(ProgramSpec("kernels.policy_cost.chain", chain, chain_args,
                           dict(_ZERO)))
    # hedge_replay's traceable core on its padded layout (S=2, K=1).
    J, P, BJ = 3, 4, 8
    Jp, Pp = 8, 128
    n_rows = 8
    hedge = jax.jit(functools.partial(
        _hedge_call, K=1, J=J, n_rows=n_rows, Pp=Pp, m=P, BJ=BJ,
        interpret=True))
    hedge_args = (_sds((2, Jp, Pp), jnp.float32), _sds((1, Jp), jnp.float32),
                  _sds((2, Jp), jnp.float32), _sds((1, Jp), jnp.int32))
    out.append(ProgramSpec("kernels.hedge_replay", hedge, hedge_args,
                           dict(_ZERO)))
    # flash attention fwd: 2 heads, Sq=Sk=8, dh=8, one block.
    flash = jax.jit(functools.partial(
        _flash_jit, causal=True, window=0, prefix=0, block_q=8, block_k=8,
        interpret=True))
    q = _sds((2, 8, 8), jnp.float32)
    out.append(ProgramSpec("kernels.flash_attention", flash, (q, q, q),
                           dict(_ZERO)))
    # ssd scan: Bb=1, S=8, H=2, P=4, G=1, N=4, one chunk.
    ssd = jax.jit(functools.partial(_ssd_jit, chunk=8, interpret=True))
    ssd_args = (_sds((1, 8, 2, 4), jnp.float32), _sds((1, 8, 2), jnp.float32),
                _sds((2,), jnp.float32), _sds((1, 8, 1, 4), jnp.float32),
                _sds((1, 8, 1, 4), jnp.float32))
    out.append(ProgramSpec("kernels.ssd_scan", ssd, ssd_args, dict(_ZERO)))
    return out


def program_inventory(mesh=None, keys: Sequence[str] | None = None
                      ) -> tuple[list[ProgramSpec], list[CheckResult]]:
    """Build (programs, build_failures) for the canonical inventory.

    ``mesh=None`` creates the default :class:`GridMesh` over all visible
    devices — a 1-D (data-only) mesh; pass ``GridMesh.create(n, m)`` to
    verify the 2-D scenario x group placement. (1-device degenerate mesh
    in single-device CI; the static-analysis and shard-smoke CI jobs force
    8 host devices so the sharded programs verify with real cross-device
    axes, including 4x2/2x4 grids.)
    """
    from repro.engine import ScenarioMesh

    if mesh is None:
        mesh = ScenarioMesh.create()
    builders = (
        lambda: _build_eval_programs(mesh),
        lambda: _build_scenario_programs(mesh),
        _build_plan_program,
        lambda: _build_learn_programs(mesh),
        _build_kernel_programs,
    )
    programs: list[ProgramSpec] = []
    failures: list[CheckResult] = []
    for build in builders:
        try:
            programs.extend(build())
        except Exception as exc:
            failures.append(CheckResult(
                getattr(build, "__name__", "inventory"), "build", False,
                f"{type(exc).__name__}: {exc}"))
    if keys is not None:
        want = set(keys)
        unknown = want - {p.key for p in programs}
        for k in sorted(unknown):
            failures.append(CheckResult(k, "build", False,
                                        "unknown program key"))
        programs = [p for p in programs if p.key in want]
    return programs, failures


def verify_all(mesh=None, keys: Sequence[str] | None = None
               ) -> list[CheckResult]:
    """Verify every inventory program; returns all check results."""
    programs, results = program_inventory(mesh, keys)
    for p in programs:
        results.extend(verify_program(
            p.fn, p.args, key=p.key, collectives=p.collectives,
            donated=p.donated))
    return results
