"""Layer-1 driver: walk files, run rules, apply noqa + baseline suppression.

The engine is pure stdlib (``ast``/``json``/``pathlib``) so the source
lint runs on any box, with or without jax installed. Entry points:

- :func:`analyze_source` — lint one source string under a virtual path
  (what the per-rule fixtures in ``tests/test_analysis.py`` use).
- :func:`run_source_analysis` — lint a set of real paths, returning
  ``(active, baselined)`` findings after suppression.

Suppression, two forms (DESIGN.md §12):

- inline: a trailing ``# repro: noqa RPR004`` (or ``RPR004,RPR005``) on
  the flagged line;
- baseline: an entry in ``analysis-baseline.json`` keyed by
  ``(rule, path, stripped line text)`` with a one-line justification.
  Keying on line *content* instead of line numbers keeps the baseline
  stable under unrelated edits above the finding.
"""

from __future__ import annotations

import ast
import json
import pathlib
import re
from typing import Iterable, Sequence

from .rules import RULES, Finding

__all__ = [
    "analyze_source", "run_source_analysis", "collect_files",
    "load_baseline", "Baseline", "BaselineEntry",
]

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\b[:\s]*([A-Z0-9,\s]*)")


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------

class BaselineEntry:
    __slots__ = ("rule", "path", "line_text", "justification")

    def __init__(self, rule: str, path: str, line_text: str,
                 justification: str = ""):
        self.rule = rule
        self.path = path
        self.line_text = line_text.strip()
        self.justification = justification

    @property
    def key(self) -> tuple:
        return (self.rule, self.path, self.line_text)


class Baseline:
    """Content-keyed suppression list loaded from ``analysis-baseline.json``."""

    def __init__(self, entries: Sequence[BaselineEntry] = ()):
        self._by_key = {e.key: e for e in entries}

    def __len__(self) -> int:
        return len(self._by_key)

    def matches(self, finding: Finding) -> bool:
        key = (finding.code, finding.path, finding.line_text.strip())
        return key in self._by_key

    @classmethod
    def from_dict(cls, data: dict) -> "Baseline":
        entries = [
            BaselineEntry(e["rule"], e["path"], e["line_text"],
                          e.get("justification", ""))
            for e in data.get("entries", ())
        ]
        return cls(entries)


def load_baseline(path: str | pathlib.Path | None) -> Baseline:
    if path is None:
        return Baseline()
    p = pathlib.Path(path)
    if not p.exists():
        return Baseline()
    with open(p) as fh:
        return Baseline.from_dict(json.load(fh))


# --------------------------------------------------------------------------
# Core analysis
# --------------------------------------------------------------------------

def _noqa_codes(line: str) -> set[str] | None:
    """Codes suppressed on this line; empty set means 'suppress all'."""
    m = _NOQA_RE.search(line)
    if not m:
        return None
    codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return codes


def analyze_source(source: str, rel_path: str) -> list[Finding]:
    """Run every applicable rule over one source blob.

    ``rel_path`` is the repo-relative posix path the rules use for module
    classification — fixtures can impersonate any module (e.g.
    ``src/repro/core/simulate.py``) without touching the real file.
    """
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(code="RPR000", path=rel_path,
                        line=exc.lineno or 1, col=exc.offset or 0,
                        message=f"syntax error: {exc.msg}",
                        line_text="")]
    lines = source.splitlines()
    findings: list[Finding] = []
    for rule in RULES:
        if not rule.applies(rel_path):
            continue
        findings.extend(rule.check(tree, lines, rel_path))

    kept = []
    for f in findings:
        if 0 < f.line <= len(lines):
            codes = _noqa_codes(lines[f.line - 1])
            if codes is not None and (not codes or f.code in codes):
                continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return kept


def collect_files(paths: Iterable[str | pathlib.Path],
                  root: pathlib.Path) -> list[pathlib.Path]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out: set[pathlib.Path] = set()
    for p in paths:
        p = pathlib.Path(p)
        if not p.is_absolute():
            p = root / p
        if p.is_dir():
            out.update(q for q in p.rglob("*.py") if q.is_file())
        elif p.is_file() and p.suffix == ".py":
            out.add(p)
    return sorted(out)


def run_source_analysis(
    paths: Sequence[str | pathlib.Path],
    root: str | pathlib.Path,
    baseline: Baseline | None = None,
) -> tuple[list[Finding], list[Finding]]:
    """Lint ``paths`` (files or dirs) relative to ``root``.

    Returns ``(active, baselined)``: findings that survive suppression,
    and the ones a baseline entry absorbed (shown separately so the
    summary table can report both).
    """
    root = pathlib.Path(root).resolve()
    baseline = baseline or Baseline()
    files = collect_files(paths, root)
    active: list[Finding] = []
    suppressed: list[Finding] = []
    for path in files:
        rel = path.resolve().relative_to(root).as_posix()
        source = path.read_text()
        for f in analyze_source(source, rel):
            (suppressed if baseline.matches(f) else active).append(f)
    return active, suppressed
