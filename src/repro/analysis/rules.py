"""Source-level contract rules (Layer 1): ``RPR0xx`` over stdlib ASTs.

Each rule machine-checks one invariant that previously lived only in
DESIGN.md prose (the section references below). Rules never execute repo
code — they parse with :mod:`ast` and walk the tree — so the linter is
safe to run anywhere, including CI boxes without jax.

Rule catalogue (DESIGN.md §12 is the prose twin of this table):

========  ==================================================================
RPR001    no ad-hoc wall-clock timing (``time.perf_counter``/``time.time``/
          ``time.monotonic``) outside ``obs/trace.py`` — spans are the
          timing source (§10)
RPR002    no unbounded ``functools.lru_cache``/``functools.cache`` — every
          factory cache carries an explicit ``maxsize`` bound (§11)
RPR003    no float64 on the device path: ``jnp.float64`` anywhere in a
          device-path module, or any ``float64`` reference inside a
          jit-reachable function (§6 — f32 is the device dtype, the f64
          oracle lives on the host side of the same modules)
RPR004    float comparisons against small epsilon literals in the
          knife-edge modules must go through a NAMED guard
          (``FLEX_REL``/``_DEVICE_CEIL_EPS``/... — §5/§6)
RPR005    no host sync (``.item()``/``.tolist()``/``np.asarray``/
          ``block_until_ready``) inside functions reachable from a
          ``jax.jit`` factory (intra-module call graph)
RPR006    ``donate_argnums`` only in the §11-whitelisted modules
RPR007    no ``pure_callback``/``io_callback``/``debug_callback``/
          ``jax.debug.print`` in device-path modules (§9 — hot-path
          programs must stay callback-free)
========  ==================================================================

Suppression: a trailing ``# repro: noqa RPR0xx`` on the finding's line, or
a baseline entry in ``analysis-baseline.json`` (see ``engine.py``).
"""

from __future__ import annotations

import ast
import dataclasses
import re

__all__ = ["Finding", "Rule", "RULES", "RULES_BY_CODE"]


# --------------------------------------------------------------------------
# Module classification (repo-relative paths with forward slashes)
# --------------------------------------------------------------------------

TIMING_SOURCE = "src/repro/obs/trace.py"

# The §6 device path: modules whose traced functions feed XLA programs.
DEVICE_PATH_FILES = frozenset({
    "src/repro/engine/backend_jax.py",
    "src/repro/engine/backend_pallas.py",
    "src/repro/engine/scenarios.py",
    "src/repro/learn/replay.py",
})
DEVICE_PATH_PREFIXES = ("src/repro/kernels/",)

# The §5/§6 knife-edge modules: every epsilon tolerance is a named guard.
GUARDED_FILES = frozenset({
    "src/repro/core/simulate.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/dealloc.py",
})

# §11: the only module whose donation is proven safe (the fold's
# accumulator carry); everything else must not donate.
DONATION_WHITELIST = frozenset({"src/repro/learn/replay.py"})

# The documented epsilon guards plus the shape every new guard must take
# (a module-level SHOUTING_CASE constant, optional leading underscore).
KNOWN_GUARDS = frozenset({
    "FLEX_REL", "FLEX_ABS", "_DEVICE_CEIL_EPS", "_DEVICE_DUST",
    "_avail_threshold",
})
_NAMED_GUARD_RE = re.compile(r"^_?[A-Z][A-Z0-9_]{2,}$")

_TIMER_NAMES = frozenset({
    "perf_counter", "perf_counter_ns", "time", "monotonic", "monotonic_ns",
    "process_time", "process_time_ns",
})

_JIT_WRAPPERS = frozenset({
    "jit", "shard_map", "vmap", "pmap", "scan", "pallas_call", "remat",
    "checkpoint", "grad", "value_and_grad", "custom_vjp", "custom_jvp",
})

_CALLBACKS = frozenset({"pure_callback", "io_callback", "debug_callback"})


def _in_device_path(rel: str) -> bool:
    return rel in DEVICE_PATH_FILES or rel.startswith(DEVICE_PATH_PREFIXES)


def _in_library(rel: str) -> bool:
    return rel.startswith("src/repro/")


# --------------------------------------------------------------------------
# Finding / Rule containers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    code: str
    path: str
    line: int
    col: int
    message: str
    line_text: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> dict:
        return {"code": self.code, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "line_text": self.line_text}


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    contract: str
    applies: "callable"
    check: "callable"


def _terminal(node: ast.AST) -> str | None:
    """Rightmost identifier of a Name/Attribute chain (``a.b.c`` -> "c")."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _mk(code, node, message, lines, path) -> Finding:
    line = getattr(node, "lineno", 1)
    text = lines[line - 1].strip() if 0 < line <= len(lines) else ""
    return Finding(code=code, path=path, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   line_text=text)


# --------------------------------------------------------------------------
# RPR001 — timing outside obs/trace.py
# --------------------------------------------------------------------------

def _check_timing(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "time" and node.attr in _TIMER_NAMES):
            out.append(_mk(
                "RPR001", node,
                f"ad-hoc wall-clock timing time.{node.attr} outside "
                f"obs/trace.py — measure with repro.obs.span (§10)",
                lines, path))
        elif (isinstance(node, ast.ImportFrom) and node.module == "time"
                and any(a.name in _TIMER_NAMES for a in node.names)):
            out.append(_mk(
                "RPR001", node,
                "importing wall-clock timers from `time` outside "
                "obs/trace.py — measure with repro.obs.span (§10)",
                lines, path))
    return out


# --------------------------------------------------------------------------
# RPR002 — unbounded caches
# --------------------------------------------------------------------------

def _lru_maxsize_unbounded(call: ast.Call) -> bool:
    """True if an ``lru_cache(...)`` call has no finite maxsize."""
    for kw in call.keywords:
        if kw.arg == "maxsize":
            return isinstance(kw.value, ast.Constant) and kw.value.value is None
    if call.args:
        a = call.args[0]
        return isinstance(a, ast.Constant) and a.value is None
    return True  # lru_cache() with no args defaults to maxsize=128 — bounded
    # (unreached: handled below)


def _check_unbounded_cache(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _terminal(node.func) == "lru_cache":
            unbounded = False
            for kw in node.keywords:
                if kw.arg == "maxsize" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is None:
                    unbounded = True
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and node.args[0].value is None:
                unbounded = True
            if unbounded:
                out.append(_mk(
                    "RPR002", node,
                    "unbounded lru_cache(maxsize=None) — long-lived "
                    "processes must not accumulate entries forever; give "
                    "it an explicit bound (§11)", lines, path))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                t = _terminal(dec) if not isinstance(dec, ast.Call) else None
                if t == "lru_cache":
                    out.append(_mk(
                        "RPR002", dec,
                        "bare @lru_cache is unbounded — give it an "
                        "explicit maxsize bound (§11)", lines, path))
                elif t == "cache":
                    out.append(_mk(
                        "RPR002", dec,
                        "@functools.cache is unbounded — use "
                        "lru_cache(maxsize=N) (§11)", lines, path))
    return out


# --------------------------------------------------------------------------
# Shared: intra-module call graph from jit factories (RPR003b / RPR005)
# --------------------------------------------------------------------------

def _own_nodes(fn: ast.AST):
    """Walk a function's own body, not descending into nested defs."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _jit_reachable(tree) -> list[ast.AST]:
    """Function nodes reachable from a jax.jit/shard_map/vmap/... root.

    A lightweight intra-module over-approximation: roots are functions
    whose NAME appears inside a jit-wrapper call (``jax.jit(f)``,
    ``shard_map(f, ...)``, ``lax.scan(step, ...)``) or that carry a jit
    decorator; edges are any Name reference to another module function.
    """
    funcs: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs.setdefault(node.name, []).append(node)

    roots: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _terminal(node.func) in _JIT_WRAPPERS:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id in funcs:
                    roots.add(sub.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if any(_terminal(d) == "jit" for d in ast.walk(dec)
                       if isinstance(d, (ast.Name, ast.Attribute))):
                    roots.add(node.name)

    edges: dict[str, set[str]] = {}
    for name, nodes in funcs.items():
        refs: set[str] = set()
        for fn in nodes:
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Name) and sub.id in funcs \
                        and sub.id != name:
                    refs.add(sub.id)
        edges[name] = refs

    seen: set[str] = set()
    frontier = list(roots & funcs.keys())
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        frontier.extend(edges.get(name, ()))
    return [fn for name in sorted(seen) for fn in funcs[name]]


# --------------------------------------------------------------------------
# RPR003 — float64 on the device path
# --------------------------------------------------------------------------

def _check_float64(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute) and node.attr == "float64"
                and isinstance(node.value, ast.Name)
                and node.value.id in ("jnp", "jax")):
            out.append(_mk(
                "RPR003", node,
                "jnp.float64 in a device-path module — the device dtype "
                "is f32; the f64 oracle is the host numpy path (§6)",
                lines, path))
        elif isinstance(node, ast.Constant) and node.value == "jax_enable_x64":
            out.append(_mk(
                "RPR003", node,
                "enabling jax x64 from a device-path module flips every "
                "traced dtype — forbidden outside test harnesses (§6)",
                lines, path))
    for fn in _jit_reachable(tree):
        for node in _own_nodes(fn):
            hit = (isinstance(node, ast.Attribute)
                   and node.attr == "float64") or \
                  (isinstance(node, ast.Constant)
                   and node.value == "float64")
            if hit:
                out.append(_mk(
                    "RPR003", node,
                    f"float64 inside jit-reachable function "
                    f"`{fn.name}` — a silent f64 leak into the compiled "
                    f"program flips knife-edge slots (§6)", lines, path))
    return out


# --------------------------------------------------------------------------
# RPR004 — unguarded epsilon comparisons in the knife-edge modules
# --------------------------------------------------------------------------

def _check_epsilon_guards(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        if not any(isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                   for op in node.ops):
            continue
        eps_literals = []
        guarded = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, float) \
                    and 0.0 < abs(sub.value) < 1e-3:
                eps_literals.append(sub.value)
            name = _terminal(sub) if isinstance(
                sub, (ast.Name, ast.Attribute)) else None
            if name and (name in KNOWN_GUARDS or _NAMED_GUARD_RE.match(name)):
                guarded = True
        if eps_literals and not guarded:
            lits = ", ".join(repr(v) for v in sorted(set(eps_literals)))
            out.append(_mk(
                "RPR004", node,
                f"float comparison against inline epsilon {lits} — "
                f"knife-edge tolerances must reference a named guard "
                f"(FLEX_REL / _DEVICE_CEIL_EPS / ... , §5/§6)",
                lines, path))
    return out


# --------------------------------------------------------------------------
# RPR005 — host sync inside jit-reachable functions
# --------------------------------------------------------------------------

_NP_NAMES = frozenset({"np", "numpy", "onp"})
_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})


def _check_host_sync(tree, lines, path):
    out = []
    for fn in _jit_reachable(tree):
        for node in _own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _SYNC_METHODS:
                out.append(_mk(
                    "RPR005", node,
                    f".{f.attr}() inside jit-reachable function "
                    f"`{fn.name}` forces a host sync under trace",
                    lines, path))
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name)
                  and f.value.id in _NP_NAMES
                  and f.attr in ("asarray", "array")):
                out.append(_mk(
                    "RPR005", node,
                    f"{f.value.id}.{f.attr}() on a traced value inside "
                    f"jit-reachable function `{fn.name}` forces a host "
                    f"round trip — use jnp", lines, path))
            elif (isinstance(f, ast.Attribute) and f.attr == "device_get"):
                out.append(_mk(
                    "RPR005", node,
                    f"device_get inside jit-reachable function "
                    f"`{fn.name}`", lines, path))
            elif (isinstance(f, ast.Name) and f.id in ("float", "int")
                  and node.args
                  and not all(isinstance(a, ast.Constant)
                              for a in node.args)):
                out.append(_mk(
                    "RPR005", node,
                    f"{f.id}(...) on a non-constant inside jit-reachable "
                    f"function `{fn.name}` concretizes a traced value",
                    lines, path))
    return out


# --------------------------------------------------------------------------
# RPR006 — donation whitelist
# --------------------------------------------------------------------------

def _check_donation(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg in ("donate_argnums", "donate_argnames"):
                    out.append(_mk(
                        "RPR006", kw.value,
                        "buffer donation outside the §11 whitelist "
                        "(learn/replay.py) — donated inputs invalidate "
                        "cross-call cached buffers", lines, path))
    return out


# --------------------------------------------------------------------------
# RPR007 — callback primitives in device-path modules
# --------------------------------------------------------------------------

def _check_callbacks(tree, lines, path):
    out = []
    for node in ast.walk(tree):
        name = _terminal(node) if isinstance(
            node, (ast.Name, ast.Attribute)) else None
        if name in _CALLBACKS:
            out.append(_mk(
                "RPR007", node,
                f"{name} in a device-path module — hot-path programs "
                f"must stay callback-free (§9)", lines, path))
        elif (isinstance(node, ast.Attribute) and node.attr == "print"
              and isinstance(node.value, ast.Attribute)
              and node.value.attr == "debug"):
            out.append(_mk(
                "RPR007", node,
                "jax.debug.print in a device-path module — hot-path "
                "programs must stay callback-free (§9)", lines, path))
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("jax") \
                and any(a.name in _CALLBACKS for a in node.names):
            out.append(_mk(
                "RPR007", node,
                "importing a callback primitive into a device-path "
                "module (§9)", lines, path))
    return out


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

RULES = (
    Rule("RPR001", "timing-outside-trace",
         "wall-clock timing only in obs/trace.py; spans are the timing "
         "source (§10)",
         lambda rel: _in_library(rel) and rel != TIMING_SOURCE,
         _check_timing),
    Rule("RPR002", "unbounded-cache",
         "every functools cache carries an explicit maxsize bound (§11)",
         _in_library,
         _check_unbounded_cache),
    Rule("RPR003", "float64-on-device-path",
         "no f64 enters a traced device program outside the documented "
         "oracle boundaries (§6)",
         _in_device_path,
         _check_float64),
    Rule("RPR004", "unguarded-epsilon",
         "knife-edge float comparisons reference named epsilon guards "
         "(§5/§6)",
         lambda rel: rel in GUARDED_FILES,
         _check_epsilon_guards),
    Rule("RPR005", "host-sync-in-jit",
         "no host sync inside functions reachable from a jit factory",
         _in_library,
         _check_host_sync),
    Rule("RPR006", "donation-whitelist",
         "donate_argnums only in §11-whitelisted modules",
         lambda rel: _in_library(rel) and rel not in DONATION_WHITELIST,
         _check_donation),
    Rule("RPR007", "callback-free-hot-path",
         "no callback primitives in device-path modules (§9)",
         _in_device_path,
         _check_callbacks),
)

RULES_BY_CODE = {r.code: r for r in RULES}
