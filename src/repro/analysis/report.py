"""Rendering for the analysis CLI: text / json formats + the summary table."""

from __future__ import annotations

import json
from typing import Sequence

from .rules import RULES, Finding

__all__ = ["render_text", "render_json", "summary_table"]


def summary_table(active: Sequence[Finding],
                  baselined: Sequence[Finding]) -> str:
    """Per-rule counts, one row per rule code, stable order."""
    act: dict[str, int] = {}
    base: dict[str, int] = {}
    for f in active:
        act[f.code] = act.get(f.code, 0) + 1
    for f in baselined:
        base[f.code] = base.get(f.code, 0) + 1
    rows = []
    header = f"{'rule':<8} {'contract':<58} {'active':>6} {'baselined':>9}"
    rows.append(header)
    rows.append("-" * len(header))
    for rule in RULES:
        contract = rule.contract if len(rule.contract) <= 58 \
            else rule.contract[:55] + "..."
        rows.append(f"{rule.code:<8} {contract:<58} "
                    f"{act.get(rule.code, 0):>6} {base.get(rule.code, 0):>9}")
    known = {r.code for r in RULES}
    for code in sorted((set(act) | set(base)) - known):
        rows.append(f"{code:<8} {'(parse error)':<58} "
                    f"{act.get(code, 0):>6} {base.get(code, 0):>9}")
    rows.append("-" * len(header))
    rows.append(f"{'total':<8} {'':<58} {len(active):>6} {len(baselined):>9}")
    return "\n".join(rows)


def render_text(active: Sequence[Finding],
                baselined: Sequence[Finding]) -> str:
    parts = []
    for f in active:
        parts.append(f"{f.location}:{f.col}: {f.code} {f.message}")
        if f.line_text:
            parts.append(f"    {f.line_text}")
    if parts:
        parts.append("")
    parts.append(summary_table(active, baselined))
    return "\n".join(parts)


def render_json(active: Sequence[Finding],
                baselined: Sequence[Finding]) -> str:
    """Stable JSON: findings sorted, keys sorted, no volatile fields."""
    payload = {
        "version": 1,
        "findings": [f.to_dict() for f in active],
        "baselined": [f.to_dict() for f in baselined],
        "counts": {
            "active": len(active),
            "baselined": len(baselined),
        },
    }
    return json.dumps(payload, indent=2, sort_keys=True)
