"""Vocab-sharded cross entropy.

The logits stay sharded over the vocab (model) axis end-to-end: the
log-sum-exp reduces over the sharded axis (GSPMD inserts a small per-token
all-reduce) and the label logit is extracted with a one-hot einsum instead of
a gather — a gather over a sharded axis would force an all-gather of the
full (B, S, V) logits, which at llama3 train_4k scale is ~1 GB/device of
avoidable traffic. This is one of the beyond-paper optimizations measured in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain

__all__ = ["cross_entropy"]


def cross_entropy(logits, labels, rules: ShardingRules | None = None,
                  mask=None):
    """Mean token-level cross entropy. logits (B, S, V), labels (B, S)."""
    V = logits.shape[-1]
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
    onehot = constrain(onehot, rules, "batch", "seq", "vocab")
    picked = jnp.einsum("bsv,bsv->bs", x, onehot)
    nll = lse - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
