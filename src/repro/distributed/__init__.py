"""Distribution substrate: logical-axis sharding rules, distributed loss,
gradient compression, and collective helpers (GSPMD/pjit based)."""

from repro.distributed.compression import compressed_psum_tree, quantize_ef
from repro.distributed.pipeline import bubble_fraction, pipeline_apply
from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    logical_to_spec,
    param_specs,
    constrain,
)
from repro.distributed.xent import cross_entropy

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "param_specs",
    "constrain",
    "cross_entropy",
    "pipeline_apply",
    "bubble_fraction",
    "compressed_psum_tree",
    "quantize_ef",
]
