"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-to-pod (DCI) links are the thin pipe: the per-step
gradient all-reduce crosses them once. Quantizing to int8 with error
feedback cuts that traffic 4x (vs f32 moments) while the residual carries
the quantization error into the next step — the standard EF-SGD trick, here
applied only on the ``pod`` axis (intra-pod reductions stay full precision
over ICI).

``compressed_psum`` demonstrates the wire format under ``shard_map``; the
trainer integrates via ``compress_tree`` / ``decompress_tree`` around the
optimizer for the cross-pod axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_ef", "dequantize", "compressed_psum_tree"]


def quantize_ef(g, err):
    """(g + err) -> int8 levels + per-tensor scale, new error residual."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, errs, axis_name: str):
    """Inside shard_map: int8-on-the-wire psum over ``axis_name``.

    Returns (mean_grads, new_errs). Each participant quantizes with its own
    error feedback; the sum of int8 payloads travels over the axis (as int32
    accumulators), then is rescaled by the max scale (conservative shared
    scale keeps the sum exact in the int domain).
    """
    def one(g, e):
        q, scale, new_e = quantize_ef(g, e)
        # Shared conservative scale across the axis.
        smax = jax.lax.pmax(scale, axis_name)
        requant = jnp.clip(jnp.round(
            dequantize(q, scale) / smax), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(requant, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * smax / n).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
