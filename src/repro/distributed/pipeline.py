"""Pipeline parallelism: GPipe-style microbatched pipelining over a
``stage`` mesh axis with `shard_map` + `ppermute`.

Each device (group) holds one stage's parameters. Time is unrolled into
``n_micro + n_stages - 1`` ticks; at every tick each stage processes the
activation it holds and `ppermute`s the result to its successor, while
stage 0 injects the next microbatch — the standard fill/steady/drain
schedule. Bubble fraction = (S-1)/(M+S-1), so callers pick M >> S.

This composes with the GSPMD axes: the stage axis is `shard_map`-manual,
everything else (data/model) stays auto — the same partial-auto pattern as
the grouped MoE dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn, stage_params, x, n_stages: int,
                   axis: str = "stage"):
    """Run ``x`` through ``n_stages`` pipeline stages.

    stage_fn:      (params_one_stage, activation (B_micro, ...)) -> same shape
    stage_params:  pytree whose leaves have a leading ``n_stages`` dim
    x:             (n_micro, B_micro, ...) microbatched activations
    Must be called under jax.set_mesh of a mesh that has ``axis``.

    Returns (n_micro, B_micro, ...) outputs of the final stage.
    """
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    def inner(params_local, x_local):
        # params_local leaves: (1, ...) — this stage's slice.
        p_one = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        state = jnp.zeros_like(x_local[0])
        outs = jnp.zeros_like(x_local)

        def tick(carry, t):
            state, outs = carry
            inject = x_local[jnp.clip(t, 0, n_micro - 1)]
            cur = jnp.where(idx == 0,
                            jnp.where(t < n_micro, inject, state), state)
            y = stage_fn(p_one, cur)
            # Last stage emits microbatch t - (n_stages - 1).
            out_t = t - (n_stages - 1)
            emit = (idx == n_stages - 1) & (out_t >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_t, 0), 0),
                lambda o: o, outs)
            # forward the activation ring: stage i -> i+1
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (state, outs), jnp.arange(ticks))
        # Only the last stage holds real outputs; replicate via a masked
        # psum (ppermute cannot one-to-many broadcast).
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return jax.shard_map(
        inner,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis}, check_vma=False,
    )(stage_params, x)
