"""Logical-axis sharding: one rule table maps model-semantic axis names to
mesh axes; every parameter and activation is annotated through it.

Logical axes used across the zoo:

  batch      — global batch                      -> ("pod", "data") [+ "model" for decode]
  seq        — sequence (context-parallel)       -> None (or "model" for long prefill)
  d_model    — residual width                    -> None
  heads      — attention query heads             -> "model"
  kv_heads   — attention kv heads                -> "model" (or None when kv < mesh)
  d_ff       — MLP hidden                        -> "model"
  vocab      — embedding/logits vocabulary       -> "model"
  experts    — MoE expert dimension              -> "model" (expert parallelism)
  fsdp       — parameter shard axis (ZeRO-3)     -> ("pod", "data")
  layers     — scan-stacked layer dim            -> None
  conv, d_state, d_head, groups                  -> None

The rules are a plain dict so perf variants (see EXPERIMENTS.md §Perf) can
override individual entries without touching model code.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "param_specs",
    "constrain",
]

Rules = dict[str, Any]

# axis name -> mesh axis (str), tuple of mesh axes, or None (replicated)
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "decode_batch": ("pod", "data", "model"),
    "seq": None,
    "seq_shard": "model",       # sequence-parallel prefill variant
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "fsdp": ("pod", "data"),
    "layers": None,
    "conv": None,
    "d_state": None,
    "d_head": None,
    "groups": None,
    "frames": None,
    "patches": None,
    # decode-time cache axes
    "cache_batch": ("pod", "data"),
    "cache_seq": "model",      # context-parallel KV cache
    "ssm_p": "model",          # SSD head_dim (divides for both ssm archs)
    "conv_ch": "model",
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Rule table bound to a mesh; filters axes the mesh doesn't have."""

    rules: tuple[tuple[str, Any], ...]
    mesh_axes: tuple[str, ...]

    @classmethod
    def create(cls, mesh: Mesh | None, overrides: Rules | None = None):
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        axes = tuple(mesh.axis_names) if mesh is not None else ()
        return cls(rules=tuple(rules.items()), mesh_axes=axes)

    def _mesh_axis(self, logical: str | None):
        if logical is None:
            return None
        rule = dict(self.rules).get(logical, None)
        if rule is None:
            return None
        if isinstance(rule, str):
            return rule if rule in self.mesh_axes else None
        picked = tuple(a for a in rule if a in self.mesh_axes)
        return picked if picked else None

    def spec(self, *logical_axes: str | None) -> P:
        """PartitionSpec for an array whose dims carry these logical names."""
        used: set[str] = set()
        out = []
        for ax in logical_axes:
            m = self._mesh_axis(ax)
            # A mesh axis may appear at most once in a PartitionSpec.
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else m
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            if not ms:
                out.append(None)
            elif len(ms) == 1:
                out.append(ms[0])
            else:
                out.append(ms)
        return P(*out)


def logical_to_spec(rules: ShardingRules, tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(*axes),
        tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def param_specs(axes_tree, rules: ShardingRules):
    """PartitionSpec tree for a parameter pytree annotated with logical axes.

    ``axes_tree`` mirrors the param tree; each leaf is a tuple of logical
    axis names (length == ndim of the corresponding array).
    """
    return logical_to_spec(rules, axes_tree)


def constrain(x, rules: ShardingRules | None, *logical_axes: str | None):
    """with_sharding_constraint through the rule table (no-op off-mesh)."""
    if rules is None or not rules.mesh_axes:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(*logical_axes))
    except (ValueError, RuntimeError):
        # Outside a mesh context (e.g. plain CPU tests) the constraint is
        # meaningless — pass through.
        return x
