"""Fault-tolerant checkpointing with async save and elastic restore.

Layout per step::

    <dir>/step_000123/
        leaf_00000.npy ... leaf_NNNNN.npy     (flattened pytree leaves)
        manifest.json                          (treedef, shapes, dtypes)
        COMMITTED                              (written LAST -> atomicity)

* ``save`` snapshots device arrays to host then writes on a background
  thread — the training loop is blocked only for the device->host copy.
* a checkpoint without the COMMITTED marker is ignored on restore, so a
  preemption mid-write can never corrupt a restart (the paper's spot
  reclamation is exactly this failure mode).
* ``restore(..., mesh, shardings)`` re-lays the arrays onto ANY mesh
  (elastic re-shard): the saved files are full logical arrays, so restoring
  a 256-chip checkpoint onto 128 or 512 chips is just a different
  device_put. Restores resume the data pipeline purely from the step number
  (see data/pipeline.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False):
        """Async checkpoint of an arbitrary pytree of arrays."""
        self.wait()  # one in-flight save at a time
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]  # device -> host copy
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host),
            "shapes": [list(x.shape) for x in host],
            "dtypes": [str(x.dtype) for x in host],
        }

        def write():
            path = self._step_dir(step)
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, arr in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            shutil.rmtree(path, ignore_errors=True)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMITTED")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        NamedShardings for elastic placement onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self._step_dir(step)
        leaves, treedef = jax.tree.flatten(template)
        host = [np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
                for i in range(len(leaves))]
        for h, t in zip(host, leaves):
            if tuple(h.shape) != tuple(t.shape):
                raise ValueError(
                    f"checkpoint leaf shape {h.shape} != template {t.shape}")
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            dev = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
        else:
            dev = [jax.numpy.asarray(h) for h in host]
        return treedef.unflatten(dev), step

    # -- internals ------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:06d}")

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
