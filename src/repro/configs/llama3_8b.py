"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 [arXiv:2407.21783; unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", kind="decoder",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", kind="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=176, vocab=512,
    )
