"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32 = MHA)
d_ff=8192 vocab=32064; phi3-mini + CLIP [hf:microsoft; hf].

The CLIP tower is a stub per assignment: input_specs() provides 576
precomputed patch embeddings; the model learns only a projection.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", kind="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064, frontend="vision", frontend_len=576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3v-smoke", kind="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        frontend="vision", frontend_len=16,
    )
