"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16 = MHA)
expert d_ff=1408 vocab=102400; 2 shared + 64 routed top-6 (fine-grained)
[arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", kind="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab=102400,
        n_experts=64, n_shared_experts=2, top_k=6, d_expert=1408,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", kind="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=512,
        n_experts=8, n_shared_experts=2, top_k=2, d_expert=32,
    )
