"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, QKV bias [hf:Qwen; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", kind="decoder",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke", kind="decoder",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_ff=192, vocab=512,
        qkv_bias=True,
    )
