"""seamless-m4t-medium [audio] — enc-dec multimodal backbone.

12L (encoder) + 12L (decoder), d_model 1024, 16H (GQA kv=16 = MHA),
d_ff 4096, vocab 256206 [arXiv:2308.11596; hf]. The audio frontend is a
stub: input_specs() provides precomputed frame embeddings (B, S/4, d_model).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", kind="encdec",
        n_layers=12, n_enc_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", kind="encdec",
        n_layers=2, n_enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        frontend="audio",
    )
