"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attn+mamba heads, sliding-window
attention + 128 meta tokens [arXiv:2411.13676; hf]. SSM branch carries
global context; see DESIGN.md §Arch-applicability for the SWA note."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", kind="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001,
        d_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        window=1024, n_meta_tokens=128,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", kind="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        d_state=8, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
        window=32, n_meta_tokens=8,
    )
