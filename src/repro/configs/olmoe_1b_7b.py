"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16 = MHA)
expert d_ff=1024 vocab=50304; 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", kind="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab=50304,
        n_experts=64, n_shared_experts=0, top_k=8, d_expert=1024,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", kind="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=512,
        n_experts=8, n_shared_experts=0, top_k=2, d_expert=32,
    )
