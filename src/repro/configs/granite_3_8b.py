"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", kind="decoder",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab=49155,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", kind="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192, vocab=512,
    )
