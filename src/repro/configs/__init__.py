from repro.configs.registry import (
    ARCH_NAMES,
    SHAPES,
    ShapeSpec,
    get_config,
    input_specs,
    smoke_config,
    supports,
)

__all__ = ["ARCH_NAMES", "SHAPES", "ShapeSpec", "get_config", "input_specs",
           "smoke_config", "supports"]
