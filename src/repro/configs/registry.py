"""Architecture registry + input-shape cells.

Every assigned architecture is a selectable config (``--arch <id>``); each
(arch x shape) cell yields ShapeDtypeStruct input specs for the dry-run
(no allocation — the same pattern the smoke tests use at reduced scale).

Shape semantics (assignment):
  train_4k     seq 4096,  global_batch 256  -> train_step
  prefill_32k  seq 32768, global_batch 32   -> prefill (serve) lowering
  decode_32k   seq 32768 KV, global_batch 128 -> one-token serve_step
  long_500k    seq 524288 KV, global_batch 1  -> one-token serve_step;
               ONLY for sub-quadratic archs (ssm/hybrid) — full-attention
               archs skip it (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ARCH_NAMES", "SHAPES", "ShapeSpec", "get_config", "input_specs",
           "supports", "smoke_config"]

ARCH_NAMES = (
    "seamless_m4t_medium",
    "granite_3_8b",
    "tinyllama_1_1b",
    "qwen2_5_32b",
    "llama3_8b",
    "phi_3_vision_4_2b",
    "deepseek_moe_16b",
    "olmoe_1b_7b",
    "hymba_1_5b",
    "mamba2_2_7b",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_NAMES:
        raise ValueError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.config()


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke()


def supports(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and why not if it doesn't."""
    if shape == "long_500k" and cfg.kind not in ("ssm", "hybrid"):
        return False, ("full quadratic attention: a 512k KV pass is O(S^2) "
                       "compute and O(S) KV memory per layer — out of scope "
                       "per assignment; served by ssm/hybrid archs")
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's ``batch`` arg.

    Frontend stubs: ``frames`` (audio, seq/4 frames) and ``vision``
    (patch embeddings) arrive as precomputed d_model embeddings.
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len

    if shape.mode in ("train", "prefill"):
        spec = {"tokens": _i32(B, S)}
        if cfg.kind == "encdec":
            spec["frames"] = _f32(B, max(S // 4, 1), cfg.d_model)
        if cfg.kind == "vlm":
            P = cfg.frontend_len
            spec = {"tokens": _i32(B, S - P),
                    "vision": _f32(B, P, cfg.d_model)}
        if shape.mode == "train":
            spec["labels"] = _i32(B, spec["tokens"].shape[1])
        return spec
    # decode: one new token against a seq_len-deep cache
    return {"token": _i32(B, 1)}
