"""Mamba-2 (SSD) attention-free stack — mamba2-2.7b.

Per-layer: in_proj -> (z | xBC | dt); causal depthwise conv over xBC; SSD
chunked scan (state-space duality — the quadratic intra-chunk term runs on
the MXU, the inter-chunk recurrence is a cheap sequential scan); gated
output norm; out_proj. Decode carries (ssd_state, conv_state) — O(1) per
token regardless of context length, which is why this family serves the
``long_500k`` cell the dense-attention archs must skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as ll
from repro.models.config import ModelConfig

__all__ = ["init", "axes", "forward", "prefill", "decode", "init_cache"]

G = 1  # SSD groups (mamba2 default ngroups=1)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner_ssm
    H = cfg.n_ssm_heads
    N = cfg.d_state
    P = cfg.ssm_head_dim
    conv_ch = di + 2 * G * N
    return di, H, N, P, conv_ch


def init(cfg: ModelConfig, key) -> dict:
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    di, H, N, P, conv_ch = _dims(cfg)
    kd, kl, kh = jax.random.split(key, 3)

    def one_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln": jnp.ones((D,), jnp.float32),
            "in_proj": ll.dense_init(k1, (D, 2 * di + 2 * G * N + H)),
            "conv_w": 0.1 * jax.random.normal(k2, (cfg.ssm_conv, conv_ch),
                                              jnp.float32),
            "conv_b": jnp.zeros((conv_ch,), jnp.float32),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
            "D_skip": jnp.ones((H,), jnp.float32),
            "dt_bias": jnp.zeros((H,), jnp.float32),
            "out_norm": jnp.ones((di,), jnp.float32),
            "out_proj": ll.dense_init(k3, (di, D)),
        }

    outs = [one_layer(k) for k in jax.random.split(kl, L)]
    params = {
        "embed": ll.dense_init(kd, (V, D), in_axis=1),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *outs),
        "final_norm": jnp.ones((D,), jnp.float32),
        "lm_head": ll.dense_init(kh, (D, V)),
    }
    return params


def axes(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "fsdp"),
        "final_norm": (None,),
        "lm_head": ("fsdp", "vocab"),
        "layers": {
            "ln": ("layers", None),
            "in_proj": ("layers", "fsdp", "d_ff"),     # wide dim TP-sharded
            "conv_w": ("layers", None, "d_ff"),
            "conv_b": ("layers", "d_ff"),
            "A_log": ("layers", None),
            "D_skip": ("layers", None),
            "dt_bias": ("layers", None),
            "out_norm": ("layers", "d_ff"),
            "out_proj": ("layers", "d_ff", "fsdp"),
        },
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, H, N, P, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * G * N]
    dt_raw = zxbcdt[..., -H:]
    return z, xbc, dt_raw


def _mix(x, lp, cfg: ModelConfig, rules, conv_state=None, ssd_state=None,
         step: bool = False):
    """The SSD mixer. Training path (step=False) takes (B, S, D); decode
    path takes (B, 1, D) plus the carried states."""
    di, H, N, P, conv_ch = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, lp["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))

    if not step:
        xbc_conv = jax.nn.silu(ll.causal_conv1d(
            xbc, lp["conv_w"].astype(x.dtype), lp["conv_b"].astype(x.dtype)))
        xin = xbc_conv[..., :di]
        B_ = xbc_conv[..., di:di + G * N].reshape(*x.shape[:2], G, N)
        C_ = xbc_conv[..., di + G * N:].reshape(*x.shape[:2], G, N)
        Bt, S = x.shape[0], x.shape[1]
        xh = xin.reshape(Bt, S, H, P)
        xh = constrain(xh, rules, "batch", "seq", "d_ff", None)
        y, final = ll.ssd(xh, dt.astype(jnp.float32), A,
                          B_.astype(jnp.float32), C_.astype(jnp.float32),
                          cfg.ssm_chunk, rules, init_state=ssd_state)
        y = y.astype(x.dtype) + lp["D_skip"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(Bt, S, di)
        new_conv = xbc[:, -(cfg.ssm_conv - 1):, :]
    else:
        xbc_t, new_conv = ll.conv1d_step(
            conv_state, xbc[:, 0, :].astype(conv_state.dtype),
            lp["conv_w"].astype(conv_state.dtype),
            lp["conv_b"].astype(conv_state.dtype))
        xbc_t = jax.nn.silu(xbc_t.astype(x.dtype))
        xin = xbc_t[..., :di]
        B_ = xbc_t[..., di:di + G * N].reshape(-1, G, N)
        C_ = xbc_t[..., di + G * N:].reshape(-1, G, N)
        xh = xin.reshape(-1, H, P)
        yt, final = ll.ssd_step(ssd_state, xh.astype(jnp.float32),
                                dt[:, 0].astype(jnp.float32), A,
                                B_.astype(jnp.float32), C_.astype(jnp.float32))
        y = yt.astype(x.dtype) + lp["D_skip"].astype(x.dtype)[None, :, None] * xh
        y = y.reshape(-1, 1, di)

    y = y * jax.nn.silu(z if not step else z)
    y = ll.rms_norm(y, lp["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, lp["out_proj"].astype(x.dtype))
    return constrain(out, rules, "batch", "seq", None), new_conv, final


def _block(x, lp, cfg, rules):
    y, _, _ = _mix(ll.rms_norm(x, lp["ln"]), lp, cfg, rules)
    return x + y


def forward(params, batch, cfg: ModelConfig, rules: ShardingRules | None):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", None)
    block = _block
    if cfg.remat:
        block = jax.checkpoint(
            block, static_argnums=(2, 3))

    def body(x, lp):
        return block(x, lp, cfg, rules), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = ll.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "ssd": ("layers", "cache_batch", None, "ssm_p", None),
        "conv": ("layers", "cache_batch", None, "conv_ch"),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    di, H, N, P, conv_ch = _dims(cfg)
    L = cfg.n_layers
    return {
        "ssd": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def prefill(params, batch, cfg: ModelConfig, rules, max_len: int):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", None)

    def body(x, lp):
        y, conv_st, ssd_st = _mix(ll.rms_norm(x, lp["ln"]), lp, cfg, rules)
        return x + y, (conv_st.astype(cfg.dtype), ssd_st.astype(jnp.float32))

    x, (convs, ssds) = jax.lax.scan(body, x, params["layers"])
    x = ll.rms_norm(x[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"ssd": ssds, "conv": convs}


def decode(params, cache, token, pos, cfg: ModelConfig,
           rules: ShardingRules | None):
    x = params["embed"].astype(cfg.dtype)[token]
    x = constrain(x, rules, "decode_batch", None, None)

    def body(x, inp):
        lp, conv_st, ssd_st = inp
        y, new_conv, new_ssd = _mix(
            ll.rms_norm(x, lp["ln"]), lp, cfg, rules,
            conv_state=conv_st, ssd_state=ssd_st, step=True)
        return x + y, (new_conv, new_ssd)

    x, (convs, ssds) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssd"]))
    x = ll.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"ssd": ssds, "conv": convs}
