"""Decoder-only LM stack (dense, MoE, and VLM variants).

Layers are scan-stacked (leading ``L`` dim on every layer param) and executed
with ``jax.lax.scan`` — essential here: compile time and HLO size stay
O(1) in depth, which is what makes the 40-cell x 512-device dry-run feasible
on a single host. ``cfg.remat`` wraps the block in jax.checkpoint with a
dots-saveable policy (activation recomputation in backward).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as ll
from repro.models.config import ModelConfig

__all__ = ["init", "axes", "forward", "prefill", "decode", "init_cache"]


def _layer_keys(key, n):
    return jax.random.split(key, n)


def init(cfg: ModelConfig, key) -> dict:
    kd, ke, kl, kh = jax.random.split(key, 4)
    D, H, K, dh, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh,
                            cfg.d_ff, cfg.vocab, cfg.n_layers)

    def stack(fn):
        outs = [fn(k) for k in _layer_keys(kl, L)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    def one_layer(k):
        k1, k2, k3, k4, k5, k6, k7 = jax.random.split(k, 7)
        p = {
            "ln1": jnp.ones((D,), jnp.float32),
            "ln2": jnp.ones((D,), jnp.float32),
            "attn": {
                "wq": ll.dense_init(k1, (D, H, dh)),
                "wk": ll.dense_init(k2, (D, K, dh)),
                "wv": ll.dense_init(k3, (D, K, dh)),
                "wo": ll.dense_init(k4, (H, dh, D), in_axis=(0, 1)),
            },
        }
        if cfg.qkv_bias:
            p["attn"]["bq"] = jnp.zeros((H, dh), jnp.float32)
            p["attn"]["bk"] = jnp.zeros((K, dh), jnp.float32)
            p["attn"]["bv"] = jnp.zeros((K, dh), jnp.float32)
        if cfg.kind == "moe":
            E, dE = cfg.n_experts, cfg.d_expert
            p["ffn"] = {
                "router": ll.dense_init(k5, (D, E)),
                "experts": {
                    "w_gate": ll.dense_init(k5, (E, D, dE), in_axis=1),
                    "w_up": ll.dense_init(k6, (E, D, dE), in_axis=1),
                    "w_down": ll.dense_init(k7, (E, dE, D), in_axis=1),
                },
            }
            if cfg.n_shared_experts:
                Fs = cfg.n_shared_experts * dE
                p["ffn"]["shared"] = {
                    "w_gate": ll.dense_init(k5, (D, Fs)),
                    "w_up": ll.dense_init(k6, (D, Fs)),
                    "w_down": ll.dense_init(k7, (Fs, D)),
                }
        else:
            p["ffn"] = {
                "w_gate": ll.dense_init(k5, (D, F)),
                "w_up": ll.dense_init(k6, (D, F)),
                "w_down": ll.dense_init(k7, (F, D)),
            }
        return p

    params = {
        "embed": ll.dense_init(kd, (V, D), in_axis=1),
        "layers": stack(one_layer),
        "final_norm": jnp.ones((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = ll.dense_init(kh, (D, V))
    if cfg.kind == "vlm":
        # Stub frontend: a learned projection applied to precomputed patch
        # embeddings (the CLIP tower itself is out of scope per assignment).
        params["vision_proj"] = ll.dense_init(ke, (D, D))
    return params


def axes(cfg: ModelConfig) -> dict:
    """Logical-axis tree mirroring ``init``'s param tree."""
    a = {
        "embed": ("vocab", "fsdp"),
        "final_norm": (None,),
        "layers": {
            "ln1": ("layers", None),
            "ln2": ("layers", None),
            "attn": {
                "wq": ("layers", "fsdp", "heads", None),
                "wk": ("layers", "fsdp", "kv_heads", None),
                "wv": ("layers", "fsdp", "kv_heads", None),
                "wo": ("layers", "heads", None, "fsdp"),
            },
        },
    }
    if cfg.qkv_bias:
        a["layers"]["attn"]["bq"] = ("layers", "heads", None)
        a["layers"]["attn"]["bk"] = ("layers", "kv_heads", None)
        a["layers"]["attn"]["bv"] = ("layers", "kv_heads", None)
    if cfg.kind == "moe":
        a["layers"]["ffn"] = {
            "router": ("layers", None, "experts"),
            "experts": {
                "w_gate": ("layers", "experts", "fsdp", None),
                "w_up": ("layers", "experts", "fsdp", None),
                "w_down": ("layers", "experts", None, "fsdp"),
            },
        }
        if cfg.n_shared_experts:
            a["layers"]["ffn"]["shared"] = {
                "w_gate": ("layers", "fsdp", "d_ff"),
                "w_up": ("layers", "fsdp", "d_ff"),
                "w_down": ("layers", "d_ff", "fsdp"),
            }
    else:
        a["layers"]["ffn"] = {
            "w_gate": ("layers", "fsdp", "d_ff"),
            "w_up": ("layers", "fsdp", "d_ff"),
            "w_down": ("layers", "d_ff", "fsdp"),
        }
    if not cfg.tie_embeddings:
        a["lm_head"] = ("fsdp", "vocab")
    if cfg.kind == "vlm":
        a["vision_proj"] = ("fsdp", None)
    return a


def _block(x, lp, cfg: ModelConfig, rules, positions):
    y = ll.attention(ll.rms_norm(x, lp["ln1"]), lp["attn"], cfg, rules,
                     positions=positions)
    x = x + y
    h = ll.rms_norm(x, lp["ln2"])
    if cfg.kind == "moe":
        f, aux = ll.moe_ffn(h, lp["ffn"], cfg, rules)
    else:
        f, aux = ll.swiglu(h, lp["ffn"], rules), jnp.zeros((), jnp.float32)
    return x + f, aux


def _scan_blocks(x, params, cfg: ModelConfig, rules, positions):
    block = _block
    if cfg.remat:
        block = jax.checkpoint(
            block, static_argnums=(2, 3))

    def body(carry, lp):
        x, aux = carry
        x2, a = block(x, lp, cfg, rules, positions)
        return (x2, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


def _embed(params, tokens, cfg: ModelConfig, rules, vision=None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.kind == "vlm" and vision is not None:
        v = jnp.einsum("bpd,de->bpe", vision.astype(cfg.dtype),
                       params["vision_proj"].astype(cfg.dtype))
        x = jnp.concatenate([v, x], axis=1)
    return constrain(x, rules, "batch", "seq", None)


def forward(params, batch, cfg: ModelConfig, rules: ShardingRules | None):
    """Training/prefill forward -> (logits, aux_loss)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg, rules, batch.get("vision"))
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, aux = _scan_blocks(x, params, cfg, rules, positions)
    x = ll.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab"), aux


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "k": ("layers", "cache_batch", "cache_seq", None, None),
        "v": ("layers", "cache_batch", "cache_seq", None, None),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    S = min(max_len, cfg.window) if cfg.window > 0 else max_len
    return {
        "k": jnp.zeros((L, batch, S, K, dh), dtype),
        "v": jnp.zeros((L, batch, S, K, dh), dtype),
    }


def prefill(params, batch, cfg: ModelConfig, rules, max_len: int):
    """Run the full prompt, returning last-position logits + a filled cache."""
    tokens = batch["tokens"]
    B, S = tokens.shape[0], tokens.shape[1]
    x = _embed(params, tokens, cfg, rules, batch.get("vision"))
    Sx = x.shape[1]
    positions = jnp.arange(Sx)[None, :]

    # VLM prompts are vision prefix + text: the cache must cover both.
    cache = init_cache(cfg, B, max(max_len, Sx), jnp.bfloat16)

    def body(carry, inp):
        x, = carry
        lp = inp
        y, (k, v) = ll.attention(ll.rms_norm(x, lp["ln1"]), lp["attn"], cfg,
                                 rules, positions=positions, return_kv=True)
        x = x + y
        h = ll.rms_norm(x, lp["ln2"])
        if cfg.kind == "moe":
            f, _ = ll.moe_ffn(h, lp["ffn"], cfg, rules)
        else:
            f = ll.swiglu(h, lp["ffn"], rules)
        return (x + f,), (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    (x,), (ks, vs) = jax.lax.scan(body, (x,), params["layers"])
    Sc = cache["k"].shape[2]
    if cfg.window > 0 and Sx > Sc:
        ks, vs = ks[:, :, -Sc:], vs[:, :, -Sc:]
        cache = {"k": ks, "v": vs}
    else:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ks, 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vs, 0, axis=2)
    x = ll.rms_norm(x[:, -1:, :], params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits, cache


def decode(params, cache, token, pos, cfg: ModelConfig,
           rules: ShardingRules | None):
    """One decode step. token: (B, 1) int; pos: scalar position index."""
    x = params["embed"].astype(cfg.dtype)[token]
    x = constrain(x, rules, "decode_batch", None, None)

    def body(x, inp):
        lp, ck, cv = inp
        y, ck, cv = ll.attention_decode(
            ll.rms_norm(x, lp["ln1"]), lp["attn"], ck, cv, pos, cfg, rules)
        x = x + y
        h = ll.rms_norm(x, lp["ln2"])
        if cfg.kind == "moe":
            f, _ = ll.moe_ffn(h, lp["ffn"], cfg, rules)
        else:
            f = ll.swiglu(h, lp["ffn"], rules)
        return x + f, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = ll.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits, {"k": ks, "v": vs}
