"""Shared pure-JAX building blocks for the model zoo.

Conventions:
  * params are nested dicts of f32 arrays; forward casts to cfg.dtype.
  * every block takes a ``ShardingRules | None`` and annotates its
    activations via ``constrain`` (no-op off-mesh) — model code never touches
    mesh axes directly.
  * decode paths operate on one new token against an explicit cache pytree.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, constrain
from repro.models.config import ModelConfig

__all__ = [
    "dense_init", "rms_norm", "rotary", "apply_rope",
    "attention", "attention_decode", "swiglu", "moe_ffn",
    "ssd", "ssd_step", "causal_conv1d", "conv1d_step",
]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init (f32 master copy)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


# --------------------------------------------------------------------------
# norms / rotary
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rotary(positions, dh: int, theta: float):
    """(..., S) int positions -> cos/sin of shape (..., S, dh//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, dh//2) or (S, dh//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# --------------------------------------------------------------------------
# attention (training / prefill path)
# --------------------------------------------------------------------------

FLASH_THRESHOLD = 8192   # use blockwise attention at/above this seq length
BLOCK_Q = 512
BLOCK_K = 1024


def blockwise_attention(q, k, v, causal: bool, window: int, prefix_len: int,
                        block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """Flash-style attention in pure JAX: O(S * block) memory, never
    materializing the (Sq, Sk) score matrix.

    Outer loop over query blocks is a python loop (so causal/window blocks
    outside each query block's reach are STATICALLY skipped — the same
    compute-skipping the Pallas kernel does on TPU); the inner loop over kv
    blocks is a lax.scan carrying the online-softmax state (m, l, acc).

    q: (B, Sq, K, g, dh) grouped queries; k/v: (B, Sk, K, dh).
    Positions are the global indices 0..S-1 (rotary already applied).
    Returns (B, Sq, K, g, dh).
    """
    B, Sq, K, g, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # Pad S to block multiples (masked out below).
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (Sk + pk) // bk

    out_blocks = []
    for qi in range(nq):
        q_blk = q[:, qi * bq:(qi + 1) * bq]               # (B,bq,K,g,dh)
        q_lo, q_hi = qi * bq, qi * bq + bq - 1
        # Statically-reachable kv blocks for this query block.
        kv_ids = []
        for ki in range(nk):
            k_lo, k_hi = ki * bk, ki * bk + bk - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window > 0 and k_hi < q_lo - window + 1 - bq \
                    and not (prefix_len > 0 and k_lo < prefix_len):
                continue  # entirely behind the window (and not meta prefix)
            kv_ids.append(ki)
        kv_ids = jnp.array(kv_ids, jnp.int32)

        def inner(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, 1)
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_blk, k_blk) * scale
            s = s.astype(jnp.float32)
            q_pos = q_lo + jnp.arange(bq)
            k_pos = ki * bk + jnp.arange(bk)
            diff = q_pos[:, None] - k_pos[None, :]
            bad = k_pos[None, :] >= Sk  # padding keys
            if causal:
                bad |= diff < 0
            if window > 0:
                oow = diff >= window
                if prefix_len > 0:
                    oow &= k_pos[None, :] >= prefix_len
                bad |= oow
            s = jnp.where(bad[None, None, None], NEG_INF, s)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(q.dtype), v_blk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, g, bq), jnp.float32)
        a0 = jnp.zeros((B, K, g, bq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), kv_ids)
        blk = acc / jnp.maximum(l[..., None], 1e-30)
        out_blocks.append(jnp.moveaxis(blk, 3, 1).astype(q.dtype))

    out = jnp.concatenate(out_blocks, axis=1)  # (B, Sq+pq, K, g, dh)
    if pq:
        out = out[:, :Sq]
    return out


# --------------------------------------------------------------------------
# flash attention with custom VJP (training path): the backward RECOMPUTES
# the score blocks instead of letting autodiff save every (bq, bk)
# probability tile — without this, jax saves O(S^2) residuals through the
# kv scan and the blockwise forward buys nothing in training (§Perf).
# --------------------------------------------------------------------------

def _flash_fwd_blocks(q, k, v, causal, window, prefix, bq, bk):
    """Returns (out, lse) with lse = m + log l per query (B, K, g, Sq)."""
    B, Sq, K, g, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    nq = Sq // bq
    outs, lses = [], []
    for qi in range(nq):
        q_blk = q[:, qi * bq:(qi + 1) * bq]
        kv_ids = _reachable_kv(qi, bq, bk, Sk, causal, window, prefix)

        def inner(carry, ki):
            m, l, acc = carry
            s, v_blk = _score_block(q_blk, k, v, ki, qi, bq, bk, Sk, scale,
                                    causal, window, prefix)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(q.dtype), v_blk)
            return (m_new, l_new, acc * corr[..., None] + pv.astype(jnp.float32)), None

        m0 = jnp.full((B, K, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, g, bq), jnp.float32)
        a0 = jnp.zeros((B, K, g, bq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0),
                                      jnp.asarray(kv_ids, jnp.int32))
        l = jnp.maximum(l, 1e-30)
        outs.append(jnp.moveaxis(acc / l[..., None], 3, 1).astype(q.dtype))
        lses.append(m + jnp.log(l))
    return jnp.concatenate(outs, axis=1), jnp.concatenate(lses, axis=-1)


def _reachable_kv(qi, bq, bk, Sk, causal, window, prefix) -> list[int]:
    """STATIC list of kv-block ids this query block can attend to."""
    nk = (Sk + bk - 1) // bk
    q_lo, q_hi = qi * bq, qi * bq + bq - 1
    ids = []
    for ki in range(nk):
        k_lo, k_hi = ki * bk, ki * bk + bk - 1
        if causal and k_lo > q_hi:
            continue
        if window > 0 and k_hi < q_lo - window + 1 - bq \
                and not (prefix > 0 and k_lo < prefix):
            continue
        ids.append(ki)
    return ids


def _score_block(q_blk, k, v, ki, qi, bq, bk, Sk, scale, causal, window,
                 prefix):
    k_blk = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 1)
    v_blk = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, 1)
    s = jnp.einsum("bqkgh,btkh->bkgqt", q_blk, k_blk).astype(jnp.float32)
    s = s * scale
    q_pos = qi * bq + jnp.arange(bq)
    k_pos = ki * bk + jnp.arange(bk)
    diff = q_pos[:, None] - k_pos[None, :]
    bad = k_pos[None, :] >= Sk
    if causal:
        bad |= diff < 0
    if window > 0:
        oow = diff >= window
        if prefix > 0:
            oow &= k_pos[None, :] >= prefix
        bad |= oow
    return jnp.where(bad[None, None, None], NEG_INF, s), v_blk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_train(q, k, v, causal=True, window=0, prefix=0,
                          bq=BLOCK_Q, bk=BLOCK_K):
    """Blockwise attention, O(S*block) memory in fwd AND bwd.

    q: (B, Sq, K, g, dh) grouped; k/v: (B, Sk, K, dh). Sq, Sk must be
    multiples of bq, bk (attention() pads)."""
    out, _ = _flash_fwd_blocks(q, k, v, causal, window, prefix, bq, bk)
    return out


def _flash_train_fwd(q, k, v, causal, window, prefix, bq, bk):
    out, lse = _flash_fwd_blocks(q, k, v, causal, window, prefix, bq, bk)
    return out, (q, k, v, out, lse)


def _flash_train_bwd(causal, window, prefix, bq, bk, res, do):
    q, k, v, out, lse = res
    B, Sq, K, g, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    nq = Sq // bq
    delta = jnp.einsum("bqkgh,bqkgh->bkgq", do.astype(jnp.float32),
                       out.astype(jnp.float32))          # (B,K,g,Sq)
    dq = jnp.zeros_like(q, dtype=jnp.float32)
    dk = jnp.zeros_like(k, dtype=jnp.float32)
    dv = jnp.zeros_like(v, dtype=jnp.float32)
    for qi in range(nq):
        sl = slice(qi * bq, (qi + 1) * bq)
        q_blk = q[:, sl]
        do_blk = do[:, sl].astype(jnp.float32)           # (B,bq,K,g,dh)
        lse_blk = lse[..., sl]                           # (B,K,g,bq)
        dl_blk = delta[..., sl]
        kv_ids = _reachable_kv(qi, bq, bk, Sk, causal, window, prefix)

        def inner(dq_acc, ki):
            s, v_blk = _score_block(q_blk, k, v, ki, qi, bq, bk, Sk, scale,
                                    causal, window, prefix)
            p = jnp.exp(s - lse_blk[..., None])          # (B,K,g,bq,bk)
            do_t = jnp.moveaxis(do_blk, 1, 3)            # (B,K,g,bq,dh)
            dv_c = jnp.einsum("bkgqt,bkgqh->btkh", p, do_t)
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 1)
            dp = jnp.einsum("bkgqh,btkh->bkgqt", do_t,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - dl_blk[..., None]) * scale
            dq_c = jnp.einsum("bkgqt,btkh->bqkgh", ds,
                              k_blk.astype(jnp.float32))
            dk_c = jnp.einsum("bkgqt,bqkgh->btkh", ds,
                              q_blk.astype(jnp.float32))
            return dq_acc + dq_c, (ki, dk_c, dv_c)

        dq_blk, (kis, dk_cs, dv_cs) = jax.lax.scan(
            inner, jnp.zeros((B, bq, K, g, dh), jnp.float32),
            jnp.asarray(kv_ids, jnp.int32))
        dq = dq.at[:, sl].add(dq_blk)
        # scatter-add per visited kv block (static id list per q block)
        for j, ki in enumerate(kv_ids):
            ksl = slice(ki * bk, ki * bk + bk)
            dk = dk.at[:, ksl].add(dk_cs[j])
            dv = dv.at[:, ksl].add(dv_cs[j])
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_train.defvjp(_flash_train_fwd, _flash_train_bwd)

def _mask(q_pos, k_pos, causal: bool, window: int, prefix_len: int = 0):
    """(..., Sq, Sk) additive mask from position grids.

    ``prefix_len``: keys at positions < prefix_len stay visible even outside
    the sliding window (Hymba meta tokens)."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                  jnp.float32)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = jnp.where(diff < 0, NEG_INF, m)
    if window > 0:
        out_of_window = diff >= window
        if prefix_len > 0:
            out_of_window &= k_pos[..., None, :] >= prefix_len
        m = jnp.where(out_of_window, NEG_INF, m)
    return m


def attention(
    x, p, cfg: ModelConfig, rules: ShardingRules | None,
    positions=None, causal: bool = True, window: int | None = None,
    kv_source=None, return_kv: bool = False, prefix_len: int = 0,
):
    """Batched multi-head attention with GQA + rotary.

    ``kv_source``: cross-attention memory (B, Sk, D) — rotary is skipped and
    causality ignored for cross attention. ``return_kv`` additionally returns
    the (k, v) tensors for cache construction during prefill.
    """
    B, S, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    win = cfg.window if window is None else window

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    src = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)

    if kv_source is None:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        cos, sin = rotary(positions, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        q_pos = k_pos = positions
        use_causal = causal
    else:
        q_pos = jnp.arange(S)[None, :]
        k_pos = jnp.arange(src.shape[1])[None, :]
        use_causal, win = False, 0

    q = constrain(q, rules, "batch", "seq", "heads", None)
    k = constrain(k, rules, "batch", "seq", "kv_heads", None)
    v = constrain(v, rules, "batch", "seq", "kv_heads", None)

    g = H // K  # GQA group size
    qg = q.reshape(B, S, K, g, dh)
    if max(S, k.shape[1]) >= (cfg.flash_threshold or FLASH_THRESHOLD):
        # Long sequences: flash-style blockwise attention — O(S*block)
        # memory in forward AND backward (custom VJP recomputes score
        # blocks). On TPU this path is the Pallas flash_attention kernel.
        Sk = k.shape[1]
        pq, pk = (-S) % BLOCK_Q, (-Sk) % BLOCK_K
        qp = jnp.pad(qg, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0))) if pq else qg
        kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
        vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
        out = flash_attention_train(qp, kp, vp, use_causal, win, prefix_len)
        out = out[:, :S].reshape(B, S, H, dh)
    else:
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(dh)
        mask = _mask(q_pos, k_pos, use_causal, win, prefix_len)  # (B, Sq, Sk)
        scores = scores + mask[:, None, None, :, :].astype(scores.dtype)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v).reshape(B, S, H, dh)
    out = constrain(out, rules, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    y = constrain(y, rules, "batch", "seq", None)
    if return_kv:
        return y, (k, v)
    return y


def attention_decode(
    x, p, cache_k, cache_v, pos, cfg: ModelConfig,
    rules: ShardingRules | None, window: int | None = None,
    cross: bool = False,
):
    """One-token decode against a cache.

    x: (B, 1, D); cache_k/v: (B, S_max, K, dh); pos: scalar int (current
    index). Returns (y, new_cache_k, new_cache_v). For ``cross=True`` the
    cache holds encoder K/V and is not updated.
    """
    B, _, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    win = cfg.window if window is None else window
    S_max = cache_k.shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
            k_new = k_new + p["bk"].astype(x.dtype)
            v_new = v_new + p["bv"].astype(x.dtype)
        pos_arr = jnp.full((B, 1), pos)
        cos, sin = rotary(pos_arr, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
        # Ring-buffer slot for windowed layers, linear slot otherwise.
        slot = pos % S_max if win > 0 else pos
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, 1)
    elif "bq" in p:
        q = q + p["bq"].astype(x.dtype)

    g = H // K
    qg = q.reshape(B, 1, K, g, dh)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, cache_k) / np.sqrt(dh)
    # Valid-key mask: slots written so far (ring buffer ⇒ all slots once
    # pos >= S_max), and within the window for windowed layers.
    idx = jnp.arange(S_max)
    if cross:
        valid = jnp.ones((S_max,), bool)
    elif win > 0:
        valid = (idx <= pos) | (pos >= S_max)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, cache_v).reshape(B, 1, H, dh)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x, p, rules: ShardingRules | None):
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = constrain(h, rules, "batch", "seq", "d_ff")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return constrain(y, rules, "batch", "seq", None)


def _expert_swiglu(buf, p, rules, grouped: bool = False):
    """buf: (E, C, D) or (G, E, C, D) routed-token buffers; per-expert
    SwiGLU."""
    if grouped:
        h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(buf.dtype))
        u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(buf.dtype))
        h = jax.nn.silu(h) * u
        h = constrain(h, rules, "batch", "experts", None, None)
        return jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(buf.dtype))
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    h = jax.nn.silu(h) * u
    h = constrain(h, rules, "experts", None, None)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(buf.dtype))


def moe_ffn(x, p, cfg: ModelConfig, rules: ShardingRules | None):
    """Fine-grained routed MoE with capacity dropping (sort-based dispatch).

    TPU-idiomatic: token->expert routing is an argsort + scatter/gather
    (O(T k D) bytes), NOT the quadratic one-hot dispatch einsum; experts are
    sharded over the model axis (EP) so the expert buffers lower to an
    all-to-all under GSPMD.

    ``cfg.moe_groups > 1`` splits the token axis into data-local groups and
    dispatches within each: routing indices then never cross the data
    shards, so the gathers/scatters stay local and only the (G, E, cap, D)
    expert buffers travel — the grouped-dispatch §Perf optimization.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = max(1, min(cfg.moe_groups, T))
    Tg = T // G
    xt = x.reshape(G, Tg, D)
    xt = constrain(xt, rules, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                      # (G, Tg, k)
    gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(x.dtype)

    cap = int(np.ceil(Tg * k / E * cfg.capacity_factor))
    flat_e = eidx.reshape(G, Tg * k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    flat_g = gate.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=1)
    se = jnp.take_along_axis(flat_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    sg = jnp.take_along_axis(flat_g, order, 1)
    # position within expert = rank - start offset of the expert's run
    counts = jnp.sum(jax.nn.one_hot(se, E, dtype=jnp.int32), axis=1)  # (G, E)
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32), jnp.cumsum(counts, 1)[:, :-1]], 1)
    slot = jnp.arange(Tg * k)[None, :] - jnp.take_along_axis(starts, se, 1)
    keep = slot < cap
    slot_c = jnp.clip(slot, 0, cap - 1)

    def dispatch_combine(xt_l, se_l, st_l, sg_l, keep_l, slot_l, experts):
        """Group-local dispatch -> expert SwiGLU -> combine. Under
        shard_map the gathers/scatters are purely local (GSPMD otherwise
        replicates the (G, Tg*k, D) gather outputs — tens of GB/device at
        32k prefill; see §Perf)."""
        Gl = xt_l.shape[0]
        gi = jnp.arange(Gl)[:, None]
        picked = xt_l[gi, st_l].astype(x.dtype)
        buf = jnp.zeros((Gl, E, cap, D), x.dtype)
        buf = buf.at[gi, se_l, slot_l].add(
            jnp.where(keep_l[..., None], picked, 0).astype(x.dtype))
        buf = constrain(buf, rules, "batch", "experts", None, None)
        out_buf = _expert_swiglu(buf, experts, rules, grouped=True)
        contrib = out_buf[gi, se_l, slot_l].astype(x.dtype)
        yt = jnp.zeros((Gl, Tg, D), x.dtype)
        yt = yt.at[gi, st_l].add(contrib * (sg_l * keep_l)[..., None])
        return yt

    batch_axes = tuple(a for a in ("pod", "data")
                       if rules is not None and a in rules.mesh_axes)
    if G > 1 and batch_axes:
        from jax.sharding import PartitionSpec as _P
        gspec = _P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
        rep = _P()
        yt = jax.shard_map(
            dispatch_combine,
            in_specs=(gspec, gspec, gspec, gspec, gspec, gspec, rep),
            out_specs=gspec,
            axis_names=set(batch_axes), check_vma=False,
        )(xt, se, st, sg, keep, slot_c, p["experts"])
    else:
        yt = dispatch_combine(xt, se, st, sg, keep, slot_c, p["experts"])
    y = yt.reshape(B, S, D)

    if cfg.n_shared_experts > 0:
        y = y + swiglu(x, p["shared"], rules)
    # Load-balance auxiliary loss (Switch-style), returned for the trainer.
    me = jnp.mean(jax.nn.one_hot(eidx, E).sum(axis=2),
                  axis=(0, 1))                                # tokens/expert
    pe = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(me / k * pe)
    return constrain(y, rules, "batch", "seq", None), aux


# --------------------------------------------------------------------------
# Mamba-2 SSD (chunked reference; the Pallas kernel mirrors this math)
# --------------------------------------------------------------------------

def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-tri cumulative sums (exclusive)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd(x, dt, A, B_, C_, chunk: int, rules: ShardingRules | None = None,
        init_state=None):
    """Chunked state-space-duality scan (Mamba-2 Alg. 1, jnp reference).

    x:  (B, S, H, P)   per-head inputs
    dt: (B, S, H)      softplus-activated step sizes
    A:  (H,)           negative decay rates
    B_: (B, S, G, N)   input projections   (G groups broadcast over H)
    C_: (B, S, G, N)   output projections
    Returns (y, final_state) with y (B, S, H, P), state (B, H, P, N).
    """
    Bb, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    pad = (-S) % chunk
    if pad:
        # dt = 0 padding is exact: decay exp(0)=1, update B*(dt*x)=0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = S + pad
    nc = S_pad // chunk
    rep = H // G

    xr = x.reshape(Bb, nc, chunk, H, Pd)
    dtr = dt.reshape(Bb, nc, chunk, H)
    Br = B_.reshape(Bb, nc, chunk, G, N)
    Cr = C_.reshape(Bb, nc, chunk, G, N)
    del S_pad
    Br = jnp.repeat(Br, rep, axis=3)          # (B, nc, Q, H, N)
    Cr = jnp.repeat(Cr, rep, axis=3)

    xdt = xr * dtr[..., None]                 # dt-weighted inputs
    Adt = A[None, None, None, :] * dtr        # (B, nc, Q, H)
    Adt_t = jnp.moveaxis(Adt, -1, 2)          # (B, nc, H, Q)

    # Intra-chunk (diagonal block): Y_d = (C B^T ⊙ L) X
    L = jnp.exp(_segsum(Adt_t))               # (B, nc, H, Q, Q)
    CB = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)
    Yd = jnp.einsum("bchls,bcshp->bclhp", CB * L, xdt)

    # Chunk-final states: S_c = sum_s exp(A_cum_end - A_cum_s) B_s x_s^T
    Acum = jnp.cumsum(Adt_t, axis=-1)          # (B, nc, H, Q)
    decay_states = jnp.exp(Acum[..., -1:] - Acum)            # (B, nc, H, Q)
    states = jnp.einsum("bchs,bcshn,bcshp->bchpn",
                        decay_states, Br, xdt)               # (B, nc, H, P, N)

    # Inter-chunk recurrence (sequential over chunks).
    chunk_decay = jnp.exp(Acum[..., -1])       # (B, nc, H)
    if init_state is None:
        init_state = jnp.zeros((Bb, H, Pd, N), x.dtype)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None].astype(carry.dtype) + st
        return new, carry  # emit the state ENTERING this chunk

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final, entered = jax.lax.scan(step, init_state.astype(states.dtype), xs)
    entered = jnp.moveaxis(entered, 0, 1)      # (B, nc, H, P, N)

    # Off-diagonal contribution: Y_off = C_s exp(A_cum_s) S_entered
    state_decay = jnp.exp(Acum)                # (B, nc, H, Q)
    Yoff = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cr, entered, state_decay)

    y = (Yd + Yoff).reshape(Bb, S + pad, H, Pd)
    if pad:
        y = y[:, :S]
    return y, final


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence for decode.

    state: (B, H, P, N); x_t: (B, H, P); dt_t: (B, H); B_t/C_t: (B, G, N).
    """
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    B_h = jnp.repeat(B_t, rep, axis=1)        # (B, H, N)
    C_h = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)        # (B, H)
    upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], B_h)
    new_state = state * decay[:, :, None, None].astype(state.dtype) + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C_h)
    return y, new_state


# --------------------------------------------------------------------------
# causal depthwise conv (Mamba front conv)
# --------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (B, S, C), w: (K, C) depthwise, left-padded causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def conv1d_step(conv_state, x_t, w, b):
    """conv_state: (B, K-1, C) last inputs; x_t: (B, C)."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", full, w) + b[None, :]
    return y, full[:, 1:, :]
