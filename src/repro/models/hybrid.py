"""Hymba-style hybrid stack — parallel attention + SSM heads per layer.

Each layer runs a sliding-window GQA attention branch and a Mamba-2 SSD
branch on the same normed input; branch outputs are RMS-normed and averaged
before the residual add (Hymba's fusion). ``n_meta_tokens`` learned meta
tokens are prepended to the sequence and stay visible to every window
(Hymba's "memory anchors" for SWA). Global context is carried by the SSM
branch, so attention stays windowed in ALL layers — this is the deviation
(documented in DESIGN.md §Arch-applicability) that keeps the ``long_500k``
decode cell O(window) in memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as ll
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig

__all__ = ["init", "axes", "forward", "prefill", "decode", "init_cache"]

G = 1


def _ssm_cfg(cfg: ModelConfig) -> ModelConfig:
    """View of the config for the SSD branch dims."""
    return cfg


def init(cfg: ModelConfig, key) -> dict:
    D, H, K, dh, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh,
                            cfg.d_ff, cfg.vocab, cfg.n_layers)
    di = cfg.d_inner_ssm
    Hs = cfg.n_ssm_heads
    N = cfg.d_state
    conv_ch = di + 2 * G * N
    kd, kl, kh, km = jax.random.split(key, 4)

    def one_layer(k):
        k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(k, 8)
        return {
            "ln1": jnp.ones((D,), jnp.float32),
            "ln2": jnp.ones((D,), jnp.float32),
            "norm_attn": jnp.ones((D,), jnp.float32),
            "norm_ssm": jnp.ones((D,), jnp.float32),
            "attn": {
                "wq": ll.dense_init(k1, (D, H, dh)),
                "wk": ll.dense_init(k2, (D, K, dh)),
                "wv": ll.dense_init(k3, (D, K, dh)),
                "wo": ll.dense_init(k4, (H, dh, D), in_axis=(0, 1)),
            },
            "ssm": {
                "in_proj": ll.dense_init(k5, (D, 2 * di + 2 * G * N + Hs)),
                "conv_w": 0.1 * jax.random.normal(
                    k6, (cfg.ssm_conv, conv_ch), jnp.float32),
                "conv_b": jnp.zeros((conv_ch,), jnp.float32),
                "A_log": jnp.log(jnp.linspace(1.0, 16.0, Hs, jnp.float32)),
                "D_skip": jnp.ones((Hs,), jnp.float32),
                "dt_bias": jnp.zeros((Hs,), jnp.float32),
                "out_norm": jnp.ones((di,), jnp.float32),
                "out_proj": ll.dense_init(k7, (di, D)),
            },
            "ffn": {
                "w_gate": ll.dense_init(k8, (D, F)),
                "w_up": ll.dense_init(k8, (D, F)),
                "w_down": ll.dense_init(k8, (F, D)),
            },
        }

    outs = [one_layer(k) for k in jax.random.split(kl, L)]
    return {
        "embed": ll.dense_init(kd, (V, D), in_axis=1),
        "meta": 0.02 * jax.random.normal(km, (cfg.n_meta_tokens, D),
                                         jnp.float32),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *outs),
        "final_norm": jnp.ones((D,), jnp.float32),
        "lm_head": ll.dense_init(kh, (D, V)),
    }


def axes(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "fsdp"),
        "meta": (None, None),
        "final_norm": (None,),
        "lm_head": ("fsdp", "vocab"),
        "layers": {
            "ln1": ("layers", None), "ln2": ("layers", None),
            "norm_attn": ("layers", None), "norm_ssm": ("layers", None),
            "attn": {
                "wq": ("layers", "fsdp", "heads", None),
                "wk": ("layers", "fsdp", "kv_heads", None),
                "wv": ("layers", "fsdp", "kv_heads", None),
                "wo": ("layers", "heads", None, "fsdp"),
            },
            "ssm": {
                "in_proj": ("layers", "fsdp", "d_ff"),
                "conv_w": ("layers", None, "d_ff"),
                "conv_b": ("layers", "d_ff"),
                "A_log": ("layers", None),
                "D_skip": ("layers", None),
                "dt_bias": ("layers", None),
                "out_norm": ("layers", "d_ff"),
                "out_proj": ("layers", "d_ff", "fsdp"),
            },
            "ffn": {
                "w_gate": ("layers", "fsdp", "d_ff"),
                "w_up": ("layers", "fsdp", "d_ff"),
                "w_down": ("layers", "d_ff", "fsdp"),
            },
        },
    }


def _block(x, lp, cfg: ModelConfig, rules, positions):
    h = ll.rms_norm(x, lp["ln1"])
    a = ll.attention(h, lp["attn"], cfg, rules, positions=positions,
                     window=cfg.window, prefix_len=cfg.n_meta_tokens)
    s, _, _ = ssm_mod._mix(h, lp["ssm"], cfg, rules)
    y = 0.5 * (ll.rms_norm(a, lp["norm_attn"]) + ll.rms_norm(s, lp["norm_ssm"]))
    x = x + y
    x = x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)
    return x


def _with_meta(params, tokens, cfg, rules):
    x = params["embed"].astype(cfg.dtype)[tokens]
    B = x.shape[0]
    meta = jnp.broadcast_to(params["meta"].astype(cfg.dtype)[None],
                            (B, cfg.n_meta_tokens, cfg.d_model))
    x = jnp.concatenate([meta, x], axis=1)
    return constrain(x, rules, "batch", "seq", None)


def forward(params, batch, cfg: ModelConfig, rules: ShardingRules | None):
    tokens = batch["tokens"]
    x = _with_meta(params, tokens, cfg, rules)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    block = _block
    if cfg.remat:
        block = jax.checkpoint(
            block, static_argnums=(2, 3))

    def body(x, lp):
        return block(x, lp, cfg, rules, positions), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = ll.rms_norm(x[:, cfg.n_meta_tokens:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "k": ("layers", "cache_batch", None, None, None),   # window cache: small
        "v": ("layers", "cache_batch", None, None, None),
        "slot_pos": (None,),
        "ssd": ("layers", "cache_batch", None, "ssm_p", None),
        "conv": ("layers", "cache_batch", None, "conv_ch"),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache = meta block + ring window (attention) + SSD/conv states."""
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    di = cfg.d_inner_ssm
    Hs, N, P = cfg.n_ssm_heads, cfg.d_state, cfg.ssm_head_dim
    Sc = cfg.n_meta_tokens + min(cfg.window, max_len)
    return {
        "k": jnp.zeros((L, batch, Sc, K, dh), dtype),
        "v": jnp.zeros((L, batch, Sc, K, dh), dtype),
        "slot_pos": jnp.full((Sc,), -1, jnp.int32),
        "ssd": jnp.zeros((L, batch, Hs, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, di + 2 * G * N), dtype),
    }


def prefill(params, batch, cfg: ModelConfig, rules, max_len: int):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = _with_meta(params, tokens, cfg, rules)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    cache = init_cache(cfg, B, max_len)
    M, W = cfg.n_meta_tokens, cache["k"].shape[2] - cfg.n_meta_tokens

    def body(x, lp):
        h = ll.rms_norm(x, lp["ln1"])
        a, (k, v) = ll.attention(h, lp["attn"], cfg, rules,
                                 positions=positions, window=cfg.window,
                                 prefix_len=M, return_kv=True)
        s, conv_st, ssd_st = ssm_mod._mix(h, lp["ssm"], cfg, rules)
        y = 0.5 * (ll.rms_norm(a, lp["norm_attn"]) +
                   ll.rms_norm(s, lp["norm_ssm"]))
        x = x + y
        x = x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)
        return x, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                   conv_st.astype(jnp.bfloat16), ssd_st.astype(jnp.float32))

    x, (ks, vs, convs, ssds) = jax.lax.scan(body, x, params["layers"])

    # Cache layout: [meta | ring window]. Fill meta slots + the window tail.
    slot_pos = jnp.full((M + W,), -1, jnp.int32)
    slot_pos = slot_pos.at[:M].set(jnp.arange(M))
    tail = min(W, S - M)
    tail_pos = jnp.arange(S - tail, S)
    ring_slots = M + (tail_pos - M) % W
    k_cache = cache["k"].at[:, :, :M].set(ks[:, :, :M])
    v_cache = cache["v"].at[:, :, :M].set(vs[:, :, :M])
    k_cache = k_cache.at[:, :, ring_slots].set(ks[:, :, tail_pos])
    v_cache = v_cache.at[:, :, ring_slots].set(vs[:, :, tail_pos])
    slot_pos = slot_pos.at[ring_slots].set(tail_pos)

    x = ll.rms_norm(x[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos,
                    "ssd": ssds, "conv": convs}


def decode(params, cache, token, pos, cfg: ModelConfig,
           rules: ShardingRules | None):
    """pos counts INCLUDING the meta prefix (first real token is at
    pos = n_meta_tokens + prompt_len)."""
    x = params["embed"].astype(cfg.dtype)[token]
    x = constrain(x, rules, "decode_batch", None, None)
    M = cfg.n_meta_tokens
    Sc = cache["k"].shape[2]
    W = Sc - M
    slot = M + (pos - M) % W
    slot_pos = cache["slot_pos"].at[slot].set(pos)
    # Keys valid if written, within window, or meta.
    valid = (slot_pos >= 0) & (
        (jnp.arange(Sc) < M) | (slot_pos > pos - cfg.window))

    def body(x, inp):
        lp, ck, cv, conv_st, ssd_st = inp
        h = ll.rms_norm(x, lp["ln1"])
        # attention against the ring cache
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(h.dtype))
        k_new = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(h.dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(h.dtype))
        cos, sin = ll.rotary(jnp.full((x.shape[0], 1), pos), cfg.dh,
                             cfg.rope_theta)
        q = ll.apply_rope(q, cos, sin)
        k_new = ll.apply_rope(k_new, cos, sin)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k_new.astype(ck.dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v_new.astype(cv.dtype), slot, 1)
        H, K = cfg.n_heads, cfg.n_kv_heads
        qg = q.reshape(x.shape[0], 1, K, H // K, cfg.dh)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, ck) / jnp.sqrt(1.0 * cfg.dh)
        scores = jnp.where(valid[None, None, None, None, :], scores, ll.NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(h.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", probs, cv)
        a = jnp.einsum("bshk,hkd->bsd", o.reshape(x.shape[0], 1, H, cfg.dh),
                       lp["attn"]["wo"].astype(h.dtype))
        s, new_conv, new_ssd = ssm_mod._mix(
            h, lp["ssm"], cfg, rules, conv_state=conv_st, ssd_state=ssd_st,
            step=True)
        y = 0.5 * (ll.rms_norm(a, lp["norm_attn"]) +
                   ll.rms_norm(s, lp["norm_ssm"]))
        x = x + y
        x = x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)
        return x, (ck, cv, new_conv, new_ssd)

    x, (ks, vs, convs, ssds) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["conv"], cache["ssd"]))
    x = ll.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"k": ks, "v": vs, "slot_pos": slot_pos,
                    "ssd": ssds, "conv": convs}
