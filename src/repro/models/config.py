"""Unified model configuration for the 10 assigned architectures.

One dataclass covers the whole zoo; family-specific fields are ignored by
families that do not use them. ``kind`` selects the stack:

  decoder  — dense decoder-only LM (GQA + rotary + SwiGLU; optional QKV bias)
  encdec   — encoder-decoder (seamless backbone; audio frontend stubbed)
  moe      — decoder with routed-expert FFN (optional shared experts)
  ssm      — attention-free Mamba-2 (SSD) stack
  hybrid   — Hymba-style parallel attention + SSM heads per layer
  vlm      — decoder LM consuming a stub patch-embedding prefix
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                  # decoder | encdec | moe | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int               # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int = 0          # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0            # 0 = full causal; >0 = sliding window

    # encoder-decoder
    n_enc_layers: int = 0
    frontend: str | None = None   # "audio" | "vision" (stub frontends)
    frontend_len: int = 0         # frames/patches emitted by the stub

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0          # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25

    # SSM (Mamba-2 SSD)
    d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (Hymba)
    n_meta_tokens: int = 0
    ssm_ratio: float = 0.5     # fraction of layer width carried by SSM heads

    # MoE dispatch grouping (1 = global dispatch; >1 = data-local groups,
    # keeping routing gathers/scatters shard-local — see §Perf)
    moe_groups: int = 1

    # training
    dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = False
    # sequences >= this use blockwise (flash-style) attention in the XLA
    # path; the Pallas kernel replaces both paths on real TPUs.
    flash_threshold: int = 8192

    def __post_init__(self):
        if self.kind in ("decoder", "encdec", "moe", "hybrid", "vlm"):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.kind == "moe":
            assert self.n_experts > 0 and self.top_k > 0 and self.d_expert > 0
        if self.kind in ("ssm", "hybrid"):
            assert self.d_state > 0

    @property
    def dh(self) -> int:
        """Attention head dim."""
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def params_dense(self) -> int:
        """Approximate parameter count (reported in DESIGN.md; the exact
        count comes from the initialized tree)."""
        D, V, L = self.d_model, self.vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.kind == "ssm":
            per = 2 * D * self.d_inner_ssm + self.d_inner_ssm * (
                2 * self.d_state + 3)
            return emb + L * per
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * self.dh + \
            self.n_heads * self.dh * D
        if self.kind == "moe":
            ffn = 3 * D * self.d_expert * (self.n_experts +
                                           self.n_shared_experts) + \
                D * self.n_experts
        else:
            ffn = 3 * D * self.d_ff
        return emb + L * (attn + ffn)

    @property
    def active_params(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if self.kind != "moe":
            return self.params_dense
        D, L = self.d_model, self.n_layers
        emb = self.vocab * D * (1 if self.tie_embeddings else 2)
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * self.dh + \
            self.n_heads * self.dh * D
        ffn = 3 * D * self.d_expert * (self.top_k + self.n_shared_experts) + \
            D * self.n_experts
        return emb + L * (attn + ffn)
