from repro.models.api import Model, build
from repro.models.config import ModelConfig

__all__ = ["Model", "ModelConfig", "build"]
