"""Model zoo entry point: ``build(cfg)`` returns a uniform Model facade.

Every family exposes init/axes/forward/prefill/decode with the same
signatures, so the trainer, server, dry-run, and fleet scheduler are
architecture-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules
from repro.distributed.xent import cross_entropy
from repro.models import decoder, encdec, hybrid, ssm
from repro.models.config import ModelConfig

__all__ = ["Model", "build"]

_FAMILIES = {
    "decoder": decoder,
    "moe": decoder,
    "vlm": decoder,
    "encdec": encdec,
    "ssm": ssm,
    "hybrid": hybrid,
}

AUX_LOSS_WEIGHT = 0.01  # MoE load-balance loss weight


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _mod: Any

    def init(self, key) -> dict:
        return self._mod.init(self.cfg, key)

    def axes(self) -> dict:
        return self._mod.axes(self.cfg)

    def forward(self, params, batch, rules: ShardingRules | None = None):
        return self._mod.forward(params, batch, self.cfg, rules)

    def loss(self, params, batch, rules: ShardingRules | None = None):
        """Mean next-token cross entropy (+ MoE aux) over batch['labels']."""
        logits, aux = self.forward(params, batch, rules)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:
            # VLM: drop the vision-prefix positions.
            logits = logits[:, -labels.shape[1]:, :]
        loss = cross_entropy(logits, labels, rules)
        return loss + AUX_LOSS_WEIGHT * aux

    def prefill(self, params, batch, rules: ShardingRules | None = None,
                max_len: int | None = None):
        max_len = max_len or batch["tokens"].shape[1]
        return self._mod.prefill(params, batch, self.cfg, rules, max_len)

    def decode(self, params, cache, token, pos,
               rules: ShardingRules | None = None):
        return self._mod.decode(params, cache, token, pos, self.cfg, rules)

    def init_cache(self, batch: int, max_len: int, **kw):
        return self._mod.init_cache(self.cfg, batch, max_len, **kw)

    def cache_axes(self) -> dict:
        return self._mod.cache_axes(self.cfg)

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))


def build(cfg: ModelConfig) -> Model:
    if cfg.kind not in _FAMILIES:
        raise ValueError(f"unknown model kind {cfg.kind!r}")
    return Model(cfg=cfg, _mod=_FAMILIES[cfg.kind])
