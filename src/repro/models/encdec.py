"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``batch["frames"]`` carries
precomputed frame embeddings (B, F, d_model) — the only learned frontend
piece is a projection. The encoder is bidirectional; the decoder is causal
with per-layer cross attention over the encoder output. Decode shapes run
the DECODER against a cached encoder output (the encoder is not re-run per
token).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import layers as ll
from repro.models.config import ModelConfig

__all__ = ["init", "axes", "forward", "prefill", "decode", "init_cache",
           "encode"]


def _attn_params(key, D, H, K, dh):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": ll.dense_init(k1, (D, H, dh)),
        "wk": ll.dense_init(k2, (D, K, dh)),
        "wv": ll.dense_init(k3, (D, K, dh)),
        "wo": ll.dense_init(k4, (H, dh, D), in_axis=(0, 1)),
    }


def _ffn_params(key, D, F):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": ll.dense_init(k1, (D, F)),
        "w_up": ll.dense_init(k2, (D, F)),
        "w_down": ll.dense_init(k3, (F, D)),
    }


_ATTN_AXES = {
    "wq": ("layers", "fsdp", "heads", None),
    "wk": ("layers", "fsdp", "kv_heads", None),
    "wv": ("layers", "fsdp", "kv_heads", None),
    "wo": ("layers", "heads", None, "fsdp"),
}
_FFN_AXES = {
    "w_gate": ("layers", "fsdp", "d_ff"),
    "w_up": ("layers", "fsdp", "d_ff"),
    "w_down": ("layers", "d_ff", "fsdp"),
}


def init(cfg: ModelConfig, key) -> dict:
    D, H, K, dh, F, V = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh,
                         cfg.d_ff, cfg.vocab)
    ke, kd, kl1, kl2, kh, kf = jax.random.split(key, 6)

    def enc_layer(k):
        ka, kf_ = jax.random.split(k)
        return {
            "ln1": jnp.ones((D,), jnp.float32),
            "ln2": jnp.ones((D,), jnp.float32),
            "attn": _attn_params(ka, D, H, K, dh),
            "ffn": _ffn_params(kf_, D, F),
        }

    def dec_layer(k):
        ka, kc, kf_ = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((D,), jnp.float32),
            "ln_cross": jnp.ones((D,), jnp.float32),
            "ln2": jnp.ones((D,), jnp.float32),
            "attn": _attn_params(ka, D, H, K, dh),
            "cross": _attn_params(kc, D, H, K, dh),
            "ffn": _ffn_params(kf_, D, F),
        }

    enc = [enc_layer(k) for k in jax.random.split(kl1, cfg.n_enc_layers)]
    dec = [dec_layer(k) for k in jax.random.split(kl2, cfg.n_layers)]
    return {
        "frame_proj": ll.dense_init(kf, (D, D)),
        "embed": ll.dense_init(kd, (V, D), in_axis=1),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": jnp.ones((D,), jnp.float32),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": jnp.ones((D,), jnp.float32),
        "lm_head": ll.dense_init(kh, (D, V)),
    }


def axes(cfg: ModelConfig) -> dict:
    return {
        "frame_proj": ("fsdp", None),
        "embed": ("vocab", "fsdp"),
        "enc_norm": (None,),
        "final_norm": (None,),
        "lm_head": ("fsdp", "vocab"),
        "enc_layers": {
            "ln1": ("layers", None), "ln2": ("layers", None),
            "attn": dict(_ATTN_AXES), "ffn": dict(_FFN_AXES),
        },
        "dec_layers": {
            "ln1": ("layers", None), "ln_cross": ("layers", None),
            "ln2": ("layers", None),
            "attn": dict(_ATTN_AXES), "cross": dict(_ATTN_AXES),
            "ffn": dict(_FFN_AXES),
        },
    }


def encode(params, frames, cfg: ModelConfig, rules):
    x = jnp.einsum("bfd,de->bfe", frames.astype(cfg.dtype),
                   params["frame_proj"].astype(cfg.dtype))
    x = constrain(x, rules, "batch", "seq", None)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def block(x, lp, cfg, rules, positions):
        y = ll.attention(ll.rms_norm(x, lp["ln1"]), lp["attn"], cfg, rules,
                         positions=positions, causal=False)
        x = x + y
        return x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)

    if cfg.remat:
        block = jax.checkpoint(
            block, static_argnums=(2, 3))

    def body(x, lp):
        return block(x, lp, cfg, rules, positions), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return ll.rms_norm(x, params["enc_norm"])


def _dec_block(x, lp, enc_out, cfg, rules, positions):
    y = ll.attention(ll.rms_norm(x, lp["ln1"]), lp["attn"], cfg, rules,
                     positions=positions, causal=True)
    x = x + y
    y = ll.attention(ll.rms_norm(x, lp["ln_cross"]), lp["cross"], cfg, rules,
                     kv_source=enc_out)
    x = x + y
    return x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)


def forward(params, batch, cfg: ModelConfig, rules: ShardingRules | None):
    enc_out = encode(params, batch["frames"], cfg, rules)
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", None)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    block = _dec_block
    if cfg.remat:
        block = jax.checkpoint(
            block, static_argnums=(3, 4))

    def body(x, lp):
        return block(x, lp, enc_out, cfg, rules, positions), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = ll.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "k": ("layers", "cache_batch", "cache_seq", None, None),
        "v": ("layers", "cache_batch", "cache_seq", None, None),
        "cross_k": ("layers", "cache_batch", "cache_seq", None, None),
        "cross_v": ("layers", "cache_batch", "cache_seq", None, None),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16):
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    return {
        "k": jnp.zeros((L, batch, max_len, K, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, K, dh), dtype),
        "cross_k": jnp.zeros((L, batch, enc_len, K, dh), dtype),
        "cross_v": jnp.zeros((L, batch, enc_len, K, dh), dtype),
    }


def prefill(params, batch, cfg: ModelConfig, rules, max_len: int):
    """Encode the frames, run the decoder prompt, build both caches."""
    enc_out = encode(params, batch["frames"], cfg, rules)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", None)
    positions = jnp.arange(S)[None, :]
    cache = init_cache(cfg, B, max_len, enc_out.shape[1])

    def body(x, lp):
        y, (k, v) = ll.attention(ll.rms_norm(x, lp["ln1"]), lp["attn"], cfg,
                                 rules, positions=positions, return_kv=True)
        x = x + y
        y, (ck, cv) = ll.attention(ll.rms_norm(x, lp["ln_cross"]), lp["cross"],
                                   cfg, rules, kv_source=enc_out,
                                   return_kv=True)
        x = x + y
        x = x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)
        return x, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                   ck.astype(jnp.bfloat16), cv.astype(jnp.bfloat16))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, 2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, 2)
    cache["cross_k"], cache["cross_v"] = cks, cvs
    x = ll.rms_norm(x[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, cache


def decode(params, cache, token, pos, cfg: ModelConfig,
           rules: ShardingRules | None):
    x = params["embed"].astype(cfg.dtype)[token]
    x = constrain(x, rules, "decode_batch", None, None)

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        y, ck, cv = ll.attention_decode(
            ll.rms_norm(x, lp["ln1"]), lp["attn"], ck, cv, pos, cfg, rules)
        x = x + y
        y, _, _ = ll.attention_decode(
            ll.rms_norm(x, lp["ln_cross"]), lp["cross"], xk, xv, pos, cfg,
            rules, cross=True)
        x = x + y
        x = x + ll.swiglu(ll.rms_norm(x, lp["ln2"]), lp["ffn"], rules)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = ll.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
