"""Compiled-program introspection: flops/bytes/collective counts per key.

Engine call sites announce every cached jit program they fetch via
``record_jit("engine.eval.chain:sharded", fn, *args)``.  Outside a
:func:`capture` context that hook is a single ContextVar read.  Inside
one, the first announcement of each key lowers and compiles ``fn`` on the
announced example arguments and records:

* ``flops`` / ``bytes`` / per-kind collective **bytes** from
  ``repro.launch.hlo_analysis.analyze`` (the cost-model pass the roofline
  section already uses), and
* per-kind collective **op counts** from :func:`collective_counts` —
  the same regex family the shard tests assert with, turned into a
  standing metric (PR 6's placement contract: zero collectives in the
  eval/synth hot loop, exactly one all-reduce in the streamed fold).

Subsequent announcements of the same key only bump its ``captures``
counter — a per-key compile-cache hit count.  :func:`factory_caches`
additionally snapshots the ``lru_cache`` hit/miss stats of every
compiled-fn factory in the engine/learn stack, so a snapshot shows both
*what* was compiled and *how often* each cache was re-entered.

Keys in use (see DESIGN.md Section 10): ``plan.device.full``,
``scenarios.synth:<kind>[:sharded]``, ``scenarios.views[:sharded]``,
``engine.eval.chain[:sharded]``, ``engine.eval.task[:sharded]``,
``engine.eval.chain_ps[:sharded]``, ``engine.eval.task_ps[:sharded]``
(the per-scenario-availability refinement programs, sharded over both
axes of a 2-D ``GridMesh``), ``learn.scan:<kind>``,
``learn.fold:sharded``.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from contextvars import ContextVar

__all__ = [
    "CompiledRegistry",
    "CompileWatch",
    "capture",
    "capturing",
    "collective_counts",
    "current_registry",
    "factory_caches",
    "hlo_metrics",
    "record_jit",
]

_CAPTURE: ContextVar["CompiledRegistry | None"] = ContextVar(
    "repro_obs_compiled", default=None
)

# Op-count regexes over lowered (scheduled) HLO text.  ``-start`` variants
# (async collectives) count as the op itself; ``-done`` halves do not.
_COLLECTIVE_OPS = {
    "all-reduce": r"\ball-reduce(?:-start)?\(",
    "all-gather": r"\ball-gather(?:-start)?\(",
    "reduce-scatter": r"\breduce-scatter(?:-start)?\(",
    "all-to-all": r"\ball-to-all(?:-start)?\(",
    "collective-permute": r"\bcollective-permute(?:-start)?\(",
}


def collective_counts(hlo_text):
    """Per-kind collective op counts (plus ``"total"``) in HLO text."""
    txt = hlo_text.lower()
    out = {kind: len(re.findall(pat, txt)) for kind, pat in _COLLECTIVE_OPS.items()}
    out["total"] = sum(out.values())
    return out


def hlo_metrics(fn, *args, **kwargs):
    """Lower+compile a jitted ``fn`` on example args and analyze the HLO.

    Returns ``{"flops", "bytes", "collective_bytes", "collective_counts",
    "warnings"}``.  This is the programmatic face of the shard tests'
    "grep the compiled text" assertions.
    """
    from repro.launch.hlo_analysis import analyze

    txt = fn.lower(*args, **kwargs).compile().as_text()
    a = analyze(txt)
    return {
        "flops": a["flops"],
        "bytes": a["bytes"],
        "collective_bytes": a["collectives"],
        "collective_counts": collective_counts(txt),
        "warnings": a["warnings"],
    }


class CompiledRegistry:
    """key -> hlo metrics for every program announced under a capture."""

    def __init__(self):
        self.entries: dict[str, dict] = {}

    def record(self, key, fn, args=(), kwargs=None):
        entry = self.entries.get(key)
        if entry is not None:
            entry["captures"] += 1
            return entry
        try:
            entry = hlo_metrics(fn, *args, **(kwargs or {}))
        except Exception as exc:  # keep capture best-effort: never break the run
            entry = {"error": f"{type(exc).__name__}: {exc}"}
        entry["captures"] = 1
        self.entries[key] = entry
        return entry

    def __getitem__(self, key):
        return self.entries[key]

    def __contains__(self, key):
        return key in self.entries

    def snapshot(self):
        return {"programs": dict(self.entries), "factory_caches": factory_caches()}

    def table(self):
        """Human-readable program x {flops, bytes, collectives} table."""
        rows = [f"{'program':<34} {'gflops':>9} {'MB':>9} {'collectives':>12}"]
        for key in sorted(self.entries):
            e = self.entries[key]
            if "error" in e:
                rows.append(f"{key:<34} <{e['error']}>")
                continue
            cc = e["collective_counts"]
            kinds = ",".join(f"{k}x{n}" for k, n in cc.items()
                             if k != "total" and n) or "none"
            rows.append(
                f"{key:<34} {e['flops'] / 1e9:>9.3f} {e['bytes'] / 1e6:>9.2f} "
                f"{kinds:>12}"
            )
        return "\n".join(rows)


def record_jit(key, fn, *args, **kwargs):
    """Announce a compiled program fetch; no-op unless capturing."""
    reg = _CAPTURE.get()
    if reg is not None:
        reg.record(key, fn, args, kwargs)


@contextmanager
def capture(registry=None):
    """Enable compiled-program capture for the block; yields the registry."""
    reg = registry if registry is not None else CompiledRegistry()
    token = _CAPTURE.set(reg)
    try:
        yield reg
    finally:
        _CAPTURE.reset(token)


def current_registry():
    return _CAPTURE.get()


def capturing():
    return _CAPTURE.get() is not None


# lru_cache'd compiled-fn factories across the stack, plus the cross-call
# plan/view caches of ``repro.engine.cache``, snapshotted for the
# per-cache-key hit/miss/eviction counters.  Imported lazily: jax (and the
# engine) may be absent or expensive, and obs must stay import-light.
_FACTORIES = (
    ("scenarios.synth_fn", "repro.engine.scenarios", "_device_synth_fn"),
    ("scenarios.views_fn", "repro.engine.scenarios", "_device_views_fn"),
    ("plan.device_fns", "repro.engine.plan", "_device_plan_fns"),
    ("engine.sharded_fns", "repro.engine.backend_jax", "_sharded_fns"),
    ("learn.scan", "repro.learn.replay", "_compiled_scan"),
    ("learn.fold", "repro.learn.replay", "_sharded_fold"),
    ("engine.plan_cache", "repro.engine.cache", "PLAN_CACHE"),
    ("engine.view_cache", "repro.engine.cache", "VIEW_CACHE"),
)


def jit_factories():
    """The registered compiled-fn factory registry: (name, module, attr).

    The programmatic face of ``_FACTORIES`` — ``repro.analysis.programs``
    builds its canonical program inventory from the same factories this
    module snapshots cache stats for.
    """
    return _FACTORIES


def placement_violations(mesh=None, keys=None):
    """Failed §9-placement (and related) checks over the canonical programs.

    Delegates to the Layer-2 verifier in :mod:`repro.analysis.programs` —
    the single implementation of the placement contract — and returns only
    the failed :class:`CheckResult`s (empty list = contract holds).  Pass
    a 2-D ``GridMesh`` to assert the scenario x group placement (the
    refinement ``_ps`` programs included).
    """
    from repro.analysis.programs import verify_all

    return [c for c in verify_all(mesh=mesh, keys=keys) if not c.ok]


def factory_caches():
    """{name: {hits, misses, maxsize, currsize, evictions}} per cache.

    Every registered cache duck-types ``functools.lru_cache``'s
    ``cache_info()``.  Evictions are exact where the cache keeps a counter
    (the cross-call ``_LRU`` caches); for plain ``lru_cache`` factories
    they are the ``misses - currsize`` lower bound (every miss inserts, so
    anything not resident was evicted — exact as long as the cache was
    never cleared mid-run).
    """
    import importlib
    import sys

    out = {}
    for name, mod_name, attr in _FACTORIES:
        mod = sys.modules.get(mod_name)
        if mod is None:
            try:
                mod = importlib.import_module(mod_name)
            except Exception:
                continue
        fn = getattr(mod, attr, None)
        info = getattr(fn, "cache_info", None)
        if info is None:
            continue
        ci = info()
        out[name] = {
            "hits": ci.hits,
            "misses": ci.misses,
            "maxsize": ci.maxsize,
            "currsize": ci.currsize,
            "evictions": getattr(fn, "evictions",
                                 max(ci.misses - ci.currsize, 0)),
        }
    return out


class CompileWatch:
    """Count ACTUAL XLA backend compilations over a scope.

    ``jax.monitoring`` fires ``/jax/core/compile/backend_compile_duration``
    once per real backend compile and NOT on jit-cache hits, so this is
    the ground truth for "the warm path ran with zero compiles" — the
    cache-smoke CI gate (``bench_pipeline --only warm``).  Listeners
    cannot be deregistered individually on current jax, so one
    process-wide listener is installed on first use and watches are
    scoped by counting against a baseline.

        watch = CompileWatch()
        with watch:
            run_warm_path()
        assert watch.compiles == 0

    Degrades to counting nothing (and reporting ``supported=False``) when
    jax or its monitoring hooks are absent.
    """

    _installed = False
    _count = 0
    _EVENT = "/jax/core/compile/backend_compile_duration"

    @classmethod
    def _install(cls) -> bool:
        if cls._installed:
            return True
        try:
            import jax.monitoring as monitoring

            def _listener(name, secs, **kw):
                if name == cls._EVENT:
                    cls._count += 1

            monitoring.register_event_duration_secs_listener(_listener)
        except Exception:
            return False
        cls._installed = True
        return True

    def __init__(self):
        self.supported = self._install()
        self._base = 0
        self.compiles = 0

    def __enter__(self):
        self._base = type(self)._count
        return self

    def __exit__(self, *exc):
        self.compiles = type(self)._count - self._base
        return False
