"""Counter / gauge / histogram registry with labeled series (stdlib only).

The global :data:`METRICS` registry is off by default: hot-path call
sites guard with ``if METRICS.enabled:`` so a disabled registry costs one
attribute read per chunk.  ``METRICS.collecting()`` flips it on for a
block (``repro.obs.observe()`` does this for you), after which the engine
and learner record:

* ``engine.chunk_seconds`` histogram, labels ``phase={synth,eval}``,
  ``backend=...`` — per-chunk latency split.
* ``engine.scenarios_per_sec`` gauge, label ``backend`` — end-to-end
  streaming throughput of the last ``evaluate_grid`` call.
* ``scenarios.adaptive_escalations`` counter, label ``to=stage`` — one
  increment per adaptive-adversary stage transition (periods -> phases ->
  locked), plus ``scenarios.adaptive_chunks`` per chunk served per stage.
* ``learn.weight_entropy`` histogram, label ``learner`` — Shannon entropy
  (nats) of the learner's mean weight posterior per streamed chunk, and
  ``learn.top_weight`` gauge — the heaviest expert's share.
* ``engine.plan_cache`` counter, label ``event={hit,miss,evict}`` — the
  cross-call grid-plan cache (``repro.engine.cache.PLAN_CACHE``): one
  ``hit``/``miss`` per eval group looked up during ``build_grid_plan``,
  one ``evict`` per LRU ejection; ``engine.view_cache`` mirrors it for
  cached ``ScenarioBatch.stacked`` views.
* ``engine.delta_groups_rescored`` counter — eval groups actually
  re-scored by :func:`repro.engine.cache.evaluate_grid_delta` (the
  unchanged remainder was spliced from the previous result).

Snapshots (:meth:`MetricsRegistry.snapshot`) are plain JSON-able dicts
attached to ``EngineResult.obs`` / ``StreamLearnResult.obs`` and dumped
into the ``BENCH_*.json`` entries.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager

__all__ = ["METRICS", "Counter", "Gauge", "Histogram", "MetricsRegistry"]

# Geometric bucket upper bounds shared by every histogram: wide enough for
# seconds (1e-5 .. 1e3) and for unitless values like entropies.
_BUCKETS = tuple(10.0 ** (e / 2.0) for e in range(-10, 7))  # 1e-5 .. ~3.2e3


def _label_key(labels):
    return tuple(sorted(labels.items()))


class _Metric:
    kind = "metric"

    def __init__(self, name, registry):
        self.name = name
        self._registry = registry
        self._series = {}

    def _snapshot_series(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def snapshot(self):
        return {
            "kind": self.kind,
            "series": [
                {"labels": dict(k), **self._snapshot_value(v)}
                for k, v in sorted(self._series.items())
            ],
        }


class Counter(_Metric):
    kind = "counter"

    def inc(self, value=1.0, **labels):
        if not self._registry.enabled:
            return
        key = _label_key(labels)
        with self._registry._lock:
            self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels):
        return self._series.get(_label_key(labels), 0.0)

    def _snapshot_value(self, v):
        return {"value": v}


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value, **labels):
        if not self._registry.enabled:
            return
        with self._registry._lock:
            self._series[_label_key(labels)] = float(value)

    def value(self, **labels):
        return self._series.get(_label_key(labels))

    def _snapshot_value(self, v):
        return {"value": v}


class _Hist:
    __slots__ = ("count", "sum", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets = [0] * (len(_BUCKETS) + 1)

    def observe(self, v):
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for i, le in enumerate(_BUCKETS):
            if v <= le:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1


class Histogram(_Metric):
    kind = "histogram"

    def observe(self, value, **labels):
        if not self._registry.enabled:
            return
        key = _label_key(labels)
        with self._registry._lock:
            h = self._series.get(key)
            if h is None:
                h = self._series[key] = _Hist()
            h.observe(float(value))

    def stats(self, **labels):
        h = self._series.get(_label_key(labels))
        return None if h is None else self._snapshot_value(h)

    def _snapshot_value(self, h):
        return {
            "count": h.count,
            "sum": h.sum,
            "mean": (h.sum / h.count) if h.count else 0.0,
            "min": None if h.count == 0 else h.min,
            "max": None if h.count == 0 else h.max,
            "buckets": [
                {"le": le, "count": c}
                for le, c in zip(list(_BUCKETS) + [math.inf], h.buckets)
                if c
            ],
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Name -> metric, get-or-create, with a global enable switch."""

    def __init__(self):
        self.enabled = False
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, name, kind):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = _KINDS[kind](name, self)
        if m.kind != kind:
            raise TypeError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name) -> Histogram:
        return self._get(name, "histogram")

    def snapshot(self):
        """JSON-able {name: {kind, series: [...]}} for all non-empty metrics."""
        return {
            name: m.snapshot()
            for name, m in sorted(self._metrics.items())
            if m._series
        }

    def reset(self):
        with self._lock:
            self._metrics.clear()

    @contextmanager
    def collecting(self, reset=False):
        """Enable recording for the block (restores the prior state)."""
        if reset:
            self.reset()
        prev = self.enabled
        self.enabled = True
        try:
            yield self
        finally:
            self.enabled = prev


METRICS = MetricsRegistry()
