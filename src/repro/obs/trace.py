"""Context-var span tracer (stdlib only).

Design constraints (ISSUE 7 / DESIGN.md Section 10):

* ``span(name, **attrs)`` is the *single* timing primitive for the whole
  stack.  It always measures wall seconds — after the ``with`` block,
  ``sp.seconds`` holds the duration, and that exact float is what the
  engine folds into ``EngineResult.timings``.  This is why the timings
  dict is bit-for-bit identical to the span-derived totals: there is only
  one measurement.
* When no tracer is installed the overhead is one ContextVar read plus
  two ``perf_counter_ns`` calls — the same cost as the ad-hoc timers the
  spans replaced.
* When a :func:`trace` context is active, finished spans are appended to
  the tracer as flat :class:`SpanRecord` rows (id/parent/name/ts/seconds/
  tid/attrs).  Nesting is tracked through a second ContextVar so the
  records form a tree; generators iterated inside a span parent their
  spans correctly (plain generators run in the caller's context).

Exporters: :meth:`Tracer.to_chrome` emits the Chrome trace-event JSON
dialect (``ph: "X"`` complete events with ts/dur in microseconds) which
https://ui.perfetto.dev loads directly; :meth:`Tracer.to_jsonl` emits one
self-contained JSON object per line for grep/jq pipelines.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar

__all__ = [
    "Span",
    "SpanRecord",
    "Tracer",
    "current_tracer",
    "span",
    "trace",
    "tracing_enabled",
]

_TRACER: ContextVar["Tracer | None"] = ContextVar("repro_obs_tracer", default=None)
_ACTIVE: ContextVar["Span | None"] = ContextVar("repro_obs_active_span", default=None)


@dataclasses.dataclass
class SpanRecord:
    """One finished span, flattened for export."""

    id: int
    parent: int | None
    name: str
    ts: float  # seconds since tracer start
    seconds: float
    tid: int
    attrs: dict


class Span:
    """A timed region.  Usable with or without an active tracer."""

    __slots__ = ("name", "attrs", "seconds", "id", "_t0", "_tracer", "_token", "_parent_id")

    def __init__(self, name, attrs):
        self.name = name
        self.attrs = attrs
        self.seconds = 0.0
        self.id = -1

    def set(self, **attrs):
        """Attach/overwrite attributes after the span was opened."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tracer = _TRACER.get()
        self._tracer = tracer
        if tracer is not None:
            self.id = tracer._next_id()
            parent = _ACTIVE.get()
            self._parent_id = parent.id if parent is not None else None
            self._token = _ACTIVE.set(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        self.seconds = (t1 - self._t0) * 1e-9
        tracer = self._tracer
        if tracer is not None:
            _ACTIVE.reset(self._token)
            tracer._record(self, self._t0)
        return False


def span(name, **attrs):
    """Open a timed (and, under :func:`trace`, recorded) region::

        with span("eval", backend="jax", chunk=k) as sp:
            ...
        timings["eval"] += sp.seconds
    """
    return Span(name, attrs)


class Tracer:
    """Collects finished spans; thread-safe append, flat storage."""

    def __init__(self):
        self.spans: list[SpanRecord] = []
        self.pid = os.getpid()
        self._lock = threading.Lock()
        self._ids = itertools.count()
        self._t0 = time.perf_counter_ns()

    def _next_id(self):
        return next(self._ids)

    def _record(self, sp: Span, t0_ns: int):
        rec = SpanRecord(
            id=sp.id,
            parent=sp._parent_id,
            name=sp.name,
            ts=(t0_ns - self._t0) * 1e-9,
            seconds=sp.seconds,
            tid=threading.get_ident(),
            attrs=dict(sp.attrs),
        )
        with self._lock:
            self.spans.append(rec)

    # -- queries ----------------------------------------------------------
    def __len__(self):
        return len(self.spans)

    def named(self, name):
        """Records with this span name, in completion order."""
        return [r for r in self.spans if r.name == name]

    def totals(self):
        """name -> summed seconds, accumulated in completion order.

        Spans finish in the same order the engine folds them into
        ``EngineResult.timings``, so for a given name this is the same
        left-to-right float sum — bit-for-bit equal on the numpy path.
        """
        out: dict[str, float] = {}
        for r in self.spans:
            out[r.name] = out.get(r.name, 0.0) + r.seconds
        return out

    def children(self, span_id):
        return [r for r in self.spans if r.parent == span_id]

    def roots(self):
        return [r for r in self.spans if r.parent is None]

    # -- exporters --------------------------------------------------------
    def to_chrome(self):
        """Chrome trace-event JSON (dict) — load at ui.perfetto.dev."""
        tids = {}
        events = []
        for r in self.spans:
            tid = tids.setdefault(r.tid, len(tids))
            args = {k: _json_safe(v) for k, v in r.attrs.items()}
            args["span_id"] = r.id
            if r.parent is not None:
                args["parent_id"] = r.parent
            events.append(
                {
                    "name": r.name,
                    "cat": "repro",
                    "ph": "X",
                    "ts": r.ts * 1e6,
                    "dur": r.seconds * 1e6,
                    "pid": self.pid,
                    "tid": tid,
                    "args": args,
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_jsonl(self):
        """One JSON object per line: id/parent/name/ts/dur/tid/attrs."""
        lines = []
        for r in self.spans:
            lines.append(
                json.dumps(
                    {
                        "id": r.id,
                        "parent": r.parent,
                        "name": r.name,
                        "ts": r.ts,
                        "dur": r.seconds,
                        "pid": self.pid,
                        "tid": r.tid,
                        "attrs": {k: _json_safe(v) for k, v in r.attrs.items()},
                    },
                    sort_keys=True,
                )
            )
        return "\n".join(lines) + ("\n" if lines else "")

    def save(self, path):
        """Write the Chrome/Perfetto trace JSON to ``path``."""
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)
        return path

    def save_jsonl(self, path):
        with open(path, "w") as fh:
            fh.write(self.to_jsonl())
        return path


def _json_safe(v):
    """Coerce span attributes to JSON-native types (numpy scalars -> py)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)
    if item is not None:
        try:
            return _json_safe(item())
        except Exception:
            pass
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return str(v)


@contextmanager
def trace(tracer=None):
    """Install ``tracer`` (or a fresh :class:`Tracer`) for the block."""
    tr = tracer if tracer is not None else Tracer()
    token = _TRACER.set(tr)
    try:
        yield tr
    finally:
        _TRACER.reset(token)


def current_tracer():
    return _TRACER.get()


def tracing_enabled():
    return _TRACER.get() is not None
