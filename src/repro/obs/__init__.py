"""repro.obs — zero-dependency observability for the engine/learn stack.

Three cooperating layers, all stdlib-only so the engine can import them
unconditionally (DESIGN.md Section 10):

* :mod:`repro.obs.trace` — a context-var span tracer.  ``span("eval",
  chunk=k)`` always measures wall seconds (``sp.seconds`` after exit, the
  single timing source `EngineResult.timings` is derived from); full span
  records (nesting, attributes, timestamps) are captured only while a
  ``trace()`` context is active, and export to Chrome-trace/Perfetto JSON
  or a flat JSONL event log.
* :mod:`repro.obs.compiled` — compile-time introspection.  Engine call
  sites announce every cached jit program via ``record_jit(key, fn,
  *args)``; inside a ``capture()`` context the program is lowered,
  compiled, and analyzed (flops / bytes / collective op counts via
  ``launch.hlo_analysis``), turning the one-off HLO assertions from the
  shard tests into a standing metric.  Outside a capture context the hook
  is a single context-var read.
* :mod:`repro.obs.metrics` — a counter/gauge/histogram registry with
  labeled series (chunk latency, scenarios/sec, adaptive-adversary
  escalations, learner weight entropy), snapshotted into
  ``EngineResult.obs`` / ``StreamLearnResult.obs``.

``observe()`` composes all three for the common "turn everything on"
case; ``maybe_snapshot()`` is what the engine attaches to results.
"""
from __future__ import annotations

import contextlib
from types import SimpleNamespace

from . import compiled, metrics, trace
from .compiled import CompiledRegistry, capture, record_jit
from .metrics import METRICS, MetricsRegistry
from .trace import Span, Tracer, current_tracer, span, trace as tracing, tracing_enabled

__all__ = [
    "CompiledRegistry",
    "METRICS",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "capture",
    "compiled",
    "current_tracer",
    "maybe_snapshot",
    "metrics",
    "observe",
    "record_jit",
    "span",
    "trace",
    "tracing",
    "tracing_enabled",
]


@contextlib.contextmanager
def observe(*, spans=True, counters=True, programs=False, tracer=None):
    """Enable span tracing, metrics collection, and (optionally) compiled-
    program capture for the dynamic extent of the block.

    Yields a namespace with ``tracer`` (:class:`Tracer` or None),
    ``metrics`` (the global :data:`METRICS` registry), and ``compiled``
    (:class:`CompiledRegistry` or None).
    """
    with contextlib.ExitStack() as stack:
        tr = stack.enter_context(trace.trace(tracer)) if spans else None
        if counters:
            stack.enter_context(METRICS.collecting())
        reg = stack.enter_context(compiled.capture()) if programs else None
        yield SimpleNamespace(tracer=tr, metrics=METRICS, compiled=reg)


def maybe_snapshot():
    """Snapshot of whatever observability collection is currently active.

    Returns ``{"metrics": ..., "compiled": ...}`` with only the active
    layers present, or ``None`` when nothing is collecting — this is what
    ``evaluate_grid`` / ``replay_stream`` attach to their results.
    """
    out = {}
    if METRICS.enabled:
        out["metrics"] = METRICS.snapshot()
    reg = compiled.current_registry()
    if reg is not None:
        out["compiled"] = reg.snapshot()
    return out or None
