"""Fleet orchestrator: the paper's Algorithm 2 + TOLA driving Layer B jobs.

Given a stream of training/eval DAG jobs (sched.jobs), the orchestrator:
  1. transforms each DAG to a chain (Nagarajan),
  2. learns {beta, beta_0, bid} online (TOLA) against the preemptible-pod
     market,
  3. allocates reserved (self-owned) pods via policy (12), preemptible pods
     while flexibility holds, and on-demand pods after each stage's turning
     point (Def. 3.2),
  4. exposes per-job schedules so the elastic trainer knows when a stage
     must migrate from preemptible to on-demand capacity (checkpoint +
     restart on the new pool — launch/train.py's preemption path).

This is the integration point between the paper (Layer A) and the training
substrate (Layer B): z_i comes from the dry-run roofline, preemption events
come from the market trace, and the cost report prices the whole fleet.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    Policy,
    SpotMarket,
    run_tola,
    selfowned_policies,
    spot_od_policies,
    transform,
)
from repro.core.scheduler import build_plans, run_jobs
from repro.core.types import ChainJob, DAGJob
from repro.sched.fleet import FleetSpec

__all__ = ["FleetOrchestrator", "ScheduleReport"]


@dataclasses.dataclass
class ScheduleReport:
    total_cost: float
    unit_cost: float
    spot_fraction: float
    selfowned_fraction: float
    ondemand_fraction: float
    best_policy: Policy
    weights_top: float


class FleetOrchestrator:
    def __init__(self, fleet: FleetSpec, horizon_units: float,
                 market_seed: int = 0):
        self.fleet = fleet
        self.market = SpotMarket(horizon_units, seed=market_seed)

    def schedule(self, dag_jobs: list[DAGJob], seed: int = 0,
                 learn: bool = True) -> ScheduleReport:
        chains: list[ChainJob] = [transform(j) for j in dag_jobs]
        r = self.fleet.reserved_pods
        grid = selfowned_policies() if r > 0 else spot_od_policies()
        if learn:
            res = run_tola(chains, grid, self.market, r_total=r, seed=seed)
            costs = res.realized
            best = grid[int(np.argmax(res.weights))]
            top_w = float(res.weights.max())
        else:
            best_alpha, best, costs = np.inf, grid[0], None
            for pol in grid:
                c = run_jobs(chains, pol, self.market, r_total=r)
                a = c.average_unit_cost()
                if a < best_alpha:
                    best_alpha, best, costs = a, pol, c
            top_w = 1.0
        Z = costs.workload.sum()
        work = costs.spot_work.sum() + costs.ondemand_work.sum() + \
            costs.selfowned_work.sum()
        return ScheduleReport(
            total_cost=float(costs.total_cost.sum()),
            unit_cost=float(costs.total_cost.sum() / Z),
            spot_fraction=float(costs.spot_work.sum() / max(work, 1e-9)),
            selfowned_fraction=float(
                costs.selfowned_work.sum() / max(work, 1e-9)),
            ondemand_fraction=float(
                costs.ondemand_work.sum() / max(work, 1e-9)),
            best_policy=best,
            weights_top=top_w,
        )

    def stage_plan(self, dag_job: DAGJob, policy: Policy):
        """Planned windows + turning points for one job under a policy —
        what the elastic trainer consumes (when to expect migration)."""
        chain = transform(dag_job)
        plan = build_plans([chain], policy, self.fleet.reserved_pods)
        return plan
