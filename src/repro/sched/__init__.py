from repro.sched.fleet import FleetSpec, estimate_stage_seconds
from repro.sched.jobs import training_job_dag
from repro.sched.orchestrator import FleetOrchestrator
from repro.sched.straggler import StragglerDetector

__all__ = ["FleetSpec", "estimate_stage_seconds", "training_job_dag",
           "FleetOrchestrator", "StragglerDetector"]
