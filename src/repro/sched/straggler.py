"""Straggler mitigation: heartbeat-quantile detection + speculative
re-execution.

At pod scale the slowest worker sets the step time; a pod whose heartbeat
latency exceeds q75 + k * IQR for ``patience`` consecutive beats is marked a
straggler and its stage is speculatively relaunched on spare capacity — the
first copy to finish wins (classic MapReduce-style speculation, applied at
the pod/stage granularity the paper's tasks have).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerDetector"]


@dataclasses.dataclass
class StragglerDetector:
    k_iqr: float = 3.0
    patience: int = 3

    def __post_init__(self):
        self._strikes: dict[int, int] = {}

    def update(self, heartbeat_s: np.ndarray) -> list[int]:
        """heartbeat_s: (n_pods,) latest per-pod step/heartbeat latencies.
        Returns pod ids to speculatively re-launch."""
        hb = np.asarray(heartbeat_s, dtype=np.float64)
        q25, q75 = np.percentile(hb, [25, 75])
        thresh = q75 + self.k_iqr * max(q75 - q25, 1e-9)
        out = []
        for pod, lat in enumerate(hb):
            if lat > thresh:
                self._strikes[pod] = self._strikes.get(pod, 0) + 1
                if self._strikes[pod] >= self.patience:
                    out.append(pod)
                    self._strikes[pod] = 0
            else:
                self._strikes[pod] = 0
        return out

    def should_speculate(self, progress: np.ndarray,
                         threshold: float = 0.7) -> list[int]:
        """Stage-level speculation: relaunch copies of stages whose progress
        lags the median by more than (1 - threshold)."""
        med = np.median(progress)
        return [i for i, p in enumerate(progress) if p < threshold * med]
