"""Training/eval pipelines as the paper's DAG jobs.

A pretraining run decomposes into malleable stages:

    tokenize -> shard -> [train segment x N] -> eval -> export
                     \\-> [eval sweep branches]

Each stage is data-parallel across pods up to its scaling bound delta_i
(pods), with workload z_i in pod-time units. Segments between checkpoints
are independent units of preemptible progress — exactly the malleable tasks
of the paper: a segment can run on fewer pods for longer (down to its
minimum window z_i / delta_i).
"""

from __future__ import annotations

import numpy as np

from repro.core.types import DAGJob, Task
from repro.sched.fleet import estimate_stage_seconds

__all__ = ["training_job_dag"]


def training_job_dag(
    arch: str,
    arrival: float,
    deadline_factor: float = 2.0,
    n_segments: int = 4,
    steps_per_segment: int = 250,
    max_pods: int = 8,
    n_evals: int = 2,
    time_unit_s: float = 3600.0,
    cache=None,
) -> DAGJob:
    """Build the DAG for one training job of ``arch``.

    z_i is pod-hours (time_unit_s = one paper time-unit); the train segments
    form a chain; eval stages branch off each segment's completion and join
    at export.
    """
    seg_pod_s = estimate_stage_seconds(
        arch, steps=steps_per_segment, cache=cache) * max_pods
    seg_z = seg_pod_s / time_unit_s                    # pod-units of work
    prep_z = max(0.05 * seg_z, 0.01)
    eval_z = max(0.1 * seg_z, 0.01)

    tasks: list[Task] = []
    preds: list[tuple[int, ...]] = []

    def add(z, delta, *ps):
        tasks.append(Task(z=float(max(z, 1e-6)), delta=float(delta)))
        preds.append(tuple(ps))
        return len(tasks) - 1

    tok = add(prep_z, max_pods)                  # tokenize/shard
    prev = tok
    seg_ids = []
    for _ in range(n_segments):
        prev = add(seg_z, max_pods, prev)        # train segment (chain)
        seg_ids.append(prev)
    ev_ids = []
    for i in range(min(n_evals, len(seg_ids))):
        ev_ids.append(add(eval_z, max(max_pods // 2, 1), seg_ids[-(i + 1)]))
    add(prep_z, max(max_pods // 2, 1), seg_ids[-1], *ev_ids)  # export

    e_c = 0.0  # critical path computed by DAGJob itself
    job = DAGJob(arrival=arrival, deadline=arrival + 1.0,
                 tasks=tuple(tasks), preds=tuple(preds))
    e_c = job.critical_path
    return DAGJob(arrival=arrival, deadline=arrival + deadline_factor * e_c,
                  tasks=tuple(tasks), preds=tuple(preds))
