"""Fleet model: TPU pods as the paper's "instances".

The paper's three instance classes map onto real fleet procurement:
  self-owned = reserved pods (sunk cost), spot = preemptible pods,
  on-demand = on-demand pods. A *task*'s workload z_i is pod-seconds derived
  from the dry-run roofline (the compiled step's dominant term x steps), and
  its parallelism bound delta_i is the data-parallel scaling limit
  (global_batch / per-pod minimum batch).
"""

from __future__ import annotations

import dataclasses
import json
import os

__all__ = ["FleetSpec", "estimate_stage_seconds", "load_roofline_cache"]

_CACHE = os.path.join(os.path.dirname(__file__),
                      "../../../benchmarks/roofline_cache.json")


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Per-pod-hour prices normalized like the paper (on-demand = 1)."""

    reserved_pods: int = 0          # self-owned
    spot_discount: float = 0.3      # spot ~ 70% cheaper
    chips_per_pod: int = 256


def load_roofline_cache(path: str | None = None) -> list[dict]:
    p = os.path.abspath(path or _CACHE)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def estimate_stage_seconds(arch: str, shape: str = "train_4k",
                           steps: int = 1000, variant: str = "base",
                           cache: list[dict] | None = None) -> float:
    """Pod-seconds for `steps` training steps of an arch, from the dry-run.

    The per-step time estimate is the max of the three roofline terms of the
    single-pod compiled cell (the roofline LOWER bound on step time — a
    deliberately optimistic z_i; the orchestrator's online learning absorbs
    systematic bias via the beta/beta_0 knobs).
    """
    cache = cache if cache is not None else load_roofline_cache()
    for r in cache:
        if (r.get("arch") == arch and r.get("shape") == shape
                and not r.get("multi_pod") and r.get("variant") == variant
                and r.get("status") == "ok"):
            step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            return step_s * steps
    # Fallback when the dry-run cache is absent: 1s/step.
    return float(steps)
