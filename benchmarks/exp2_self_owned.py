"""Experiment 2 (paper Table 3) — overall improvement with self-owned pool.

Proposed: Algorithm 2 end-to-end — Dealloc(beta/beta_0) windows + policy (12)
self-owned allocation + Prop 4.1 composition, minimized over
P = C1 x C2 x B (175 policies). Benchmark: Even windows + naive FCFS
self-owned (r_i = min{N, delta_i}), minimized over P' = B.
"""

from __future__ import annotations

from benchmarks.common import Timer, argparser, make_setup, print_table, sweep_min
from repro.core import benchmark_bid_policies, selfowned_policies


def run(n_jobs: int, types: list[int], rs: list[int], seed: int = 0,
        scenarios: int = 1, scenario_kind: str = "fresh",
        backend: str = "auto", scenario_chunk: int | None = None,
        mesh: int | None = None) -> dict:
    out = {}
    for jt in types:
        s = make_setup(n_jobs, jt, seed, scenarios=scenarios,
                       scenario_kind=scenario_kind, backend=backend,
                       scenario_chunk=scenario_chunk, mesh=mesh)
        for r in rs:
            with Timer(f"exp2 type {jt} r={r}"):
                pol, alpha, costs = sweep_min(
                    s, selfowned_policies(), r_total=r, early_start=True)
                bench_alpha = sweep_min(
                    s, benchmark_bid_policies(), r_total=r, windows="even",
                    selfowned="naive", early_start=False)[1]
                out[(r, jt)] = {
                    "alpha": alpha,
                    "bench": bench_alpha,
                    "rho": 1 - alpha / bench_alpha,
                    "best_policy": (round(pol.beta, 3), pol.bid,
                                    round(pol.beta0, 3)),
                }
    return out


def main(argv=None):
    args = argparser(__doc__).parse_args(argv)
    res = run(args.jobs, args.types, args.r, args.seed, args.scenarios,
              args.scenario_kind, args.backend, args.scenario_chunk,
              args.mesh)
    rows = [[r, jt, f"{v['alpha']:.4f}", f"{v['bench']:.4f}",
             f"{v['rho']:.2%}", v["best_policy"]]
            for (r, jt), v in sorted(res.items())]
    print_table("Table 3 — overall improvement with self-owned instances",
                ["r", "type", "alpha", "bench", "rho", "best_policy"], rows)
    return res


if __name__ == "__main__":
    main()
