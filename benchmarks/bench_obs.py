"""Observability bench: phase/collective table + tracing-overhead gate.

Runs one representative grid under full observation (span tracing, metrics
collection, compiled-program capture) — an ``evaluate_grid`` chunked
stream plus a ``replay_stream`` regret fold — and prints:

* the span-derived phase totals (plan / pool / synth / eval / fold), which
  are by construction the same floats as ``EngineResult.timings``;
* the compiled-program table (gflops / MB / collective op counts per
  cached jit program, via ``repro.obs.compiled``) — the standing form of
  the §9 placement contract (zero collectives in the eval/synth hot loop,
  one packed psum per streamed fold chunk). On the jax backend the
  observed run includes a ``run_tola_scenarios`` pool-refinement pass on
  a 2-D ``GridMesh``, so the sharded refinement programs
  (``engine.eval.chain_ps:sharded`` / ``engine.eval.task_ps:sharded``)
  appear in the table with their collective counts (zero, per §9);
* the metrics snapshot (chunk latency histogram, scenarios/sec,
  learner weight entropy) plus the cross-call plan/view cache counters
  (``engine.plan_cache{event=hit|miss|evict}`` and friends, DESIGN.md
  §11).

    PYTHONPATH=src python -m benchmarks.bench_obs \
        [--jobs 64] [--policies 24] [--scenarios 16] [--chunk 4] \
        [--backend auto] [--trace out.json] [--overhead-gate 1.1]

``--trace PATH`` saves the Chrome/Perfetto trace JSON of the observed run
(load it at https://ui.perfetto.dev). ``--overhead-gate R`` additionally
times the SAME workload untraced vs traced (best of --iters) and exits
nonzero if traced/untraced exceeds R — the CI tracing-overhead gate.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import obs
from repro.core import generate_chain_jobs, selfowned_policies
from repro.engine import ScenarioSpec, evaluate_grid, resolve_backend
from repro.learn import replay_stream

__all__ = ["run", "main"]


def _best_of(fn, iters: int) -> float:
    best = np.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_jobs: int, n_policies: int, n_scenarios: int, chunk: int,
        r_total: int, backend: str, seed: int = 0, job_type: int = 2,
        iters: int = 3, trace_path: str | None = None,
        overhead_gate: float | None = None) -> dict:
    backend = resolve_backend(backend)
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    grid = selfowned_policies()[:n_policies]
    spec = ScenarioSpec("fresh", horizon, n_scenarios, seed=seed + 1000)

    def grid_pass():
        return evaluate_grid(jobs, grid, spec, r_total, backend=backend,
                             scenario_chunk=chunk)

    def stream_pass():
        return replay_stream(jobs, grid[:max(4, n_policies // 4)], spec,
                             r_total, learners=["hedge"], seed=seed,
                             scenario_chunk=chunk, backend=backend,
                             engine_backend=backend)

    # Pool-refinement on a 2-D GridMesh (jax only): puts the sharded
    # per-scenario-availability programs (engine.eval.chain_ps:sharded,
    # engine.eval.task_ps:sharded) into the compiled-program table. A
    # 1-device box degenerates to the 1x1 mesh — same programs, same keys.
    refine_pass = None
    if backend == "jax":
        import jax

        from repro.core import run_tola_scenarios
        from repro.engine import GridMesh, make_scenarios

        avail = len(jax.devices())
        mesh = GridMesh.create(model_devices=2 if avail >= 2 else 1)
        markets = make_scenarios(horizon, 2, seed=seed + 2000)

        def refine_pass():
            return run_tola_scenarios(jobs, grid[:8], markets, r_total,
                                      seed=seed, pool_iters=1,
                                      backend="jax", mesh=mesh)

    grid_pass()          # absorb jit compilation before any timing
    stream_pass()
    if refine_pass is not None:
        refine_pass()

    # --- the observed run: spans + metrics + compiled capture ------------
    with obs.observe(programs=True) as session:
        res = grid_pass()
        slr = stream_pass()
        if refine_pass is not None:
            refine_pass()
    tracer, reg = session.tracer, session.compiled
    totals = tracer.totals()
    out = {
        "backend": backend,
        "n_jobs": n_jobs,
        "n_policies": len(grid),
        "n_scenarios": n_scenarios,
        "scenario_chunk": chunk,
        "n_spans": len(tracer),
        "span_totals": {k: totals[k] for k in sorted(totals)},
        "timings": {k: v for k, v in res.timings.items() if k != "chunks"},
        "programs": {
            key: {k: v for k, v in e.items() if k != "warnings"}
            for key, e in reg.entries.items()
        },
        "factory_caches": obs.compiled.factory_caches(),
        "metrics": (slr.obs or {}).get("metrics", {}),
    }
    print(f"[obs] backend={backend}  {len(tracer)} spans  "
          f"{len(reg.entries)} compiled programs")
    print("\nphase totals (span-derived, == EngineResult.timings):")
    for name in sorted(totals):
        print(f"  {name:<18} {totals[name]:9.4f}s")
    print("\n" + reg.table())
    print("\ncross-call caches (DESIGN.md §11):")
    for name in ("engine.plan_cache", "engine.view_cache"):
        c = out["factory_caches"].get(name)
        if c:
            print(f"  {name:<18} {c['hits']:>5} hits  {c['misses']:>5} "
                  f"misses  {c['evictions']:>4} evictions  "
                  f"(size {c['currsize']}/{c['maxsize']})")
    # The labeled counter series of the same events, as recorded under
    # METRICS during the observed pass (grid_pass snapshots onto res.obs).
    for mname in ("engine.plan_cache", "engine.view_cache",
                  "engine.delta_groups_rescored"):
        m = (res.obs or {}).get("metrics", {}).get(mname)
        for s in (m or {}).get("series", []):
            lbl = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            print(f"  {mname}{{{lbl}}} = {s['value']:g}")
    if trace_path:
        tracer.save(trace_path)
        print(f"\nwrote Perfetto trace: {trace_path} "
              f"(load at https://ui.perfetto.dev)")
        out["trace_path"] = trace_path

    # --- tracing-overhead gate: traced vs untraced, best of iters --------
    if overhead_gate is not None:
        t_plain = _best_of(grid_pass, iters)

        def traced():
            with obs.tracing():
                grid_pass()

        t_traced = _best_of(traced, iters)
        ratio = t_traced / t_plain
        out["untraced_seconds"] = t_plain
        out["traced_seconds"] = t_traced
        out["tracing_overhead_ratio"] = ratio
        status = "OK" if ratio <= overhead_gate else "FAIL"
        print(f"\n[overhead] untraced {t_plain:.3f}s  traced {t_traced:.3f}s"
              f"  ratio {ratio:.3f} (gate {overhead_gate:.2f}) {status}")
        if ratio > overhead_gate:
            raise SystemExit(
                f"tracing overhead {ratio:.3f}x exceeds the "
                f"{overhead_gate:.2f}x gate")
    return out


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=64)
    p.add_argument("--policies", type=int, default=24)
    p.add_argument("--scenarios", type=int, default=16)
    p.add_argument("--chunk", type=int, default=4)
    p.add_argument("--r", type=int, default=600)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--job-type", type=int, default=2)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--backend", default="auto",
                   choices=["auto", "numpy", "jax", "pallas"])
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="save the Chrome/Perfetto trace JSON here")
    p.add_argument("--overhead-gate", type=float, default=None,
                   metavar="RATIO",
                   help="fail if traced/untraced wall exceeds RATIO "
                        "(CI uses 1.1)")
    p.add_argument("--out", default=None,
                   help="optionally dump the full report as JSON")
    args = p.parse_args(argv)
    res = run(args.jobs, args.policies, args.scenarios, args.chunk, args.r,
              args.backend, seed=args.seed, job_type=args.job_type,
              iters=args.iters, trace_path=args.trace,
              overhead_gate=args.overhead_gate)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
