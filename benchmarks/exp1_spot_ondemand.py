"""Experiment 1 (paper Table 2) — spot + on-demand only.

rho_{0,x2} = 1 - alpha_proposed / alpha_benchmark, where the proposed policy
is Dealloc (Algorithm 1) + Prop 4.1 composition minimized over
P = C2 x B (25 policies), and the benchmarks are Greedy / Even minimized
over P' = B (bid only; Even's window split needs no parameter).

Also reports the strengthened Even(early-start) baseline — beyond-paper,
see EXPERIMENTS.md.
"""

from __future__ import annotations

from benchmarks.common import (
    Timer,
    argparser,
    greedy_min,
    make_setup,
    print_table,
    sweep_min,
)
from repro.core import B_BIDS, spot_od_policies


def run(n_jobs: int, types: list[int], seed: int = 0, scenarios: int = 1,
        scenario_kind: str = "fresh", backend: str = "auto",
        scenario_chunk: int | None = None, mesh: int | None = None) -> dict:
    out = {}
    for jt in types:
        with Timer(f"exp1 type {jt}"):
            s = make_setup(n_jobs, jt, seed, scenarios=scenarios,
                           scenario_kind=scenario_kind, backend=backend,
                           scenario_chunk=scenario_chunk, mesh=mesh)
            pol, alpha, _ = sweep_min(s, spot_od_policies(), early_start=True)
            greedy = greedy_min(s, B_BIDS)
            even_planned = sweep_min(
                s, spot_od_policies(), windows="even", early_start=False)[1]
            even_early = sweep_min(
                s, spot_od_policies(), windows="even", early_start=True)[1]
            out[jt] = {
                "alpha": alpha,
                "best_policy": (round(pol.beta, 3), pol.bid),
                "rho_vs_greedy": 1 - alpha / greedy,
                "rho_vs_even": 1 - alpha / even_planned,
                "rho_vs_even_early": 1 - alpha / even_early,
            }
    return out


def main(argv=None):
    args = argparser(__doc__).parse_args(argv)
    res = run(args.jobs, args.types, args.seed, args.scenarios,
              args.scenario_kind, args.backend, args.scenario_chunk,
              args.mesh)
    rows = [[jt, f"{r['alpha']:.4f}", r["best_policy"],
             f"{r['rho_vs_greedy']:.2%}", f"{r['rho_vs_even']:.2%}",
             f"{r['rho_vs_even_early']:.2%}"] for jt, r in res.items()]
    print_table("Table 2 — cost improvement, spot + on-demand",
                ["type", "alpha", "best_policy", "rho_vs_greedy",
                 "rho_vs_even", "rho_vs_even_early(beyond-paper)"], rows)
    return res


if __name__ == "__main__":
    main()
