"""Benchmark driver — one experiment per paper table + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--jobs N] [--skip ...]

Sections:
  exp1  Table 2  — spot+on-demand cost improvement (Greedy / Even)
  exp2  Table 3  — overall improvement with self-owned instances
  exp3  Tables 4+5 — policy (12) vs naive self-owned (+ utilization ratio)
  exp4  Table 6  — TOLA online learning
  engine          — evaluation-engine throughput (numpy vs jax vs pallas)
                    on a (512 jobs x 70 policies x 4 scenarios) grid; emits
                    BENCH_engine.json (see benchmarks/bench_engine.py for
                    how to read it — off-TPU the pallas number is interpret
                    mode, i.e. kernel logic, not TPU speed)
  pipeline        — END-TO-END jobs -> plans -> pool -> cost tensor per
                    backend with a plan/pool/eval phase split, plus the
                    batched-plan-builder vs per-group-loop race; emits
                    BENCH_pipeline.json (benchmarks/bench_pipeline.py)
  learn           — online-learning replay throughput (numpy oracle vs the
                    scan-compiled jax replay) across a learner x eta-grid
                    sweep over the same grid; emits BENCH_learn.json
                    (benchmarks/bench_learn.py)
  obs             — observability report for one representative grid: the
                    span-derived phase totals, the compiled-program
                    gflops/MB/collective table, and the metrics snapshot
                    (benchmarks/bench_obs.py; --trace saves the Perfetto
                    trace of that run)
  roofline        — per-(arch x shape) roofline terms from the compiled
                    dry-run (reads benchmarks/roofline_cache.json if the
                    dry-run sweep has been run; see launch/dryrun.py)

--trace PATH runs the WHOLE driver under the repro.obs span tracer and
saves one Chrome/Perfetto trace JSON covering every selected section
(load it at https://ui.perfetto.dev).

Every exp accepts --scenarios S / --scenario-kind / --backend to evaluate S
spot-market scenarios in one engine pass (S=1 = the paper's tables), and
--mesh N to shard the scenario axis over an N-way device mesh (jax
backend; clamped to the visible device count).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=None,
                   help="jobs per stream (default: 1500; --quick: 300)")
    p.add_argument("--quick", action="store_true",
                   help="small streams / reduced grids for CI-speed runs")
    sections = ["exp1", "exp2", "exp3", "exp4", "engine", "pipeline",
                "learn", "obs", "roofline"]
    p.add_argument("--skip", nargs="*", default=[], metavar="SECTION")
    p.add_argument("--only", nargs="*", default=None, metavar="SECTION")
    p.add_argument("--mesh", type=int, default=None,
                   help="shard the exp1-4 scenario axis over an N-way "
                        "device mesh (forwarded as --mesh N)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="trace the whole run with the repro.obs span "
                        "tracer and save the Chrome/Perfetto JSON here")
    args = p.parse_args(argv)

    for flag, values in (("--only", args.only), ("--skip", args.skip)):
        unknown = [v for v in (values or []) if v not in sections]
        if unknown:
            p.error(f"{flag}: unknown section(s): {', '.join(unknown)}. "
                    f"Valid sections: {', '.join(sections)}")

    n_jobs = args.jobs or (300 if args.quick else 1500)
    types = [1, 2] if args.quick else [1, 2, 3, 4]
    rs = [300, 1200] if args.quick else [300, 600, 900, 1200]
    rs4 = [0, 600] if args.quick else [0, 300, 600, 900, 1200]

    def want(name: str) -> bool:
        if args.only is not None:
            return name in args.only
        return name not in args.skip

    mesh_args = [] if args.mesh is None else ["--mesh", str(args.mesh)]

    import contextlib

    from repro import obs

    tracer = obs.Tracer() if args.trace else None
    ctx = obs.tracing(tracer) if tracer is not None \
        else contextlib.nullcontext()

    t0 = time.time()
    with ctx:
        _sections(args, want, n_jobs, types, rs, rs4, mesh_args)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote Perfetto trace ({len(tracer)} spans): {args.trace}")
    print(f"\n[benchmarks total: {time.time() - t0:.1f}s]")


def _sections(args, want, n_jobs, types, rs, rs4, mesh_args):
    if want("exp1"):
        from benchmarks import exp1_spot_ondemand
        exp1_spot_ondemand.main(["--jobs", str(n_jobs),
                                 "--types", *map(str, types), *mesh_args])
    if want("exp2"):
        from benchmarks import exp2_self_owned
        exp2_self_owned.main(["--jobs", str(n_jobs),
                              "--types", *map(str, types),
                              "--r", *map(str, rs), *mesh_args])
    if want("exp3"):
        from benchmarks import exp3_policy12
        exp3_policy12.main(["--jobs", str(n_jobs),
                            "--types", *map(str, types),
                            "--r", *map(str, rs), *mesh_args])
    if want("exp4"):
        from benchmarks import exp4_online_learning
        exp4_online_learning.main(["--jobs", str(n_jobs),
                                   "--r", *map(str, rs4), *mesh_args])
    if want("engine"):
        from benchmarks import bench_engine
        if args.quick:
            bench_engine.main(["--jobs", "128", "--policies", "64",
                               "--scenarios", "2", "--iters", "1"])
        else:
            bench_engine.main([])
    if want("pipeline"):
        from benchmarks import bench_pipeline
        if args.quick:
            bench_pipeline.main(["--jobs", "128", "--policies", "64",
                                 "--scenarios", "2", "--iters", "1"])
        else:
            bench_pipeline.main([])
    if want("learn"):
        from benchmarks import bench_learn
        if args.quick:
            bench_learn.main(["--jobs", "128", "--policies", "64",
                              "--scenarios", "2", "--iters", "1"])
        else:
            bench_learn.main([])
    if want("obs"):
        from benchmarks import bench_obs
        # Explicit jax (like the bench_engine/bench_learn default backend
        # lists): "auto" resolves to numpy on CPU, whose run captures no
        # compiled programs — the point of this section.
        obs_args = (["--jobs", "32", "--policies", "12", "--scenarios", "8",
                     "--chunk", "4", "--iters", "2"] if args.quick else [])
        bench_obs.main(obs_args + ["--backend", "jax"])
    if want("roofline"):
        from benchmarks import roofline
        roofline.main([])


if __name__ == "__main__":
    main()
