"""Roofline report: reads benchmarks/roofline_cache.json (written by
launch/dryrun.py) and prints the per-(arch x shape x mesh) three-term
roofline table with bottleneck classification and useful-flops ratios.

    PYTHONPATH=src python -m benchmarks.roofline [--variant base] [--csv]
"""

from __future__ import annotations

import argparse
import json
import os

CACHE = os.path.join(os.path.dirname(__file__), "roofline_cache.json")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path: str | None = None) -> list[dict]:
    with open(path or CACHE) as f:
        return json.load(f)


def fmt_row(r: dict) -> list[str]:
    if r["status"] == "skipped":
        return [r["arch"], r["shape"], "2pod" if r["multi_pod"] else "1pod",
                r.get("variant", "base"), "SKIP", "-", "-", "-", "-", "-", "-"]
    if r["status"] != "ok":
        return [r["arch"], r["shape"], "2pod" if r["multi_pod"] else "1pod",
                r.get("variant", "base"), "FAIL", "-", "-", "-", "-", "-", "-"]
    peak = r["bytes_per_device"]["peak"] / 2 ** 30
    return [
        r["arch"], r["shape"], "2pod" if r["multi_pod"] else "1pod",
        r.get("variant", "base"),
        f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
        f"{r['collective_s']:.4g}", r["bottleneck"].replace("_s", ""),
        f"{r['useful_flops_ratio']:.3f}",
        f"{peak:.2f}", "yes" if r["fits_hbm"] else "NO",
    ]


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--variant", default=None,
                   help="filter to one variant (default: all)")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all-meshes", action="store_true")
    args = p.parse_args(argv)

    try:
        rows = load()
    except FileNotFoundError:
        print("roofline: no cache yet — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return []
    rows = [r for r in rows if args.all_meshes
            or r["multi_pod"] == args.multi_pod]
    if args.variant:
        rows = [r for r in rows if r.get("variant", "base") == args.variant]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["multi_pod"], r.get("variant", "base")))
    header = ["arch", "shape", "mesh", "variant", "compute_s", "memory_s",
              "collective_s", "bottleneck", "useful_ratio", "peak_GiB",
              "fits"]
    print(",".join(header))
    for r in rows:
        print(",".join(fmt_row(r)))
    n_ok = sum(1 for r in rows if r["status"] == "ok")
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    n_fail = len(rows) - n_ok - n_skip
    print(f"\n# {n_ok} ok, {n_skip} skipped-by-design, {n_fail} failed")
    return rows


if __name__ == "__main__":
    main()
