"""Experiment 3 (paper Tables 4 + 5) — isolating policy (12).

Both sides use the SAME deadline allocation (lines 1-5 of Algorithm 2); they
differ only in the self-owned allocator: policy (12) vs naive FCFS
(r_i = min{N, delta_i}). Each side is minimized over the full grid
P = C1 x C2 x B so the comparison isolates the self-owned policy alone.

Table 5's utilization ratio mu = util(prop12) / util(naive) is reported for
the cost-minimizing policy of each side (self-owned instance-time that
processed real workload, over the pool's capacity within the stream
horizon).
"""

from __future__ import annotations

from benchmarks.common import Timer, argparser, make_setup, print_table, sweep_min
from repro.core import selfowned_policies


def _best(setup, r, selfowned):
    """Engine-batched sweep; returns (alpha, policy, StreamCosts)."""
    pol, alpha, costs = sweep_min(setup, selfowned_policies(), r_total=r,
                                  selfowned=selfowned, early_start=True)
    return alpha, pol, costs


def run(n_jobs: int, types: list[int], rs: list[int], seed: int = 0,
        scenarios: int = 1, scenario_kind: str = "fresh",
        backend: str = "auto", scenario_chunk: int | None = None,
        mesh: int | None = None) -> dict:
    out = {}
    for jt in types:
        s = make_setup(n_jobs, jt, seed, scenarios=scenarios,
                       scenario_kind=scenario_kind, backend=backend,
                       scenario_chunk=scenario_chunk, mesh=mesh)
        horizon = max(j.deadline for j in s.jobs)
        for r in rs:
            with Timer(f"exp3 type {jt} r={r}"):
                a_prop, _, c_prop = _best(s, r, "prop12")
                a_naive, _, c_naive = _best(s, r, "naive")
                util_prop = c_prop.selfowned_work.sum() / (r * horizon)
                util_naive = c_naive.selfowned_work.sum() / (r * horizon)
                out[(r, jt)] = {
                    "rho": 1 - a_prop / a_naive,
                    "alpha_prop": a_prop,
                    "alpha_naive": a_naive,
                    "mu": util_prop / max(util_naive, 1e-12),
                }
    return out


def main(argv=None):
    args = argparser(__doc__).parse_args(argv)
    res = run(args.jobs, args.types, args.r, args.seed, args.scenarios,
              args.scenario_kind, args.backend, args.scenario_chunk,
              args.mesh)
    rows = [[r, jt, f"{v['alpha_prop']:.4f}", f"{v['alpha_naive']:.4f}",
             f"{v['rho']:.2%}", f"{v['mu']:.4f}"]
            for (r, jt), v in sorted(res.items())]
    print_table("Tables 4+5 — policy (12) vs naive self-owned",
                ["r", "type", "alpha_prop12", "alpha_naive", "rho",
                 "utilization_ratio_mu"], rows)
    return res


if __name__ == "__main__":
    main()
