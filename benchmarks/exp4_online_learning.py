"""Experiment 4 (paper Table 6) — TOLA online learning.

rho_bar = 1 - alpha_bar(P) / alpha_bar(P'): realized average unit cost when
TOLA drives the proposed grid vs when it drives the benchmark grid
(Even windows + naive self-owned, bid-only policies). Job type fixed to 2
(paper), r in {0, 300, 600, 900, 1200}.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, argparser, make_setup, print_table
from repro.core import (
    benchmark_bid_policies,
    run_tola_scenarios,
    selfowned_policies,
    spot_od_policies,
)


def run(n_jobs: int, rs: list[int], seed: int = 0, job_type: int = 2,
        scenarios: int = 1, scenario_kind: str = "fresh",
        backend: str = "auto") -> dict:
    out = {}
    s = make_setup(n_jobs, job_type, seed, scenarios=scenarios,
                   scenario_kind=scenario_kind, backend=backend)
    for r in rs:
        with Timer(f"exp4 r={r}"):
            grid = selfowned_policies() if r > 0 else spot_od_policies()
            # Counterfactual matrices for ALL scenarios come out of one
            # engine pass; the sequential replay runs per scenario.
            props = run_tola_scenarios(
                s.jobs, grid, s.markets, r_total=r, seed=seed,
                early_start=True, backend=backend)
            benches = run_tola_scenarios(
                s.jobs, benchmark_bid_policies(), s.markets, r_total=r,
                windows="even", selfowned="naive", early_start=False,
                seed=seed, backend=backend)
            a_prop = np.array([p.average_unit_cost() for p in props])
            a_bench = np.array([b.average_unit_cost() for b in benches])
            out[r] = {
                "alpha_tola": float(a_prop.mean()),
                "alpha_bench": float(a_bench.mean()),
                "rho_bar": 1 - float(a_prop.mean()) / float(a_bench.mean()),
                "best_fixed": float(np.mean(
                    [p.best_fixed_unit_cost for p in props])),
                "regret": float(np.mean([p.regret_per_job for p in props])),
                "top_weight": float(np.mean(
                    [p.weights.max() for p in props])),
            }
            if len(s.markets) > 1:
                out[r]["alpha_tola_std"] = float(a_prop.std())
    return out


def main(argv=None):
    p = argparser(__doc__)
    p.set_defaults(r=[0, 300, 600, 900, 1200])
    args = p.parse_args(argv)
    res = run(args.jobs, args.r, args.seed, scenarios=args.scenarios,
              scenario_kind=args.scenario_kind, backend=args.backend)
    rows = [[r, f"{v['alpha_tola']:.4f}", f"{v['alpha_bench']:.4f}",
             f"{v['rho_bar']:.2%}", f"{v['best_fixed']:.4f}",
             f"{v['regret']:.4f}", f"{v['top_weight']:.3f}"]
            for r, v in sorted(res.items())]
    print_table("Table 6 — TOLA online learning (job type 2)",
                ["r", "alpha_tola", "alpha_bench", "rho_bar",
                 "best_fixed", "regret", "top_weight"], rows)
    return res


if __name__ == "__main__":
    main()
