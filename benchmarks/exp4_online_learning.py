"""Experiment 4 (paper Table 6) — TOLA online learning.

rho_bar = 1 - alpha_bar(P) / alpha_bar(P'): realized average unit cost when
TOLA drives the proposed grid vs when it drives the benchmark grid
(Even windows + naive self-owned, bid-only policies). Job type fixed to 2
(paper), r in {0, 300, 600, 900, 1200}.
"""

from __future__ import annotations

from benchmarks.common import Timer, argparser, make_setup, print_table
from repro.core import (
    benchmark_bid_policies,
    run_tola,
    selfowned_policies,
    spot_od_policies,
)


def run(n_jobs: int, rs: list[int], seed: int = 0, job_type: int = 2) -> dict:
    out = {}
    s = make_setup(n_jobs, job_type, seed)
    for r in rs:
        with Timer(f"exp4 r={r}"):
            grid = selfowned_policies() if r > 0 else spot_od_policies()
            prop = run_tola(s.jobs, grid, s.market, r_total=r, seed=seed,
                            early_start=True)
            bench = run_tola(
                s.jobs, benchmark_bid_policies(), s.market, r_total=r,
                windows="even", selfowned="naive", early_start=False,
                seed=seed)
            out[r] = {
                "alpha_tola": prop.average_unit_cost(),
                "alpha_bench": bench.average_unit_cost(),
                "rho_bar": 1 - prop.average_unit_cost() / bench.average_unit_cost(),
                "best_fixed": prop.best_fixed_unit_cost,
                "regret": prop.regret_per_job,
                "top_weight": float(prop.weights.max()),
            }
    return out


def main(argv=None):
    p = argparser(__doc__)
    p.set_defaults(r=[0, 300, 600, 900, 1200])
    args = p.parse_args(argv)
    res = run(args.jobs, args.r, args.seed)
    rows = [[r, f"{v['alpha_tola']:.4f}", f"{v['alpha_bench']:.4f}",
             f"{v['rho_bar']:.2%}", f"{v['best_fixed']:.4f}",
             f"{v['regret']:.4f}", f"{v['top_weight']:.3f}"]
            for r, v in sorted(res.items())]
    print_table("Table 6 — TOLA online learning (job type 2)",
                ["r", "alpha_tola", "alpha_bench", "rho_bar",
                 "best_fixed", "regret", "top_weight"], rows)
    return res


if __name__ == "__main__":
    main()
