"""Experiment 4 (paper Table 6) — TOLA online learning.

rho_bar = 1 - alpha_bar(P) / alpha_bar(P'): realized average unit cost when
TOLA drives the proposed grid vs when it drives the benchmark grid
(Even windows + naive self-owned, bid-only policies). Job type fixed to 2
(paper), r in {0, 300, 600, 900, 1200}.

``--learner`` swaps the online learner (hedge = the paper's Alg. 4 —
reproduces Table 6 bit-for-bit — or any bandit learner from
``repro.learn``); several learners and/or ``--eta-grid`` values additionally
print a learner-comparison table, evaluated by the batched ``repro.learn``
replay over ONE engine pass per r (counterfactual dedicated-pool regret,
common random numbers across learners).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, argparser, make_setup, print_table
from repro.core import (
    benchmark_bid_policies,
    run_tola_scenarios,
    selfowned_policies,
    spot_od_policies,
)
from repro.engine import ScenarioSpec, ScenarioStream
from repro.learn import LEARNER_KINDS, LearnerSpec, Schedule
from repro.learn import replay as learn_replay
from repro.learn import replay_stream


def comparison_specs(learners: list[str], eta_grid: list[float]):
    """The flat spec list of the comparison sweep: every requested learner
    with its default (alg4) schedule, plus one variant per eta-grid point
    for the learners that consume a learning rate."""
    specs = []
    for kind in learners:
        specs.append(LearnerSpec(kind))
        if kind in ("hedge", "exp3"):
            for c in eta_grid:
                specs.append(LearnerSpec(kind, eta=Schedule("const", c)))
    return specs


def run(n_jobs: int, rs: list[int], seed: int = 0, job_type: int = 2,
        scenarios: int = 1, scenario_kind: str = "fresh",
        backend: str = "auto", learners: list[str] | None = None,
        eta_grid: list[float] | None = None,
        scenario_chunk: int | None = None,
        mesh: int | None = None) -> dict:
    learners = learners or ["hedge"]
    eta_grid = eta_grid or []
    compare = len(learners) > 1 or eta_grid
    out = {}
    s = make_setup(n_jobs, job_type, seed, scenarios=scenarios,
                   scenario_kind=scenario_kind, backend=backend,
                   scenario_chunk=scenario_chunk, mesh=mesh)
    arrivals = np.array([j.arrival for j in s.jobs])
    d = max(j.deadline - j.arrival for j in s.jobs)
    Z = np.array([j.total_work for j in s.jobs])
    for r in rs:
        with Timer(f"exp4 r={r}"):
            grid = selfowned_policies() if r > 0 else spot_od_policies()
            # Counterfactual matrices for ALL scenarios come out of one
            # engine pass; the sequential replay runs per scenario.
            props = run_tola_scenarios(
                s.jobs, grid, s.markets, r_total=r, seed=seed,
                early_start=True, backend=backend, learner=learners[0],
                mesh=mesh)
            benches = run_tola_scenarios(
                s.jobs, benchmark_bid_policies(), s.markets, r_total=r,
                windows="even", selfowned="naive", early_start=False,
                seed=seed, backend=backend, learner=learners[0], mesh=mesh)
            a_prop = np.array([p.average_unit_cost() for p in props])
            a_bench = np.array([b.average_unit_cost() for b in benches])
            out[r] = {
                "learner": learners[0],
                "alpha_tola": float(a_prop.mean()),
                "alpha_bench": float(a_bench.mean()),
                "rho_bar": 1 - float(a_prop.mean()) / float(a_bench.mean()),
                "best_fixed": float(np.mean(
                    [p.best_fixed_unit_cost for p in props])),
                "regret": float(np.mean([p.regret_per_job for p in props])),
                "top_weight": float(np.mean(
                    [p.weights.max() for p in props])),
            }
            if len(s.markets) > 1:
                out[r]["alpha_tola_std"] = float(a_prop.std())
            if compare:
                # One batched replay of every (learner, eta) instance over
                # the scenario-stacked cost tensor of the last iteration.
                C = np.stack([p.cost_matrix for p in props])
                lr = learn_replay(C, arrivals, d, workload=Z,
                                  learners=comparison_specs(learners,
                                                            eta_grid),
                                  seed=seed, backend="auto")
                out[r]["comparison"] = lr.summary()
            if scenario_chunk:
                # Streamed counterfactual regret straight from the spec:
                # chunk-wise engine evaluation + replay, no (S, J, P)
                # tensor and no per-scenario market objects on the hot
                # path. An adaptive spec reacts to learners[0] at each
                # chunk boundary (fresh adversary state per r).
                assert isinstance(s.scenarios, ScenarioSpec)
                stream = ScenarioStream(s.scenarios)
                slr = replay_stream(
                    s.jobs, grid, stream, r_total=r,
                    learners=comparison_specs(learners, eta_grid),
                    seed=seed, scenario_chunk=scenario_chunk,
                    backend="auto", engine_backend=backend, mesh=mesh)
                out[r]["stream"] = slr.summary()
    return out


def main(argv=None):
    p = argparser(__doc__)
    p.set_defaults(r=[0, 300, 600, 900, 1200])
    p.add_argument("--learner", nargs="+", default=["hedge"],
                   choices=list(LEARNER_KINDS),
                   help="online learner(s); the first drives the Table-6 "
                        "realized runs, all enter the comparison table")
    p.add_argument("--eta-grid", type=float, nargs="*", default=[],
                   help="extra constant learning rates for the comparison "
                        "sweep (default schedule: the paper's Alg. 4 eta_t)")
    args = p.parse_args(argv)
    res = run(args.jobs, args.r, args.seed, scenarios=args.scenarios,
              scenario_kind=args.scenario_kind, backend=args.backend,
              learners=args.learner, eta_grid=args.eta_grid,
              scenario_chunk=args.scenario_chunk, mesh=args.mesh)
    rows = [[r, f"{v['alpha_tola']:.4f}", f"{v['alpha_bench']:.4f}",
             f"{v['rho_bar']:.2%}", f"{v['best_fixed']:.4f}",
             f"{v['regret']:.4f}", f"{v['top_weight']:.3f}"]
            for r, v in sorted(res.items())]
    print_table(f"Table 6 — TOLA online learning (job type 2, "
                f"learner {args.learner[0]})",
                ["r", "alpha_tola", "alpha_bench", "rho_bar",
                 "best_fixed", "regret", "top_weight"], rows)
    if any("comparison" in v for v in res.values()):
        rows = [[r, row["learner"], f"{row['realized_unit']:.4f}",
                 f"{row['regret']:.4f}", f"{row['expected_regret']:.4f}",
                 f"{row['top_weight']:.3f}"]
                for r, v in sorted(res.items())
                for row in v.get("comparison", [])]
        print_table("Learner comparison (counterfactual dedicated-pool "
                    "replay, common random numbers)",
                    ["r", "learner", "alpha_cf", "regret",
                     "expected_regret", "top_weight"], rows)
    if any("stream" in v for v in res.values()):
        rows = [[r, row["learner"], f"{row['realized_unit']:.4f}",
                 f"{row['regret']:.4f}", f"{row['expected_regret']:.4f}",
                 f"{row['top_weight']:.3f}"]
                for r, v in sorted(res.items())
                for row in v.get("stream", [])]
        print_table(f"Streamed regret (ScenarioSpec "
                    f"{args.scenario_kind}, S={args.scenarios}, "
                    f"chunk={args.scenario_chunk})",
                    ["r", "learner", "alpha_cf", "regret",
                     "expected_regret", "top_weight"], rows)
    return res


if __name__ == "__main__":
    main()
