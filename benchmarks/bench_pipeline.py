"""End-to-end pipeline throughput: jobs -> plans -> pool -> cost tensor.

``bench_engine`` times only the backend evaluation of a prebuilt grid plan;
this benchmark times the WHOLE ``evaluate_grid`` pass per backend — plan
tensor construction, self-owned pool arithmetic, and market realization —
and breaks the wall time into those three phases (``EngineResult.timings``),
so the plan layer's cost is a tracked number instead of hidden warmup.
It also races the batched plan builder (``build_plans_batch``, one
vectorized (G, J, L) pass over the deduplicated window-parameter grid)
against the legacy per-group ``build_plans`` loop it replaced, and — for
every non-numpy backend — the HOST plan path (f64 numpy oracle) against
the DEVICE plan path (``plan_backend="device"``: the whole jobs->plan
tensor pass as one jit program, ``<backend>+device-plan`` entries).

Cross-call reuse legs (DESIGN.md §11, run FIRST so the cold numbers are
honest):

* ``jax+warm`` — the identical ``evaluate_grid`` call twice in one
  process: the first pays every XLA compile and plan build, the second
  must hit the cross-call plan cache on every group and compile nothing
  (both counted, via the plan-cache counters and ``CompileWatch``); the
  cache-smoke CI job gates hit-rate == 100%, warm compiles == 0, and the
  cold/warm speedup.
* ``jax+delta`` — ~10% of the grid re-bid, re-scored through
  ``evaluate_grid_delta`` against the warm result; records how many eval
  groups were actually re-scored and the max deviation from a full
  re-eval.

Scenario legs (the stream side of the pipeline):

* ``scenario_synthesis`` — price-path construction throughput, host
  materialized list (``make_scenarios``, one numpy Generator + SpotMarket
  per scenario) vs declarative ``ScenarioSpec`` (counter-hash synthesis:
  f64 oracle rows, and the jitted device generator when jax is present),
  S swept geometrically up to ``--scenario-sweep-max`` (default 4096) over
  the same horizon as the grid.
* ``<backend>+spec-stream`` — the full end-to-end pass from a
  ``ScenarioSpec`` with ``scenario_chunk`` (chunked device synthesis +
  evaluation against one shared grid plan), gated in CI with the same
  2x per-cell regression rule as the other legs; its cost tensor is
  cross-checked against the numpy oracle on the SAME spec.
* ``jax+shard`` / ``jax+shard+overlap`` — the spec-stream workload with
  the scenario axis sharded over a device mesh (DESIGN.md §9; ``--mesh``
  shards, default every visible device), without and with double-buffered
  chunk synthesis; both cross-checked against the same numpy spec oracle.
  The ``shard_scaling`` sweep then streams regret curves through
  ``replay_stream`` at geometrically growing S (up to
  ``--shard-scale-max``) on a reduced grid — peak memory stays
  chunk-sized no matter how large S grows, which is the point.
* ``jax+shard2d`` — the same workload on the 2-D scenario x policy-group
  ``GridMesh`` (``--mesh2d NxM``; default splits the visible devices
  N//2 x 2), so the eval-group axis shards over ``"model"`` next to the
  scenario axis over ``"data"``.

Refinement legs (``--only refine``; TOLA pool-refinement rounds, the
per-scenario-availability path of DESIGN.md §9):

* ``jax+refine`` — ``run_tola_scenarios`` with ``pool_iters`` refinement
  rounds, ONE batched per-scenario-availability engine pass per round,
  raced against the per-scenario ``run_tola`` loop it replaced (one
  engine call per scenario per round, same results to f32 tolerance);
  ``refine_batch_speedup`` is a same-machine ratio with a modest CI
  floor — the engine pass batches but the learner replay between rounds
  is identical host work in both paths, so Amdahl caps the end-to-end
  ratio well below the engine-only win.
* ``jax+refine+shard`` — the same batched refinement on the 2-D mesh;
  ``refine_shard_speedup`` is recorded honestly (forced host devices
  SPLIT the visible cores, so on a small CPU box expect ~1x — like the
  other shard legs, the CI gate is the 2x per-cell regression rule vs
  the committed JSON plus bit-parity, not an absolute speedup; the
  absolute win needs real multi-device hardware).

``--only {warm,plan,e2e,stream,synth,shard,refine}`` runs a subset of
those sections (default: all).

Emits ``BENCH_pipeline.json``:

    PYTHONPATH=src python -m benchmarks.bench_pipeline \
        [--jobs 512] [--policies 70] [--scenarios 4] [--r 600] \
        [--backends numpy jax] [--out BENCH_pipeline.json]

Off-TPU the pallas backend runs in interpret mode — kernel-logic timing,
not TPU speed (tagged in the output; compare numpy vs jax there). The
shard legs on a 1-device box are the degenerate mesh — run CI-style with
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise real
sharding on CPU.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

import numpy as np

from repro import obs
from repro.core import Policy, generate_chain_jobs, selfowned_policies
from repro.core.scheduler import build_plans, build_plans_batch
from repro.engine import ScenarioSpec, evaluate_grid, make_scenarios
from repro.engine.plan import distinct_window_params
from benchmarks.bench_engine import obs_block

__all__ = ["run", "main"]


def _best_of(fn, iters: int) -> float:
    best = np.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _synth_sweep(horizon: float, n_scenarios: int, sweep_max: int,
                 seed: int, iters: int) -> dict:
    """Scenario-synthesis throughput: host list vs spec (numpy / device)."""
    try:
        import jax
        has_jax = True
    except Exception:
        has_jax = False
    from repro.engine.scenarios import SynthBatch, _device_synth_fn

    sweep = []
    S = max(n_scenarios, 64)
    sizes = []
    while S <= sweep_max:
        sizes.append(S)
        S *= 4
    for S in sizes:
        spec = ScenarioSpec("fresh", horizon, S, seed=seed + 1000)
        cells = S * spec.n_slots
        it = 1 if S > 1024 else iters   # the big host lists take seconds
        t_list = _best_of(
            lambda: make_scenarios(horizon, S, seed=seed + 1000), it)
        t_spec = _best_of(lambda: spec.prices(), it)
        entry = {"S": S, "n_slots": spec.n_slots, "cells": cells,
                 "host_list_seconds": t_list,
                 "spec_numpy_seconds": t_spec,
                 "spec_numpy_speedup": t_list / t_spec}
        msg = (f"[synth S={S:5d}] list {t_list:7.3f}s  "
               f"spec {t_spec:7.3f}s ({t_list / t_spec:.1f}x)")
        if has_jax:
            def dev():
                SynthBatch(spec, 0, S, device=True).prepare()

            dev()                        # absorb the jit compile
            entry["spec_device_seconds"] = _best_of(dev, it)
            entry["spec_device_speedup"] = (t_list
                                            / entry["spec_device_seconds"])
            msg += (f"  device {entry['spec_device_seconds']:7.3f}s "
                    f"({entry['spec_device_speedup']:.1f}x)")
            _device_synth_fn.cache_clear()  # free the big per-S programs
        sweep.append(entry)
        print(msg)
    return {"kind": "fresh", "sweep": sweep}


SECTIONS = ("warm", "plan", "e2e", "stream", "synth", "shard", "refine")


def _parse_mesh2d(mesh2d: str | None):
    """``"NxM"`` -> a 2-D GridMesh; None -> N//2 x 2 over visible devices.

    Degenerates to 1x1 (the unsharded-equivalent mesh) on a 1-device box,
    so the legs always run; CI forces 8 host devices and passes the 4x2 /
    2x4 matrix explicitly.
    """
    from repro.engine import GridMesh

    if mesh2d is not None:
        n, _, m = mesh2d.lower().partition("x")
        return GridMesh.create(int(n), model_devices=int(m or 1))
    import jax

    avail = len(jax.devices())
    m = 2 if avail >= 2 else 1
    return GridMesh.create(max(avail // m, 1), model_devices=m)


def _warm_section(out, jobs, grid, horizon, n_scenarios, r_total, cells,
                  seed):
    """Cross-call reuse legs (DESIGN.md §11): cold/warm/delta evaluate_grid.

    Runs FIRST among the jax-touching sections so the cold call genuinely
    pays every XLA compile of the process; the warm call (same
    jobs/spec/grid, same process) must then hit the plan cache on every
    group and compile nothing — the cache-smoke CI job gates on exactly
    these numbers. The jax persistent compilation cache is deliberately
    NOT wired up here (it would hollow out the cold leg).
    """
    import dataclasses

    from repro.engine import cache as engine_cache
    from repro.engine import evaluate_grid_delta
    from repro.obs.compiled import CompileWatch

    spec = ScenarioSpec("fresh", horizon, n_scenarios, seed=seed + 1000)
    engine_cache.clear_caches()

    watch = CompileWatch()
    with watch:
        t0 = time.perf_counter()
        res_cold = evaluate_grid(jobs, grid, spec, r_total, backend="jax")
        cold = time.perf_counter() - t0
    cold_compiles = watch.compiles

    pc0 = engine_cache.PLAN_CACHE.cache_info()
    with watch:
        t0 = time.perf_counter()
        res_warm = evaluate_grid(jobs, grid, spec, r_total, backend="jax")
        warm = time.perf_counter() - t0
    pc1 = engine_cache.PLAN_CACHE.cache_info()
    hits, misses = pc1.hits - pc0.hits, pc1.misses - pc0.misses
    entry = {
        "cold_end_to_end_seconds": cold,
        "end_to_end_seconds": warm,
        "warm_speedup": cold / warm,
        "cold_compiles": cold_compiles,
        "warm_compiles": watch.compiles,
        "compile_watch_supported": watch.supported,
        "plan_cache_hits": hits,
        "plan_cache_misses": misses,
        "plan_cache_hit_rate": hits / max(hits + misses, 1),
        "plan_cached_groups": res_warm.timings.get("plan_cached", 0),
        "cells_per_sec_end_to_end": cells / warm,
        "max_abs_diff_vs_cold": float(
            np.abs(res_warm.unit_cost - res_cold.unit_cost).max()),
    }
    out["backends"]["jax+warm"] = entry
    print(f"[jax+warm        ] cold {cold:7.3f}s ({cold_compiles} compiles)"
          f"  warm {warm:7.3f}s ({watch.compiles} compiles, "
          f"{hits}/{hits + misses} plan-cache hits)  "
          f"{entry['warm_speedup']:.1f}x")

    # ~10% of the grid gets perturbed bids -> new eval groups; the delta
    # path re-scores only those and splices everything else straight out
    # of res_warm's tensors.
    idx = list(range(0, len(grid), 10))
    grid2 = list(grid)
    for k, i in enumerate(idx):
        grid2[i] = dataclasses.replace(
            grid[i], bid=grid[i].bid * 1.01 + 1e-4 * (k + 1))
    # Full re-eval FIRST: it pays the XLA compiles for the new bids'
    # batch shapes, so the delta timing below measures the work saved by
    # re-scoring fewer groups, not a compile-order artifact.
    t0 = time.perf_counter()
    res_full = evaluate_grid(jobs, grid2, spec, r_total, backend="jax")
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_delta = evaluate_grid_delta(res_warm, jobs, grid2, spec, r_total,
                                    backend="jax")
    t_delta = time.perf_counter() - t0
    dentry = {
        "end_to_end_seconds": t_delta,
        "full_end_to_end_seconds": t_full,
        "delta_speedup": t_full / t_delta,
        "n_policies_changed": len(idx),
        "delta_groups_rescored": int(
            res_delta.timings["delta_groups_rescored"]),
        "delta_groups_total": int(res_delta.timings["delta_groups_total"]),
        "max_abs_diff_vs_full": float(
            np.abs(res_delta.unit_cost - res_full.unit_cost).max()),
    }
    out["backends"]["jax+delta"] = dentry
    print(f"[jax+delta       ] {t_delta:7.3f}s re-scoring "
          f"{dentry['delta_groups_rescored']}/{dentry['delta_groups_total']} "
          f"groups (full {t_full:7.3f}s, {dentry['delta_speedup']:.1f}x, "
          f"max diff {dentry['max_abs_diff_vs_full']:.2e})")


def run(n_jobs: int, n_policies: int, n_scenarios: int, r_total: int,
        backends: list[str], seed: int = 0, job_type: int = 2,
        iters: int = 3, scenario_sweep_max: int = 4096,
        sections=None, mesh: int | None = None,
        shard_scale_max: int = 65536, mesh2d: str | None = None,
        pool_iters: int = 2) -> dict:
    if iters < 1:
        raise ValueError("need --iters >= 1 (one timed pass after warmup)")
    sections = SECTIONS if sections is None else tuple(sections)
    for s in sections:
        if s not in SECTIONS:
            raise ValueError(f"unknown section {s!r}; pick from {SECTIONS}")
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    markets = make_scenarios(horizon, n_scenarios, seed=seed + 1000)
    grid = selfowned_policies()[:n_policies]
    if len(grid) < n_policies:
        raise ValueError(f"policy grid has only {len(grid)} policies")
    cells = n_scenarios * n_jobs * len(grid)

    # --- plan phase: batched builder vs the legacy per-group loop --------
    xs = list(distinct_window_params(grid, r_total).values())

    out = {
        "n_jobs": n_jobs,
        "n_policies": len(grid),
        "n_scenarios": n_scenarios,
        "r_total": r_total,
        "job_type": job_type,
        "seed": seed,
        "cells": cells,
        "window_groups": len(xs),
        "backends": {},
    }
    try:
        import jax
        out["jax_backend"] = jax.default_backend()
    except Exception:
        out["jax_backend"] = None

    # Metrics collect across every leg; compiled programs are captured on
    # the warmup pass of each leg (capture lowers+compiles once, which
    # must not count against the timed iterations). Both land in
    # out["obs"] — the enriched phase/collective breakdown.
    reg = obs.CompiledRegistry()
    _obs_stack = contextlib.ExitStack()
    _obs_stack.enter_context(obs.METRICS.collecting(reset=True))

    if "warm" in sections:
        if out["jax_backend"] is None or "jax" not in backends:
            print("[warm   ] skipped (needs jax and the jax backend)")
        else:
            _warm_section(out, jobs, grid, horizon, n_scenarios, r_total,
                          cells, seed)

    if "plan" in sections:
        t_loop = _best_of(
            lambda: [build_plans(jobs, Policy(beta=x, bid=0.0), r_total)
                     for x in xs], iters)
        t_batch = _best_of(lambda: build_plans_batch(jobs, xs), iters)
        out["plan_loop_seconds"] = t_loop
        out["plan_batch_seconds"] = t_batch
        out["plan_batch_speedup"] = t_loop / t_batch
        print(f"[plan  ] loop {t_loop:7.3f}s  batch {t_batch:7.3f}s  "
              f"({out['plan_batch_speedup']:.1f}x, {len(xs)} window groups)")

    # --- end-to-end jobs -> cost tensor, per (backend, plan-backend) -----
    # Host-plan legs keep the bare backend key (the CI regression gate
    # compares them across runs); the device-plan leg of each non-numpy
    # backend races the SAME end-to-end pass with the plan tensors built
    # on device ("<backend>+device-plan").
    legs = [(b, "host") for b in backends]
    legs += [(b, "device") for b in backends if b != "numpy"]
    if "e2e" not in sections:
        legs = []
    ref = None
    for backend, plan_backend in legs:
        name = backend if plan_backend == "host" \
            else f"{backend}+device-plan"
        res = None
        best = np.inf
        phases = None
        for it in range(iters + 1):
            cap = obs.capture(reg) if it == 0 else contextlib.nullcontext()
            t0 = time.perf_counter()
            with cap:
                res = evaluate_grid(jobs, grid, markets, r_total,
                                    backend=backend,
                                    plan_backend=plan_backend)
            dt = time.perf_counter() - t0
            if it == 0:
                warmup = dt      # absorbs jit / pallas compilation
            elif dt < best:
                best, phases = dt, dict(res.timings)
        entry = {
            "end_to_end_seconds": best,
            "warmup_seconds": warmup,
            "cells_per_sec_end_to_end": cells / best,
            "plan_seconds": phases["plan"],
            "pool_seconds": phases["pool"],
            "eval_seconds": phases["eval"],
            # timings is always fully populated now (span-derived; the
            # .get guard predates the empty-dict default of EngineResult)
            "synth_seconds": phases["synth"],
            "plan_device_seconds": phases["plan_device"],
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
        }
        if entry["interpret"]:
            entry["note"] = ("pallas kernels ran in INTERPRET mode on CPU — "
                             "kernel-logic timing, NOT TPU speed; do not "
                             "compare against the numpy/jax entries")
        out["backends"][name] = entry
        if ref is None:
            ref = res.unit_cost
            entry["max_abs_diff_vs_first"] = 0.0
        else:
            entry["max_abs_diff_vs_first"] = float(
                np.abs(res.unit_cost - ref).max())
        tag = "  (interpret — kernel logic, NOT TPU speed)" \
            if entry["interpret"] else ""
        print(f"[{name:16s}] {best:7.3f}s end-to-end  "
              f"(plan {phases['plan']:.3f}  pool {phases['pool']:.3f}  "
              f"eval {phases['eval']:.3f})  "
              f"{cells / best / 1e3:9.1f}k cells/s{tag}")

    # --- chunked scenario stream from a declarative spec -----------------
    # Same grid, but the scenarios come from a ScenarioSpec streamed
    # scenario_chunk per pass (device-synthesized price paths on the
    # non-numpy backends). Cross-checked against the numpy oracle on the
    # SAME spec (the list-path ref above realizes different prices).
    spec = ScenarioSpec("fresh", horizon, n_scenarios, seed=seed + 1000)
    chunk = max(1, n_scenarios // 2)
    spec_ref = None
    if "stream" in sections or "shard" in sections:
        spec_ref = evaluate_grid(jobs, grid, spec, r_total,
                                 backend="numpy").unit_cost

    def stream_leg(name, backend, smesh=None, overlap=None):
        res = None
        best = np.inf
        phases = None
        for it in range(iters + 1):
            cap = obs.capture(reg) if it == 0 else contextlib.nullcontext()
            t0 = time.perf_counter()
            with cap:
                res = evaluate_grid(jobs, grid, spec, r_total,
                                    backend=backend, scenario_chunk=chunk,
                                    mesh=smesh, overlap=overlap)
            dt = time.perf_counter() - t0
            if it == 0:
                warmup = dt
            elif dt < best:
                best, phases = dt, dict(res.timings)
        entry = {
            "end_to_end_seconds": best,
            "warmup_seconds": warmup,
            "cells_per_sec_end_to_end": cells / best,
            "plan_seconds": phases["plan"],
            "pool_seconds": phases["pool"],
            "eval_seconds": phases["eval"],
            "synth_seconds": phases["synth"],
            "plan_device_seconds": phases["plan_device"],
            "scenario_chunk": chunk,
            "n_chunks": len(phases["chunks"]),
            "overlap": bool(phases["overlap"]),
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
            "max_abs_diff_vs_numpy_spec": float(
                np.abs(res.unit_cost - spec_ref).max()),
        }
        if smesh is not None:
            entry["mesh_shards"] = smesh.n_shards
        if entry["interpret"]:
            entry["note"] = ("pallas kernels ran in INTERPRET mode on CPU — "
                             "kernel-logic timing, NOT TPU speed; do not "
                             "compare against the numpy/jax entries")
        out["backends"][name] = entry
        print(f"[{name:17s}] {best:7.3f}s end-to-end  "
              f"(plan {phases['plan']:.3f}  synth {phases['synth']:.3f}  "
              f"eval {phases['eval']:.3f}, {len(phases['chunks'])} chunks)  "
              f"{cells / best / 1e3:9.1f}k cells/s")
        return entry

    if "stream" in sections:
        for backend in [b for b in backends if b != "numpy"]:
            stream_leg(f"{backend}+spec-stream", backend)

    if "synth" in sections:
        out["scenario_synthesis"] = _synth_sweep(
            horizon, n_scenarios, scenario_sweep_max, seed, iters)

    if "shard" in sections:
        if out["jax_backend"] is None or "jax" not in backends:
            print("[shard  ] skipped (needs jax and the jax backend)")
        else:
            _shard_section(out, jobs, grid, stream_leg, mesh,
                           shard_scale_max, r_total, horizon, seed,
                           job_type, reg, mesh2d)

    if "refine" in sections:
        if out["jax_backend"] is None or "jax" not in backends:
            print("[refine ] skipped (needs jax and the jax backend)")
        elif r_total <= 0:
            print("[refine ] skipped (needs --r > 0 for pool refinement)")
        else:
            _refine_section(out, jobs, grid, markets, r_total, seed,
                            pool_iters, iters, mesh2d, reg)
    _obs_stack.close()
    out["obs"] = obs_block(reg)
    return out


def _shard_section(out, jobs, grid, stream_leg, mesh, shard_scale_max,
                   r_total, horizon, seed, job_type, reg, mesh2d=None):
    """Sharded spec-stream legs + the replay_stream scenario-scaling sweep.

    The sweep runs on a REDUCED grid (its point is the scenario axis, not
    the cell count): regret statistics for S up to ``shard_scale_max``
    scenarios streamed ``chunk`` at a time through the sharded engine +
    sharded fold — wall clock grows linearly in S while peak memory stays
    pinned at one chunk.
    """
    from repro.engine import ScenarioMesh
    from repro.engine.mesh import as_scenario_mesh
    from repro.learn import replay_stream

    smesh = as_scenario_mesh(mesh)
    if smesh is None:
        smesh = ScenarioMesh.create()
    plain = stream_leg("jax+shard", "jax", smesh=smesh, overlap=False)
    over = stream_leg("jax+shard+overlap", "jax", smesh=smesh, overlap=True)
    # The overlap win: residual synth wait once chunk k+1 is dispatched
    # before chunk k's eval blocks (see EngineResult.timings "overlap").
    over["overlap_synth_win_seconds"] = (plain["synth_seconds"]
                                         - over["synth_seconds"])

    # 2-D scenario x policy-group grid (DESIGN.md Section 9): the same
    # stream workload with the eval-group axis sharded over "model".
    gmesh = _parse_mesh2d(mesh2d)
    e2d = stream_leg("jax+shard2d", "jax", smesh=gmesh, overlap=False)
    e2d["mesh_shape"] = [gmesh.data_shards, gmesh.model_shards]

    chunk = 8192
    sw_jobs = generate_chain_jobs(16, job_type, seed=seed)
    sw_horizon = max(j.deadline for j in sw_jobs) + 1.0
    sw_grid = grid[:4]
    sweep = []
    S = chunk
    while S <= shard_scale_max:
        spec = ScenarioSpec("fresh", sw_horizon, S, seed=seed + 1)
        # First sweep point doubles as the capture pass for the sharded
        # fold program (its one-psum-per-chunk collective count belongs in
        # the obs block); its wall clock absorbs the capture's compile.
        cap = obs.capture(reg) if not sweep else contextlib.nullcontext()
        t0 = time.perf_counter()
        with cap:
            slr = replay_stream(sw_jobs, sw_grid, spec, r_total,
                                learners=["hedge"], seed=seed,
                                scenario_chunk=chunk, backend="jax",
                                engine_backend="jax", mesh=smesh,
                                overlap=True)
        dt = time.perf_counter() - t0
        sweep.append({
            "S": S, "seconds": dt, "scenarios_per_sec": S / dt,
            "n_chunks": slr.n_chunks,
            "regret": float(slr.regret_per_job()[0]),
            "regret_std": float(slr.regret_std()[0]),
        })
        print(f"[shard scale S={S:8d}] {dt:8.2f}s  "
              f"{S / dt:8.0f} scenarios/s  {slr.n_chunks:4d} chunks  "
              f"regret {sweep[-1]['regret']:.4f} "
              f"+- {sweep[-1]['regret_std']:.4f}")
        if S >= shard_scale_max:
            break
        S = min(S * 4, shard_scale_max)  # always land on the cap itself
    out["shard_scaling"] = {
        "mesh_shards": smesh.n_shards, "scenario_chunk": chunk,
        "n_jobs": len(sw_jobs), "n_policies": len(sw_grid),
        "sweep": sweep,
    }


def _refine_section(out, jobs, grid, markets, r_total, seed, pool_iters,
                    iters, mesh2d, reg):
    """TOLA pool-refinement legs: per-scenario loop vs batched vs sharded.

    ``run_tola_scenarios`` makes exactly ONE per-scenario-availability
    engine pass per refinement round; the loop baseline is the
    ``run_tola``-per-market path it replaced (one engine call per
    scenario per round, same results to f32 tolerance).
    ``refine_batch_speedup`` is a same-machine ratio with a modest CI
    floor (the per-round learner replay is identical host work in both
    paths, so Amdahl caps the end-to-end ratio). The sharded leg rides
    the 2-D GridMesh through EVERY round (refined per-scenario plan
    stacks on "data", group rows on "model"); its speedup is recorded
    honestly and gated only by the per-cell regression rule plus
    bit-parity with the batched leg — forced host devices share the
    visible cores, so the absolute shard win needs real multi-device
    hardware.
    """
    from repro.core import run_tola, run_tola_scenarios

    S = len(markets)
    kw = dict(r_total=r_total, pool_iters=pool_iters, backend="jax")
    rounds = 1 + pool_iters
    cells = S * len(jobs) * len(grid) * rounds

    def loop():
        return [run_tola(jobs, grid, markets[s], seed=seed + s, **kw)
                for s in range(S)]

    run_tola(jobs, grid, markets[0], seed=seed, **kw)  # absorb S=1 compiles
    t0 = time.perf_counter()
    res_loop = loop()
    t_loop = time.perf_counter() - t0

    def timed(fn, capture_first):
        best, res = np.inf, None
        for it in range(iters + 1):
            cap = obs.capture(reg) if it == 0 and capture_first \
                else contextlib.nullcontext()
            t0 = time.perf_counter()
            with cap:
                res = fn()
            dt = time.perf_counter() - t0
            if it == 0:
                warmup = dt
            else:
                best = min(best, dt)
        return best, warmup, res

    t_batch, warm_b, res_batch = timed(
        lambda: run_tola_scenarios(jobs, grid, markets, seed=seed, **kw),
        capture_first=False)
    diff_loop = max(
        float(np.abs(rb.cost_matrix - rl.cost_matrix).max())
        for rb, rl in zip(res_batch, res_loop))
    entry = {
        "end_to_end_seconds": t_batch,
        "warmup_seconds": warm_b,
        "loop_seconds": t_loop,
        "refine_batch_speedup": t_loop / t_batch,
        "pool_iters": pool_iters,
        "refine_rounds": rounds,
        "n_scenarios": S,
        "refine_cells": cells,
        "cells_per_sec_end_to_end": cells / t_batch,
        "max_abs_diff_vs_loop": diff_loop,
        "note": ("end-to-end includes the per-round host learner replay, "
                 "identical in both paths — the batched win is in the "
                 "engine pass, Amdahl caps the e2e ratio"),
    }
    out["backends"]["jax+refine"] = entry
    print(f"[jax+refine      ] {t_batch:7.3f}s batched "
          f"({rounds} rounds x 1 engine pass)  loop {t_loop:7.3f}s "
          f"({S * rounds} passes)  {entry['refine_batch_speedup']:.1f}x  "
          f"max diff {diff_loop:.2e}")

    gmesh = _parse_mesh2d(mesh2d)
    t_shard, warm_s, res_shard = timed(
        lambda: run_tola_scenarios(jobs, grid, markets, seed=seed,
                                   mesh=gmesh, **kw),
        capture_first=True)   # captures the chain_ps/task_ps:sharded HLO
    diff_shard = max(
        float(np.abs(rs.cost_matrix - rb.cost_matrix).max())
        for rs, rb in zip(res_shard, res_batch))
    sentry = {
        "end_to_end_seconds": t_shard,
        "warmup_seconds": warm_s,
        "refine_shard_speedup": t_batch / t_shard,
        "mesh_shards": gmesh.n_shards,
        "mesh_shape": [gmesh.data_shards, gmesh.model_shards],
        "pool_iters": pool_iters,
        "refine_rounds": rounds,
        "n_scenarios": S,
        "refine_cells": cells,
        "cells_per_sec_end_to_end": cells / t_shard,
        "max_abs_diff_vs_batched": diff_shard,
        "note": ("forced host devices split the visible CPU cores, so "
                 "expect ~1x on a small box; the absolute shard win "
                 "needs real multi-device hardware"),
    }
    out["backends"]["jax+refine+shard"] = sentry
    print(f"[jax+refine+shard] {t_shard:7.3f}s on "
          f"{gmesh.data_shards}x{gmesh.model_shards} mesh "
          f"({sentry['refine_shard_speedup']:.2f}x vs batched)  "
          f"max diff {diff_shard:.2e}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=512)
    p.add_argument("--policies", type=int, default=70)
    p.add_argument("--scenarios", type=int, default=4)
    p.add_argument("--r", type=int, default=600)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--job-type", type=int, default=2)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--backends", nargs="+", default=["numpy", "jax"],
                   choices=["numpy", "jax", "pallas"])
    p.add_argument("--scenario-sweep-max", type=int, default=4096,
                   help="largest S of the scenario-synthesis sweep")
    p.add_argument("--only", nargs="+", default=None, choices=SECTIONS,
                   help="run a subset of the benchmark sections")
    p.add_argument("--mesh", type=int, default=None,
                   help="shard count of the jax+shard legs (default: every "
                        "visible device; clamped with a warning)")
    p.add_argument("--mesh2d", default=None, metavar="NxM",
                   help="scenario x policy-group grid of the jax+shard2d "
                        "and jax+refine+shard legs, e.g. 4x2 (default: "
                        "N//2 x 2 over the visible devices)")
    p.add_argument("--pool-iters", type=int, default=2,
                   help="TOLA pool-refinement rounds of the refine legs")
    p.add_argument("--shard-scale-max", type=int, default=65536,
                   help="largest S of the sharded replay_stream scaling "
                        "sweep (the committed baseline uses 1048576)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="save a Chrome/Perfetto span trace of the run "
                        "(CI uploads this from the smoke grid)")
    p.add_argument("--out", default="BENCH_pipeline.json")
    args = p.parse_args(argv)
    tracer = obs.Tracer() if args.trace else None
    ctx = obs.tracing(tracer) if tracer is not None \
        else contextlib.nullcontext()
    with ctx:
        res = run(args.jobs, args.policies, args.scenarios, args.r,
                  args.backends, seed=args.seed, job_type=args.job_type,
                  iters=args.iters,
                  scenario_sweep_max=args.scenario_sweep_max,
                  sections=args.only, mesh=args.mesh,
                  shard_scale_max=args.shard_scale_max,
                  mesh2d=args.mesh2d, pool_iters=args.pool_iters)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote Perfetto trace ({len(tracer)} spans): {args.trace}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
