"""End-to-end pipeline throughput: jobs -> plans -> pool -> cost tensor.

``bench_engine`` times only the backend evaluation of a prebuilt grid plan;
this benchmark times the WHOLE ``evaluate_grid`` pass per backend — plan
tensor construction, self-owned pool arithmetic, and market realization —
and breaks the wall time into those three phases (``EngineResult.timings``),
so the plan layer's cost is a tracked number instead of hidden warmup.
It also races the batched plan builder (``build_plans_batch``, one
vectorized (G, J, L) pass over the deduplicated window-parameter grid)
against the legacy per-group ``build_plans`` loop it replaced, and — for
every non-numpy backend — the HOST plan path (f64 numpy oracle) against
the DEVICE plan path (``plan_backend="device"``: the whole jobs->plan
tensor pass as one jit program, ``<backend>+device-plan`` entries).

Scenario legs (the stream side of the pipeline):

* ``scenario_synthesis`` — price-path construction throughput, host
  materialized list (``make_scenarios``, one numpy Generator + SpotMarket
  per scenario) vs declarative ``ScenarioSpec`` (counter-hash synthesis:
  f64 oracle rows, and the jitted device generator when jax is present),
  S swept geometrically up to ``--scenario-sweep-max`` (default 4096) over
  the same horizon as the grid.
* ``<backend>+spec-stream`` — the full end-to-end pass from a
  ``ScenarioSpec`` with ``scenario_chunk`` (chunked device synthesis +
  evaluation against one shared grid plan), gated in CI with the same
  2x per-cell regression rule as the other legs; its cost tensor is
  cross-checked against the numpy oracle on the SAME spec.

Emits ``BENCH_pipeline.json``:

    PYTHONPATH=src python -m benchmarks.bench_pipeline \
        [--jobs 512] [--policies 70] [--scenarios 4] [--r 600] \
        [--backends numpy jax] [--out BENCH_pipeline.json]

Off-TPU the pallas backend runs in interpret mode — kernel-logic timing,
not TPU speed (tagged in the output; compare numpy vs jax there).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import Policy, generate_chain_jobs, selfowned_policies
from repro.core.scheduler import build_plans, build_plans_batch
from repro.engine import ScenarioSpec, evaluate_grid, make_scenarios
from repro.engine.plan import distinct_window_params

__all__ = ["run", "main"]


def _best_of(fn, iters: int) -> float:
    best = np.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _synth_sweep(horizon: float, n_scenarios: int, sweep_max: int,
                 seed: int, iters: int) -> dict:
    """Scenario-synthesis throughput: host list vs spec (numpy / device)."""
    try:
        import jax
        has_jax = True
    except Exception:
        has_jax = False
    from repro.engine.scenarios import SynthBatch, _device_synth_fn

    sweep = []
    S = max(n_scenarios, 64)
    sizes = []
    while S <= sweep_max:
        sizes.append(S)
        S *= 4
    for S in sizes:
        spec = ScenarioSpec("fresh", horizon, S, seed=seed + 1000)
        cells = S * spec.n_slots
        it = 1 if S > 1024 else iters   # the big host lists take seconds
        t_list = _best_of(
            lambda: make_scenarios(horizon, S, seed=seed + 1000), it)
        t_spec = _best_of(lambda: spec.prices(), it)
        entry = {"S": S, "n_slots": spec.n_slots, "cells": cells,
                 "host_list_seconds": t_list,
                 "spec_numpy_seconds": t_spec,
                 "spec_numpy_speedup": t_list / t_spec}
        msg = (f"[synth S={S:5d}] list {t_list:7.3f}s  "
               f"spec {t_spec:7.3f}s ({t_list / t_spec:.1f}x)")
        if has_jax:
            def dev():
                SynthBatch(spec, 0, S, device=True).prepare()

            dev()                        # absorb the jit compile
            entry["spec_device_seconds"] = _best_of(dev, it)
            entry["spec_device_speedup"] = (t_list
                                            / entry["spec_device_seconds"])
            msg += (f"  device {entry['spec_device_seconds']:7.3f}s "
                    f"({entry['spec_device_speedup']:.1f}x)")
            _device_synth_fn.cache_clear()  # free the big per-S programs
        sweep.append(entry)
        print(msg)
    return {"kind": "fresh", "sweep": sweep}


def run(n_jobs: int, n_policies: int, n_scenarios: int, r_total: int,
        backends: list[str], seed: int = 0, job_type: int = 2,
        iters: int = 3, scenario_sweep_max: int = 4096) -> dict:
    if iters < 1:
        raise ValueError("need --iters >= 1 (one timed pass after warmup)")
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    markets = make_scenarios(horizon, n_scenarios, seed=seed + 1000)
    grid = selfowned_policies()[:n_policies]
    if len(grid) < n_policies:
        raise ValueError(f"policy grid has only {len(grid)} policies")
    cells = n_scenarios * n_jobs * len(grid)

    # --- plan phase: batched builder vs the legacy per-group loop --------
    xs = list(distinct_window_params(grid, r_total).values())

    t_loop = _best_of(
        lambda: [build_plans(jobs, Policy(beta=x, bid=0.0), r_total)
                 for x in xs], iters)
    t_batch = _best_of(lambda: build_plans_batch(jobs, xs), iters)

    out = {
        "n_jobs": n_jobs,
        "n_policies": len(grid),
        "n_scenarios": n_scenarios,
        "r_total": r_total,
        "job_type": job_type,
        "seed": seed,
        "cells": cells,
        "window_groups": len(xs),
        "plan_loop_seconds": t_loop,
        "plan_batch_seconds": t_batch,
        "plan_batch_speedup": t_loop / t_batch,
        "backends": {},
    }
    try:
        import jax
        out["jax_backend"] = jax.default_backend()
    except Exception:
        out["jax_backend"] = None
    print(f"[plan  ] loop {t_loop:7.3f}s  batch {t_batch:7.3f}s  "
          f"({out['plan_batch_speedup']:.1f}x, {len(xs)} window groups)")

    # --- end-to-end jobs -> cost tensor, per (backend, plan-backend) -----
    # Host-plan legs keep the bare backend key (the CI regression gate
    # compares them across runs); the device-plan leg of each non-numpy
    # backend races the SAME end-to-end pass with the plan tensors built
    # on device ("<backend>+device-plan").
    legs = [(b, "host") for b in backends]
    legs += [(b, "device") for b in backends if b != "numpy"]
    ref = None
    for backend, plan_backend in legs:
        name = backend if plan_backend == "host" \
            else f"{backend}+device-plan"
        res = None
        best = np.inf
        phases = None
        for it in range(iters + 1):
            t0 = time.perf_counter()
            res = evaluate_grid(jobs, grid, markets, r_total,
                                backend=backend, plan_backend=plan_backend)
            dt = time.perf_counter() - t0
            if it == 0:
                warmup = dt      # absorbs jit / pallas compilation
            elif dt < best:
                best, phases = dt, dict(res.timings)
        entry = {
            "end_to_end_seconds": best,
            "warmup_seconds": warmup,
            "cells_per_sec_end_to_end": cells / best,
            "plan_seconds": phases["plan"],
            "pool_seconds": phases["pool"],
            "eval_seconds": phases["eval"],
            "synth_seconds": phases.get("synth", 0.0),
            "plan_device_seconds": phases["plan_device"],
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
        }
        if entry["interpret"]:
            entry["note"] = ("pallas kernels ran in INTERPRET mode on CPU — "
                             "kernel-logic timing, NOT TPU speed; do not "
                             "compare against the numpy/jax entries")
        out["backends"][name] = entry
        if ref is None:
            ref = res.unit_cost
            entry["max_abs_diff_vs_first"] = 0.0
        else:
            entry["max_abs_diff_vs_first"] = float(
                np.abs(res.unit_cost - ref).max())
        tag = "  (interpret — kernel logic, NOT TPU speed)" \
            if entry["interpret"] else ""
        print(f"[{name:16s}] {best:7.3f}s end-to-end  "
              f"(plan {phases['plan']:.3f}  pool {phases['pool']:.3f}  "
              f"eval {phases['eval']:.3f})  "
              f"{cells / best / 1e3:9.1f}k cells/s{tag}")

    # --- chunked scenario stream from a declarative spec -----------------
    # Same grid, but the scenarios come from a ScenarioSpec streamed
    # scenario_chunk per pass (device-synthesized price paths on the
    # non-numpy backends). Cross-checked against the numpy oracle on the
    # SAME spec (the list-path ref above realizes different prices).
    spec = ScenarioSpec("fresh", horizon, n_scenarios, seed=seed + 1000)
    chunk = max(1, n_scenarios // 2)
    spec_ref = evaluate_grid(jobs, grid, spec, r_total,
                             backend="numpy").unit_cost
    for backend in [b for b in backends if b != "numpy"]:
        name = f"{backend}+spec-stream"
        res = None
        best = np.inf
        phases = None
        for it in range(iters + 1):
            t0 = time.perf_counter()
            res = evaluate_grid(jobs, grid, spec, r_total, backend=backend,
                                scenario_chunk=chunk)
            dt = time.perf_counter() - t0
            if it == 0:
                warmup = dt
            elif dt < best:
                best, phases = dt, dict(res.timings)
        entry = {
            "end_to_end_seconds": best,
            "warmup_seconds": warmup,
            "cells_per_sec_end_to_end": cells / best,
            "plan_seconds": phases["plan"],
            "pool_seconds": phases["pool"],
            "eval_seconds": phases["eval"],
            "synth_seconds": phases["synth"],
            "plan_device_seconds": phases["plan_device"],
            "scenario_chunk": chunk,
            "n_chunks": len(phases["chunks"]),
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
            "max_abs_diff_vs_numpy_spec": float(
                np.abs(res.unit_cost - spec_ref).max()),
        }
        if entry["interpret"]:
            entry["note"] = ("pallas kernels ran in INTERPRET mode on CPU — "
                             "kernel-logic timing, NOT TPU speed; do not "
                             "compare against the numpy/jax entries")
        out["backends"][name] = entry
        print(f"[{name:16s}] {best:7.3f}s end-to-end  "
              f"(plan {phases['plan']:.3f}  synth {phases['synth']:.3f}  "
              f"eval {phases['eval']:.3f}, {len(phases['chunks'])} chunks)  "
              f"{cells / best / 1e3:9.1f}k cells/s")

    out["scenario_synthesis"] = _synth_sweep(horizon, n_scenarios,
                                             scenario_sweep_max, seed, iters)
    return out


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=512)
    p.add_argument("--policies", type=int, default=70)
    p.add_argument("--scenarios", type=int, default=4)
    p.add_argument("--r", type=int, default=600)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--job-type", type=int, default=2)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--backends", nargs="+", default=["numpy", "jax"],
                   choices=["numpy", "jax", "pallas"])
    p.add_argument("--scenario-sweep-max", type=int, default=4096,
                   help="largest S of the scenario-synthesis sweep")
    p.add_argument("--out", default="BENCH_pipeline.json")
    args = p.parse_args(argv)
    res = run(args.jobs, args.policies, args.scenarios, args.r,
              args.backends, seed=args.seed, job_type=args.job_type,
              iters=args.iters, scenario_sweep_max=args.scenario_sweep_max)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
