"""Shared harness for the paper-table benchmarks (Experiments 1-4).

Job streams and the market follow Section 6.1 exactly; see
``repro.core.workload`` / ``repro.core.market`` for the distributional
details and DESIGN.md Section 4 for the two documented interpretation
choices (price law, early starts).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import SpotMarket, generate_chain_jobs
from repro.core.scheduler import Policy, run_jobs

__all__ = ["Setup", "make_setup", "sweep_min", "argparser", "print_table"]


class Setup:
    def __init__(self, jobs, market, job_type: int, seed: int):
        self.jobs = jobs
        self.market = market
        self.job_type = job_type
        self.seed = seed

    @property
    def total_workload(self) -> float:
        return float(sum(j.total_work for j in self.jobs))


def make_setup(n_jobs: int, job_type: int, seed: int = 0) -> Setup:
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    market = SpotMarket(horizon, seed=seed + 1000)
    return Setup(jobs, market, job_type, seed)


def sweep_min(setup: Setup, policies: list[Policy], **run_kwargs):
    """min over a policy grid of the realized average unit cost."""
    best = None
    for pol in policies:
        costs = run_jobs(setup.jobs, pol, setup.market, **run_kwargs)
        a = costs.average_unit_cost()
        if best is None or a < best[1]:
            best = (pol, a, costs)
    return best


def argparser(desc: str) -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=desc)
    p.add_argument("--jobs", type=int, default=1500,
                   help="jobs per stream (paper: ~10000)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--types", type=int, nargs="+", default=[1, 2, 3, 4])
    p.add_argument("--r", type=int, nargs="+", default=[300, 600, 900, 1200])
    return p


def print_table(title: str, header: list[str], rows: list[list[str]]):
    print(f"\n== {title} ==")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}: {time.time() - self.t0:.1f}s]")
