"""Shared harness for the paper-table benchmarks (Experiments 1-4).

Job streams and the market follow Section 6.1 exactly; see
``repro.core.workload`` / ``repro.core.market`` for the distributional
details and DESIGN.md Section 4 for the two documented interpretation
choices (price law, early starts).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import generate_chain_jobs, sweep_policies
from repro.core.scheduler import Policy
from repro.engine import ScenarioSpec, as_source, make_scenarios

__all__ = ["Setup", "make_setup", "sweep_min", "greedy_min",
           "argparser", "print_table"]

# Reuse XLA executables across benchmark PROCESSES (DESIGN.md §11): point
# jax's persistent compilation cache at a local directory so repeated
# paper-table runs skip recompilation entirely. Opt out (e.g. when timing
# cold compiles, as bench_pipeline does by not importing this module)
# with REPRO_JAX_CACHE_DIR=0.
if os.environ.get("REPRO_JAX_CACHE_DIR") != "0":
    try:
        from repro.engine import setup_persistent_cache

        setup_persistent_cache()
    except Exception:
        pass  # jax absent or too old: benchmarks still run, just colder


class Setup:
    def __init__(self, jobs, scenarios, job_type: int, seed: int,
                 backend: str = "auto", scenario_chunk: int | None = None,
                 mesh=None):
        self.jobs = jobs
        self.scenarios = scenarios      # ScenarioSource | ScenarioSpec
        self.job_type = job_type
        self.seed = seed
        self.backend = backend
        self.scenario_chunk = scenario_chunk
        self.mesh = mesh                # ScenarioMesh | int | None
        self._source = as_source(scenarios)

    @property
    def markets(self):
        """Materialized scenario markets (host-only consumers: the greedy
        baseline, the realized shared-pool TOLA replay)."""
        return self._source.markets

    @property
    def market(self):
        """Scenario 0 — the single market of the paper's tables."""
        return self.markets[0]

    @property
    def total_workload(self) -> float:
        return float(sum(j.total_work for j in self.jobs))


def make_setup(n_jobs: int, job_type: int, seed: int = 0,
               scenarios: int = 1, scenario_kind: str = "fresh",
               backend: str = "auto",
               scenario_chunk: int | None = None, mesh=None) -> Setup:
    """Job stream + S market scenarios (S=1 reproduces the paper setup).

    Without ``scenario_chunk`` the scenarios are the legacy materialized
    ``make_scenarios`` list (bit-compatible with every earlier PR's
    tables). With it, they are a declarative ``ScenarioSpec`` streamed
    through the engine ``scenario_chunk`` scenarios per pass — synthesized
    on device for the jax/pallas backends, S bounded by wall clock rather
    than host memory (``adaptive`` requires this path: it needs the
    stream's chunk-boundary feedback). ``mesh`` (an int shard count from
    ``--mesh``, clamped to visible devices with a warning) shards the
    scenario axis across a device mesh (DESIGN.md §9; jax backend only).
    """
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    if scenario_chunk is not None or scenario_kind == "adaptive":
        if scenario_chunk is None:
            raise ValueError(
                "--scenario-kind adaptive needs --scenario-chunk (the "
                "adversary reacts at chunk boundaries)")
        scn = ScenarioSpec(scenario_kind, horizon, max(scenarios, 1),
                           seed=seed + 1000)
    else:
        scn = make_scenarios(horizon, max(scenarios, 1), seed=seed + 1000,
                             kind=scenario_kind)
    return Setup(jobs, scn, job_type, seed, backend,
                 scenario_chunk=scenario_chunk, mesh=mesh)


def sweep_min(setup: Setup, policies: list[Policy], **kwargs):
    """min over a policy grid of the realized average unit cost.

    One batched engine pass over policies x bids x scenarios (the alpha of
    each policy is its scenario mean); see ``repro.core.sweep_policies``.
    For a materialized list setup the scenario source is reused across
    sweeps, so the stacked per-bid view tensors are built once per bid,
    not once per sweep. (Chunked spec setups trade that cache away on
    purpose: streaming re-synthesizes each chunk so peak memory stays
    chunk-sized.)
    """
    kwargs.setdefault("backend", setup.backend)
    kwargs.setdefault("scenario_chunk", setup.scenario_chunk)
    kwargs.setdefault("mesh", setup.mesh)
    pol, alpha, costs, _ = sweep_policies(setup.jobs, policies,
                                          setup._source, **kwargs)
    return pol, alpha, costs


def greedy_min(setup: Setup, bids) -> float:
    """min over bids of the (scenario-mean) Greedy benchmark alpha."""
    from repro.core import run_greedy

    return min(
        float(np.mean([run_greedy(setup.jobs, b, m).average_unit_cost()
                       for m in setup.markets]))
        for b in bids)


def argparser(desc: str) -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=desc)
    p.add_argument("--jobs", type=int, default=1500,
                   help="jobs per stream (paper: ~10000)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--types", type=int, nargs="+", default=[1, 2, 3, 4])
    p.add_argument("--r", type=int, nargs="+", default=[300, 600, 900, 1200])
    p.add_argument("--scenarios", type=int, default=1,
                   help="market scenarios evaluated in one engine pass "
                        "(1 = the paper's single market)")
    p.add_argument("--scenario-kind",
                   choices=["fresh", "regime", "adversarial", "adaptive"],
                   default="fresh",
                   help="market family (adversarial = lure/spike square "
                        "waves driving worst-case TOLA regret; adaptive = "
                        "spikes placed by watching the learner, needs "
                        "--scenario-chunk)")
    p.add_argument("--scenario-chunk", type=int, default=None,
                   help="stream scenarios through the engine K per pass "
                        "from a declarative ScenarioSpec (device-side "
                        "synthesis on jax/pallas; peak memory bounded by "
                        "the chunk, so --scenarios can exceed host memory)")
    p.add_argument("--backend", default="auto",
                   choices=["auto", "numpy", "jax", "pallas"],
                   help="evaluation-engine backend")
    p.add_argument("--mesh", type=int, default=None,
                   help="shard the scenario axis over an N-way device mesh "
                        "(jax backend; clamped to visible devices with a "
                        "warning — force N CPU devices with XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N)")
    return p


def print_table(title: str, header: list[str], rows: list[list[str]]):
    print(f"\n== {title} ==")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}: {time.time() - self.t0:.1f}s]")
