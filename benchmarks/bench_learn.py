"""Online-learning replay throughput: numpy vs jax scan vs pallas kernel.

Times ``repro.learn.replay`` — the sequential sample/observe/reweight
recurrence of Alg. 4 and its bandit variants — over an engine-produced
(scenarios x jobs x policies) cost tensor, batched across a learner x
eta-grid sweep, and emits ``BENCH_learn.json``:

    PYTHONPATH=src python -m benchmarks.bench_learn \
        [--jobs 512] [--policies 70] [--scenarios 4] [--r 600] \
        [--learners hedge exp3 ...] [--eta-grid 0.05 0.2] \
        [--backends numpy jax] [--out BENCH_learn.json]

Reported per backend: wall seconds (best of --iters after one untimed
warmup that absorbs jit/pallas compilation), throughput in learner steps
per second (steps = scenarios x learner instances x jobs — one sampled
decision each), and agreement vs the first backend (fraction of sampled-
trace mismatches, max final-weight deviation). The numpy backend is the
sequential float64 oracle, so the ratio jax/numpy is the speedup the
scan-compiled replay buys. ``pallas`` is opt-in off-TPU: it runs the
weight-update kernel in interpret mode there (kernel logic, not TPU speed)
and only covers hedge-family instances natively.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

import numpy as np

from repro import obs
from repro.core import generate_chain_jobs, selfowned_policies
from repro.engine import evaluate_grid, make_scenarios
from repro.learn import LEARNER_KINDS
from repro.learn import replay as learn_replay
from benchmarks.bench_engine import obs_block
from benchmarks.exp4_online_learning import comparison_specs

__all__ = ["run", "main"]


def run(n_jobs: int, n_policies: int, n_scenarios: int, r_total: int,
        backends: list[str], learners: list[str], eta_grid: list[float],
        seed: int = 0, job_type: int = 2, iters: int = 2) -> dict:
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    markets = make_scenarios(horizon, n_scenarios, seed=seed + 1000)
    grid = selfowned_policies()[:n_policies]
    if len(grid) < n_policies:
        raise ValueError(f"policy grid has only {len(grid)} policies")
    res = evaluate_grid(jobs, grid, markets, r_total, backend="numpy")
    arrivals = np.array([j.arrival for j in jobs])
    d = max(j.deadline - j.arrival for j in jobs)
    specs = comparison_specs(learners, eta_grid)
    steps = n_scenarios * len(specs) * n_jobs
    out = {
        "n_jobs": n_jobs,
        "n_policies": len(grid),
        "n_scenarios": n_scenarios,
        "n_learner_instances": len(specs),
        "learners": [sp.label for sp in specs],
        "r_total": r_total,
        "job_type": job_type,
        "seed": seed,
        "steps": steps,
        "backends": {},
    }
    try:
        import jax
        out["jax_backend"] = jax.default_backend()
    except Exception:
        out["jax_backend"] = None

    ref = None
    reg = obs.CompiledRegistry()
    stack = contextlib.ExitStack()
    stack.enter_context(obs.METRICS.collecting(reset=True))
    for backend in backends:
        times = []
        warmup = None
        lr = None
        for it in range(iters + 1):
            # Program capture on the warmup pass only: the capture's
            # lower+compile must not count against the timed iterations.
            cap = obs.capture(reg) if it == 0 else contextlib.nullcontext()
            t0 = time.time()
            with cap:
                lr = learn_replay(res, arrivals, d, learners=specs,
                                  seed=seed, backend=backend)
            dt = time.time() - t0
            if it == 0:          # warmup absorbs jit/pallas compilation
                warmup = dt
            else:
                times.append(dt)
        best = min(times)
        entry = {
            "seconds": best,
            "warmup_seconds": warmup,
            "steps_per_sec": steps / best,
            # Mirrors the kernel's default: interpret iff CPU.
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
        }
        out["backends"][backend] = entry
        if ref is None:
            ref = lr
            entry["trace_mismatch_vs_first"] = 0.0
            entry["weights_maxdiff_vs_first"] = 0.0
        else:
            entry["trace_mismatch_vs_first"] = float(
                (lr.chosen != ref.chosen).mean())
            entry["weights_maxdiff_vs_first"] = float(
                np.abs(lr.weights - ref.weights).max())
        print(f"[{backend:6s}] {best:8.3f}s  "
              f"{steps / best / 1e3:10.1f}k steps/s  "
              f"trace mismatch {entry['trace_mismatch_vs_first']:.2e}"
              + ("  (interpret)" if entry["interpret"] else ""))
    stack.close()
    out["obs"] = obs_block(reg)
    return out


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=512)
    p.add_argument("--policies", type=int, default=70)
    p.add_argument("--scenarios", type=int, default=4)
    p.add_argument("--r", type=int, default=600)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--job-type", type=int, default=2)
    p.add_argument("--iters", type=int, default=2)
    p.add_argument("--learners", nargs="+", default=list(LEARNER_KINDS),
                   choices=list(LEARNER_KINDS))
    p.add_argument("--eta-grid", type=float, nargs="*", default=[0.05, 0.2])
    p.add_argument("--backends", nargs="+", default=["numpy", "jax"],
                   choices=["numpy", "jax", "pallas"],
                   help="pallas is opt-in: off-TPU it interprets the "
                        "weight-update kernel (logic check, not speed)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="save a Chrome/Perfetto span trace of the run")
    p.add_argument("--out", default="BENCH_learn.json")
    args = p.parse_args(argv)
    tracer = obs.Tracer() if args.trace else None
    ctx = obs.tracing(tracer) if tracer is not None \
        else contextlib.nullcontext()
    with ctx:
        res = run(args.jobs, args.policies, args.scenarios, args.r,
                  args.backends, args.learners, args.eta_grid,
                  seed=args.seed, job_type=args.job_type, iters=args.iters)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote Perfetto trace ({len(tracer)} spans): {args.trace}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
