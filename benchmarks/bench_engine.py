"""Evaluation-engine throughput: numpy vs jax vs pallas.

Times ``repro.engine.evaluate_grid`` on a (n_jobs x n_policies x S) grid —
the TOLA counterfactual cost-matrix workload — per backend, and emits
``BENCH_engine.json``:

    PYTHONPATH=src python -m benchmarks.bench_engine \
        [--jobs 512] [--policies 70] [--scenarios 4] [--r 600] \
        [--backends numpy jax pallas] [--out BENCH_engine.json]

Reported per backend: end-to-end wall seconds (best of --iters, after one
untimed warmup that absorbs jit/pallas compilation) with the plan / pool /
eval phase split, eval-only throughput in grid cells per second (cells =
S * n_jobs * n_policies), and the deduplicated evaluation group count (the
engine collapses policies sharing (windows, beta_0, bid) — throughput is
quoted over the FULL grid the caller asked for). Off-TPU the pallas backend
runs its kernels in interpret mode — such entries carry ``"interpret":
true`` and a ``"note"`` spelling out that the number is kernel-logic
timing, NOT TPU speed (read the pallas number on real hardware only; see
``benchmarks/bench_pipeline.py`` for the end-to-end pipeline benchmark).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

import numpy as np

from repro import obs
from repro.core import generate_chain_jobs, selfowned_policies
from repro.engine import build_grid_plan, evaluate_grid, make_scenarios

__all__ = ["run", "main"]


def obs_block(reg: "obs.CompiledRegistry") -> dict:
    """The enriched per-run breakdown every BENCH_*.json entry carries:
    metrics snapshot (chunk latency / throughput series recorded while the
    registry collected) + compiled-program flops/bytes/collective counts
    (captured on the warmup pass, so timed iterations pay nothing)."""
    return {
        "metrics": obs.METRICS.snapshot(),
        "programs": {
            key: {k: v for k, v in e.items() if k != "warnings"}
            for key, e in reg.entries.items()
        },
    }


def run(n_jobs: int, n_policies: int, n_scenarios: int, r_total: int,
        backends: list[str], seed: int = 0, job_type: int = 2,
        iters: int = 2) -> dict:
    if iters < 1:
        raise ValueError("need --iters >= 1 (one timed pass after warmup)")
    jobs = generate_chain_jobs(n_jobs, job_type, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    markets = make_scenarios(horizon, n_scenarios, seed=seed + 1000)
    grid = selfowned_policies()[:n_policies]
    if len(grid) < n_policies:
        raise ValueError(f"policy grid has only {len(grid)} policies")
    gplan = build_grid_plan(jobs, grid, r_total)
    cells = n_scenarios * n_jobs * len(grid)
    out = {
        "n_jobs": n_jobs,
        "n_policies": len(grid),
        "n_scenarios": n_scenarios,
        "r_total": r_total,
        "job_type": job_type,
        "seed": seed,
        "cells": cells,
        "eval_groups": len(gplan.groups),
        "L": gplan.L,
        "n_slots": markets[0].n_slots,
        "backends": {},
    }
    try:
        import jax
        out["jax_backend"] = jax.default_backend()
    except Exception:
        out["jax_backend"] = None

    reg = obs.CompiledRegistry()
    with obs.METRICS.collecting(reset=True):
        run_body(out, backends, jobs, grid, markets, r_total, iters, cells,
                 reg)
    out["obs"] = obs_block(reg)
    return out


def run_body(out, backends, jobs, grid, markets, r_total, iters, cells,
             reg):
    ref = None
    for backend in backends:
        warmup = None
        res = None
        best = float("inf")
        phases = None
        for it in range(iters + 1):
            # Capture compiled-program metrics on the warmup pass only —
            # the capture lowers+compiles each announced program once,
            # which must not count against the timed iterations.
            cap = obs.capture(reg) if it == 0 else contextlib.nullcontext()
            t0 = time.time()
            with cap:
                res = evaluate_grid(jobs, grid, markets, r_total,
                                    backend=backend)
            dt = time.time() - t0
            if it == 0:          # warmup pass absorbs jit/pallas compilation
                warmup = dt
            elif dt < best:
                best, phases = dt, dict(res.timings)
        entry = {
            "seconds": best,                  # end-to-end wall
            "warmup_seconds": warmup,
            "plan_seconds": phases["plan"],
            "pool_seconds": phases["pool"],
            "eval_seconds": phases["eval"],
            "synth_seconds": phases["synth"],
            "cells_per_sec_eval": cells / phases["eval"],
            "cells_per_sec_end_to_end": cells / best,
            # Mirrors backend_pallas.run's default: interpret iff CPU.
            "interpret": backend == "pallas"
            and out["jax_backend"] == "cpu",
        }
        if entry["interpret"]:
            entry["note"] = ("pallas kernels ran in INTERPRET mode on CPU — "
                             "kernel-logic timing, NOT TPU speed; do not "
                             "compare against the numpy/jax entries")
        out["backends"][backend] = entry
        if ref is None:
            ref = res.unit_cost
            entry["max_abs_diff_vs_first"] = 0.0
        else:
            entry["max_abs_diff_vs_first"] = float(
                np.abs(res.unit_cost - ref).max())
        print(f"[{backend:6s}] {best:8.3f}s end-to-end "
              f"(plan {phases['plan']:.3f} pool {phases['pool']:.3f} "
              f"eval {phases['eval']:.3f})  "
              f"{cells / phases['eval'] / 1e3:10.1f}k cells/s eval  "
              f"maxdiff {entry['max_abs_diff_vs_first']:.2e}"
              + ("  (INTERPRET — not TPU speed)" if entry["interpret"]
                 else ""))
    return out


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=512)
    p.add_argument("--policies", type=int, default=70)
    p.add_argument("--scenarios", type=int, default=4)
    p.add_argument("--r", type=int, default=600)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--job-type", type=int, default=2)
    p.add_argument("--iters", type=int, default=2)
    p.add_argument("--backends", nargs="+",
                   default=["numpy", "jax", "pallas"],
                   choices=["numpy", "jax", "pallas"])
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="save a Chrome/Perfetto span trace of the run")
    p.add_argument("--out", default="BENCH_engine.json")
    args = p.parse_args(argv)
    tracer = obs.Tracer() if args.trace else None
    ctx = obs.tracing(tracer) if tracer is not None \
        else contextlib.nullcontext()
    with ctx:
        res = run(args.jobs, args.policies, args.scenarios, args.r,
                  args.backends, seed=args.seed, job_type=args.job_type,
                  iters=args.iters)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote Perfetto trace ({len(tracer)} spans): {args.trace}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
