"""The two attention implementations inside the models (einsum vs
flash/blockwise custom-VJP) must agree — values AND gradients — since the
dry-run exercises both depending on sequence length."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build
from repro.configs import smoke_config


def _cfg_pair(arch):
    base = smoke_config(arch)
    # force one config down each path for the same 256-token batch
    einsum_cfg = dataclasses.replace(base, flash_threshold=100_000)
    flash_cfg = dataclasses.replace(base, flash_threshold=64)
    return einsum_cfg, flash_cfg


def test_decoder_paths_agree_values_and_grads():
    e_cfg, f_cfg = _cfg_pair("llama3_8b")
    m_e, m_f = build(e_cfg), build(f_cfg)
    params = m_e.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, e_cfg.vocab, (2, 257)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    l_e = jax.jit(m_e.loss)(params, batch)
    l_f = jax.jit(m_f.loss)(params, batch)
    assert abs(float(l_e) - float(l_f)) < 2e-3
    g_e = jax.grad(lambda p: m_e.loss(p, batch))(params)
    g_f = jax.grad(lambda p: m_f.loss(p, batch))(params)
    for a, b in zip(jax.tree.leaves(g_e), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-2)


def test_hybrid_windowed_paths_agree():
    e_cfg, f_cfg = _cfg_pair("hymba_1_5b")
    m_e, m_f = build(e_cfg), build(f_cfg)
    params = m_e.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, e_cfg.vocab, (2, 129)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    l_e = float(jax.jit(m_e.loss)(params, batch))
    l_f = float(jax.jit(m_f.loss)(params, batch))
    assert abs(l_e - l_f) < 2e-3
