"""Substrate tests: data determinism, checkpoint atomicity + elastic
restore, distributed xent, AdamW, compression, sharding rules, straggler
detection, fleet orchestration."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.ckpt import CheckpointManager
from repro.data import SyntheticTokens, make_batches
from repro.distributed.compression import dequantize, quantize_ef
from repro.distributed.sharding import DEFAULT_RULES, ShardingRules
from repro.distributed.xent import cross_entropy
from repro.optim import AdamW, cosine_schedule


class TestData:
    def test_deterministic_across_restarts(self):
        ds = SyntheticTokens(1000, 8, 32, seed=1, host_rank=0, host_count=1)
        a = ds.batch(7)
        b = ds.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_shards_partition_global_batch(self):
        full = SyntheticTokens(1000, 8, 32, seed=1, host_rank=0, host_count=1)
        h0 = SyntheticTokens(1000, 8, 32, seed=1, host_rank=0, host_count=2)
        h1 = SyntheticTokens(1000, 8, 32, seed=1, host_rank=1, host_count=2)
        got = np.concatenate([h0.batch(3)["tokens"], h1.batch(3)["tokens"]])
        np.testing.assert_array_equal(got, full.batch(3)["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticTokens(1000, 4, 16, seed=2, host_rank=0, host_count=1)
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_iterator_order(self):
        ds = SyntheticTokens(100, 2, 8, seed=0, host_rank=0, host_count=1)
        steps = [s for s, _ in make_batches(ds, 5, 4)]
        assert steps == [5, 6, 7, 8]


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        for s in (1, 2, 3):
            mgr.save(s, tree, blocking=True)
        assert mgr.latest_step() == 3
        got, step = mgr.restore(tree)
        assert step == 3
        np.testing.assert_array_equal(got["a"], tree["a"])
        # keep=2 garbage collection
        assert not os.path.exists(str(tmp_path / "step_000001"))

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"x": jnp.ones(3)}
        mgr.save(5, tree, blocking=True)
        # simulate a preemption mid-write of step 9: no COMMITTED marker
        os.makedirs(tmp_path / "step_000009")
        np.save(tmp_path / "step_000009" / "leaf_00000.npy", np.zeros(3))
        assert mgr.latest_step() == 5

    def test_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones(3)}, blocking=True)
        with pytest.raises(ValueError):
            mgr.restore({"x": jnp.ones(4)})


class TestXent:
    def test_matches_log_softmax_gather(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(2, 5, 11)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 11, (2, 5)))
        got = cross_entropy(logits, labels)
        want = -jnp.take_along_axis(
            jax.nn.log_softmax(logits, -1), labels[..., None], -1).mean()
        assert abs(float(got) - float(want)) < 1e-6

    def test_mask(self):
        logits = jnp.zeros((1, 4, 7))
        labels = jnp.zeros((1, 4), jnp.int32)
        mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        got = cross_entropy(logits, labels, mask=mask)
        assert abs(float(got) - float(np.log(7))) < 1e-6


class TestOptim:
    def test_adamw_reduces_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, gn = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_clip_norm(self):
        opt = AdamW(lr=0.0, clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        _, _, gn = opt.update({"w": jnp.full(3, 100.0)}, state, params)
        assert float(gn) > 1.0  # reported pre-clip norm

    def test_cosine_schedule_endpoints(self):
        f = cosine_schedule(1.0, 10, 100, floor=0.1)
        assert float(f(jnp.asarray(0))) == 0.0
        assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
        assert abs(float(f(jnp.asarray(100))) - 0.1) < 1e-3


class TestCompression:
    def test_error_feedback_is_unbiased_over_steps(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        err = jnp.zeros_like(g)
        total_q = jnp.zeros_like(g)
        n = 50
        for _ in range(n):
            q, scale, err = quantize_ef(g, err)
            total_q += dequantize(q, scale)
        # time-averaged dequantized signal converges to g (EF property)
        np.testing.assert_allclose(np.asarray(total_q / n), np.asarray(g),
                                   atol=1e-2)

    def test_quantization_error_bounded(self):
        g = jnp.asarray(np.linspace(-5, 5, 100), jnp.float32)
        q, scale, err = quantize_ef(g, jnp.zeros_like(g))
        assert float(jnp.abs(err).max()) <= float(scale) / 2 + 1e-6


class TestShardingRules:
    def test_duplicate_mesh_axes_dropped(self):
        r = ShardingRules.create(None)
        # no mesh: everything replicated
        assert r.spec("batch", "seq") == P(None, None)

    def test_fit_spec_divisibility(self):
        from repro.launch.steps import _fit_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # with axis sizes 1 everything divides
        s = _fit_spec(P("data", "model"), (4, 4), mesh)
        assert s == P("data", "model")

    def test_rules_cover_all_logical_axes(self):
        for k in ("batch", "heads", "kv_heads", "d_ff", "vocab", "experts",
                  "fsdp", "cache_seq", "cache_batch"):
            assert k in DEFAULT_RULES


class TestStraggler:
    def test_detects_persistent_straggler(self):
        from repro.sched import StragglerDetector
        det = StragglerDetector(patience=2)
        hb = np.ones(8)
        hb[3] = 50.0
        assert det.update(hb) == []          # strike 1
        assert det.update(hb) == [3]         # strike 2 -> speculate
        assert det.update(np.ones(8)) == []  # recovered

    def test_progress_speculation(self):
        from repro.sched import StragglerDetector
        det = StragglerDetector()
        prog = np.array([1.0, 0.95, 1.05, 0.3])
        assert det.should_speculate(prog) == [3]
