"""repro.obs: span tracing, trace export, metrics, compiled introspection,
and the EngineResult.timings contracts (ISSUE 7).

The timing-derivation tests assert BIT-FOR-BIT equality between
``EngineResult.timings`` and the span-derived totals on the numpy path:
the engine folds ``span.seconds`` floats directly, so the dict is a view
of the span tree, not a parallel measurement.
"""

import dataclasses
import json
import pickle
import time

import numpy as np
import pytest

from repro import obs
from repro.core import generate_chain_jobs, selfowned_policies
from repro.engine import EngineResult, ScenarioSpec, evaluate_grid
from repro.engine.api import evaluate_grid_chunks
from repro.obs import METRICS, span
from repro.obs.metrics import MetricsRegistry


def _setup(n=8, seed=0):
    jobs = generate_chain_jobs(n, 2, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    return jobs, horizon


GRID = selfowned_policies()[:6]


# --------------------------------------------------------------------------
# Span tracer core
# --------------------------------------------------------------------------

def test_span_measures_without_tracer():
    assert obs.current_tracer() is None
    with span("work", tag="x") as sp:
        time.sleep(0.001)
    assert sp.seconds > 0.0
    assert sp.attrs == {"tag": "x"}
    assert obs.current_tracer() is None


def test_span_nesting_and_parents():
    with obs.tracing() as tr:
        with span("outer") as outer:
            with span("inner_a"):
                pass
            with span("inner_b"):
                with span("leaf"):
                    pass
    by_name = {r.name: r for r in tr.spans}
    assert by_name["inner_a"].parent == outer.id
    assert by_name["inner_b"].parent == outer.id
    assert by_name["leaf"].parent == by_name["inner_b"].id
    assert by_name["outer"].parent is None
    # children finish (and record) before their parent
    assert tr.spans[-1].name == "outer"
    kids = tr.children(outer.id)
    assert {r.name for r in kids} == {"inner_a", "inner_b"}
    assert [r.name for r in tr.roots()] == ["outer"]
    # parent duration covers its children
    assert by_name["outer"].seconds >= (
        by_name["inner_a"].seconds + by_name["inner_b"].seconds)


def test_span_set_attrs_and_totals():
    with obs.tracing() as tr:
        with span("phase") as sp:
            sp.set(backend="numpy", n=3)
        with span("phase"):
            pass
    assert tr.named("phase")[0].attrs == {"backend": "numpy", "n": 3}
    tot = tr.totals()
    assert tot["phase"] == (tr.spans[0].seconds + tr.spans[1].seconds)


def test_nested_tracers_restore():
    with obs.tracing() as outer_tr:
        with span("a"):
            pass
        with obs.tracing() as inner_tr:
            with span("b"):
                pass
        assert obs.current_tracer() is outer_tr
        with span("c"):
            pass
    assert [r.name for r in outer_tr.spans] == ["a", "c"]
    assert [r.name for r in inner_tr.spans] == ["b"]


def test_spans_not_recorded_when_disabled():
    with span("ghost"):
        pass
    with obs.tracing() as tr:
        pass
    assert len(tr) == 0


# --------------------------------------------------------------------------
# Trace export: Chrome/Perfetto JSON + JSONL
# --------------------------------------------------------------------------

def _traced_numpy_run(S=6, chunk=3):
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, S, seed=1)
    with obs.tracing() as tr:
        res = evaluate_grid(jobs, GRID, spec, backend="numpy",
                            scenario_chunk=chunk)
    return tr, res


def test_chrome_trace_schema(tmp_path):
    tr, _ = _traced_numpy_run()
    path = tmp_path / "trace.json"
    tr.save(path)
    doc = json.load(open(path))
    assert "traceEvents" in doc and doc["traceEvents"]
    for ev in doc["traceEvents"]:
        # The Perfetto-required complete-event fields.
        assert ev["ph"] == "X"
        assert isinstance(ev["name"], str)
        for field in ("ts", "dur"):
            assert isinstance(ev[field], (int, float))
        for field in ("pid", "tid"):
            assert isinstance(ev[field], int)
        assert isinstance(ev["args"], dict)
    names = {ev["name"] for ev in doc["traceEvents"]}
    assert {"evaluate_grid", "plan", "synth", "eval", "chunk"} <= names


def test_jsonl_export_line_parseable(tmp_path):
    tr, _ = _traced_numpy_run()
    path = tmp_path / "trace.jsonl"
    tr.save_jsonl(path)
    lines = open(path).read().splitlines()
    assert len(lines) == len(tr)
    for line in lines:
        rec = json.loads(line)
        assert {"id", "parent", "name", "ts", "dur", "pid", "tid",
                "attrs"} <= set(rec)


def test_attr_coercion_json_safe():
    with obs.tracing() as tr:
        with span("np_attrs", f=np.float64(1.5), i=np.int32(2),
                  arr=(np.int64(1), np.int64(2)), obj=object()):
            pass
    doc = tr.to_chrome()
    args = doc["traceEvents"][0]["args"]
    json.dumps(doc)  # round-trips
    assert args["f"] == 1.5 and args["i"] == 2
    assert args["arr"] == [1, 2]
    assert isinstance(args["obj"], str)


# --------------------------------------------------------------------------
# EngineResult.timings as a span-derived view (bit-for-bit, numpy path)
# --------------------------------------------------------------------------

def test_timings_match_span_totals_bitforbit():
    tr, res = _traced_numpy_run(S=6, chunk=2)
    tot = tr.totals()
    assert res.timings["plan"] == tot["plan"]
    assert res.timings["pool"] == tot["pool"]
    assert res.timings["synth"] == tot["synth"]
    assert res.timings["eval"] == tot["eval"]
    # per-chunk split: each entry is exactly its span's seconds, and the
    # split sums exactly to the phase totals (same accumulation order).
    synth_spans = tr.named("synth")
    eval_spans = tr.named("eval")
    chunks = res.timings["chunks"]
    assert len(chunks) == len(synth_spans) == len(eval_spans) == 3
    for entry, ss, es in zip(chunks, synth_spans, eval_spans):
        assert entry["synth"] == ss.seconds
        assert entry["eval"] == es.seconds
    assert sum(c["synth"] for c in chunks) == res.timings["synth"]
    assert sum(c["eval"] for c in chunks) == res.timings["eval"]
    # every chunk span parents exactly one synth + one eval span
    for c in tr.named("chunk"):
        kids = tr.children(c.id)
        assert sorted(r.name for r in kids) == ["eval", "synth"]


def test_grid_chunks_spans_and_timings():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 6, seed=3)
    with obs.tracing() as tr:
        chunks = list(evaluate_grid_chunks(jobs, GRID, spec,
                                           scenario_chunk=3,
                                           backend="numpy"))
    assert len(chunks) == 2
    synth_spans = tr.named("synth")
    for ch, ss in zip(chunks, synth_spans):
        assert ch.timings["synth"] == ss.seconds
    assert len(tr.named("chunk")) == 2


# --------------------------------------------------------------------------
# Disabled-mode overhead: span machinery must cost < 2% of a small grid
# --------------------------------------------------------------------------

def test_disabled_overhead_under_two_percent():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 8, seed=2)
    args = (jobs, GRID, spec)
    kw = dict(backend="numpy", scenario_chunk=2)
    evaluate_grid(*args, **kw)  # warm caches
    t0 = time.perf_counter()
    evaluate_grid(*args, **kw)
    wall = time.perf_counter() - t0
    # How many spans does this run open? (count via a traced pass)
    with obs.tracing() as tr:
        evaluate_grid(*args, **kw)
    n_spans = len(tr)
    # Per-span disabled cost, measured directly.
    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with span("x", a=1, b=2):
            pass
    per_span = (time.perf_counter() - t0) / reps
    assert n_spans * per_span < 0.02 * wall, (
        f"{n_spans} spans x {per_span * 1e6:.2f}us = "
        f"{n_spans * per_span * 1e3:.3f}ms vs 2% of {wall * 1e3:.1f}ms")


# --------------------------------------------------------------------------
# timings["synth"] contract under overlap (satellite): residual wait <=
# full synthesis on the same workload, and chunk splits sum to totals.
# --------------------------------------------------------------------------

def test_overlap_synth_contract():
    pytest.importorskip("jax")
    jobs, horizon = _setup(n=16)
    spec = ScenarioSpec("fresh", horizon, 32, seed=5)
    kw = dict(backend="jax", scenario_chunk=8)
    base = evaluate_grid(jobs, GRID, spec, overlap=False, **kw)
    ov = evaluate_grid(jobs, GRID, spec, overlap=True, **kw)
    assert base.timings["overlap"] is False
    assert ov.timings["overlap"] is True
    # Residual wait after async dispatch must not exceed the full blocking
    # synthesis of the identical workload (1ms absolute slack absorbs
    # timer jitter when both sides are near zero).
    assert ov.timings["synth"] <= base.timings["synth"] + 1e-3, (
        f"overlap synth {ov.timings['synth']:.4f}s > non-overlap "
        f"{base.timings['synth']:.4f}s")
    for res in (base, ov):
        chunks = res.timings["chunks"]
        assert len(chunks) == 4
        assert sum(c["synth"] for c in chunks) == res.timings["synth"]
        assert sum(c["eval"] for c in chunks) == res.timings["eval"]
    np.testing.assert_allclose(ov.unit_cost, base.unit_cost, rtol=0, atol=0)


# --------------------------------------------------------------------------
# EngineResult.timings defaults + round-trips (satellite)
# --------------------------------------------------------------------------

def _min_result():
    z = np.zeros((1, 2, 3))
    return EngineResult(unit_cost=z, spot_cost=z, ondemand_cost=z,
                        spot_work=z, ondemand_work=z,
                        workload=np.ones(2), selfowned_work=z[0],
                        selfowned_reserved=z[0])


def test_timings_default_empty_dict():
    res = _min_result()
    assert res.timings == {} and isinstance(res.timings, dict)
    assert res.obs is None
    # instances do not share the default dict
    res.timings["plan"] = 1.0
    assert _min_result().timings == {}


def test_engine_result_replace_and_pickle_roundtrip():
    res = _min_result()
    res.timings.update({"plan": 0.5, "chunks": []})
    rep = dataclasses.replace(res, backend="jax")
    assert rep.timings == {"plan": 0.5, "chunks": []}
    assert rep.backend == "jax"
    back = pickle.loads(pickle.dumps(rep))
    assert back.timings == rep.timings
    assert back.obs is None
    jobs, horizon = _setup()
    real = evaluate_grid(jobs, GRID,
                         ScenarioSpec("fresh", horizon, 2, seed=0),
                         backend="numpy")
    back = pickle.loads(pickle.dumps(real))
    assert back.timings == real.timings
    np.testing.assert_array_equal(back.unit_cost, real.unit_cost)


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------

def test_metrics_disabled_records_nothing():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.gauge("g").set(2.0)
    reg.histogram("h").observe(1.0)
    assert reg.snapshot() == {}


def test_metrics_counter_gauge_histogram_labels():
    reg = MetricsRegistry()
    with reg.collecting():
        reg.counter("c").inc(stage="a")
        reg.counter("c").inc(2.0, stage="a")
        reg.counter("c").inc(stage="b")
        reg.gauge("g").set(1.5, backend="jax")
        for v in (0.01, 0.02, 5.0):
            reg.histogram("h").observe(v, phase="eval")
    assert not reg.enabled
    snap = reg.snapshot()
    c = {tuple(s["labels"].items()): s["value"] for s in snap["c"]["series"]}
    assert c[(("stage", "a"),)] == 3.0 and c[(("stage", "b"),)] == 1.0
    assert snap["g"]["series"][0]["value"] == 1.5
    h = snap["h"]["series"][0]
    assert h["count"] == 3 and h["min"] == 0.01 and h["max"] == 5.0
    assert h["sum"] == pytest.approx(5.03)
    assert sum(b["count"] for b in h["buckets"]) == 3
    json.dumps(snap)


def test_metrics_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_engine_metrics_snapshot_on_result():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 4, seed=1)
    with METRICS.collecting(reset=True):
        res = evaluate_grid(jobs, GRID, spec, backend="numpy",
                            scenario_chunk=2)
    assert res.obs is not None
    m = res.obs["metrics"]
    series = m["engine.chunk_seconds"]["series"]
    by_phase = {tuple(sorted(s["labels"].items())): s for s in series}
    key = (("backend", "numpy"), ("phase", "eval"))
    assert by_phase[key]["count"] == 2
    assert "engine.scenarios_per_sec" in m
    # no active collection -> no snapshot
    res2 = evaluate_grid(jobs, GRID, spec, backend="numpy")
    assert res2.obs is None


def test_adaptive_escalation_counter():
    from repro.learn import replay_stream

    jobs, horizon = _setup()
    spec = ScenarioSpec("adaptive", horizon, 12, seed=7, n_periods=2,
                        n_phases=2)
    with METRICS.collecting(reset=True):
        out = replay_stream(jobs, GRID, spec, scenario_chunk=4,
                            backend="numpy", engine_backend="numpy")
    m = out.obs["metrics"]
    stages = {s["labels"]["stage"]: s["value"]
              for s in m["scenarios.adaptive_chunks"]["series"]}
    assert sum(stages.values()) == 3          # one increment per chunk
    assert "periods" in stages
    if "scenarios.adaptive_escalations" in m:
        esc = m["scenarios.adaptive_escalations"]["series"]
        assert all(s["value"] >= 1 for s in esc)
    ent = m["learn.weight_entropy"]["series"]
    assert ent and all(s["count"] == 3 for s in ent)   # one obs per chunk
    assert "learn.top_weight" in m


# --------------------------------------------------------------------------
# Compiled-program introspection
# --------------------------------------------------------------------------

def test_collective_counts_regex():
    txt = """
      x = all-reduce(a), y = all-reduce-start(b), z = all-reduce-done(c)
      g = all-gather(d), p = collective-permute(e)
    """
    counts = obs.compiled.collective_counts(txt)
    assert counts["all-reduce"] == 2          # -start counts, -done doesn't
    assert counts["all-gather"] == 1
    assert counts["collective-permute"] == 1
    assert counts["total"] == 4


def test_hlo_metrics_and_capture_counters():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    fn = jax.jit(lambda a, b: a @ b)
    x = jnp.ones((8, 8), jnp.float32)
    m = obs.compiled.hlo_metrics(fn, x, x)
    assert m["flops"] > 0
    assert m["collective_counts"]["total"] == 0
    assert obs.compiled.current_registry() is None
    with obs.capture() as reg:
        obs.record_jit("k", fn, x, x)
        obs.record_jit("k", fn, x, x)
    assert reg["k"]["captures"] == 2
    assert reg["k"]["flops"] == m["flops"]
    snap = reg.snapshot()
    assert "k" in snap["programs"] and "factory_caches" in snap
    assert "k" in reg.table()
    json.dumps(snap)
    assert obs.compiled.current_registry() is None


def test_record_jit_noop_without_capture():
    # must not lower/compile anything — works with a non-jit callable
    obs.record_jit("nope", None)


def test_capture_never_raises_on_bad_program():
    with obs.capture() as reg:
        obs.record_jit("bad", object())
    assert "error" in reg["bad"]


# --------------------------------------------------------------------------
# Acceptance: streamed run under full observation — span tree covers
# plan/synth/eval/fold per chunk, compiled metrics carry the Section 9
# collective counts (one psum in the fold, zero in the eval hot loop).
# --------------------------------------------------------------------------

def test_streamed_observation_end_to_end(tmp_path):
    pytest.importorskip("jax")
    from repro.engine import ScenarioMesh
    from repro.learn import replay_stream

    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 4, seed=9)
    mesh = ScenarioMesh.create(1)
    with obs.observe(programs=True) as session:
        out = replay_stream(jobs, GRID[:4], spec, scenario_chunk=2,
                            backend="jax", engine_backend="jax", mesh=mesh)
    tr, reg = session.tracer, session.compiled
    names = {r.name for r in tr.spans}
    assert {"plan", "synth", "eval", "fold", "chunk",
            "replay_stream"} <= names
    assert len(tr.named("fold")) == 2 and len(tr.named("chunk")) == 2
    # fold spans are children of the replay_stream root
    root = tr.named("replay_stream")[0]
    assert all(r.parent == root.id for r in tr.named("fold"))
    # Perfetto-loadable trace on disk
    doc = json.load(open(tr.save(tmp_path / "stream.json")))
    assert {ev["name"] for ev in doc["traceEvents"]} == names
    # Section 9 placement contract as standing compiled metrics
    fold = reg["learn.fold:sharded"]["collective_counts"]
    assert fold["all-reduce"] == 1 and fold["total"] == 1
    chain = reg["engine.eval.chain:sharded"]["collective_counts"]
    assert chain["total"] == 0
    synth = reg["scenarios.synth:fresh:sharded"]["collective_counts"]
    assert synth["total"] == 0
    # the snapshot rode along on the stream result
    assert out.obs is not None and "compiled" in out.obs
    assert out.obs["compiled"]["programs"]["learn.fold:sharded"][
        "collective_counts"]["all-reduce"] == 1
    caches = out.obs["compiled"]["factory_caches"]
    assert caches["learn.fold"]["misses"] >= 1
