"""Property tests (hypothesis) for the 2-D mesh padding contract
(DESIGN.md §9): edge-repeat padding/splice invariants under
``S % data_shards != 0`` AND ``n_groups % model_shards != 0``
simultaneously — padded-lane results never leak into spliced tensors,
``reduce="mean"`` weights by true counts. Pure-host arithmetic over the
same ``pad_to``/``edge_repeat`` helpers the backends use, so the
invariants hold on any device count (real multi-device coverage lives in
tests/test_shard.py).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine.mesh import edge_repeat, pad_to  # noqa: E402


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 23), st.integers(1, 8), st.integers(1, 11),
       st.integers(1, 5), st.integers(1, 4), st.data())
def test_padding_splice_never_leaks(S, d, G, m, J, data):
    # The draw space covers BOTH nondivisibilities simultaneously
    # (S % d != 0 and G % m != 0 — the interesting lanes) as well as the
    # divisible cases, where padding must be the identity.
    Sp, Gp = pad_to(S, d), pad_to(G, m)
    vals = data.draw(st.lists(
        st.floats(-1e3, 1e3, allow_nan=False, width=32),
        min_size=S * G * J, max_size=S * G * J))
    X = np.asarray(vals, np.float64).reshape(S, G * J)

    # pad groups (whole J-row blocks, LAST group repeated), then scenarios
    # (LAST row repeated) — the exact order backend_jax applies them
    Xg = X.reshape(S, G, J)
    Xg = np.concatenate([Xg] + [Xg[:, -1:]] * (Gp - G), axis=1)
    Xp = edge_repeat(Xg.reshape(S, Gp * J), Sp)
    assert Xp.shape == (Sp, Gp * J)
    if S % d == 0:
        assert Xp.shape[0] == S            # divisible: no scenario padding
    if G % m == 0:
        assert Xg.shape[1] == G            # divisible: no group padding

    # "evaluate" elementwise per (scenario, group-row) lane — stand-in for
    # the cost kernel, which never mixes lanes — then splice exactly the
    # way the backend does: [:S] drops scenario padding, [:, :G] drops
    # group padding.
    res = 3.0 * Xp + 1.0
    spliced = res[:S].reshape(S, Gp, J)[:, :G]

    direct = 3.0 * X.reshape(S, G, J) + 1.0
    # padded-lane results never leak into the spliced tensor
    assert np.array_equal(spliced, direct)
    # reduce="mean" runs over the SPLICED tensor, so it weights by the
    # TRUE scenario count S (not Sp) and true group count G (not Gp) —
    # duplicated lanes cannot bias the mean
    assert np.allclose(spliced.mean(axis=0), direct.mean(axis=0))


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 200), st.integers(1, 16))
def test_pad_to_properties(k, n):
    kp = pad_to(k, n)
    assert kp % n == 0
    assert kp >= k
    assert kp - k < n              # minimal padding
    assert pad_to(kp, n) == kp     # idempotent


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 12), st.integers(0, 10), st.integers(1, 4))
def test_edge_repeat_properties(k, extra, cols):
    a = np.arange(float(k * cols)).reshape(k, cols)
    p = edge_repeat(a, k + extra)
    assert p.shape == (k + extra, cols)
    assert np.array_equal(p[:k], a)              # real rows untouched
    assert np.array_equal(p[k:], np.repeat(a[-1:], extra, axis=0))
    with pytest.raises(ValueError):
        edge_repeat(a, k - 1)      # padding down is always an error
