"""Pallas kernels vs jnp oracles (interpret mode), shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpotMarket
from repro.core.simulate import simulate_tasks
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.policy_cost import policy_cost
from repro.kernels.ref import attention_ref, policy_cost_ref, ssd_ref
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "BH,BK,Sq,Sk,dh,causal,window,prefix",
    [
        (4, 2, 256, 256, 64, True, 0, 0),      # GQA causal
        (2, 2, 384, 384, 128, True, 0, 0),     # MHA, dh=128
        (4, 1, 128, 512, 64, False, 0, 0),     # cross attention (enc-dec)
        (2, 2, 512, 512, 64, True, 128, 16),   # sliding window + meta prefix
        (2, 1, 200, 300, 64, True, 0, 0),      # ragged (padding path)
        (1, 1, 640, 640, 64, True, 256, 0),    # window without prefix
    ],
)
def test_flash_attention_vs_ref(BH, BK, Sq, Sk, dh, causal, window, prefix,
                                dtype):
    q = jnp.asarray(RNG.normal(size=(BH, Sq, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(BK, Sk, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(BK, Sk, dh)), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              prefix=prefix, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, prefix=prefix)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize(
    "Bb,S,H,P,G,N,chunk",
    [
        (2, 256, 4, 64, 1, 64, 64),
        (1, 200, 2, 32, 1, 16, 64),    # ragged
        (2, 128, 4, 64, 2, 32, 32),    # grouped B/C
        (1, 512, 8, 64, 1, 128, 128),  # mamba2-like dims
    ],
)
def test_ssd_scan_vs_sequential_ref(Bb, S, H, P, G, N, chunk):
    x = jnp.asarray(RNG.normal(size=(Bb, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(Bb, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(Bb, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(Bb, S, G, N)), jnp.float32)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=1e-4,
                               rtol=1e-4)


def test_ssd_jnp_chunked_matches_sequential():
    """The model's chunked jnp implementation (layers.ssd) against the
    sequential recurrence — independent check of the training path."""
    from repro.models.layers import ssd as ssd_jnp
    Bb, S, H, P, G, N = 2, 160, 4, 32, 1, 16
    x = jnp.asarray(RNG.normal(size=(Bb, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(Bb, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(Bb, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(Bb, S, G, N)), jnp.float32)
    y, st = ssd_jnp(x, dt, A, B, C, chunk=64)
    yr, str_ = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=1e-4,
                               rtol=1e-4)


class TestPolicyCostKernel:
    def setup_method(self):
        self.m = SpotMarket(120.0, seed=3)
        self.v = self.m.view(0.24)

    def _tasks(self, T):
        start = RNG.uniform(0, 90, T)
        size = RNG.uniform(0.05, 20, T)
        end = start + size
        d = RNG.choice([1.0, 8.0, 64.0], T)
        z = RNG.uniform(0.0, 1.0, T) * d * size
        return start, end, z, d

    @pytest.mark.parametrize("T", [7, 64, 300])
    def test_against_exact_numpy_simulator(self, T):
        start, end, z, d = self._tasks(T)
        ref = simulate_tasks(self.v, start, end, z, d)
        out = policy_cost(
            jnp.asarray(self.v.A_cum, jnp.float32),
            jnp.asarray(self.v.C_cum, jnp.float32),
            jnp.asarray(start), jnp.asarray(end), jnp.asarray(z),
            jnp.asarray(d), interpret=True)
        np.testing.assert_allclose(out["spot_cost"], ref.spot_cost,
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(out["ondemand_cost"], ref.ondemand_cost,
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(out["spot_work"], ref.spot_work,
                                   atol=2e-3, rtol=2e-3)

    def test_jnp_ref_matches_numpy(self):
        start, end, z, d = self._tasks(128)
        ref = simulate_tasks(self.v, start, end, z, d)
        out = policy_cost_ref(
            jnp.asarray(self.v.A_cum, jnp.float32),
            jnp.asarray(self.v.C_cum, jnp.float32),
            jnp.asarray(start), jnp.asarray(end), jnp.asarray(z),
            jnp.asarray(d))
        np.testing.assert_allclose(out["spot_cost"], ref.spot_cost,
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(out["ondemand_cost"], ref.ondemand_cost,
                                   atol=2e-3, rtol=2e-3)
