"""System behaviour: Algorithm 2 end-to-end, pool accounting, TOLA learning,
and the paper's headline claim (proposed < baselines)."""

import numpy as np

from repro.core import (
    B_BIDS,
    Policy,
    SelfOwnedPool,
    SpotMarket,
    generate_chain_jobs,
    run_greedy,
    run_jobs,
    run_tola,
    spot_od_policies,
)
from repro.core.pool import RangeMax
from repro.core.scheduler import evaluate_policy_fullpool


def _setup(n=120, jt=1, seed=3):
    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=seed + 1)
    return jobs, market


def test_proposed_beats_baselines():
    """The paper's core claim at small scale: min-over-grid proposed cost
    undercuts Greedy and Even benchmarks."""
    jobs, m = _setup(150, jt=1)
    best = min(run_jobs(jobs, p, m).average_unit_cost()
               for p in spot_od_policies())
    greedy = min(run_greedy(jobs, b, m).average_unit_cost() for b in B_BIDS)
    even = min(run_jobs(jobs, p, m, windows="even",
                        early_start=False).average_unit_cost()
               for p in spot_od_policies())
    assert best < greedy
    assert best < even


def test_selfowned_reduces_cost_monotonically():
    jobs, m = _setup(80, jt=2)
    pol = Policy(beta=0.625, bid=0.27, beta0=0.5)
    alphas = [run_jobs(jobs, pol, m, r_total=r).average_unit_cost()
              for r in (0, 200, 600)]
    assert alphas[0] > alphas[1] > alphas[2]


def test_pool_never_oversubscribed():
    jobs, m = _setup(60, jt=2)
    pol = Policy(beta=0.625, bid=0.27, beta0=1 / 2.2)
    costs, r_alloc, pool = run_jobs(jobs, pol, m, r_total=50,
                                    return_pool=True)
    assert pool is not None
    assert pool.used.max() <= 50
    assert costs.selfowned_work.sum() <= pool.worked_instance_time + 1e-6


def test_deadlines_always_met():
    """No allocation path may ever miss a deadline (on-demand backstop)."""
    jobs, m = _setup(100, jt=1)
    for pol in (Policy(beta=0.455, bid=0.18), Policy(beta=1.0, bid=0.30)):
        c = run_jobs(jobs, pol, m)
        # all workload processed by one of the three classes
        total = c.spot_work + c.ondemand_work + c.selfowned_work
        np.testing.assert_allclose(total, c.workload, rtol=1e-9)


def test_fullpool_equals_realized_when_no_selfowned():
    jobs, m = _setup(50, jt=3)
    pol = Policy(beta=0.769, bid=0.24)
    a = run_jobs(jobs, pol, m)
    b = evaluate_policy_fullpool(jobs, pol, m)
    np.testing.assert_allclose(a.total_cost, b.total_cost, atol=1e-9)


def test_tola_learns_good_policy():
    """With enough jobs the weight mass should concentrate on policies whose
    fixed cost is near the best fixed cost."""
    jobs, m = _setup(400, jt=2, seed=11)
    grid = spot_od_policies()
    res = run_tola(jobs, grid, m, seed=0)
    fixed = res.fixed_unit_costs
    # weight-weighted expected cost is better than the uniform average
    uniform = fixed.mean()
    weighted = float((res.weights * fixed).sum())
    assert weighted < uniform
    # realized cost is within the policy-grid range
    assert fixed.min() - 1e-9 <= res.average_unit_cost() <= fixed.max() + 0.05


def test_rangemax_matches_naive():
    rng = np.random.default_rng(0)
    v = rng.integers(0, 100, 500).astype(float)
    rm = RangeMax(v)
    lo = rng.integers(0, 499, 200)
    hi = lo + rng.integers(1, 80, 200)
    got = rm.query(lo, hi)
    want = np.array([v[l:h].max() if h <= 500 else v[l:500].max()
                     for l, h in zip(lo, np.minimum(hi, 500))])
    np.testing.assert_allclose(got, want)


def test_early_start_never_hurts():
    jobs, m = _setup(100, jt=1)
    pol = Policy(beta=0.625, bid=0.27)
    early = run_jobs(jobs, pol, m, early_start=True).average_unit_cost()
    planned = run_jobs(jobs, pol, m, early_start=False).average_unit_cost()
    assert early <= planned + 1e-9
