"""System behaviour: Algorithm 2 end-to-end, pool accounting, TOLA learning,
and the paper's headline claim (proposed < baselines)."""

import numpy as np
import pytest

from repro.core import (
    B_BIDS,
    Policy,
    SelfOwnedPool,
    SpotMarket,
    generate_chain_jobs,
    run_greedy,
    run_jobs,
    run_tola,
    spot_od_policies,
)
from repro.core.pool import RangeMax
from repro.core.scheduler import evaluate_policy_fullpool


def _setup(n=120, jt=1, seed=3):
    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=seed + 1)
    return jobs, market


def test_proposed_beats_baselines():
    """The paper's core claim at small scale: min-over-grid proposed cost
    undercuts Greedy and Even benchmarks."""
    jobs, m = _setup(150, jt=1)
    best = min(run_jobs(jobs, p, m).average_unit_cost()
               for p in spot_od_policies())
    greedy = min(run_greedy(jobs, b, m).average_unit_cost() for b in B_BIDS)
    even = min(run_jobs(jobs, p, m, windows="even",
                        early_start=False).average_unit_cost()
               for p in spot_od_policies())
    assert best < greedy
    assert best < even


def test_selfowned_reduces_cost_monotonically():
    jobs, m = _setup(80, jt=2)
    pol = Policy(beta=0.625, bid=0.27, beta0=0.5)
    alphas = [run_jobs(jobs, pol, m, r_total=r).average_unit_cost()
              for r in (0, 200, 600)]
    assert alphas[0] > alphas[1] > alphas[2]


def test_pool_never_oversubscribed():
    jobs, m = _setup(60, jt=2)
    pol = Policy(beta=0.625, bid=0.27, beta0=1 / 2.2)
    costs, r_alloc, pool = run_jobs(jobs, pol, m, r_total=50,
                                    return_pool=True)
    assert pool is not None
    assert pool.used.max() <= 50
    assert costs.selfowned_work.sum() <= pool.worked_instance_time + 1e-6


def test_deadlines_always_met():
    """No allocation path may ever miss a deadline (on-demand backstop)."""
    jobs, m = _setup(100, jt=1)
    for pol in (Policy(beta=0.455, bid=0.18), Policy(beta=1.0, bid=0.30)):
        c = run_jobs(jobs, pol, m)
        # all workload processed by one of the three classes
        total = c.spot_work + c.ondemand_work + c.selfowned_work
        np.testing.assert_allclose(total, c.workload, rtol=1e-9)


def test_fullpool_equals_realized_when_no_selfowned():
    jobs, m = _setup(50, jt=3)
    pol = Policy(beta=0.769, bid=0.24)
    a = run_jobs(jobs, pol, m)
    b = evaluate_policy_fullpool(jobs, pol, m)
    np.testing.assert_allclose(a.total_cost, b.total_cost, atol=1e-9)


def test_tola_learns_good_policy():
    """With enough jobs the weight mass should concentrate on policies whose
    fixed cost is near the best fixed cost."""
    jobs, m = _setup(400, jt=2, seed=11)
    grid = spot_od_policies()
    res = run_tola(jobs, grid, m, seed=0)
    fixed = res.fixed_unit_costs
    # weight-weighted expected cost is better than the uniform average
    uniform = fixed.mean()
    weighted = float((res.weights * fixed).sum())
    assert weighted < uniform
    # realized cost is within the policy-grid range
    assert fixed.min() - 1e-9 <= res.average_unit_cost() <= fixed.max() + 0.05


def test_rangemax_matches_naive():
    rng = np.random.default_rng(0)
    v = rng.integers(0, 100, 500).astype(float)
    rm = RangeMax(v)
    lo = rng.integers(0, 499, 200)
    hi = lo + rng.integers(1, 80, 200)
    got = rm.query(lo, hi)
    want = np.array([v[l:h].max() if h <= 500 else v[l:500].max()
                     for l, h in zip(lo, np.minimum(hi, 500))])
    np.testing.assert_allclose(got, want)


def test_early_start_never_hurts():
    jobs, m = _setup(100, jt=1)
    pol = Policy(beta=0.625, bid=0.27)
    early = run_jobs(jobs, pol, m, early_start=True).average_unit_cost()
    planned = run_jobs(jobs, pol, m, early_start=False).average_unit_cost()
    assert early <= planned + 1e-9


def _allocate_pool_reference(plan, r_total, selfowned, spu):
    """The original one-task-at-a-time chronological allocation loop."""
    from repro.core.pool import SelfOwnedPool
    from repro.core.scheduler import _selfowned_counts_vec

    J, L = plan.z.shape
    r_alloc = np.zeros((J, L))
    if r_total <= 0:
        return r_alloc, None
    flat = np.nonzero(plan.mask.ravel())[0]
    starts = plan.starts.ravel()[flat]
    ends = plan.ends.ravel()[flat]
    zf = plan.z.ravel()[flat]
    df = plan.delta.ravel()[flat]
    b0f = np.repeat(plan.beta0, L)[flat]
    sizes = np.maximum(ends - starts, 1e-12)
    cap = _selfowned_counts_vec(zf, df, sizes, b0f, np.inf, selfowned)
    pool = SelfOwnedPool(r_total, max(float(ends.max()), 1.0), spu)
    out = np.zeros(len(flat))
    slot = pool.slot
    k1s = np.maximum(np.floor(starts / slot + 1e-9).astype(np.int64), 0)
    k2s = np.minimum(np.ceil(ends / slot - 1e-9).astype(np.int64),
                     pool.n_slots)
    k2s = np.maximum(k2s, k1s + 1)
    used, total = pool.used, pool.total
    for i in np.argsort(starts, kind="stable"):
        if cap[i] <= 0.0 or ends[i] - starts[i] <= 1e-12:
            continue
        k1, k2 = k1s[i], k2s[i]
        r = int(min(cap[i], total - used[k1:k2].max(initial=0)))
        if r > 0:
            used[k1:k2] += r
            span = ends[i] - starts[i]
            pool.reserved_instance_time += r * span
            pool.worked_instance_time += min(r * span, zf[i])
            out[i] = r
    r_alloc.ravel()[flat] = out
    return r_alloc, pool


@pytest.mark.parametrize("n,jt,r,so", [
    (120, 2, 600, "prop12"),   # saturated interior (paper regime)
    (120, 2, 15, "prop12"),    # tiny pool, contended from the start
    (150, 1, 40, "naive"),     # naive self-owned benchmark
    (80, 3, 2000, "prop12"),   # uncontended: pure batched-commit path
    (90, 4, 7, "naive"),
])
def test_allocate_pool_batched_equals_sequential(n, jt, r, so):
    """The chunked-optimistic allocation (batched occupancy writes +
    segment-tree contended passes) is EXACTLY the sequential scan."""
    from repro.core.scheduler import _allocate_pool, build_plans

    jobs, _ = _setup(n, jt=jt, seed=n + r)
    pol = Policy(beta=0.625, bid=0.27, beta0=0.5)
    plan = build_plans(jobs, pol, r)
    got_a, got_p = _allocate_pool(plan, r, so, 12)
    want_a, want_p = _allocate_pool_reference(plan, r, so, 12)
    np.testing.assert_array_equal(got_a, want_a)
    np.testing.assert_array_equal(got_p.used, want_p.used)
    assert abs(got_p.reserved_instance_time
               - want_p.reserved_instance_time) < 1e-6
    assert abs(got_p.worked_instance_time
               - want_p.worked_instance_time) < 1e-6


