"""Online-learning subsystem: numpy-vs-scan-vs-pallas replay parity, Hedge
bit-compatibility with the legacy run_tola loop, seed determinism of the
sampled trace across backends, weight-underflow robustness on long
horizons, the Prop. B.1 regret-bound scaling, and the adversarial scenario
family."""

import numpy as np
import pytest

from repro.core import (
    Policy,
    SpotMarket,
    generate_chain_jobs,
    run_tola,
    spot_od_policies,
)
from repro.learn import (
    LEARNER_KINDS,
    LearnerSpec,
    Schedule,
    build_events,
    prop_b1_bound,
    replay,
)

TOL = 1e-5
ALL_SPECS = [LearnerSpec(k) for k in LEARNER_KINDS]


def _tensor(S=2, n=45, m=7, seed=0, spread=0.4):
    """Synthetic (S, n, m) unit-cost tensor + Poisson-ish arrivals."""
    rng = np.random.default_rng(seed)
    C = rng.random((S, n, m)) * (1 - spread) + np.linspace(
        0, spread, m)[None, None, :]
    arrivals = np.cumsum(rng.exponential(0.25, n))
    d = 3.0
    Z = rng.random(n) + 0.5
    return C, arrivals, d, Z


def test_numpy_vs_scan_parity_every_learner():
    """The jax scan replay matches the float64 oracle for every learner:
    identical sampled traces, weights/probabilities within 1e-5."""
    C, arrivals, d, Z = _tensor()
    a = replay(C, arrivals, d, workload=Z, learners=ALL_SPECS, seed=3,
               backend="numpy")
    b = replay(C, arrivals, d, workload=Z, learners=ALL_SPECS, seed=3,
               backend="jax")
    np.testing.assert_array_equal(a.chosen, b.chosen)
    np.testing.assert_allclose(a.weights, b.weights, atol=TOL)
    np.testing.assert_allclose(a.p_chosen, b.p_chosen, atol=TOL)
    np.testing.assert_allclose(a.expected_unit, b.expected_unit, atol=TOL)
    np.testing.assert_allclose(a.regret_curve(), b.regret_curve(), atol=TOL)


def test_replay_accepts_device_tensor():
    """A jax cost tensor feeds the compiled scan directly (no f64 staging
    copy) and yields the same replay as the equivalent numpy input; the
    result container still hands back host float64."""
    jnp = pytest.importorskip("jax.numpy")
    C, arrivals, d, Z = _tensor()
    host = replay(C, arrivals, d, workload=Z, learners=["hedge"], seed=3,
                  backend="jax")
    dev = replay(jnp.asarray(C), arrivals, d, workload=Z,
                 learners=["hedge"], seed=3, backend="jax")
    np.testing.assert_array_equal(host.chosen, dev.chosen)
    np.testing.assert_allclose(host.weights, dev.weights, atol=TOL)
    assert isinstance(dev.unit_cost, np.ndarray)
    assert dev.unit_cost.dtype == np.float64
    # the numpy oracle transparently pulls a device tensor to host
    oracle = replay(jnp.asarray(C), arrivals, d, workload=Z,
                    learners=["hedge"], seed=3, backend="numpy")
    np.testing.assert_array_equal(oracle.chosen, host.chosen)


def test_pallas_kernel_parity_hedge():
    """The fused weight-update kernel (interpret mode on CPU) matches the
    oracle, including across an eta schedule grid."""
    C, arrivals, d, Z = _tensor(n=60, m=9, seed=1)
    specs = [LearnerSpec("hedge"),
             LearnerSpec("hedge", eta=Schedule("const", 0.3)),
             LearnerSpec("hedge", eta=Schedule("invsqrt", 0.5))]
    a = replay(C, arrivals, d, workload=Z, learners=specs, seed=5,
               backend="numpy")
    b = replay(C, arrivals, d, workload=Z, learners=specs, seed=5,
               backend="pallas")
    np.testing.assert_array_equal(a.chosen, b.chosen)
    np.testing.assert_allclose(a.weights, b.weights, atol=TOL)
    np.testing.assert_allclose(a.p_chosen, b.p_chosen, atol=TOL)


def test_hedge_replay_ref_matches_oracle():
    """kernels/ref.py's loop-free trajectory formulation == the sequential
    event loop (structurally different algorithms, same numbers)."""
    from repro.kernels.ref import hedge_replay_ref

    C, arrivals, d, _ = _tensor(S=1, seed=2)
    _, _, n_done = build_events(arrivals, d)
    etas = Schedule().values(arrivals, d, C.shape[-1])
    u = np.random.default_rng(9).random(len(arrivals))
    ref = hedge_replay_ref(C[0], etas, u, n_done)
    a = replay(C, arrivals, d, learners=["hedge"], seed=9, backend="numpy")
    np.testing.assert_array_equal(ref["chosen"], a.chosen[0, 0])
    np.testing.assert_allclose(ref["weights"], a.weights[0, 0], atol=1e-12)
    np.testing.assert_allclose(ref["p_chosen"], a.p_chosen[0, 0], atol=1e-12)


def test_seed_determinism_across_backends():
    """One seed -> ONE sampled-policy trace, whichever backend replays it
    (the uniform stream is drawn once in numpy and shared)."""
    C, arrivals, d, _ = _tensor(S=2, n=50, m=6, seed=4)
    outs = [replay(C, arrivals, d, learners=ALL_SPECS, seed=11, backend=bk)
            for bk in ("numpy", "jax", "pallas")]
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0].chosen, other.chosen)
    # and the same call repeated is bitwise identical
    again = replay(C, arrivals, d, learners=ALL_SPECS, seed=11,
                   backend="numpy")
    np.testing.assert_array_equal(outs[0].chosen, again.chosen)
    np.testing.assert_array_equal(outs[0].weights, again.weights)


def test_hedge_bit_compatible_with_legacy_loop():
    """run_tola delegates to repro.learn and must reproduce the ORIGINAL
    in-module event loop draw for draw (rng.choice consumption included)."""
    jobs = generate_chain_jobs(60, job_type=2, seed=3)
    market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=4)
    grid = spot_od_policies()[:8]
    res = run_tola(jobs, grid, market, seed=7, backend="numpy")

    # The pre-subsystem Algorithm 4 loop, verbatim.
    from repro.core.tola import cost_matrix

    C = cost_matrix(jobs, grid, market, backend="numpy")
    arrivals = np.array([j.arrival for j in jobs])
    n, m = C.shape
    d = max(j.deadline - j.arrival for j in jobs)
    rng = np.random.default_rng(7)
    logw = np.full(m, -np.log(m))
    chosen = np.zeros(n, dtype=np.int64)
    events = sorted([(arrivals[j], 0, j) for j in range(n)]
                    + [(arrivals[j] + d, 1, j) for j in range(n)])
    for t, kind, j in events:
        if kind == 0:
            w = np.exp(logw - logw.max())
            w /= w.sum()
            chosen[j] = rng.choice(m, p=w)
        else:
            eta = np.sqrt(2.0 * np.log(m) / (d * max(t - d, d)))
            logw = logw - eta * C[j]
            logw -= logw.max()
    final_w = np.exp(logw - logw.max())
    final_w /= final_w.sum()

    np.testing.assert_array_equal(res.chosen, chosen)
    np.testing.assert_array_equal(res.weights, final_w)


def test_hedge_no_underflow_long_horizon():
    """Log-space renormalization regression: a 5k-job stream with losses
    biased against most policies must keep the weights finite and summing
    to one in every backend (naive w *= exp(-eta c) flushes to all-zero)."""
    rng = np.random.default_rng(0)
    n, m = 5000, 12
    C = rng.random((1, n, m)) * 0.2 + np.linspace(0, 0.8, m)[None, None, :]
    arrivals = np.cumsum(rng.exponential(0.25, n))
    spec = LearnerSpec("hedge", eta=Schedule("const", 0.5))
    for backend in ("numpy", "jax"):
        lr = replay(C, arrivals, 3.0, learners=[spec], seed=0,
                    backend=backend)
        w = lr.weights[0, 0]
        assert np.all(np.isfinite(w)), backend
        assert abs(w.sum() - 1.0) < 1e-5, backend
        assert w.max() > 1e-3, backend  # mass survived somewhere
        # and the learner actually concentrated on the cheap policies
        assert lr.chosen[0, 0][-100:].mean() < m / 4


@pytest.mark.parametrize("seed", range(4))
def test_hedge_regret_respects_prop_b1_scaling(seed):
    """Property: expected (sampling-noise-free) Hedge regret on synthetic
    cost matrices stays within the Prop. B.1-style delayed-feedback bound."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(80, 300))
    m = int(rng.integers(3, 25))
    C = rng.random((1, n, m))
    arrivals = np.cumsum(rng.exponential(float(rng.uniform(0.1, 0.6)), n))
    d = float(rng.uniform(0.5, 4.0))
    lr = replay(C, arrivals, d, learners=["hedge"], seed=seed,
                backend="numpy")
    total_regret = float(lr.regret_per_job(expected=True)[0, 0]) * n
    bound = prop_b1_bound(arrivals, d, m, c_max=1.0)
    assert total_regret <= bound, (total_regret, bound)


def test_prop_b1_bound_scaling_shape():
    """The bound itself scales like sqrt(n log m) at fixed delay."""
    arr = np.arange(400) * 0.25
    b1 = prop_b1_bound(arr[:100], 1.0, 8)
    b2 = prop_b1_bound(arr, 1.0, 8)
    assert 1.5 < b2 / b1 < 2.5  # sqrt(4x jobs) ~ 2x


def test_bandit_learners_only_see_sampled_column():
    """Feedback-model check: corrupting every UNSAMPLED cost entry after
    the fact cannot change a bandit learner's trajectory, but must change a
    full-information learner's."""
    C, arrivals, d, _ = _tensor(S=1, n=60, m=6, seed=6)
    base = replay(C, arrivals, d, learners=["exp3", "hedge"], seed=2,
                  backend="numpy")
    # corrupt: double every cost EXCEPT the entries exp3 actually sampled
    C2 = C * 2.0
    ch = base.chosen[0, 0]
    C2[0, np.arange(C.shape[1]), ch] = C[0, np.arange(C.shape[1]), ch]
    again = replay(C2, arrivals, d, learners=["exp3", "hedge"], seed=2,
                   backend="numpy")
    np.testing.assert_array_equal(base.chosen[0, 0], again.chosen[0, 0])
    np.testing.assert_allclose(base.weights[0, 0], again.weights[0, 0],
                               atol=1e-12)
    assert not np.array_equal(base.weights[0, 1], again.weights[0, 1])


def test_ftl_plays_cumulative_leader():
    C, arrivals, d, _ = _tensor(S=1, n=40, m=5, seed=8)
    lr = replay(C, arrivals, d, learners=["ftl"], seed=0, backend="numpy")
    _, _, n_done = build_events(arrivals, d)
    cum = np.concatenate([np.zeros((1, C.shape[2])),
                          np.cumsum(C[0], axis=0)])
    leaders = cum[n_done].argmin(axis=1)
    np.testing.assert_array_equal(lr.chosen[0, 0], leaders)


def test_learn_result_accessors():
    C, arrivals, d, Z = _tensor()
    lr = replay(C, arrivals, d, workload=Z, learners=ALL_SPECS, seed=1,
                backend="numpy")
    S, K, n = lr.chosen.shape
    assert (S, K) == (2, len(ALL_SPECS))
    curves = lr.regret_curve()
    assert curves.shape == (S, K, n)
    # the curve ends exactly at the headline per-job regret
    np.testing.assert_allclose(curves[..., -1], lr.regret_per_job(),
                               atol=1e-12)
    mean, lo, hi = lr.confidence_bands()
    assert mean.shape == (K, n)
    assert np.all(lo <= mean + 1e-12) and np.all(mean <= hi + 1e-12)
    assert len(lr.summary()) == K
    # fixed-policy accounting matches the tensor
    np.testing.assert_allclose(
        lr.fixed_unit_costs(),
        (C * Z[None, :, None]).sum(axis=1) / Z.sum(), atol=1e-12)


def test_adversarial_scenarios_share_grid_and_bite():
    """The adversarial family stacks with fresh scenarios (same slot grid)
    and drives realized unit costs strictly above the fresh-market level."""
    from repro.engine import evaluate_grid, make_scenarios

    jobs = generate_chain_jobs(40, job_type=2, seed=0)
    h = max(j.deadline for j in jobs) + 1
    adv = make_scenarios(h, 3, seed=5, kind="adversarial")
    fresh = make_scenarios(h, 3, seed=5, kind="fresh")
    assert adv[0].n_slots == fresh[0].n_slots
    # spikes sit at the on-demand ceiling, above every bid of the grid
    for m in adv:
        assert (m.price >= 0.999).mean() > 0.2
        assert m.beta_realized(0.30) < 0.8
    grid = spot_od_policies()[:10]
    res_a = evaluate_grid(jobs, grid, adv, backend="numpy")
    res_f = evaluate_grid(jobs, grid, fresh, backend="numpy")
    assert res_a.avg_unit_cost().mean() > res_f.avg_unit_cost().mean()


def test_run_tola_bandit_learner():
    """run_tola accepts any learner kind; the realized bandit-TOLA stream
    stays within the on-demand unit-cost ceiling and carries its replay."""
    jobs = generate_chain_jobs(150, job_type=2, seed=11)
    market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=12)
    grid = spot_od_policies()[:10]
    res = run_tola(jobs, grid, market, seed=0, backend="numpy",
                   learner="exp3")
    assert res.learn is not None and res.learn.specs[0].kind == "exp3"
    assert 0.0 < res.average_unit_cost() <= market.p_ondemand + 1e-9
    # the counterfactual replay regret is consistent with the cost matrix
    r = res.learn.regret_per_job()[0, 0]
    assert np.isfinite(r)


@pytest.mark.slow
def test_learner_sweep_end_to_end():
    """Heavyweight: full learner x eta-grid sweep through the engine tensor
    across scenarios, jax vs numpy, with sane regret ordering."""
    from repro.engine import evaluate_grid, make_scenarios

    jobs = generate_chain_jobs(300, job_type=2, seed=1)
    h = max(j.deadline for j in jobs) + 1
    markets = make_scenarios(h, 3, seed=100, kind="fresh")
    grid = spot_od_policies()
    res = evaluate_grid(jobs, grid, markets, backend="numpy")
    arrivals = np.array([j.arrival for j in jobs])
    d = max(j.deadline - j.arrival for j in jobs)
    specs = [LearnerSpec(k) for k in LEARNER_KINDS] + [
        LearnerSpec("hedge", eta=Schedule("const", c)) for c in (0.05, 0.2)]
    a = replay(res, arrivals, d, learners=specs, seed=0, backend="numpy")
    b = replay(res, arrivals, d, learners=specs, seed=0, backend="jax")
    np.testing.assert_array_equal(a.chosen, b.chosen)
    np.testing.assert_allclose(a.weights, b.weights, atol=TOL)
    # full-information hedge should be no worse than uniform play
    uniform = a.fixed_unit_costs().mean(axis=1)
    hedge = a.realized_unit()[:, 0]
    assert (hedge <= uniform + 0.02).all()