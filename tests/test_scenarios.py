"""Scenario subsystem: declarative specs, device synthesis parity, chunked
streaming bit-identity, the adaptive adversary, and the satellite
contracts (view caching, validation, replay padding)."""

import warnings

import numpy as np
import pytest

from repro.core import SpotMarket, generate_chain_jobs, spot_od_policies
from repro.engine import (
    ScenarioSpec,
    ScenarioStream,
    as_source,
    check_scenarios,
    evaluate_grid,
    make_scenarios,
    replay_scenarios,
)
from repro.engine.scenarios import (
    MarketListBatch,
    SynthBatch,
    _avail_threshold,
    _levels,
)
from repro.learn import replay, replay_stream

TOL = 1e-5


def _setup(n=16, jt=1, seed=5):
    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    return jobs, max(j.deadline for j in jobs) + 1.0


def _grid(n=8):
    return spot_od_policies()[:n]


# --------------------------------------------------------------------------
# ScenarioSpec basics
# --------------------------------------------------------------------------

def test_spec_hashable_and_validated():
    spec = ScenarioSpec("fresh", 20.0, 4, seed=3)
    assert {spec: 1}[ScenarioSpec("fresh", 20.0, 4, seed=3)] == 1
    assert spec != ScenarioSpec("fresh", 20.0, 4, seed=4)
    with pytest.raises(ValueError, match="kind"):
        ScenarioSpec("bogus", 20.0, 4)
    with pytest.raises(ValueError, match="scenario"):
        ScenarioSpec("fresh", 20.0, 0)
    with pytest.raises(ValueError, match="trace"):
        ScenarioSpec("replay", 20.0, 1)
    with pytest.raises(ValueError, match="replay"):
        ScenarioSpec("fresh", 20.0, 1, traces=((1.0,),))
    with pytest.raises(ValueError, match="2 traces"):
        ScenarioSpec("replay", 1.0, 3, traces=((1.0,), (0.5,)))


def test_make_scenarios_adaptive_needs_stream():
    with pytest.raises(ValueError, match="adaptive"):
        make_scenarios(20.0, 4, kind="adaptive")


def test_levels_bit_identical_numpy_vs_jax():
    jnp = pytest.importorskip("jax.numpy")
    idx = np.arange(5, 17)
    hn = _levels(99, 1, idx, 301)
    hj = np.asarray(_levels(99, 1, jnp.asarray(idx, jnp.int32), 301, xp=jnp))
    np.testing.assert_array_equal(hn, hj)
    assert hn.max() < 2 ** 24


@pytest.mark.parametrize("kind", ["fresh", "regime", "adversarial",
                                  "adaptive"])
def test_prices_chunk_slicing_and_materialize_bitwise(kind):
    """Any chunk reproduces the monolithic rows exactly, and materialize()
    wraps exactly those rows (today's from_prices path)."""
    spec = ScenarioSpec(kind, 15.0, 7, seed=11)
    P = spec.prices()
    np.testing.assert_array_equal(spec.prices(2, 6), P[2:6])
    np.testing.assert_array_equal(spec.prices(6, 7), P[6:7])
    mats = spec.materialize()
    np.testing.assert_array_equal(np.stack([m.price for m in mats]), P)
    assert all(m.n_slots == spec.n_slots for m in mats)


def test_avail_threshold_replicates_f64_comparison():
    """The device path's integer availability threshold selects EXACTLY the
    slots the host f64 ``price <= bid + 1e-12`` comparison selects."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        mean = float(rng.uniform(0.05, 0.3))
        bid = float(rng.uniform(0.1, 0.45))
        t = _avail_threshold(mean, 0.12, 1.0, bid)
        hs = rng.integers(0, 2 ** 24, 4000)
        price = np.minimum(0.12 + mean * (-np.log1p(-(hs * 2.0 ** -24))),
                           1.0)
        np.testing.assert_array_equal(price <= bid + 1e-12, hs <= t)


# --------------------------------------------------------------------------
# Engine integration: spec paths vs the materialized list path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fresh", "adversarial"])
def test_spec_numpy_bit_identical_to_materialized_list(kind):
    """numpy backend: spec (chunked or not) == materialized list, bitwise."""
    jobs, horizon = _setup()
    spec = ScenarioSpec(kind, horizon, 5, seed=9)
    ref = evaluate_grid(jobs, _grid(), spec.materialize(), 30,
                        backend="numpy")
    whole = evaluate_grid(jobs, _grid(), spec, 30, backend="numpy")
    chunked = evaluate_grid(jobs, _grid(), spec, 30, backend="numpy",
                            scenario_chunk=2)
    np.testing.assert_array_equal(whole.unit_cost, ref.unit_cost)
    np.testing.assert_array_equal(chunked.unit_cost, ref.unit_cost)
    assert len(chunked.timings["chunks"]) == 3


def test_chunked_list_path_bit_identical():
    """scenario_chunk=K == scenario_chunk=S == today's list path, bitwise."""
    jobs, horizon = _setup()
    markets = make_scenarios(horizon, 5, seed=21, kind="regime")
    ref = evaluate_grid(jobs, _grid(), markets, 30, backend="numpy")
    for k in (1, 2, 5):
        got = evaluate_grid(jobs, _grid(), markets, 30, backend="numpy",
                            scenario_chunk=k)
        np.testing.assert_array_equal(got.unit_cost, ref.unit_cost)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("kind", ["fresh", "regime", "adversarial"])
def test_spec_device_parity_all_backends(backend, kind):
    """Device-synthesized spec chunks vs the f64 numpy oracle — including
    the spiked (adversarial) grid — within the engine's 1e-5 contract."""
    jobs, horizon = _setup(n=10)
    spec = ScenarioSpec(kind, horizon, 4, seed=13)
    ref = evaluate_grid(jobs, _grid(6), spec, 20, backend="numpy")
    got = evaluate_grid(jobs, _grid(6), spec, 20, backend=backend,
                        scenario_chunk=2,
                        interpret=True if backend == "pallas" else None)
    np.testing.assert_allclose(got.unit_cost, ref.unit_cost,
                               atol=TOL, rtol=TOL)


def test_spec_jax_chunked_matches_monolithic():
    jobs, horizon = _setup(n=10)
    spec = ScenarioSpec("adversarial", horizon, 6, seed=3)
    whole = evaluate_grid(jobs, _grid(6), spec, 20, backend="jax")
    chunked = evaluate_grid(jobs, _grid(6), spec, 20, backend="jax",
                            scenario_chunk=2)
    np.testing.assert_allclose(chunked.unit_cost, whole.unit_cost,
                               atol=1e-7, rtol=1e-7)


def test_adaptive_spec_device_parity():
    """The adaptive family's streamed chunks (periods + pinned phases) agree
    across numpy and jax given identical adversary decisions."""
    pytest.importorskip("jax")
    jobs, horizon = _setup(n=10)
    spec = ScenarioSpec("adaptive", horizon, 4, seed=5, n_periods=2,
                        n_phases=2)
    periods = np.array([0.5, 0.5, 2.0, 2.0])
    offsets = np.array([0, 3, -1, 7])
    host = SynthBatch(spec, 0, 4, periods=periods, offsets=offsets,
                      device=False).prepare()
    dev = SynthBatch(spec, 0, 4, periods=periods, offsets=offsets,
                     device=True).prepare()
    for bid in (0.18, 0.30):
        Ah, Ch = host.stacked(bid)
        Ad, Cd = (np.asarray(x, np.float64) for x in dev.stacked(bid))
        # identical availability slot sets -> identical A steps
        np.testing.assert_array_equal(np.diff(Ah, axis=1) > 0,
                                      np.diff(Ad, axis=1) > 0)
        np.testing.assert_allclose(Cd, Ch, atol=1e-4)


def test_reduce_mean_matches_stacked_mean():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 6, seed=2)
    ref = evaluate_grid(jobs, _grid(), spec, 30, backend="numpy")
    red = evaluate_grid(jobs, _grid(), spec, 30, backend="numpy",
                        scenario_chunk=2, reduce="mean")
    assert red.unit_cost.shape[0] == 1
    assert red.n_scenarios_total == 6
    np.testing.assert_allclose(red.unit_cost[0], ref.unit_cost.mean(axis=0),
                               rtol=1e-12)
    with pytest.raises(ValueError, match="reduce"):
        evaluate_grid(jobs, _grid(), spec, 30, backend="numpy",
                      reduce="median")


# --------------------------------------------------------------------------
# Satellites: validation, view caching, replay padding
# --------------------------------------------------------------------------

def test_check_scenarios_empty_is_clear_value_error():
    with pytest.raises(ValueError, match="at least one"):
        check_scenarios([])
    jobs, _ = _setup(n=4)
    with pytest.raises(ValueError, match="at least one"):
        evaluate_grid(jobs, _grid(4), [], backend="numpy")


def test_scenario_chunk_validated_at_api_boundary():
    jobs, horizon = _setup(n=4)
    m = SpotMarket(horizon, seed=1)
    for bad in (0, -3, 2.5, True, "4"):
        with pytest.raises(ValueError, match="scenario_chunk"):
            evaluate_grid(jobs, _grid(4), m, backend="numpy",
                          scenario_chunk=bad)
    # chunking cannot split per-scenario availability batches
    markets = [SpotMarket(horizon, seed=s) for s in range(2)]
    queries = [lambda s, e: np.full(s.shape, 3.0)] * 2
    with pytest.raises(ValueError, match="per-scenario"):
        evaluate_grid(jobs, _grid(4), markets, 30, backend="numpy",
                      availability=queries, scenario_chunk=1)


def test_stacked_views_cached_no_recompute(monkeypatch):
    """The batch builds each bid's stacked views ONCE: repeated calls (and
    repeated engine passes over the same source) hand back the same arrays
    without touching SpotMarket.view again."""
    jobs, horizon = _setup(n=6)
    markets = make_scenarios(horizon, 3, seed=8)
    built = {"n": 0}
    orig = SpotMarket.view

    def counting_view(self, bid):
        if round(float(bid), 12) not in self._views:
            built["n"] += 1              # an actual view CONSTRUCTION
        return orig(self, bid)

    monkeypatch.setattr(SpotMarket, "view", counting_view)
    batch = MarketListBatch(markets)
    A1, C1 = batch.stacked(0.25)
    assert built["n"] == len(markets)
    A2, C2 = batch.stacked(0.25)
    assert A2 is A1 and C2 is C1
    assert built["n"] == len(markets)
    # same rounding rule as the GridPlan dedup: a 13th-decimal twin hits
    # the same cache entry (and constructs nothing)
    A3, _ = batch.stacked(0.25 + 1e-13)
    assert A3 is A1
    assert built["n"] == len(markets)

    # engine passes over one source never rebuild a (market, bid) view
    source = as_source(markets)
    built["n"] = 0
    evaluate_grid(jobs, _grid(4), source, backend="numpy")
    n_one_pass = built["n"]
    assert n_one_pass > 0
    evaluate_grid(jobs, _grid(4), source, backend="numpy")
    assert built["n"] == n_one_pass


def test_replay_padding_contract():
    """Short traces are right-padded with the documented above-every-bid
    price, a warning names the padding, and the padded scenario evaluates
    exactly like a manually padded market."""
    jobs, horizon = _setup(n=6)
    m = SpotMarket(horizon, seed=3)
    short = m.price[:m.n_slots // 2]
    with pytest.warns(UserWarning, match="1 trace"):
        markets = replay_scenarios([m.price, short])
    manual = np.concatenate([short, np.full(m.n_slots - len(short), 1.0)])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        np.testing.assert_array_equal(markets[1].price, manual)
        ref = evaluate_grid(jobs, _grid(4),
                            SpotMarket.from_prices(manual),
                            backend="numpy")
        got = evaluate_grid(jobs, _grid(4), markets[1], backend="numpy")
    np.testing.assert_array_equal(got.unit_cost, ref.unit_cost)
    # equal-length traces pad nothing and warn nothing
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        replay_scenarios([m.price, m.price * 0.5])
    # the declarative replay spec carries the same contract
    with pytest.warns(UserWarning, match="padded"):
        spec = ScenarioSpec.from_traces([m.price, short])
        np.testing.assert_array_equal(spec.prices()[1], manual)


# --------------------------------------------------------------------------
# Streamed learning + the adaptive adversary
# --------------------------------------------------------------------------

def test_replay_stream_matches_monolithic_replay():
    """Chunked replay_stream == replay over the materialized tensor (same
    seeds per scenario, summaries to float-summation tolerance)."""
    jobs, horizon = _setup(n=12, jt=2)
    grid = _grid(6)
    spec = ScenarioSpec("fresh", horizon, 6, seed=4)
    arrivals = np.array([j.arrival for j in jobs])
    d = max(j.deadline - j.arrival for j in jobs)
    Z = np.array([j.total_work for j in jobs])
    res = evaluate_grid(jobs, grid, spec.materialize(), 0, backend="numpy")
    lr = replay(res.unit_cost, arrivals, d, workload=Z,
                learners=["hedge", "exp3"], seed=0, backend="numpy")
    slr = replay_stream(jobs, grid, spec, 0, learners=["hedge", "exp3"],
                        seed=0, scenario_chunk=2, backend="numpy",
                        engine_backend="numpy")
    assert slr.n_scenarios == 6 and slr.n_chunks == 3
    np.testing.assert_allclose(slr.realized_unit(),
                               lr.realized_unit().mean(axis=0), rtol=1e-12)
    np.testing.assert_allclose(slr.regret_per_job(),
                               lr.regret_per_job().mean(axis=0),
                               rtol=1e-9, atol=1e-13)
    m_s, lo_s, hi_s = slr.confidence_bands()
    m_m, lo_m, hi_m = lr.confidence_bands()
    np.testing.assert_allclose(m_s, m_m, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(lo_s, lo_m, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(hi_s, hi_m, rtol=1e-6, atol=1e-9)
    for a, b in zip(slr.summary(), lr.summary()):
        assert a["learner"] == b["learner"]
        assert abs(a["regret"] - b["regret"]) < 1e-9


def test_adaptive_stream_stages_and_lock():
    """Stage machine: period sweep -> phase sweep at the worst period ->
    locked (period, phase); driven with synthetic feedback, no engine."""
    spec = ScenarioSpec("adaptive", 10.0, 12, seed=1, n_periods=2,
                        n_phases=3, spike_range=(0.5, 4.0))
    stream = ScenarioStream(spec)
    it = stream.chunks(4)
    next(it)
    assert stream.stage == "periods"
    # period 1 (4.0) hurts much more
    stream.observe(np.array([0.1, 0.9, 0.1, 0.9]))
    next(it)
    assert stream.stage == "phases"
    assert np.all(stream.chunk_periods[-1] == spec.period_menu()[1])
    assert len(np.unique(stream.chunk_offsets[-1])) == 3   # phase sweep
    # chunk 2 covers global indices 4..7 -> phase candidates [1, 2, 0, 1];
    # make candidate 2 (global index 5) hurt most
    stream.observe(np.array([0.5, 1.4, 0.6, 0.5]))
    next(it)
    assert stream.stage == "locked"
    cand = stream._phase_candidates(1)
    assert np.all(stream.chunk_offsets[-1] == cand[2])
    assert np.all(stream.chunk_periods[-1] == spec.period_menu()[1])


@pytest.mark.parametrize("engine_backend", ["numpy"])
def test_adaptive_adversary_beats_best_fixed_family(engine_backend):
    """ROADMAP adaptive-adversary regression: on the same scenario budget,
    the adaptive family's realized TOLA (hedge) regret must be >= every
    FIXED square-wave family's — it finds the worst period AND pins the
    phase, a lever the phase-randomized fixed families don't have.
    Deterministic: f64 numpy end to end, fixed seeds.
    """
    jobs = generate_chain_jobs(20, 2, seed=4)
    grid = spot_od_policies()[:10]
    horizon = max(j.deadline for j in jobs) + 1.0
    S, K = 48, 8
    kw = dict(learners=["hedge"], seed=0, backend="numpy",
              engine_backend=engine_backend)
    fixed = {}
    for p in (0.25, 8.0):
        spec_p = ScenarioSpec("adversarial", horizon, S, seed=7,
                              spike_range=(p, p))
        fixed[p] = float(replay_stream(jobs, grid, spec_p, 0,
                                       scenario_chunk=S, **kw)
                         .regret_per_job()[0])
    spec_a = ScenarioSpec("adaptive", horizon, S, seed=7,
                          spike_range=(0.25, 8.0), n_periods=2, n_phases=4)
    stream = ScenarioStream(spec_a)
    adaptive = float(replay_stream(jobs, grid, stream, 0, scenario_chunk=K,
                                   **kw).regret_per_job()[0])
    best_fixed = max(fixed.values())
    assert stream.stage == "locked"
    # locked onto the genuinely worst period of the menu
    assert stream._menu[stream._locked_period] == max(fixed, key=fixed.get)
    assert adaptive >= best_fixed, (
        f"adaptive adversary regret {adaptive:.4f} fell below the best "
        f"fixed square-wave family {best_fixed:.4f} ({fixed})")
