"""Vectorized plan layer: bit-compatibility of the batched builders with the
sequential Dealloc/plan loops, the jitted jax twin, the bid-stacked pallas
chain kernel, and the one-engine-pass-per-round TOLA refinement loop."""

import numpy as np
import pytest

from repro.core import (
    Policy,
    benchmark_bid_policies,
    generate_chain_jobs,
    selfowned_policies,
    spot_od_policies,
)
from repro.core.dealloc import (
    window_sizes,
    window_sizes_batch,
    window_sizes_batch_jax,
)
from repro.core.scheduler import build_plans, build_plans_batch, job_arrays
from repro.core.tola import run_tola, run_tola_scenarios
from repro.engine import make_scenarios
from repro.engine.plan import distinct_window_params

PLAN_FIELDS = ("starts", "ends", "z", "delta", "mask", "arrival")


def _grid_params(policies, r_total):
    """Distinct Dealloc parameters of a policy grid (engine dedup order)."""
    return list(distinct_window_params(policies, r_total).values())


@pytest.mark.parametrize("job_type", [1, 2, 3, 4])
def test_batched_plans_bitwise_vs_loop(job_type):
    """build_plans_batch over the exp1-exp4 policy grids is BIT-identical to
    looping build_plans per distinct window parameter."""
    jobs = generate_chain_jobs(40, job_type, seed=10 + job_type)
    grid = spot_od_policies() + selfowned_policies()
    for r_total in (0, 300):
        xs = _grid_params(grid, r_total)
        batch = build_plans_batch(jobs, xs)
        assert len(batch) == len(xs)
        for bp, x in zip(batch, xs):
            loop = build_plans(jobs, Policy(beta=x, bid=0.27), r_total)
            for f in PLAN_FIELDS:
                np.testing.assert_array_equal(
                    getattr(bp, f), getattr(loop, f), err_msg=f)


def test_batched_even_plans_bitwise_vs_loop():
    """The Even-benchmark window mode (exp1/exp4 bench grids) matches too."""
    jobs = generate_chain_jobs(35, 2, seed=9)
    pol = benchmark_bid_policies()[0]
    (bp,) = build_plans_batch(jobs, windows="even")
    loop = build_plans(jobs, pol, 0, windows="even")
    for f in PLAN_FIELDS:
        np.testing.assert_array_equal(getattr(bp, f), getattr(loop, f),
                                      err_msg=f)


def test_window_sizes_batch_validates():
    jobs = generate_chain_jobs(5, 1, seed=1)
    a = job_arrays(jobs)
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega, [0.0])
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega, [1.5])
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega - 1e3, [0.5])


def test_window_sizes_jax_twin_parity():
    """The jitted device twin agrees with the f64 canonical batch pass."""
    pytest.importorskip("jax")
    jobs = generate_chain_jobs(30, 3, seed=4)
    a = job_arrays(jobs)
    xs = np.array([0.3, 0.625, 1.0])
    want = window_sizes_batch(a.e, a.delta, a.mask, a.omega, xs)
    got = np.asarray(window_sizes_batch_jax(a.e, a.delta, a.mask,
                                            a.omega, xs))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # and the canonical pass matches the scalar Algorithm 1 loop
    for g, x in enumerate(xs):
        for ji, job in enumerate(jobs):
            np.testing.assert_array_equal(want[g, ji, :job.l],
                                          window_sizes(job, float(x)))


def test_chain_kernel_bid_stacked_parity():
    """One bid-stacked launch == per-bid chain_costs_ref, incl. row padding
    and scenario-specific plans."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.kernels.policy_cost import policy_cost_chain
    from repro.kernels.ref import chain_costs_ref

    rng = np.random.default_rng(0)
    S, L = 2, 4
    rows_per_bid = [10, 7]          # un-equal -> exercises zero-padding
    bids = [0.18, 0.27]
    markets = make_scenarios(60.0, S, seed=5)
    R_max = max(rows_per_bid)
    B = len(bids)
    A = np.stack([np.stack([m.view(b).A_cum for m in markets])
                  for b in bids])
    C = np.stack([np.stack([m.view(b).C_cum for m in markets])
                  for b in bids])
    arrival = np.zeros((B, R_max))
    ends = np.zeros((B, R_max, L))
    z_t = np.zeros((B, S, R_max, L))
    d_eff = np.zeros((B, S, R_max, L))
    pins = np.zeros((B, S, R_max, L), dtype=bool)
    for bi, R in enumerate(rows_per_bid):
        arrival[bi, :R] = rng.uniform(0, 20, R)
        sizes = rng.uniform(0.2, 6, (R, L))
        ends[bi, :R] = arrival[bi, :R, None] + np.cumsum(sizes, axis=1)
        d = rng.choice([1.0, 8.0, 64.0], (S, R, L))
        z_t[bi, :, :R] = rng.uniform(0, 1, (S, R, L)) * d * sizes
        d_eff[bi, :, :R] = d
        pins[bi, :, :R] = rng.random((S, R, L)) < 0.15
    got = policy_cost_chain(A, C, arrival, ends, z_t, d_eff, pins,
                            interpret=True)
    for bi, R in enumerate(rows_per_bid):
        for s in range(S):
            ref = chain_costs_ref(
                jnp.asarray(A[bi, s], jnp.float32),
                jnp.asarray(C[bi, s], jnp.float32),
                jnp.asarray(arrival[bi, :R], jnp.float32),
                jnp.asarray(ends[bi, :R], jnp.float32),
                jnp.asarray(z_t[bi, s, :R], jnp.float32),
                jnp.asarray(d_eff[bi, s, :R], jnp.float32),
                jnp.asarray(pins[bi, s, :R]))
            for key in ("spot_cost", "ondemand_cost", "spot_work",
                        "ondemand_work"):
                np.testing.assert_allclose(
                    np.asarray(got[key])[bi, s, :R], np.asarray(ref[key]),
                    atol=3e-3, rtol=3e-3, err_msg=f"{key} bid {bi} s {s}")


def test_run_tola_scenarios_one_engine_pass_per_round(monkeypatch):
    """Refinement issues EXACTLY one evaluate_grid call per round, and the
    Table-6 outputs stay bit-identical to the sequential per-scenario path."""
    import repro.engine as engine_mod

    jobs = generate_chain_jobs(30, 2, seed=3)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=21)
    pols = selfowned_policies()[::25]
    pool_iters = 2

    calls = []
    real = engine_mod.evaluate_grid

    def counting(*args, **kwargs):
        calls.append(kwargs.get("availability"))
        return real(*args, **kwargs)

    monkeypatch.setattr(engine_mod, "evaluate_grid", counting)
    batch = run_tola_scenarios(jobs, pols, markets, r_total=50, seed=7,
                               pool_iters=pool_iters, backend="numpy")
    monkeypatch.undo()
    # one call per round: the dedicated round 0 plus each refinement
    assert len(calls) == 1 + pool_iters
    assert calls[0] is None
    assert all(isinstance(a, list) and len(a) == len(markets)
               for a in calls[1:])

    for s, m in enumerate(markets):
        solo = run_tola(jobs, pols, m, r_total=50, seed=7 + s,
                        pool_iters=pool_iters, backend="numpy")
        np.testing.assert_array_equal(batch[s].cost_matrix, solo.cost_matrix)
        np.testing.assert_array_equal(batch[s].chosen, solo.chosen)
        np.testing.assert_array_equal(batch[s].weights, solo.weights)
        np.testing.assert_array_equal(batch[s].fixed_unit_costs,
                                      solo.fixed_unit_costs)
        np.testing.assert_array_equal(batch[s].realized.total_cost,
                                      solo.realized.total_cost)
        assert batch[s].average_unit_cost() == solo.average_unit_cost()


def test_per_scenario_availability_matches_per_scenario_calls():
    """engine: a list of S availability queries == S single-query passes."""
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(25, 2, seed=6)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=11)
    pols = selfowned_policies()[::30]
    qs = [lambda s0, e0: np.full_like(s0, 13.0),
          lambda s0, e0: np.maximum(40.0 - s0, 0.0)]
    both = evaluate_grid(jobs, pols, markets, 60, availability=qs,
                         backend="numpy")
    assert both.selfowned_work.ndim == 3
    for s, m in enumerate(markets):
        alone = evaluate_grid(jobs, pols, m, 60, availability=qs[s],
                              backend="numpy")
        np.testing.assert_array_equal(both.unit_cost[s], alone.matrix)
        np.testing.assert_array_equal(both.selfowned_work[s],
                                      alone.selfowned_work)
    with pytest.raises(ValueError):
        evaluate_grid(jobs, pols, markets, 60, availability=qs[:1],
                      backend="numpy")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("early_start", [True, False])
def test_per_scenario_availability_backend_parity(backend, early_start):
    """jax / pallas(interpret) agree with numpy on per-scenario-refined
    grids, for both the chain and the planned-start paths."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(20, 2, seed=8)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=13)
    pols = selfowned_policies()[::40]
    qs = [lambda s0, e0: np.full_like(s0, 9.0),
          lambda s0, e0: np.maximum(30.0 - 0.5 * s0, 0.0)]
    kw = dict(availability=qs, early_start=early_start)
    if not early_start:
        kw.update(windows="even", selfowned="naive")
    ref = evaluate_grid(jobs, pols, markets, 50, backend="numpy", **kw)
    got = evaluate_grid(jobs, pols, markets, 50, backend=backend,
                        interpret=True if backend == "pallas" else None,
                        **kw)
    np.testing.assert_allclose(got.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# window_sizes_batch knife edges (bit-identity with the sequential Alg.-1
# loop on the paths a generic random stream rarely exercises)
# ---------------------------------------------------------------------------

def _chain(arrival, deadline, zs, deltas):
    from repro.core.types import ChainJob, Task

    return ChainJob(arrival=arrival, deadline=deadline,
                    tasks=tuple(Task(z=z, delta=d)
                                for z, d in zip(zs, deltas)))


def _assert_batch_matches_loop(jobs, xs):
    a = job_arrays(jobs)
    got = window_sizes_batch(a.e, a.delta, a.mask, a.omega, xs)
    for g, x in enumerate(xs):
        for ji, job in enumerate(jobs):
            np.testing.assert_array_equal(
                got[g, ji, :job.l], window_sizes(job, float(x)),
                err_msg=f"x={x} job={ji}")
            assert np.all(got[g, ji, job.l:] == 0.0)  # padding takes none


def test_window_sizes_batch_single_task_residual():
    """Single-task jobs whose slack exceeds the cap: the overflow parks on
    the one task (order[0]) exactly like the sequential residual branch."""
    jobs = [_chain(0.0, 12.0, [4.0], [2.0]),      # e=2, cap(0.5)=2, slack 10
            _chain(3.0, 5.0, [1.5], [3.0]),       # e=0.5, slack 1.5
            _chain(1.0, 1.5, [0.5], [1.0])]       # zero slack single task
    _assert_batch_matches_loop(jobs, np.array([0.25, 0.5, 0.9]))


def test_window_sizes_batch_x_one_zero_cap():
    """x == 1.0: every cap is zero, so ALL slack is residual and parks on
    the max-delta task (ties broken by the stable sort, matching the loop)."""
    jobs = [_chain(0.0, 20.0, [2.0, 6.0, 1.0], [1.0, 4.0, 2.0]),
            # tie on delta: residual must land on the FIRST max-delta task
            _chain(2.0, 15.0, [3.0, 3.0, 2.0], [2.0, 2.0, 2.0]),
            _chain(0.0, 9.0, [4.0], [2.0])]
    _assert_batch_matches_loop(jobs, np.array([1.0]))
    # and mixed with x < 1 parameters in the same grid pass
    _assert_batch_matches_loop(jobs, np.array([0.5, 1.0, 0.8]))


def test_window_sizes_batch_all_slack_exhausted_break():
    """A grid where every job's slack is zero takes the early ``break`` (rem
    never populated) and must stay bit-identical to the sequential loop —
    all windows exactly the minimum execution times."""
    jobs = [_chain(0.0, 2.0, [2.0, 4.0], [2.0, 4.0]),     # window == e.sum()
            _chain(1.0, 3.5, [1.0, 3.0], [1.0, 2.0]),
            _chain(0.5, 2.0, [1.5, 3.0], [2.0, 4.0])]
    xs = np.array([0.3, 0.625, 1.0])
    a = job_arrays(jobs)
    assert np.all(a.omega == 0.0)
    got = window_sizes_batch(a.e, a.delta, a.mask, a.omega, xs)
    np.testing.assert_array_equal(
        got, np.broadcast_to(a.e, got.shape), err_msg="sizes must equal e")
    _assert_batch_matches_loop(jobs, xs)


# ---------------------------------------------------------------------------
# GridPlan bid dedup (rounded-key regression) + plan-layer availability check
# ---------------------------------------------------------------------------

def test_gridplan_bid_lookup_uses_rounded_key():
    """Bids differing at the 13th decimal collapse into ONE group, and
    groups_for_bid finds that group under EITHER raw float (regression:
    raw-float comparison silently returned [])."""
    from repro.engine.plan import build_grid_plan

    jobs = generate_chain_jobs(6, 2, seed=2)
    b1, b2 = 0.27, 0.27 + 1e-13
    assert b1 != b2                      # genuinely distinct floats
    pols = [Policy(beta=0.5, bid=b1), Policy(beta=0.5, bid=b2),
            Policy(beta=0.5, bid=0.3)]
    gplan = build_grid_plan(jobs, pols, r_total=0)
    assert len(gplan.groups) == 2
    assert len(gplan.bids) == 2
    g1 = gplan.groups_for_bid(b1)
    g2 = gplan.groups_for_bid(b2)
    assert g1 == g2 and len(g1) == 1
    assert sorted(g1[0].policy_idx.tolist()) == [0, 1]
    # every policy column is covered exactly once across bids
    covered = np.concatenate(
        [g.policy_idx for b in gplan.bids for g in gplan.groups_for_bid(b)])
    assert sorted(covered.tolist()) == [0, 1, 2]


def test_availability_length_checked_in_plan_layer():
    """A mismatched per-scenario availability list fails loudly inside
    build_grid_plan (not via a later backend shape error)."""
    from repro.engine.plan import build_grid_plan

    jobs = generate_chain_jobs(5, 1, seed=3)
    pols = selfowned_policies()[:2]
    q = lambda s0, e0: np.full_like(s0, 5.0)
    with pytest.raises(ValueError, match="one query per scenario"):
        build_grid_plan(jobs, pols, 40, availability=[q], n_scenarios=2)
    # without n_scenarios the caller opted out of the check (S' = len(list))
    gp = build_grid_plan(jobs, pols, 40, availability=[q, q])
    assert gp.per_scenario


# ---------------------------------------------------------------------------
# Device plan path: parity with the f64 canonical plan layer, hot-path
# device residency, and the jitted core twins
# ---------------------------------------------------------------------------

def test_expected_spot_work_jax_parity():
    pytest.importorskip("jax")
    from repro.core.dealloc import expected_spot_work, expected_spot_work_jax

    rng = np.random.default_rng(2)
    z = rng.uniform(0.1, 30.0, (40, 5))
    delta = rng.choice([1.0, 2.0, 8.0], (40, 5))
    sizes = z / delta + rng.uniform(0.0, 4.0, (40, 5))
    for x in (0.3, 0.625, 1.0):
        want = expected_spot_work(z, delta, sizes, x)
        got = np.asarray(expected_spot_work_jax(z, delta, sizes, x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["prop12", "naive"])
def test_selfowned_counts_jax_parity(mode):
    """The jitted policy-(12) twin matches the f64 oracle exactly on generic
    (non-knife-edge) grids, including the NaN-beta0 convention."""
    pytest.importorskip("jax")
    from repro.core.scheduler import (
        _selfowned_counts_vec,
        selfowned_counts_vec_jax,
    )

    rng = np.random.default_rng(7)
    z = rng.uniform(0.3, 6.0, (30, 4))
    delta = rng.choice([1.0, 2.0, 4.0], (30, 4))
    sizes = rng.uniform(0.4, 3.0, (30, 4))
    beta0 = rng.choice([0.31, 0.57, np.nan], (30, 1))
    for avail in (7.0, rng.uniform(0.0, 5.0, (2, 30, 4))):
        want = _selfowned_counts_vec(z, delta, sizes, beta0, avail, mode)
        got = np.asarray(selfowned_counts_vec_jax(z, delta, sizes, beta0,
                                                  avail, mode=mode))
        if np.isscalar(avail):
            # integral counts (or the integral pool bound): exact match
            np.testing.assert_array_equal(got, want)
        else:
            # a continuous availability query can be the binding min —
            # then the result is the f32-rounded query value itself
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("job_type", [1, 2, 3, 4])
def test_device_plan_parity_exp_grids(job_type):
    """evaluate_grid with the device plan path matches the f64 canonical
    (host plan + numpy oracle) to <=1e-5 over the exp1-exp4 workloads."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(30, job_type, seed=5 + job_type)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=7)
    grid = spot_od_policies() + selfowned_policies()[::7]
    ref = evaluate_grid(jobs, grid, markets, 60, backend="numpy")
    dev = evaluate_grid(jobs, grid, markets, 60, backend="jax",
                        plan_backend="device")
    assert dev.timings["plan_device"] > 0.0
    np.testing.assert_allclose(dev.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(dev.selfowned_work, ref.selfowned_work,
                               atol=1e-2, rtol=1e-4)


def test_device_plan_hot_path_never_calls_host_plan_layer(monkeypatch):
    """backend="jax" (plan_backend auto -> device) must not touch the host
    f64 plan builders: window_sizes_batch, build_plans_batch and the host
    policy-(12) counts are all stubbed out to fail loudly."""
    pytest.importorskip("jax")
    import sys

    import repro.core.scheduler as sched_mod
    import repro.engine.plan as plan_mod
    from repro.engine import evaluate_grid

    # repro.core re-exports a `dealloc` FUNCTION that shadows the submodule
    # attribute, so fetch the module object itself.
    dealloc_mod = sys.modules["repro.core.dealloc"]

    jobs = generate_chain_jobs(12, 2, seed=4)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=9)
    pols = selfowned_policies()[::40]

    def boom(*a, **k):
        raise AssertionError("host plan layer called on the device path")

    monkeypatch.setattr(plan_mod, "build_plans_batch", boom)
    monkeypatch.setattr(plan_mod, "_selfowned_counts_vec", boom)
    monkeypatch.setattr(sched_mod, "window_sizes_batch", boom)
    monkeypatch.setattr(dealloc_mod, "window_sizes_batch", boom)
    res = evaluate_grid(jobs, pols, markets, 50, backend="jax")
    assert res.timings["plan_device"] > 0.0
    monkeypatch.undo()
    ref = evaluate_grid(jobs, pols, markets, 50, backend="numpy")
    np.testing.assert_allclose(res.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)


def test_device_plan_single_availability_query_parity():
    """The staged device path (host availability callables between the two
    jit stages) matches the host plan for a single shared query."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(18, 3, seed=6)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=11)
    pols = selfowned_policies()[::30]
    q = lambda s0, e0: np.maximum(35.0 - 0.25 * s0, 0.0)
    ref = evaluate_grid(jobs, pols, markets, 50, availability=q,
                        backend="numpy")
    dev = evaluate_grid(jobs, pols, markets, 50, availability=q,
                        backend="jax", plan_backend="device")
    assert dev.timings["pool"] > 0.0      # staged leg, not the fused one
    np.testing.assert_allclose(dev.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)


def test_device_plan_pallas_backend_parity():
    """The pallas (interpret) backend consumes device plan tensors and
    agrees with the canonical path."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(8, 2, seed=12)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=13)
    pols = selfowned_policies()[::60]
    ref = evaluate_grid(jobs, pols, markets, 40, backend="numpy")
    dev = evaluate_grid(jobs, pols, markets, 40, backend="pallas",
                        plan_backend="device", interpret=True)
    np.testing.assert_allclose(dev.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)


def test_plan_backend_resolution():
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid, resolve_plan_backend

    assert resolve_plan_backend("auto", "numpy") == "host"
    assert resolve_plan_backend("auto", "jax") == "device"
    assert resolve_plan_backend("auto", "pallas") == "device"
    assert resolve_plan_backend("auto", "jax", pool="shared") == "host"
    assert resolve_plan_backend("host", "numpy") == "host"
    with pytest.raises(ValueError, match="host-only"):
        resolve_plan_backend("device", "numpy")
    with pytest.raises(ValueError, match="shared"):
        resolve_plan_backend("device", "jax", pool="shared")
    with pytest.raises(ValueError, match="unknown plan backend"):
        resolve_plan_backend("tpu", "jax")

    jobs = generate_chain_jobs(4, 1, seed=1)
    m = make_scenarios(max(j.deadline for j in jobs) + 1, 1, seed=1)
    with pytest.raises(ValueError, match="host-only"):
        evaluate_grid(jobs, [Policy(beta=0.5, bid=0.2)], m,
                      backend="numpy", plan_backend="device")


def test_device_plan_naive_scalar_availability_parity():
    """Regression: the naive counts rule ignores the window sizes, so with a
    SCALAR availability its result used to drop the akey axis and the group
    gather sliced the wrong dimension (exp4's Even-benchmark leg)."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(14, 2, seed=15)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=16)
    pols = [Policy(beta=0.5, bid=b, beta0=0.4) for b in (0.18, 0.27)]
    kw = dict(windows="even", selfowned="naive", early_start=False)
    ref = evaluate_grid(jobs, pols, markets, 40, backend="numpy", **kw)
    dev = evaluate_grid(jobs, pols, markets, 40, backend="jax",
                        plan_backend="device", **kw)
    np.testing.assert_allclose(dev.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)
