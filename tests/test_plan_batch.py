"""Vectorized plan layer: bit-compatibility of the batched builders with the
sequential Dealloc/plan loops, the jitted jax twin, the bid-stacked pallas
chain kernel, and the one-engine-pass-per-round TOLA refinement loop."""

import numpy as np
import pytest

from repro.core import (
    Policy,
    benchmark_bid_policies,
    generate_chain_jobs,
    selfowned_policies,
    spot_od_policies,
)
from repro.core.dealloc import (
    window_sizes,
    window_sizes_batch,
    window_sizes_batch_jax,
)
from repro.core.scheduler import build_plans, build_plans_batch, job_arrays
from repro.core.tola import run_tola, run_tola_scenarios
from repro.engine import make_scenarios
from repro.engine.plan import distinct_window_params

PLAN_FIELDS = ("starts", "ends", "z", "delta", "mask", "arrival")


def _grid_params(policies, r_total):
    """Distinct Dealloc parameters of a policy grid (engine dedup order)."""
    return list(distinct_window_params(policies, r_total).values())


@pytest.mark.parametrize("job_type", [1, 2, 3, 4])
def test_batched_plans_bitwise_vs_loop(job_type):
    """build_plans_batch over the exp1-exp4 policy grids is BIT-identical to
    looping build_plans per distinct window parameter."""
    jobs = generate_chain_jobs(40, job_type, seed=10 + job_type)
    grid = spot_od_policies() + selfowned_policies()
    for r_total in (0, 300):
        xs = _grid_params(grid, r_total)
        batch = build_plans_batch(jobs, xs)
        assert len(batch) == len(xs)
        for bp, x in zip(batch, xs):
            loop = build_plans(jobs, Policy(beta=x, bid=0.27), r_total)
            for f in PLAN_FIELDS:
                np.testing.assert_array_equal(
                    getattr(bp, f), getattr(loop, f), err_msg=f)


def test_batched_even_plans_bitwise_vs_loop():
    """The Even-benchmark window mode (exp1/exp4 bench grids) matches too."""
    jobs = generate_chain_jobs(35, 2, seed=9)
    pol = benchmark_bid_policies()[0]
    (bp,) = build_plans_batch(jobs, windows="even")
    loop = build_plans(jobs, pol, 0, windows="even")
    for f in PLAN_FIELDS:
        np.testing.assert_array_equal(getattr(bp, f), getattr(loop, f),
                                      err_msg=f)


def test_window_sizes_batch_validates():
    jobs = generate_chain_jobs(5, 1, seed=1)
    a = job_arrays(jobs)
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega, [0.0])
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega, [1.5])
    with pytest.raises(ValueError):
        window_sizes_batch(a.e, a.delta, a.mask, a.omega - 1e3, [0.5])


def test_window_sizes_jax_twin_parity():
    """The jitted device twin agrees with the f64 canonical batch pass."""
    pytest.importorskip("jax")
    jobs = generate_chain_jobs(30, 3, seed=4)
    a = job_arrays(jobs)
    xs = np.array([0.3, 0.625, 1.0])
    want = window_sizes_batch(a.e, a.delta, a.mask, a.omega, xs)
    got = np.asarray(window_sizes_batch_jax(a.e, a.delta, a.mask,
                                            a.omega, xs))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # and the canonical pass matches the scalar Algorithm 1 loop
    for g, x in enumerate(xs):
        for ji, job in enumerate(jobs):
            np.testing.assert_array_equal(want[g, ji, :job.l],
                                          window_sizes(job, float(x)))


def test_chain_kernel_bid_stacked_parity():
    """One bid-stacked launch == per-bid chain_costs_ref, incl. row padding
    and scenario-specific plans."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.kernels.policy_cost import policy_cost_chain
    from repro.kernels.ref import chain_costs_ref

    rng = np.random.default_rng(0)
    S, L = 2, 4
    rows_per_bid = [10, 7]          # un-equal -> exercises zero-padding
    bids = [0.18, 0.27]
    markets = make_scenarios(60.0, S, seed=5)
    R_max = max(rows_per_bid)
    B = len(bids)
    A = np.stack([np.stack([m.view(b).A_cum for m in markets])
                  for b in bids])
    C = np.stack([np.stack([m.view(b).C_cum for m in markets])
                  for b in bids])
    arrival = np.zeros((B, R_max))
    ends = np.zeros((B, R_max, L))
    z_t = np.zeros((B, S, R_max, L))
    d_eff = np.zeros((B, S, R_max, L))
    pins = np.zeros((B, S, R_max, L), dtype=bool)
    for bi, R in enumerate(rows_per_bid):
        arrival[bi, :R] = rng.uniform(0, 20, R)
        sizes = rng.uniform(0.2, 6, (R, L))
        ends[bi, :R] = arrival[bi, :R, None] + np.cumsum(sizes, axis=1)
        d = rng.choice([1.0, 8.0, 64.0], (S, R, L))
        z_t[bi, :, :R] = rng.uniform(0, 1, (S, R, L)) * d * sizes
        d_eff[bi, :, :R] = d
        pins[bi, :, :R] = rng.random((S, R, L)) < 0.15
    got = policy_cost_chain(A, C, arrival, ends, z_t, d_eff, pins,
                            interpret=True)
    for bi, R in enumerate(rows_per_bid):
        for s in range(S):
            ref = chain_costs_ref(
                jnp.asarray(A[bi, s], jnp.float32),
                jnp.asarray(C[bi, s], jnp.float32),
                jnp.asarray(arrival[bi, :R], jnp.float32),
                jnp.asarray(ends[bi, :R], jnp.float32),
                jnp.asarray(z_t[bi, s, :R], jnp.float32),
                jnp.asarray(d_eff[bi, s, :R], jnp.float32),
                jnp.asarray(pins[bi, s, :R]))
            for key in ("spot_cost", "ondemand_cost", "spot_work",
                        "ondemand_work"):
                np.testing.assert_allclose(
                    np.asarray(got[key])[bi, s, :R], np.asarray(ref[key]),
                    atol=3e-3, rtol=3e-3, err_msg=f"{key} bid {bi} s {s}")


def test_run_tola_scenarios_one_engine_pass_per_round(monkeypatch):
    """Refinement issues EXACTLY one evaluate_grid call per round, and the
    Table-6 outputs stay bit-identical to the sequential per-scenario path."""
    import repro.engine as engine_mod

    jobs = generate_chain_jobs(30, 2, seed=3)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=21)
    pols = selfowned_policies()[::25]
    pool_iters = 2

    calls = []
    real = engine_mod.evaluate_grid

    def counting(*args, **kwargs):
        calls.append(kwargs.get("availability"))
        return real(*args, **kwargs)

    monkeypatch.setattr(engine_mod, "evaluate_grid", counting)
    batch = run_tola_scenarios(jobs, pols, markets, r_total=50, seed=7,
                               pool_iters=pool_iters, backend="numpy")
    monkeypatch.undo()
    # one call per round: the dedicated round 0 plus each refinement
    assert len(calls) == 1 + pool_iters
    assert calls[0] is None
    assert all(isinstance(a, list) and len(a) == len(markets)
               for a in calls[1:])

    for s, m in enumerate(markets):
        solo = run_tola(jobs, pols, m, r_total=50, seed=7 + s,
                        pool_iters=pool_iters, backend="numpy")
        np.testing.assert_array_equal(batch[s].cost_matrix, solo.cost_matrix)
        np.testing.assert_array_equal(batch[s].chosen, solo.chosen)
        np.testing.assert_array_equal(batch[s].weights, solo.weights)
        np.testing.assert_array_equal(batch[s].fixed_unit_costs,
                                      solo.fixed_unit_costs)
        np.testing.assert_array_equal(batch[s].realized.total_cost,
                                      solo.realized.total_cost)
        assert batch[s].average_unit_cost() == solo.average_unit_cost()


def test_per_scenario_availability_matches_per_scenario_calls():
    """engine: a list of S availability queries == S single-query passes."""
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(25, 2, seed=6)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=11)
    pols = selfowned_policies()[::30]
    qs = [lambda s0, e0: np.full_like(s0, 13.0),
          lambda s0, e0: np.maximum(40.0 - s0, 0.0)]
    both = evaluate_grid(jobs, pols, markets, 60, availability=qs,
                         backend="numpy")
    assert both.selfowned_work.ndim == 3
    for s, m in enumerate(markets):
        alone = evaluate_grid(jobs, pols, m, 60, availability=qs[s],
                              backend="numpy")
        np.testing.assert_array_equal(both.unit_cost[s], alone.matrix)
        np.testing.assert_array_equal(both.selfowned_work[s],
                                      alone.selfowned_work)
    with pytest.raises(ValueError):
        evaluate_grid(jobs, pols, markets, 60, availability=qs[:1],
                      backend="numpy")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("early_start", [True, False])
def test_per_scenario_availability_backend_parity(backend, early_start):
    """jax / pallas(interpret) agree with numpy on per-scenario-refined
    grids, for both the chain and the planned-start paths."""
    pytest.importorskip("jax")
    from repro.engine import evaluate_grid

    jobs = generate_chain_jobs(20, 2, seed=8)
    markets = make_scenarios(max(j.deadline for j in jobs) + 1, 2, seed=13)
    pols = selfowned_policies()[::40]
    qs = [lambda s0, e0: np.full_like(s0, 9.0),
          lambda s0, e0: np.maximum(30.0 - 0.5 * s0, 0.0)]
    kw = dict(availability=qs, early_start=early_start)
    if not early_start:
        kw.update(windows="even", selfowned="naive")
    ref = evaluate_grid(jobs, pols, markets, 50, backend="numpy", **kw)
    got = evaluate_grid(jobs, pols, markets, 50, backend=backend,
                        interpret=True if backend == "pallas" else None,
                        **kw)
    np.testing.assert_allclose(got.unit_cost, ref.unit_cost,
                               atol=1e-5, rtol=1e-5)
