"""Property tests (hypothesis) for the core invariants: Dealloc optimality,
closed-form simulator == slot-stepping oracle, transform feasibility,
batch Greedy == sequential Greedy."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    SpotMarket,
    chain_from_arrays,
    expected_spot_work,
    generate_dag_jobs,
    run_greedy,
    transform,
    window_sizes,
)
from repro.core.oracle import oracle_greedy_chain, oracle_task
from repro.core.simulate import simulate_tasks

MARKET = SpotMarket(250.0, seed=42)

chain_strategy = st.builds(
    lambda zs, ds, slack: (zs, ds, slack),
    st.lists(st.floats(0.1, 30.0), min_size=1, max_size=8),
    st.lists(st.sampled_from([1.0, 2.0, 8.0, 64.0]), min_size=8, max_size=8),
    st.floats(0.0, 20.0),
)


@settings(max_examples=60, deadline=None)
@given(chain_strategy, st.floats(0.05, 0.95))
def test_dealloc_optimal_vs_random_splits(args, beta):
    zs, ds, slack = args
    ds = ds[:len(zs)]
    job = chain_from_arrays(0.0, sum(z / d for z, d in zip(zs, ds)) + slack,
                            zs, ds)
    sizes = window_sizes(job, beta)
    # feasibility: every window >= e_i, total == window
    e = job.e_array()
    assert np.all(sizes >= e - 1e-9)
    assert abs(sizes.sum() - job.window) < 1e-6
    zo_opt = expected_spot_work(job.z_array(), job.delta_array(), sizes,
                                beta).sum()
    rng = np.random.default_rng(int(beta * 1e6) % 2**31)
    for _ in range(20):
        w = rng.dirichlet(np.ones(job.l)) * job.slack
        zo = expected_spot_work(job.z_array(), job.delta_array(), e + w,
                                beta).sum()
        assert zo <= zo_opt + 1e-6


@settings(max_examples=80, deadline=None)
@given(
    st.floats(0.0, 150.0),     # start
    st.floats(0.05, 40.0),     # window size
    st.floats(0.0, 1.0),       # load fraction
    st.sampled_from([1.0, 2.0, 8.0, 64.0]),
    st.sampled_from([0.18, 0.21, 0.24, 0.27, 0.30]),
)
def test_simulator_matches_oracle(start, size, frac, delta, bid):
    end = start + size
    z = frac * delta * size
    sim = simulate_tasks(MARKET.view(bid), np.array([start]), np.array([end]),
                         np.array([z]), np.array([delta]))
    orc = oracle_task(MARKET, bid, start, end, z, delta)
    assert abs(sim.spot_cost[0] - orc["spot_cost"]) < 1e-8
    assert abs(sim.ondemand_cost[0] - orc["ondemand_cost"]) < 1e-8
    assert abs(sim.spot_work[0] - orc["spot_work"]) < 1e-8
    assert abs(sim.finish[0] - orc["finish"]) < 1e-8
    # invariants
    assert sim.spot_work[0] + sim.ondemand_work[0] <= z + 1e-9
    assert sim.finish[0] <= end + 1e-9
    if np.isfinite(sim.turning[0]):
        assert sim.ondemand_work[0] > -1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_transform_preserves_work_and_critical_path(seed):
    job = generate_dag_jobs(1, job_type=2, seed=seed)[0]
    chain = transform(job)
    assert abs(chain.total_work - job.total_work) < 1e-6 * job.total_work
    # chain critical path == pseudo-schedule makespan == DAG critical path
    assert abs(chain.min_makespan - job.critical_path) < 1e-8
    assert chain.feasible()
    # parallelism bounds: each pseudo-task's delta <= total DAG parallelism
    assert max(t.delta for t in chain.tasks) <= sum(
        t.delta for t in job.tasks) + 1e-9


def test_batch_greedy_equals_oracle_greedy():
    from repro.core import generate_chain_jobs
    jobs = generate_chain_jobs(60, job_type=1, seed=5)
    m = SpotMarket(max(j.deadline for j in jobs) + 1, seed=6)
    for bid in (0.18, 0.30):
        batch = run_greedy(jobs, bid, m, batch=True)
        for ji, job in enumerate(jobs):
            orc = oracle_greedy_chain(m, bid, job.arrival, job.deadline,
                                      job.z_array(), job.delta_array())
            assert abs(batch.spot_cost[ji] - orc["spot_cost"]) < 1e-6
            assert abs(batch.ondemand_cost[ji] - orc["ondemand_cost"]) < 1e-6


def test_market_invariants():
    m = SpotMarket(50.0, seed=7)
    assert np.all(m.price >= 0.12 - 1e-12) and np.all(m.price <= 1.0 + 1e-12)
    betas = [m.beta_realized(b) for b in (0.18, 0.21, 0.24, 0.27, 0.30)]
    assert all(b2 >= b1 for b1, b2 in zip(betas, betas[1:]))  # monotone
    v = m.view(0.24)
    t = np.linspace(0, 49, 300)
    np.testing.assert_allclose(v.A(t) + v.H(t), t, atol=1e-9)
    # inverse queries are true inverses on the availability support
    targets = np.linspace(0, v.A(np.array([49.0]))[0] - 1e-6, 50)
    tt = v.t_for_A(targets)
    np.testing.assert_allclose(v.A(tt), targets, atol=1e-9)
