"""Cross-call reuse layer (DESIGN.md §11): plan/view cache parity and
invalidation, the 12-decimal bid-key contract across calls, bounded
eviction, incremental (delta) grid evaluation, and the warm-path
zero-compile guarantee."""

import dataclasses

import numpy as np
import pytest

from repro.core import SpotMarket, generate_chain_jobs, selfowned_policies
from repro.engine import (
    available_backends,
    evaluate_grid,
    evaluate_grid_delta,
    make_scenarios,
)
from repro.engine import cache
from repro.obs import METRICS

BACKENDS = [b for b in ("numpy", "jax", "pallas") if b in available_backends()]


@pytest.fixture(autouse=True)
def _fresh_caches():
    """Every test counts cache events from zero and leaves the global
    caches the way it found them (other test modules share them)."""
    prev = cache._ENABLED_OVERRIDE
    cache.clear_caches()
    cache.configure(enabled=True, plan_maxsize=1024, view_maxsize=128)
    yield
    cache.clear_caches()
    cache._ENABLED_OVERRIDE = prev
    cache.configure(plan_maxsize=1024, view_maxsize=128)


def _setup(n=16, jt=1, seed=3, scenarios=2):
    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    return jobs, make_scenarios(horizon, scenarios, seed=seed + 100)


def _grid(n=10):
    return selfowned_policies()[:n]


def _tensors(res):
    return (res.unit_cost, res.spot_cost, res.ondemand_cost,
            res.selfowned_work)


def _assert_bitwise(a, b):
    for x, y in zip(_tensors(a), _tensors(b)):
        assert np.array_equal(x, y)


# The paper-table configurations (exp1-4 shapes): dedicated/shared pool,
# dealloc/even windows, chain and planned-start editions, r=0 and r>0.
CONFIGS = [
    dict(r_total=0),
    dict(r_total=600),
    dict(r_total=600, windows="even", selfowned="naive", pool="shared"),
    dict(r_total=600, early_start=False),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("cfg", CONFIGS,
                         ids=["r0", "r600", "shared-even", "planned"])
def test_cache_on_off_parity_bitwise(backend, cfg):
    """Cold, warm (all groups from cache) and cache-off runs of the same
    grid are BITWISE identical on every backend: the cache returns the
    exact tensors the builder would have produced."""
    jobs, markets = _setup(jt=2 if cfg.get("early_start") is False else 1)
    kw = dict(cfg, backend=backend)
    cold = evaluate_grid(jobs, _grid(), markets, **kw)
    assert cold.timings["plan_cached"] == 0
    warm = evaluate_grid(jobs, _grid(), markets, **kw)
    assert warm.timings["plan_cached"] > 0
    assert warm.timings["plan_cached"] == len(cache.PLAN_CACHE)
    with cache.disabled():
        off = evaluate_grid(jobs, _grid(), markets, **kw)
        assert off.timings["plan_cached"] == 0
    _assert_bitwise(cold, warm)
    _assert_bitwise(cold, off)


def test_bid_collision_cross_call_bitwise():
    """Two bids differing in the 13th decimal hit the SAME cache entry
    across calls (the in-grid dedup already rounds bids to 12 decimals;
    the cross-call key must not be finer) and score bitwise-identically."""
    jobs, markets = _setup()
    p = _grid(1)[0]
    base = evaluate_grid(jobs, [p], markets, 600, backend="numpy")
    h0 = cache.PLAN_CACHE.cache_info().hits
    q = dataclasses.replace(p, bid=p.bid + 1e-13)
    assert q.bid != p.bid            # genuinely different floats...
    res = evaluate_grid(jobs, [q], markets, 600, backend="numpy")
    assert cache.PLAN_CACHE.cache_info().hits == h0 + 1  # ...same entry
    assert res.timings["plan_cached"] == 1
    _assert_bitwise(base, res)


def test_eviction_under_bound_rebuilds_identical():
    """A plan cache too small for the grid keeps evicting, but evicted
    groups rebuild to bitwise-identical tensors on the next call."""
    jobs, markets = _setup()
    grid = _grid(10)
    ref = evaluate_grid(jobs, grid, markets, 600, backend="numpy")
    n_groups = len(set(cache.PLAN_CACHE._data)) or 1
    cache.clear_caches()
    cache.configure(plan_maxsize=max(n_groups // 2, 1))
    a = evaluate_grid(jobs, grid, markets, 600, backend="numpy")
    b = evaluate_grid(jobs, grid, markets, 600, backend="numpy")
    info = cache.PLAN_CACHE.cache_info()
    assert cache.PLAN_CACHE.evictions > 0
    assert info.currsize <= info.maxsize
    _assert_bitwise(ref, a)
    _assert_bitwise(ref, b)


def test_resize_evicts_and_counts():
    lru = cache._LRU(4)
    for i in range(4):
        lru.put(i, i)
    lru.resize(2)
    assert len(lru) == 2 and lru.evictions == 2
    assert 3 in lru and 0 not in lru


def _perturbed(grid, every=4):
    out = list(grid)
    idx = list(range(0, len(grid), every))
    for k, i in enumerate(idx):
        out[i] = dataclasses.replace(grid[i],
                                     bid=grid[i].bid * 1.01 + 1e-4 * (k + 1))
    return out, len(idx)


@pytest.mark.parametrize("backend", BACKENDS)
def test_delta_matches_full(backend):
    """evaluate_grid_delta over a partially re-bid grid re-scores ONLY the
    changed groups and matches the full re-eval — bitwise on the numpy
    oracle, <=1e-5 on the f32 backends."""
    jobs, markets = _setup()
    grid = _grid(12)
    prev = evaluate_grid(jobs, grid, markets, 600, backend=backend)
    assert prev.delta_state is not None
    grid2, n_changed = _perturbed(grid)
    with METRICS.collecting(reset=True):
        delta = evaluate_grid_delta(prev, jobs, grid2, markets, 600,
                                    backend=backend)
        snap = METRICS.snapshot()
    full = evaluate_grid(jobs, grid2, markets, 600, backend=backend)
    rescored = delta.timings["delta_groups_rescored"]
    assert 0 < rescored <= n_changed
    assert rescored < delta.timings["delta_groups_total"]
    series = snap["engine.delta_groups_rescored"]["series"]
    assert series and series[0]["value"] == rescored
    if backend == "numpy":
        _assert_bitwise(delta, full)
    else:
        for x, y in zip(_tensors(delta), _tensors(full)):
            np.testing.assert_allclose(x, y, atol=1e-5, rtol=1e-5)
    # the chained state supports a second round of edits
    assert delta.delta_state is not None
    grid3, _ = _perturbed(grid2, every=6)
    again = evaluate_grid_delta(delta, jobs, grid3, markets, 600,
                                backend=backend)
    full3 = evaluate_grid(jobs, grid3, markets, 600, backend=backend)
    if backend == "numpy":
        _assert_bitwise(again, full3)


def test_delta_no_change_rescoring_zero():
    jobs, markets = _setup()
    grid = _grid(6)
    prev = evaluate_grid(jobs, grid, markets, 600, backend="numpy")
    same = evaluate_grid_delta(prev, jobs, grid, markets, 600,
                               backend="numpy")
    assert same.timings["delta_groups_rescored"] == 0
    _assert_bitwise(prev, same)


def test_delta_validation_names_the_mismatch():
    jobs, markets = _setup()
    grid = _grid(4)
    prev = evaluate_grid(jobs, grid, markets, 600, backend="numpy")
    other_jobs, _ = _setup(seed=9)
    with pytest.raises(ValueError, match="jobs"):
        evaluate_grid_delta(prev, other_jobs, grid, markets, 600,
                            backend="numpy")
    _, other_markets = _setup(seed=9)
    with pytest.raises(ValueError, match="scenario"):
        evaluate_grid_delta(prev, jobs, grid, other_markets, 600,
                            backend="numpy")
    with pytest.raises(ValueError, match="r_total|config"):
        evaluate_grid_delta(prev, jobs, grid, markets, 300,
                            backend="numpy")
    mean = evaluate_grid(jobs, grid, markets, 600, backend="numpy",
                         reduce="mean")
    assert mean.delta_state is None
    with pytest.raises(ValueError, match="delta_state"):
        evaluate_grid_delta(mean, jobs, grid, markets, 600,
                            backend="numpy")


def test_availability_queries_not_cached():
    """Availability-query plans (TOLA pool refinement) bypass the cache
    entirely — their tensors depend on realized pool state."""
    jobs, markets = _setup()
    m = markets[0]
    grid = _grid(4)
    q = lambda s0, e0: np.maximum(40.0 - s0, 0.0)
    res = evaluate_grid(jobs, grid, m, 600, backend="numpy",
                        availability=q)
    assert res.timings["plan_cached"] == 0
    assert len(cache.PLAN_CACHE) == 0
    assert res.delta_state is None


@pytest.mark.skipif("jax" not in BACKENDS, reason="needs jax")
def test_warm_call_compiles_nothing():
    """Second identical evaluate_grid call in one process triggers ZERO
    XLA backend compiles (the cache-smoke CI gate, via jax.monitoring)."""
    from repro.obs.compiled import CompileWatch

    jobs, markets = _setup()
    grid = _grid(8)
    kw = dict(backend="jax")
    evaluate_grid(jobs, grid, markets, 600, **kw)   # cold: compiles freely
    watch = CompileWatch()
    assert watch.supported
    with watch:
        res = evaluate_grid(jobs, grid, markets, 600, **kw)
    assert watch.compiles == 0
    assert res.timings["plan_cached"] > 0


def test_factory_caches_reports_bounds_and_evictions():
    from repro.obs.compiled import factory_caches

    jobs, markets = _setup()
    evaluate_grid(jobs, _grid(4), markets, 600, backend="numpy")
    caches = factory_caches()
    for name in ("engine.plan_cache", "engine.view_cache"):
        assert name in caches
        entry = caches[name]
        assert set(entry) == {"hits", "misses", "maxsize", "currsize",
                              "evictions"}
        assert entry["maxsize"] is not None
    assert caches["engine.plan_cache"]["misses"] > 0


def test_plan_cache_metrics_series():
    jobs, markets = _setup()
    grid = _grid(6)
    with METRICS.collecting(reset=True):
        evaluate_grid(jobs, grid, markets, 600, backend="numpy")
        evaluate_grid(jobs, grid, markets, 600, backend="numpy")
        snap = METRICS.snapshot()
    series = {tuple(sorted(s["labels"].items())): s["value"]
              for s in snap["engine.plan_cache"]["series"]}
    assert series[(("event", "miss"),)] > 0
    assert series[(("event", "hit"),)] == series[(("event", "miss"),)]


def test_jobs_fingerprint_invalidates():
    jobs, markets = _setup()
    res1 = evaluate_grid(jobs, _grid(4), markets, 600, backend="numpy")
    h0 = cache.PLAN_CACHE.cache_info()
    jobs2, _ = _setup(seed=11)
    res2 = evaluate_grid(jobs2, _grid(4), markets, 600, backend="numpy")
    h1 = cache.PLAN_CACHE.cache_info()
    assert res2.timings["plan_cached"] == 0       # different jobs: all miss
    assert h1.hits == h0.hits
    assert cache.jobs_fingerprint(jobs) != cache.jobs_fingerprint(jobs2)


def test_scenario_fingerprint_kinds():
    jobs, markets = _setup()
    assert cache.scenario_fingerprint(markets) is not None
    assert (cache.scenario_fingerprint(markets)
            == cache.scenario_fingerprint(list(markets)))
    single = markets[0]
    assert cache.scenario_fingerprint(single) is not None
    assert (cache.scenario_fingerprint(single)
            != cache.scenario_fingerprint(markets))
    from repro.engine import ScenarioSpec
    spec = ScenarioSpec("fresh", 100.0, 4, seed=1)
    assert cache.scenario_fingerprint(spec) == spec
