"""Backend-dispatching evaluation engine: numpy/jax/pallas parity on the
cost matrix, scenario-axis semantics, shared-pool sweep equivalence, and
the legacy-path routing (cost_matrix / evaluate_policy_fullpool)."""

import numpy as np
import pytest

from repro.core import (
    Policy,
    SpotMarket,
    generate_chain_jobs,
    run_jobs,
    selfowned_policies,
    spot_od_policies,
)
from repro.core.scheduler import evaluate_policy_fullpool
from repro.core.tola import cost_matrix, run_tola, run_tola_scenarios
from repro.engine import (
    available_backends,
    evaluate_grid,
    make_scenarios,
    replay_scenarios,
    resolve_backend,
)

TOL = 1e-5


def _setup(n=25, jt=1, seed=5, mseed=7):
    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=mseed)
    return jobs, market


def _grid():
    return spot_od_policies()[:6] + selfowned_policies()[:6]


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_backend_parity_randomized_streams(backend, seed):
    """numpy vs jax vs pallas(interpret) agree on the cost matrix to 1e-5."""
    jobs, m = _setup(seed=seed, mseed=seed + 10)
    ref = evaluate_grid(jobs, _grid(), m, r_total=60, backend="numpy")
    got = evaluate_grid(jobs, _grid(), m, r_total=60, backend=backend,
                        interpret=True if backend == "pallas" else None)
    np.testing.assert_allclose(got.matrix, ref.matrix, atol=TOL, rtol=TOL)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_backend_parity_planned_starts(backend):
    """early_start=False (per-task windows) path, even windows + naive."""
    jobs, m = _setup(jt=2)
    kw = dict(r_total=40, windows="even", selfowned="naive",
              early_start=False, pool="shared")
    ref = evaluate_grid(jobs, _grid(), m, backend="numpy", **kw)
    got = evaluate_grid(jobs, _grid(), m, backend=backend, **kw)
    np.testing.assert_allclose(got.matrix, ref.matrix, atol=TOL, rtol=TOL)


def test_scenario_axis_reduces_to_single_market():
    """S=1 scenario list gives exactly the single-market result."""
    jobs, m = _setup()
    single = evaluate_grid(jobs, _grid(), m, r_total=30, backend="numpy")
    listed = evaluate_grid(jobs, _grid(), [m], r_total=30, backend="numpy")
    assert single.single_market and not listed.single_market
    np.testing.assert_array_equal(listed.unit_cost[0], single.matrix)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_scenario_batch_matches_per_scenario(backend):
    """Batching S markets in one pass == evaluating each market alone."""
    jobs, m = _setup()
    markets = make_scenarios(m.horizon, 3, seed=21, kind="regime")
    batched = evaluate_grid(jobs, _grid(), markets, r_total=30,
                            backend=backend)
    for s, ms in enumerate(markets):
        alone = evaluate_grid(jobs, _grid(), ms, r_total=30,
                              backend="numpy")
        np.testing.assert_allclose(batched.unit_cost[s], alone.matrix,
                                   atol=TOL, rtol=TOL)


def test_engine_matches_legacy_fullpool_loop():
    """The engine's dedicated-pool numpy path is bit-identical to the
    per-policy evaluate_policy_fullpool loop it replaced."""
    jobs, m = _setup(jt=3)
    pols = _grid()
    res = evaluate_grid(jobs, pols, m, r_total=50, backend="numpy")
    for p, pol in enumerate(pols):
        costs = evaluate_policy_fullpool(jobs, pol, m, r_total=50)
        np.testing.assert_array_equal(res.total_cost[0, :, p],
                                      costs.total_cost)
        np.testing.assert_array_equal(res.workload, costs.workload)


def test_shared_pool_matches_run_jobs():
    """pool="shared" replicates the realized run_jobs sweep semantics."""
    jobs, m = _setup(jt=2)
    pols = selfowned_policies()[::29]
    res = evaluate_grid(jobs, pols, m, r_total=60, pool="shared",
                        backend="numpy")
    for p, pol in enumerate(pols):
        costs = run_jobs(jobs, pol, m, r_total=60)
        np.testing.assert_array_equal(res.total_cost[0, :, p],
                                      costs.total_cost)
        np.testing.assert_array_equal(res.selfowned_work[:, p],
                                      costs.selfowned_work)


def test_cost_matrix_routes_through_engine():
    jobs, m = _setup()
    pols = _grid()
    C = cost_matrix(jobs, pols, m, r_total=30)
    res = evaluate_grid(jobs, pols, m, r_total=30, backend="numpy")
    np.testing.assert_array_equal(C, res.matrix)
    assert C.shape == (len(jobs), len(pols))


def test_dedup_groups():
    """C1 x C2 x B collapses: every beta >= beta_0 shares Dealloc(beta_0)."""
    from repro.engine import build_grid_plan

    jobs, _ = _setup(n=8)
    grid = selfowned_policies()          # 175 policies
    gplan = build_grid_plan(jobs, grid, r_total=300)
    assert gplan.n_policies == 175
    # 13 distinct (Dealloc param, beta_0) pairs x 5 bids.
    assert len(gplan.groups) == 65
    covered = np.concatenate([g.policy_idx for g in gplan.groups])
    assert sorted(covered.tolist()) == list(range(175))


def test_replay_adapter_roundtrip():
    """A replayed price trace reproduces the source market's evaluation."""
    jobs, m = _setup()
    replay = replay_scenarios([m.price])[0]
    a = evaluate_grid(jobs, _grid(), m, backend="numpy")
    b = evaluate_grid(jobs, _grid(), replay, backend="numpy")
    np.testing.assert_array_equal(a.matrix, b.matrix)


def test_run_tola_scenarios_batches():
    """Scenario-batched TOLA: scenario 0 equals the plain single-market run."""
    jobs, m = _setup(n=40, jt=2)
    pols = spot_od_policies()[:8]
    markets = make_scenarios(m.horizon, 2, seed=33)
    batch = run_tola_scenarios(jobs, pols, markets, seed=3,
                               backend="numpy")
    solo = run_tola(jobs, pols, markets[0], seed=3, backend="numpy")
    assert len(batch) == 2
    np.testing.assert_array_equal(batch[0].cost_matrix, solo.cost_matrix)
    np.testing.assert_array_equal(batch[0].chosen, solo.chosen)
    assert batch[0].average_unit_cost() == solo.average_unit_cost()


def test_backend_resolution():
    assert "numpy" in available_backends()
    assert resolve_backend("numpy") == "numpy"
    assert resolve_backend("auto") in ("numpy", "jax", "pallas")
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_scenarios_must_share_grid():
    jobs, m = _setup()
    bad = SpotMarket(m.horizon + 50, seed=1)
    with pytest.raises(ValueError):
        evaluate_grid(jobs, _grid(), [m, bad], backend="numpy")


def test_engine_result_accessors():
    jobs, m = _setup()
    pols = _grid()
    res = evaluate_grid(jobs, pols, m, r_total=30, backend="numpy")
    p, alpha = res.best()
    assert alpha == res.avg_unit_cost()[0].min()
    sc = res.stream_costs(p, 0)
    assert abs(sc.average_unit_cost() - alpha) < 1e-12
    # work conservation: spot + on-demand + self-owned == workload
    total = (res.spot_work[0, :, p] + res.ondemand_work[0, :, p]
             + res.selfowned_work[:, p])
    np.testing.assert_allclose(total, res.workload, rtol=1e-9)


def test_available_backends_probes_pallas(monkeypatch):
    """"pallas" is advertised only when jax.experimental.pallas actually
    imports — a jax build without it must fail at SELECTION time with a
    message naming the missing piece, not mid-run."""
    import sys

    pytest.importorskip("jax")
    # Poison the pallas module: `import jax.experimental.pallas` now raises
    # ImportError even though `import jax` still succeeds.
    monkeypatch.setitem(sys.modules, "jax.experimental.pallas", None)
    avail = available_backends()
    assert "jax" in avail and "pallas" not in avail
    with pytest.raises(ValueError, match="jax.experimental.pallas"):
        resolve_backend("pallas")
    monkeypatch.undo()
    assert "pallas" in available_backends()
    assert resolve_backend("pallas") == "pallas"


def test_resolve_backend_env_override_validated(monkeypatch):
    """An invalid REPRO_ENGINE_BACKEND value is reported as the ENV problem
    it is (naming the variable), instead of blaming the caller's "auto"."""
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_ENGINE_BACKEND"):
        resolve_backend("auto")
    # explicit backends bypass the env override entirely
    assert resolve_backend("numpy") == "numpy"
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "numpy")
    assert resolve_backend("auto") == "numpy"
