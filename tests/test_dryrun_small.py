"""Isolated small-mesh dry-run: proves the lower+compile+analyze pipeline
end-to-end in a subprocess (the forced host device count must not leak into
the other tests' single-device world)."""

import json
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # heavyweight; excluded from the fast tier-1 loop

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import smoke_config
from repro.distributed.sharding import ShardingRules
from repro.launch import steps as step_lib
from repro.launch.hlo_analysis import analyze
from repro.models import build
from repro.optim import AdamW
import jax.numpy as jnp

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_config("llama3_8b")
model = build(cfg)
rules = ShardingRules.create(mesh)
opt = AdamW()
params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
opt_s = jax.eval_shape(opt.init, params_s)
batch_s = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
           "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
with jax.set_mesh(mesh):
    in_sh, out_sh = step_lib.train_shardings(model, rules, mesh, params_s,
                                             opt_s, batch_s)
    fn = step_lib.make_train_step(model, opt, rules, n_microbatches=2)
    compiled = jax.jit(fn, in_shardings=in_sh,
                       out_shardings=out_sh).lower(params_s, opt_s,
                                                   batch_s).compile()
ana = analyze(compiled.as_text())
print(json.dumps({
    "flops": ana["flops"],
    "coll": ana["collectives"]["total"],
    "devices": len(jax.devices()),
}))
"""


def test_small_mesh_dryrun_pipeline():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        cwd="/root/repo", timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    assert res["flops"] > 0          # trip-count-corrected dot flops
    assert res["coll"] > 0           # DP grad all-reduce present
