"""Unit tests for the HLO roofline analyzer on synthetic module text."""

from repro.launch.hlo_analysis import analyze

MODULE = """HloModule jit_step, is_scheduled=true

%fused_computation.1 (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[16,4]{1,0} parameter(1)
  ROOT %dot.9 = f32[8,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body.2 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={{0,1}}, to_apply=%add.1
  ROOT %tuple = (s32[], f32[8,16]{1,0}) tuple(%gte0, %all-reduce.1)
}

%cond.3 (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main.1 (x: f32[8,16], w1: f32[16,4]) -> f32[8,4] {
  %x = f32[8,16]{1,0} parameter(0)
  %w1 = f32[16,4]{1,0} parameter(1)
  %t = (s32[], f32[8,16]{1,0}) tuple(%x)
  %while.1 = (s32[], f32[8,16]{1,0}) while(%t), condition=%cond.3, body=%body.2, backend_config={"known_trip_count":{"n":"5"}}
  %gte = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
  ROOT %fusion.1 = f32[8,4]{1,0} fusion(%gte, %w1), kind=kOutput, calls=%fused_computation.1
}
"""


def test_trip_count_multiplies_loop_body():
    res = analyze(MODULE)
    # body dot: 2*8*16*16 = 4096 flops, x5 trips; fusion dot: 2*8*4*16 = 1024
    assert res["flops"] == 5 * 4096 + 1024


def test_collectives_resolved_via_symtab_and_multiplied():
    res = analyze(MODULE)
    # all-reduce operand f32[8,16] = 512 B, x5 trips
    assert res["collectives"]["all-reduce"] == 5 * 512
    assert res["collectives"]["total"] == 5 * 512


def test_fusion_internal_ops_do_not_count_bytes():
    res = analyze(MODULE)
    # bytes: body dot (512 out + 512 gte1 + 1024 w) + all-reduce(512+512) x5
    # + entry fusion (128 out + 512 + 256 operands). The fused dot itself
    # must NOT be double counted.
    body_per_iter = (512 + 512 + 1024) + (512 + 512)
    entry = 128 + 512 + 256
    assert res["bytes"] == 5 * body_per_iter + entry


def test_warnings_empty_for_wellformed_module():
    assert analyze(MODULE)["warnings"] == []
