"""Pipeline parallelism + compressed psum under shard_map (4 host devices,
isolated subprocess so the device-count flag can't leak)."""

import json
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # heavyweight; excluded from the fast tier-1 loop

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.pipeline import pipeline_apply, bubble_fraction
from repro.distributed.compression import compressed_psum_tree

mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
n_stages, n_micro, Bm, D = 4, 8, 2, 16
W = jnp.asarray(rng.normal(size=(n_stages, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(n_micro, Bm, D)), jnp.float32)

def stage_fn(w, a):
    return jnp.tanh(a @ w)

with jax.set_mesh(mesh):
    out = pipeline_apply(stage_fn, W, x, n_stages)

# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ W[s])
pipe_err = float(jnp.max(jnp.abs(out - ref)))

# compressed psum over the stage axis (reused as a pod-like axis)
g = {"w": jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)}
e = {"w": jnp.zeros((4, 32), jnp.float32)}
def reduce_fn(g_l, e_l):
    return compressed_psum_tree(g_l, e_l, "stage")
with jax.set_mesh(mesh):
    mean_g, new_e = jax.shard_map(
        reduce_fn, in_specs=({"w": P("stage")}, {"w": P("stage")}),
        out_specs=({"w": P("stage")}, {"w": P("stage")}),
        axis_names={"stage"}, check_vma=False)(g, e)
# exact mean for comparison
exact = jnp.mean(g["w"], axis=0, keepdims=True)
comp_err = float(jnp.max(jnp.abs(mean_g["w"][0] - exact[0])))
scale = float(jnp.max(jnp.abs(g["w"])))
print(json.dumps({"pipe_err": pipe_err, "comp_err": comp_err,
                  "rel": comp_err / scale,
                  "bubble": bubble_fraction(n_micro, n_stages)}))
"""


def test_pipeline_and_compression_on_4_devices():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        cwd="/root/repo", timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["pipe_err"] < 1e-5          # pipeline == sequential stages
    assert res["rel"] < 0.02               # int8 quantization error bound
    assert abs(res["bubble"] - 3 / 11) < 1e-9
