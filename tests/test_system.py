"""End-to-end behaviour: elastic training with preemption + restart, the
fleet orchestrator driving paper-scheduled training DAGs, and the serving
loop — the full two-layer system."""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.serve import serve_requests
from repro.launch.train import train_loop
from repro.sched import FleetOrchestrator, FleetSpec, training_job_dag

pytestmark = pytest.mark.slow  # heavyweight; excluded from the fast tier-1 loop


def test_train_preempt_restart_resumes_exactly(tmp_path):
    cfg = smoke_config("tinyllama_1_1b")
    r1 = train_loop(cfg, steps=8, ckpt_dir=str(tmp_path), global_batch=4,
                    seq_len=32, preempt_at=6, ckpt_every=3, log_every=100)
    assert r1["status"] == "preempted" and r1["step"] == 6
    # elastic restart (same single CPU device here; restores step 6)
    r2 = train_loop(cfg, steps=8, ckpt_dir=str(tmp_path), global_batch=4,
                    seq_len=32, resume=True, ckpt_every=3, log_every=100)
    assert r2["status"] == "done"
    # deterministic pipeline: steps 0..5 ran once, 6..7 after restore
    assert len(r1["losses"]) + len(r2["losses"]) == 8
    assert np.isfinite(r2["final_loss"])


def test_train_loss_decreases(tmp_path):
    cfg = smoke_config("tinyllama_1_1b")
    r = train_loop(cfg, steps=30, ckpt_dir=str(tmp_path), global_batch=4,
                   seq_len=32, ckpt_every=100, log_every=100)
    first = np.mean(r["losses"][:5])
    last = np.mean(r["losses"][-5:])
    assert last < first  # synthetic but learnable (hash n-gram structure)


def test_serve_smoke():
    cfg = smoke_config("granite_3_8b")
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 16), dtype=np.int32)
    out, stats = serve_requests(cfg, prompts, batch=2, max_new=6)
    assert out.shape == (4, 6)
    assert stats["requests"] == 4
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_fleet_orchestrator_end_to_end():
    """Layer A scheduling Layer B jobs: training DAGs -> chain transform ->
    TOLA-learned policies -> cost report."""
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0, 30))
    jobs = [training_job_dag("llama3_8b", float(a), deadline_factor=2.0,
                             max_pods=8, cache=[]) for a in arrivals]
    fleet = FleetSpec(reserved_pods=4)
    orch = FleetOrchestrator(fleet, horizon_units=float(arrivals[-1] + 50))
    rep = orch.schedule(jobs, learn=True)
    fr = rep.spot_fraction + rep.selfowned_fraction + rep.ondemand_fraction
    assert abs(fr - 1.0) < 1e-6
    assert rep.unit_cost < 1.0          # better than all-on-demand
    assert rep.selfowned_fraction > 0   # reserved pods actually used

    # learning beats not-learning-at-all only in expectation; but the fixed
    # best policy must beat the single worst policy:
    rep_fixed = orch.schedule(jobs, learn=False)
    assert rep_fixed.unit_cost <= rep.unit_cost + 0.05


def test_stage_plan_windows_are_feasible():
    jobs = [training_job_dag("mamba2_2_7b", 0.0, max_pods=4, cache=[])]
    orch = FleetOrchestrator(FleetSpec(reserved_pods=2), horizon_units=200.0)
    from repro.core import Policy
    plan = orch.stage_plan(jobs[0], Policy(beta=0.625, bid=0.24, beta0=0.5))
    sizes = plan.sizes[plan.mask]
    assert np.all(sizes > 0)
    assert plan.ends[0, plan.mask[0]][-1] <= jobs[0].deadline + 1e-6
