"""Property tests (hypothesis) for the segment-tree shared-pool allocation:
the lazy-add occupancy structure must match the sequential chronological
scan EXACTLY, especially under deep oversubscription (r << demand), where
every chunk is contended and allocation lives entirely on the tree."""

import numpy as np
import pytest

from repro.core import Policy, generate_chain_jobs

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_scheduler_tola import _allocate_pool_reference  # noqa: E402


@settings(max_examples=12, deadline=None)
@given(n=st.integers(15, 45), jt=st.integers(1, 4), r=st.integers(1, 25),
       seed=st.integers(0, 10_000),
       so=st.sampled_from(["prop12", "naive"]))
def test_segment_tree_pool_matches_sequential_oversubscribed(n, jt, r, seed,
                                                             so):
    """Deeply oversubscribed pools (r << task demand, which reaches delta =
    64 per task): grants, occupancy trace and accounting all equal the
    one-task-at-a-time reference loop."""
    from repro.core.scheduler import _allocate_pool, build_plans

    jobs = generate_chain_jobs(n, job_type=jt, seed=seed)
    pol = Policy(beta=0.625, bid=0.27, beta0=0.5)
    plan = build_plans(jobs, pol, r)
    got_a, got_p = _allocate_pool(plan, r, so, 12)
    want_a, want_p = _allocate_pool_reference(plan, r, so, 12)
    np.testing.assert_array_equal(got_a, want_a)
    np.testing.assert_array_equal(got_p.used, want_p.used)
    assert abs(got_p.reserved_instance_time
               - want_p.reserved_instance_time) < 1e-6
    assert abs(got_p.worked_instance_time
               - want_p.worked_instance_time) < 1e-6
    assert got_p.used.max(initial=0) <= r


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_lazy_segment_tree_matches_naive(data):
    """LazySegmentTree range-add / range-max == flat numpy reference under
    arbitrary interleavings (non-power-of-two sizes included)."""
    from repro.core.pool import LazySegmentTree

    n = data.draw(st.integers(1, 200), label="n")
    base = data.draw(st.lists(st.integers(0, 50), min_size=n, max_size=n),
                     label="base")
    naive = np.array(base, dtype=np.int64)
    tree = LazySegmentTree(naive.copy())
    for _ in range(data.draw(st.integers(1, 30), label="ops")):
        lo = data.draw(st.integers(0, n - 1))
        hi = data.draw(st.integers(lo + 1, n))
        if data.draw(st.booleans()):
            v = data.draw(st.integers(0, 20))
            tree.add(lo, hi, v)
            naive[lo:hi] += v
        else:
            assert tree.max(lo, hi) == naive[lo:hi].max()
    for lo, hi in [(0, n), (n // 2, n), (0, max(1, n // 3))]:
        assert tree.max(lo, hi) == naive[lo:hi].max()
