"""Per-architecture smoke tests: every assigned arch at a REDUCED config —
one train step (forward + grad + optimizer update) on CPU, asserting output
shapes and no NaNs; plus prefill/decode consistency for the serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, smoke_config
from repro.distributed.sharding import ShardingRules
from repro.launch import steps as step_lib
from repro.models import build
from repro.optim import AdamW

pytestmark = pytest.mark.slow  # heavyweight; excluded from the fast tier-1 loop

B, S = 2, 64


def _batch(cfg):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.kind == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = smoke_config(arch)
    model = build(cfg)
    rules = ShardingRules.create(None)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(step_lib.make_train_step(model, opt, rules))
    batch = _batch(cfg)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_opt.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert delta > 0
    # logits shape check
    logits, _ = model.forward(params, batch, rules)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_grad_accumulation_matches_single_batch(arch):
    """n_microbatches=2 must reproduce the single-shot loss (same data)."""
    cfg = smoke_config(arch)
    model = build(cfg)
    rules = ShardingRules.create(None)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    batch = _batch(cfg)
    s1 = jax.jit(step_lib.make_train_step(model, opt, rules, 1))
    s2 = jax.jit(step_lib.make_train_step(model, opt, rules, 2))
    _, _, m1 = s1(params, opt.init(params), batch)
    _, _, m2 = s2(params, opt.init(params), batch)
    # microbatched mean-of-means == full-batch mean for equal-sized batches.
    # MoE is only approximately equal: capacity dropping and the
    # load-balance aux loss see different token populations per microbatch.
    tol = 2e-1 if cfg.kind == "moe" else 5e-3
    assert abs(float(m1["loss"]) - float(m2["loss"])) < tol


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_matches_forward(arch):
    cfg = smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits_f, _ = model.forward(params, batch)
    logits_p, cache = model.prefill(params, batch, max_len=S + 8)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(logits_f[:, -1], np.float32), atol=2e-2, rtol=2e-2)
    # one decode step runs and produces finite logits
    pos = S + (cfg.n_meta_tokens or 0) + (
        cfg.frontend_len if cfg.kind == "vlm" else 0)
    lg, cache2 = model.decode(params, cache, batch["tokens"][:, :1], pos)
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))


def test_decode_consistency_with_forward():
    """Teacher-forced decode must reproduce forward logits step by step
    (decoder family, exactness of the KV-cache path)."""
    cfg = smoke_config("llama3_8b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    toks = batch["tokens"]
    logits_f, _ = model.forward(params, batch)
    n_prefix = 8
    _, cache = model.prefill(params, {"tokens": toks[:, :n_prefix]},
                             max_len=S)
    for t in range(n_prefix, min(n_prefix + 4, S)):
        lg, cache = model.decode(params, cache, toks[:, t:t + 1] * 0 +
                                 toks[:, t:t + 1], t)
        # decode at position t sees tokens[:, :t+1]; forward logits at t match
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(logits_f[:, t], np.float32), atol=2e-2, rtol=2e-2)
