"""The paper's own worked examples and propositions as unit tests."""

import numpy as np
import pytest

from repro.core import chain_from_arrays, expected_spot_work, window_sizes
from repro.core.policy import (
    f_selfowned,
    selfowned_allocation,
    spot_ondemand_split,
    turning_point_expected,
)


class TestFig3Fig4Example:
    """Section 4.1.1 example: l=4, z=(1.5,.5,2.5,.5), delta=(2,1,3,1),
    beta=0.5, window [0,4] -> optimal spot workload 22/6 with window sizes
    (4/3, 1/2, 5/3, 1/2)."""

    def setup_method(self):
        self.job = chain_from_arrays(0.0, 4.0, [1.5, 0.5, 2.5, 0.5],
                                     [2, 1, 3, 1])

    def test_optimal_window_sizes(self):
        sizes = window_sizes(self.job, 0.5)
        np.testing.assert_allclose(sizes, [4 / 3, 0.5, 5 / 3, 0.5], atol=1e-12)

    def test_optimal_spot_work_is_22_over_6(self):
        sizes = window_sizes(self.job, 0.5)
        zo = expected_spot_work(self.job.z_array(), self.job.delta_array(),
                                sizes, 0.5)
        assert abs(zo.sum() - 22 / 6) < 1e-12

    def test_paper_naive_allocation_gets_2(self):
        """The artificial allocation s_i = i yields only 2 units on spot."""
        sizes = np.ones(4)
        zo = expected_spot_work(self.job.z_array(), self.job.delta_array(),
                                sizes, 0.5)
        assert abs(zo.sum() - 2.0) < 1e-12

    def test_dealloc_beats_any_random_split(self):
        rng = np.random.default_rng(0)
        sizes_opt = window_sizes(self.job, 0.5)
        zo_opt = expected_spot_work(self.job.z_array(),
                                    self.job.delta_array(), sizes_opt, 0.5).sum()
        e = self.job.e_array()
        slack = self.job.slack
        for _ in range(200):
            w = rng.dirichlet(np.ones(4)) * slack
            zo = expected_spot_work(self.job.z_array(),
                                    self.job.delta_array(), e + w, 0.5).sum()
            assert zo <= zo_opt + 1e-9


class TestDefinition32Example:
    """Section 3.3.1 toy: delta=3, window [0,2], r=1, beta=0.5 =>
    z=3.5 -> no turning point; z=5.5 -> turning point at t=1."""

    def test_no_turning_point(self):
        # z_tilde = 3.5 - 1*2 = 1.5; d_eff = 2; expected finish: spot+od
        # process at rate 0.5*1 + 1 = 1.5/unit -> done at t=1.
        split = spot_ondemand_split(z=1.5, delta=2, size=2.0, beta=0.5)
        # 1.5/2 = 0.75 = e; e/beta = 1.5 < 2 -> spot alone expected.
        assert split.turning is None and split.s == 2

    def test_turning_point_at_1(self):
        # z_tilde = 5.5 - 2 = 3.5, d_eff = 2, window 2: e = 1.75,
        # e/beta = 3.5 > 2 -> two phases; expected turning:
        # tau = (2*2 - 3.5) / (2 * 0.5) = 0.5 with all-spot phase 1
        # (the paper's mixed o=s=1 example reaches state z(1)=2 at t=1;
        # the OPTIMAL composition turns at tau=(size*d - z)/(d*(1-beta))).
        tau = turning_point_expected(z=3.5, delta=2, size=2.0, beta=0.5)
        assert abs(tau - 0.5) < 1e-12


class TestProp41Cases:
    def test_spot_alone_iff_window_geq_e_over_beta(self):
        s = spot_ondemand_split(z=4.0, delta=2.0, size=4.0, beta=0.5)
        assert s.phase2 is False  # size = e/beta exactly
        s = spot_ondemand_split(z=4.0, delta=2.0, size=3.9, beta=0.5)
        assert s.phase2 is True and s.s == 2.0
        s = spot_ondemand_split(z=4.0, delta=2.0, size=2.0, beta=0.5)
        assert s.o == 2.0 and s.turning == 0.0

    def test_infeasible_window_raises(self):
        with pytest.raises(ValueError):
            spot_ondemand_split(z=4.0, delta=2.0, size=1.9, beta=0.5)


class TestProp44SelfOwned:
    def test_f_nonincreasing_in_x(self):
        xs = np.linspace(0.05, 0.99, 50)
        vals = f_selfowned(10.0, 4.0, 3.0, xs)
        assert np.all(np.diff(vals) <= 1e-9)

    def test_f_beta_finishes_on_spot(self):
        """After r = f(beta) self-owned, the remainder fits on spot alone:
        beta * (delta - r) * size >= z - r * size."""
        for (z, d, size, beta) in [(10, 4, 3, 0.5), (5, 8, 1, 0.3),
                                   (20, 4, 6, 0.9)]:
            r = float(f_selfowned(z, d, size, beta))
            assert beta * (d - r) * size + r * size >= z - 1e-9

    def test_f_zero_when_window_large(self):
        # x >= e / size => f = 0
        assert f_selfowned(6.0, 3.0, 4.0, 0.5) == 0.0  # e/size = .5 <= x

    def test_policy12_caps(self):
        r = selfowned_allocation(z=100.0, delta=4.0, size=3.0, beta0=0.1,
                                 available=2.0)
        assert r <= 2.0  # pool cap
        r = selfowned_allocation(z=100.0, delta=4.0, size=3.0, beta0=0.1,
                                 available=100.0)
        assert r <= 4.0  # parallelism cap
        r = selfowned_allocation(z=1.0, delta=64.0, size=10.0, beta0=0.01,
                                 available=100.0)
        assert r <= 1.0  # useful-work cap (ceil(z / size))
