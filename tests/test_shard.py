"""Sharded scenario x group axes (DESIGN.md §9): GridMesh, shard_map'ed
jobs -> cost -> regret, the two-axis padding contract, and the
one-psum-per-chunk rule.

Fast tests run in-process on whatever devices are visible (a 1-device mesh
is the degenerate case and must be BITWISE identical to the unsharded jax
path — same program, same f32 arithmetic). Multi-device behavior (real
2-D sharding, padding of S % data_shards != 0 and G % model_shards != 0,
sharded refinement rounds) runs in-process when 8 devices are visible (the
shard-smoke CI job forces 8 host devices) and in a slow subprocess test
that forces them itself, because the XLA device-count flag must be set
before jax initializes.
"""

import json
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core import generate_chain_jobs, selfowned_policies
from repro.engine import (
    GridMesh,
    ScenarioMesh,
    ScenarioSpec,
    as_scenario_mesh,
    evaluate_grid,
    make_scenarios,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


def _setup(n=20, jt=2, seed=0):
    jobs = generate_chain_jobs(n, jt, seed=seed)
    horizon = max(j.deadline for j in jobs) + 1.0
    return jobs, horizon


GRID = selfowned_policies()[:12]


# --------------------------------------------------------------------------
# Mesh construction and argument normalization
# --------------------------------------------------------------------------

def test_mesh_create_defaults_and_padding():
    mesh = ScenarioMesh.create()
    assert mesh.n_shards == len(jax.devices())
    n = mesh.n_shards
    assert mesh.pad(0) == 0
    assert mesh.pad(1) == n
    assert mesh.pad(n) == n
    assert mesh.pad(n + 1) == 2 * n
    a = np.arange(10.0).reshape(5, 2)
    padded = mesh.pad_rows(a)
    assert padded.shape[0] == mesh.pad(5)
    # padding repeats the LAST row — real scenario data, masked downstream
    assert np.array_equal(padded[5:], np.repeat(a[-1:], len(padded) - 5, 0))


def test_mesh_2d_axes_and_group_padding():
    # GridMesh generalizes ScenarioMesh (same class): a second logical
    # axis group -> "model" with its own whole-group padding contract.
    assert GridMesh is ScenarioMesh
    mesh = GridMesh.create(1)          # 1-D: model axis absent, 1-wide
    assert mesh.data_shards == 1
    assert mesh.model_shards == 1
    assert mesh.pad_groups(5) == 5
    from repro.engine.mesh import edge_repeat, pad_to
    assert pad_to(13, 4) == 16 and pad_to(8, 4) == 8 and pad_to(0, 3) == 0
    a = np.arange(6.0).reshape(3, 2)
    p = edge_repeat(a, 5)
    assert p.shape == (5, 2)
    assert np.array_equal(p[3:], np.repeat(a[-1:], 2, axis=0))
    with pytest.raises(ValueError):
        edge_repeat(a, 2)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
@pytest.mark.parametrize("shape", [(4, 2), (2, 4)])
def test_mesh_2d_create(shape):
    n, m = shape
    mesh = GridMesh.create(n, m)
    assert mesh.n_shards == n * m
    assert (mesh.data_shards, mesh.model_shards) == (n, m)
    assert tuple(mesh.mesh.axis_names) == ("data", "model")
    # scenario rows pad to data_shards, groups to model_shards
    assert mesh.pad(n + 1) == 2 * n
    assert mesh.pad_groups(m + 1) == 2 * m
    # logical-axis routing: scenario -> data, group -> model
    from jax.sharding import PartitionSpec as P
    assert mesh.spec("scenario") == P("data")
    assert mesh.spec("group") == P("model")
    assert mesh.spec("scenario", "group") == P("data", "model")
    # a raw 2-D jax Mesh normalizes too
    from repro.launch.mesh import make_mesh
    got = as_scenario_mesh(make_mesh(shape, ("data", "model")))
    assert (got.data_shards, got.model_shards) == shape


def _clear_clamp_dedupe():
    from repro.engine import mesh as mesh_mod

    mesh_mod._CLAMP_WARNED.clear()


def test_mesh_create_clamps_with_warning():
    _clear_clamp_dedupe()
    avail = len(jax.devices())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = ScenarioMesh.create(avail + 7)
    assert mesh.n_shards == avail
    msgs = [str(x.message) for x in w]
    assert any("clamping" in s for s in msgs)
    assert any("xla_force_host_platform_device_count" in s for s in msgs)
    # the message names both the requested and the visible device counts
    assert any(str(avail + 7) in s and str(avail) in s for s in msgs)


def test_mesh_clamp_warning_dedupes_per_process():
    # A sweep building the same over-subscribed mesh in every cell warns
    # exactly ONCE per distinct (requested, visible) key — not per call.
    _clear_clamp_dedupe()
    avail = len(jax.devices())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ScenarioMesh.create(avail + 7)
        ScenarioMesh.create(avail + 7)
        ScenarioMesh.create(avail + 7)
    assert len([x for x in w if "clamping" in str(x.message)]) == 1
    # a DIFFERENT over-subscription is a new key and warns again
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ScenarioMesh.create(avail + 9)
    assert len([x for x in w if "clamping" in str(x.message)]) == 1


def test_as_scenario_mesh_normalization():
    assert as_scenario_mesh(None) is None
    mesh = ScenarioMesh.create(1)
    assert as_scenario_mesh(mesh) is mesh
    assert as_scenario_mesh(1).n_shards == 1
    with pytest.raises(ValueError):
        as_scenario_mesh(True)
    with pytest.raises(ValueError):
        as_scenario_mesh(0)
    with pytest.raises(ValueError):
        as_scenario_mesh("data")
    # a raw jax Mesh is accepted iff it has a "data" axis
    from repro.launch.mesh import make_mesh
    assert as_scenario_mesh(make_mesh((1,), ("data",))).n_shards == 1
    with pytest.raises(ValueError, match="data"):
        as_scenario_mesh(make_mesh((1,), ("model",)))


def test_mesh_is_hashable_cache_key():
    m1 = ScenarioMesh.create(1)
    m2 = ScenarioMesh.create(1)
    assert hash(m1) == hash(m2)
    assert m1 == m2


# --------------------------------------------------------------------------
# Guard rails at the API boundary
# --------------------------------------------------------------------------

def test_mesh_rejects_non_jax_backends():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 4, seed=3)
    mesh = ScenarioMesh.create(1)
    with pytest.raises(ValueError, match="mesh"):
        evaluate_grid(jobs, GRID, spec, 300, backend="numpy", mesh=mesh)
    with pytest.raises(ValueError, match="mesh"):
        evaluate_grid(jobs, GRID, spec, 300, backend="pallas", mesh=mesh)


def _per_scenario_avails(S, J):
    """Deterministic per-scenario availability queries (one per scenario,
    distinct results) shaped like TOLA's realized-residual queries."""
    def make(s):
        return lambda starts, ends: np.full_like(
            np.asarray(starts, np.float64), float(s % 3))
    return [make(s) for s in range(S)]


def test_mesh_shards_per_scenario_availability():
    # Refined (per-scenario availability) plans evaluate SHARDED since the
    # 2-D GridMesh landed: the (S, R, L) self-owned stacks ride the "data"
    # axis next to the views. 1-device mesh: bitwise == unsharded jax;
    # both within 1e-5 of the f64 numpy oracle.
    jobs, horizon = _setup()
    markets = make_scenarios(horizon, 3, seed=1)
    avail = _per_scenario_avails(len(markets), len(jobs))
    oracle = evaluate_grid(jobs, GRID, markets, 300, backend="numpy",
                           availability=avail).unit_cost
    ref = evaluate_grid(jobs, GRID, markets, 300, backend="jax",
                        availability=avail).unit_cost
    got = evaluate_grid(jobs, GRID, markets, 300, backend="jax",
                        availability=avail,
                        mesh=ScenarioMesh.create(1)).unit_cost
    assert np.array_equal(ref, got)
    assert np.abs(got - oracle).max() < 1e-5


def test_overlap_rejects_reactive_stream():
    jobs, horizon = _setup()
    spec = ScenarioSpec("adaptive", horizon, 8, seed=3)
    with pytest.raises(ValueError, match="reactive|adaptive"):
        evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                      scenario_chunk=4, overlap=True)


def test_replay_stream_mesh_rejects_numpy_replay():
    from repro.learn import replay_stream

    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 4, seed=3)
    with pytest.raises(ValueError, match="mesh"):
        replay_stream(jobs, GRID, spec, 300, backend="numpy",
                      mesh=ScenarioMesh.create(1))


# --------------------------------------------------------------------------
# 1-device mesh: the degenerate case is bitwise the unsharded jax program
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fresh", "adversarial", "adaptive"])
def test_one_device_mesh_bitwise_spec(kind):
    jobs, horizon = _setup()
    spec = ScenarioSpec(kind, horizon, 5, seed=7)
    ref = evaluate_grid(jobs, GRID, spec, 300, backend="jax")
    got = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                        mesh=ScenarioMesh.create(1))
    assert np.array_equal(ref.unit_cost, got.unit_cost)
    assert np.array_equal(ref.spot_cost, got.spot_cost)


def test_one_device_mesh_bitwise_market_list():
    jobs, horizon = _setup()
    markets = make_scenarios(horizon, 3, seed=1)
    ref = evaluate_grid(jobs, GRID, markets, 300, backend="jax")
    got = evaluate_grid(jobs, GRID, markets, 300, backend="jax",
                        mesh=ScenarioMesh.create(1))
    assert np.array_equal(ref.unit_cost, got.unit_cost)


def test_one_device_mesh_bitwise_task_path():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 4, seed=7)
    ref = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                        early_start=False)
    got = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                        early_start=False, mesh=ScenarioMesh.create(1))
    assert np.array_equal(ref.unit_cost, got.unit_cost)


def test_mesh_chunked_uneven_mean_matches_oracle():
    # S=7 with chunk=3 exercises BOTH uneven weighting (a final short
    # chunk under reduce="mean") and mesh padding of every chunk.
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 7, seed=7)
    oracle = evaluate_grid(jobs, GRID, spec, 300, backend="numpy",
                           reduce="mean").unit_cost
    sharded = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                            scenario_chunk=3, reduce="mean",
                            mesh=ScenarioMesh.create(1)).unit_cost
    assert np.abs(sharded - oracle).max() < 1e-5
    # and without reduce: concatenated chunks, padding sliced off
    full = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                         scenario_chunk=3,
                         mesh=ScenarioMesh.create(1)).unit_cost
    assert full.shape[0] == 7
    mono = evaluate_grid(jobs, GRID, spec, 300, backend="jax").unit_cost
    assert np.array_equal(full, mono)


def test_overlap_bitwise_and_flagged():
    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 6, seed=7)
    ref = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                        scenario_chunk=2, overlap=False)
    ov = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                       scenario_chunk=2, overlap=True)
    assert np.array_equal(ref.unit_cost, ov.unit_cost)
    assert ov.timings["overlap"] is True
    assert ref.timings["overlap"] is False
    # overlap is the DEFAULT for non-reactive jax streams
    dflt = evaluate_grid(jobs, GRID, spec, 300, backend="jax",
                         scenario_chunk=2)
    assert dflt.timings["overlap"] is True


def test_replay_stream_sharded_fold_matches_host_fold():
    from repro.learn import replay_stream

    jobs, horizon = _setup()
    spec = ScenarioSpec("fresh", horizon, 7, seed=5)
    # multi-kind learner set exercises the grouped scan + inverse perm
    learners = ["hedge", "exp3", "egreedy"]
    ref = replay_stream(jobs, GRID, spec, 300, learners=learners, seed=11,
                        scenario_chunk=3, backend="jax",
                        engine_backend="jax")
    sh = replay_stream(jobs, GRID, spec, 300, learners=learners, seed=11,
                       scenario_chunk=3, backend="jax",
                       engine_backend="jax", mesh=ScenarioMesh.create(1))
    assert sh.n_scenarios == ref.n_scenarios == 7
    assert sh.n_chunks == ref.n_chunks == 3
    # device f32 fold vs host f64-on-f32-traces fold: ~1e-4 budget
    assert np.abs(ref.regret_per_job() - sh.regret_per_job()).max() < 1e-4
    assert np.abs(ref.realized_unit() - sh.realized_unit()).max() < 1e-4
    assert abs(ref.best_fixed() - sh.best_fixed()) < 1e-4
    m0, lo0, hi0 = ref.confidence_bands()
    m1, lo1, hi1 = sh.confidence_bands()
    assert np.abs(m0 - m1).max() < 1e-4
    assert np.abs(hi0 - hi1).max() < 1e-4
    assert np.abs(ref.weights() - sh.weights()).max() < 1e-4
    for a, b in zip(ref.summary(), sh.summary()):
        assert a["learner"] == b["learner"]
        assert abs(a["top_weight"] - b["top_weight"]) < 1e-4
        assert abs(a["expected_regret"] - b["expected_regret"]) < 1e-4


def test_replay_stream_sharded_adaptive_round_trip():
    from repro.learn import replay_stream

    jobs, horizon = _setup()
    spec = ScenarioSpec("adaptive", horizon, 8, seed=5)
    ref = replay_stream(jobs, GRID, spec, 300, learners=["hedge"], seed=3,
                        scenario_chunk=4, backend="jax",
                        engine_backend="jax")
    sh = replay_stream(jobs, GRID, spec, 300, learners=["hedge"], seed=3,
                       scenario_chunk=4, backend="jax",
                       engine_backend="jax", mesh=ScenarioMesh.create(1))
    # the adversary consumed the SAME feedback signal chunk by chunk
    assert np.abs(ref.regret_per_job() - sh.regret_per_job()).max() < 1e-4


def test_run_tola_scenarios_accepts_mesh():
    from repro.core import run_tola_scenarios

    jobs, horizon = _setup(n=12)
    markets = make_scenarios(horizon, 2, seed=1)
    ref = run_tola_scenarios(jobs, GRID, markets, r_total=300, seed=0,
                             pool_iters=2, backend="jax")
    # the mesh rides EVERY round now — round 0 and the per-scenario
    # refinement rounds alike (DESIGN.md §9); 1-device mesh is bitwise
    got = run_tola_scenarios(jobs, GRID, markets, r_total=300, seed=0,
                             pool_iters=2, backend="jax",
                             mesh=ScenarioMesh.create(1))
    for a, b in zip(ref, got):
        assert np.array_equal(a.cost_matrix, b.cost_matrix)
        assert np.array_equal(a.chosen, b.chosen)


def test_run_tola_scenarios_mesh_fallback_warns(monkeypatch):
    # Regression (PR 10 satellite): a dropped mesh is NEVER silent. With
    # the sharded per-scenario path disabled, refinement rounds fall back
    # to unsharded evaluation and say so.
    from repro.core import run_tola_scenarios
    from repro.engine import backend_jax

    jobs, horizon = _setup(n=12)
    markets = make_scenarios(horizon, 2, seed=1)
    ref = run_tola_scenarios(jobs, GRID, markets, r_total=300, seed=0,
                             pool_iters=1, backend="jax")
    monkeypatch.setattr(backend_jax, "SHARDED_PS", False)
    with pytest.warns(UserWarning, match="dropping mesh=.*SHARDED_PS"):
        got = run_tola_scenarios(jobs, GRID, markets, r_total=300, seed=0,
                                 pool_iters=1, backend="jax",
                                 mesh=ScenarioMesh.create(1))
    # the fallback still computes the same answer, just unsharded
    for a, b in zip(ref, got):
        assert np.array_equal(a.cost_matrix, b.cost_matrix)


def test_sweep_policies_accepts_mesh():
    from repro.core import sweep_policies

    jobs, horizon = _setup(n=12)
    spec = ScenarioSpec("fresh", horizon, 4, seed=2)
    _, a_ref, _, _ = sweep_policies(jobs, GRID, spec, 300, backend="jax")
    _, a_mesh, _, _ = sweep_policies(jobs, GRID, spec, 300, backend="jax",
                                     mesh=ScenarioMesh.create(1))
    assert a_ref == a_mesh


# --------------------------------------------------------------------------
# Collective counts in the compiled programs: the §9 placement contract,
# verified through the single implementation in repro.analysis.programs
# (the same Layer-2 pass CI runs) — not ad-hoc HLO greps.
# --------------------------------------------------------------------------

def _verify(keys):
    from repro.analysis.programs import verify_all

    checks = verify_all(mesh=ScenarioMesh.create(), keys=keys)
    assert checks, f"no checks produced for {keys}"
    failed = [c for c in checks if not c.ok]
    assert not failed, "\n".join(f"{c.program}/{c.check}: {c.detail}"
                                 for c in failed)
    return checks


def test_cost_program_has_zero_collectives():
    # The scenario axis never reduces inside the cost tensor, so the
    # compiled sharded chain/task programs must contain NO collectives —
    # sharding the hot loop costs zero cross-device traffic.
    checks = _verify(["engine.eval.chain:sharded", "engine.eval.task:sharded"])
    colls = [c for c in checks if c.check == "collectives"]
    assert len(colls) == 2
    for c in colls:
        assert "'total': 0" in c.detail


def test_refinement_program_has_zero_collectives():
    # The per-scenario (pool refinement) programs obey the same contract:
    # the (S, R, L) self-owned stacks shard alongside the views and no
    # axis reduces cross-device — refinement rounds cost zero collectives.
    checks = _verify(["engine.eval.chain_ps:sharded",
                      "engine.eval.task_ps:sharded"])
    colls = [c for c in checks if c.check == "collectives"]
    assert len(colls) == 2
    for c in colls:
        assert "'total': 0" in c.detail


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
@pytest.mark.parametrize("shape", [(4, 2), (2, 4)])
def test_2d_placement_contract(shape):
    # The §9 standing metric on a REAL 2-D mesh: every canonical program
    # (refinement included) placed per contract, zero violations.
    from repro.obs.compiled import placement_violations

    assert placement_violations(mesh=GridMesh.create(*shape)) == []


def test_synth_program_has_zero_collectives():
    checks = _verify(["scenarios.synth:fresh:sharded"])
    (coll,) = [c for c in checks if c.check == "collectives"]
    assert "'total': 0" in coll.detail


def test_fold_program_has_exactly_one_allreduce():
    # replay_stream's sharded fold: every per-learner sum rides ONE packed
    # psum — exactly one all-reduce per chunk, and no other collective.
    checks = _verify(["learn.fold:sharded"])
    (coll,) = [c for c in checks if c.check == "collectives"]
    assert "'all-reduce': 1" in coll.detail
    assert "'total': 1" in coll.detail


def test_placement_violations_empty_on_contract():
    # obs.compiled.placement_violations is the standing-metric face of the
    # same verifier: the §9 contract holding means an empty violation list.
    from repro.obs.compiled import placement_violations

    assert placement_violations(
        mesh=ScenarioMesh.create(),
        keys=["engine.eval.chain:sharded", "learn.fold:sharded"]) == []


# --------------------------------------------------------------------------
# Real 2-D sharding in-process (the shard-smoke CI job forces 8 devices)
# --------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
@pytest.mark.parametrize("shape", [(4, 2), (2, 4)])
def test_2d_mesh_eval_parity(shape):
    # S=13 % data_shards != 0 AND (with 7 policies) G % model_shards != 0:
    # both padding contracts at once. Bitwise vs unsharded jax (no
    # cross-lane arithmetic anywhere in the cost tensor), <=1e-5 vs the
    # f64 oracle.
    jobs, horizon = _setup(n=13, seed=3)
    grid = selfowned_policies()[:7]
    markets = make_scenarios(horizon, 13, seed=1)
    mesh = GridMesh.create(*shape)
    for early in (True, False):
        ref = evaluate_grid(jobs, grid, markets, 300, backend="jax",
                            early_start=early).unit_cost
        orc = evaluate_grid(jobs, grid, markets, 300, backend="numpy",
                            early_start=early).unit_cost
        got = evaluate_grid(jobs, grid, markets, 300, backend="jax",
                            early_start=early, mesh=mesh).unit_cost
        assert np.array_equal(ref, got)
        assert np.abs(got - orc).max() < 1e-5


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
@pytest.mark.parametrize("shape", [(4, 2), (2, 4)])
def test_2d_mesh_refinement_rounds(shape):
    # run_tola_scenarios keeps mesh= through the refinement rounds: the
    # per-scenario availability pass shards over both axes and matches
    # the unsharded run bitwise.
    from repro.core import run_tola_scenarios

    jobs, horizon = _setup(n=13, seed=3)
    markets = make_scenarios(horizon, 5, seed=1)
    ref = run_tola_scenarios(jobs, GRID, markets, r_total=6, seed=0,
                             pool_iters=2, backend="jax")
    got = run_tola_scenarios(jobs, GRID, markets, r_total=6, seed=0,
                             pool_iters=2, backend="jax",
                             mesh=GridMesh.create(*shape))
    for a, b in zip(ref, got):
        assert np.array_equal(a.cost_matrix, b.cost_matrix)
        assert np.array_equal(a.chosen, b.chosen)


# --------------------------------------------------------------------------
# Real multi-device sharding: 8 forced host devices in a subprocess
# --------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import numpy as np
import jax
from repro.core import generate_chain_jobs, selfowned_policies
from repro.core import run_tola_scenarios
from repro.engine import GridMesh, ScenarioMesh, ScenarioSpec, evaluate_grid
from repro.engine import make_scenarios
from repro.learn import replay_stream

assert len(jax.devices()) == 8
jobs = generate_chain_jobs(20, 2, seed=0)
horizon = max(j.deadline for j in jobs) + 1.0
grid = selfowned_policies()[:12]
mesh = ScenarioMesh.create(8)
out = {"n_shards": mesh.n_shards}

# S=13 % 8 != 0 forces padding; parity vs the f64 oracle AND bitwise vs
# the unsharded jax program (no cross-scenario arithmetic in the tensor)
diffs, bitwise = {}, {}
for kind in ("fresh", "adversarial", "regime"):
    spec = ScenarioSpec(kind, horizon, 13, seed=7)
    oracle = evaluate_grid(jobs, grid, spec, 300, backend="numpy").unit_cost
    sh = evaluate_grid(jobs, grid, spec, 300, backend="jax",
                       mesh=mesh).unit_cost
    un = evaluate_grid(jobs, grid, spec, 300, backend="jax").unit_cost
    diffs[kind] = float(np.abs(sh - oracle).max())
    bitwise[kind] = bool(np.array_equal(sh, un))
out["oracle_diffs"] = diffs
out["bitwise_vs_unsharded"] = bitwise

# sharded replay fold on 8 devices vs the host fold
spec = ScenarioSpec("fresh", horizon, 13, seed=5)
ref = replay_stream(jobs, grid, spec, 300, learners=["hedge", "exp3"],
                    seed=11, scenario_chunk=5, backend="jax",
                    engine_backend="jax")
sh = replay_stream(jobs, grid, spec, 300, learners=["hedge", "exp3"],
                   seed=11, scenario_chunk=5, backend="jax",
                   engine_backend="jax", mesh=mesh)
out["fold_n"] = [ref.n_scenarios, sh.n_scenarios]
out["fold_regret_diff"] = float(
    np.abs(ref.regret_per_job() - sh.regret_per_job()).max())
out["fold_curve_diff"] = float(
    np.abs(ref.confidence_bands()[0] - sh.confidence_bands()[0]).max())

# 2-D meshes (4x2, 2x4): S=13 % 4 != 0 AND 7 policies force group padding;
# refinement rounds (per-scenario availability) stay sharded throughout
grid7 = selfowned_policies()[:7]
markets = make_scenarios(horizon, 13, seed=1)
orc = evaluate_grid(jobs, grid7, markets, 300, backend="numpy").unit_cost
un = evaluate_grid(jobs, grid7, markets, 300, backend="jax").unit_cost
m5 = make_scenarios(horizon, 5, seed=2)
ref_tola = run_tola_scenarios(jobs, grid, m5, r_total=6, seed=0,
                              pool_iters=2, backend="jax")
grid2d = {}
for shape in ((4, 2), (2, 4)):
    gmesh = GridMesh.create(*shape)
    sh2 = evaluate_grid(jobs, grid7, markets, 300, backend="jax",
                        mesh=gmesh).unit_cost
    got_tola = run_tola_scenarios(jobs, grid, m5, r_total=6, seed=0,
                                  pool_iters=2, backend="jax", mesh=gmesh)
    grid2d["%dx%d" % shape] = {
        "shards": [gmesh.data_shards, gmesh.model_shards],
        "oracle_diff": float(np.abs(sh2 - orc).max()),
        "bitwise_vs_unsharded": bool(np.array_equal(sh2, un)),
        "refine_bitwise": bool(all(
            np.array_equal(a.cost_matrix, b.cost_matrix)
            and np.array_equal(a.chosen, b.chosen)
            for a, b in zip(ref_tola, got_tola))),
    }
out["grid2d"] = grid2d
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_8_devices_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        cwd="/root/repo", timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_shards"] == 8
    for kind, diff in res["oracle_diffs"].items():
        assert diff < 1e-5, (kind, diff)
    assert all(res["bitwise_vs_unsharded"].values())
    assert res["fold_n"] == [13, 13]
    assert res["fold_regret_diff"] < 1e-4
    assert res["fold_curve_diff"] < 1e-4
    assert set(res["grid2d"]) == {"4x2", "2x4"}
    for shape, r in res["grid2d"].items():
        assert r["shards"] == [int(x) for x in shape.split("x")], shape
        assert r["oracle_diff"] < 1e-5, (shape, r)
        assert r["bitwise_vs_unsharded"], shape
        assert r["refine_bitwise"], shape
