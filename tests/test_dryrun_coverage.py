"""Deliverable guard: the dry-run cache must cover every (arch x shape x
mesh) cell — 40 cells per mesh, with exactly the sub-quadratic skip rules —
and every compiled cell must carry the three roofline terms."""

import json
import os

import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, supports

pytestmark = pytest.mark.slow  # heavyweight; excluded from the fast tier-1 loop

CACHE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "roofline_cache.json")


@pytest.fixture(scope="module")
def cache():
    if not os.path.exists(CACHE):
        pytest.skip("dry-run cache absent — run repro.launch.dryrun --all")
    with open(CACHE) as f:
        return json.load(f)


def test_all_80_base_cells_present_and_green(cache):
    base = {(r["arch"], r["shape"], r["multi_pod"]): r
            for r in cache if r.get("variant") == "base"}
    missing, wrong = [], []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok_expected, _ = supports(cfg, shape)
            for mp in (False, True):
                r = base.get((arch, shape, mp))
                if r is None:
                    missing.append((arch, shape, mp))
                    continue
                want = "ok" if ok_expected else "skipped"
                if r["status"] != want:
                    wrong.append((arch, shape, mp, r["status"], want))
    assert not missing, f"missing cells: {missing}"
    assert not wrong, f"wrong status: {wrong}"


def test_compiled_cells_have_roofline_terms(cache):
    for r in cache:
        if r.get("status") != "ok":
            continue
        assert r["hlo_flops"] > 0, r["arch"]
        assert r["hlo_bytes"] > 0, r["arch"]
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["bottleneck"] in ("compute_s", "memory_s", "collective_s")
        assert 0 < r["useful_flops_ratio"] <= 1.5, (
            r["arch"], r["shape"], r["useful_flops_ratio"])
        assert r["bytes_per_device"]["peak"] > 0


def test_skip_rules_only_full_attention_long_context(cache):
    for r in cache:
        if r.get("status") == "skipped":
            assert r["shape"] == "long_500k"
            assert get_config(r["arch"]).kind not in ("ssm", "hybrid")


def test_perf_cells_fit_hbm_after_optimization(cache):
    """The §Perf endpoints: optimized variants of the three hillclimb cells
    fit the 16 GiB v5e HBM."""
    want = [("qwen2_5_32b", "train_4k", False, "flash_accum16"),
            ("olmoe_1b_7b", "prefill_32k", False, "moe_grouped"),
            ("deepseek_moe_16b", "train_4k", False, "moe_grouped"),
            ("llama3_8b", "train_4k", False, "accum8")]
    recs = {(r["arch"], r["shape"], r["multi_pod"], r.get("variant")): r
            for r in cache}
    for key in want:
        r = recs.get(key)
        assert r is not None and r["status"] == "ok", key
        assert r["fits_hbm"], key
